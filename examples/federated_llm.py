"""Federated LLM pretraining with StoCFL (the substrate path).

Clients hold token streams from two latent domains (distinct Markov
processes); StoCFL clusters them from anchor-gradient representations
(with JL projection, since Ψ is model-sized) and trains per-domain
cluster models with the bi-level objective — the exact program the
multi-pod dry-run lowers at production scale.

  PYTHONPATH=src python examples/federated_llm.py [--arch qwen2-1.5b]
"""
import subprocess
import sys

if __name__ == "__main__":
    arch = sys.argv[sys.argv.index("--arch") + 1] if "--arch" in sys.argv else "qwen2-1.5b"
    sys.exit(subprocess.call(
        [sys.executable, "-m", "repro.launch.train", "--arch", arch, "--smoke",
         "--rounds", "8", "--clients", "8", "--domains", "2",
         "--sample-rate", "0.5", "--tau", "0.12", "--lr", "0.05"],
        env={**__import__("os").environ, "PYTHONPATH": "src"}))
