"""End-to-end cross-device driver (the paper's §4.1/§4.2 setting, scaled
to this host): 400 clients, 4 latent clusters, 10% participation, ~1.7M-
parameter MLP (the paper's MNIST task model), 100 federated rounds of the
full StoCFL pipeline — stochastic clustering + bi-level optimization —
with round-time telemetry and a FedAvg comparison.

  PYTHONPATH=src python examples/cross_device_fl.py [--rounds 100] [--clients 400]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import FLConfig, FedAvg, StoCFL, StoCFLConfig, adjusted_rand_index
from repro.data import pathological
from repro.models import simple


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--clients", type=int, default=400)
    ap.add_argument("--sample-rate", type=float, default=0.1)
    args = ap.parse_args()

    clients, true_cluster, test_sets = pathological(n_clients=args.clients, seed=0)
    clients = [jax.tree.map(jnp.asarray, c) for c in clients]
    test_sets = {k: jax.tree.map(jnp.asarray, v) for k, v in test_sets.items()}

    import dataclasses
    # the paper's 2048-hidden MLP, on the synthetic 64-d feature space
    task = dataclasses.replace(simple.MNIST_MLP, input_shape=(64,), name="mlp2048")
    params = simple.init(jax.random.PRNGKey(0), task)
    n_params = sum(int(p.size) for p in jax.tree.leaves(params))
    print(f"task model: {n_params/1e6:.2f}M params; clients={args.clients}; "
          f"participation={args.sample_rate:.0%}")

    loss_fn = lambda p, b: simple.loss_fn(p, b, task)
    acc_fn = jax.jit(lambda p, b: simple.accuracy(p, b, task))

    tr = StoCFL(loss_fn, params, clients,
                StoCFLConfig(tau=0.5, lam=0.05, lr=0.1, local_steps=5,
                             sample_rate=args.sample_rate, seed=0),
                eval_fn=acc_fn)
    t0 = time.time()
    for t in range(args.rounds):
        rec = tr.round()
        if t % 10 == 0:
            print(f"round {t:4d}: K~={rec['n_clusters']:3d} "
                  f"obj={rec['objective']:8.3f} ({time.time()-t0:.1f}s)")
    assign = tr.state.assignment()
    ids = sorted(assign)
    ari = adjusted_rand_index([assign[i] for i in ids], [true_cluster[i] for i in ids])
    res = tr.evaluate(test_sets, true_cluster)

    fed = FedAvg(loss_fn, params, clients,
                 FLConfig(lr=0.1, local_steps=5, sample_rate=args.sample_rate, seed=0),
                 eval_fn=acc_fn)
    fed.fit(args.rounds)
    res_f = fed.evaluate(test_sets)

    print(f"\nStoCFL : K~={tr.state.n_clusters()} ARI={ari:.3f} "
          f"cluster_acc={res['cluster_avg']:.4f} global_acc={res['global_avg']:.4f}")
    print(f"FedAvg : acc={res_f['cluster_avg']:.4f}")
    print(f"total wall: {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
