"""Serving example: cluster-routed LLM inference (§4.4 as a service).

Spins up a reduced qwen2-family model with two cluster-personalized
parameter sets, routes incoming requests to clusters via Ψ cosine
similarity, and serves batched prefill + greedy decode.

  PYTHONPATH=src python examples/serve_clusters.py
"""
import subprocess
import sys

if __name__ == "__main__":
    sys.exit(subprocess.call(
        [sys.executable, "-m", "repro.launch.serve",
         "--arch", "qwen2-1.5b", "--requests", "6", "--prompt-len", "24",
         "--gen", "8"],
        env={**__import__("os").environ, "PYTHONPATH": "src"}))
