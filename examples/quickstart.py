"""Quickstart: StoCFL on the functional engine API, in ~40 lines.

Builds a 4-cluster rotated Non-IID federation, runs stochastic clustered
federated learning with 20% participation, and shows that (a) the latent
clusters are discovered without knowing K, and (b) cluster models beat a
single global model. The server is an explicit pytree ``ServerState``;
every round is a pure transition ``state -> (state, metrics)``.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import engine
from repro.core import adjusted_rand_index
from repro.data import rotated
from repro.models import simple

# 1. A federation: 80 clients drawn from 4 latent data distributions.
clients, true_cluster, test_sets = rotated(n_clusters=4, n_clients=80, seed=0)
clients = [jax.tree.map(jnp.asarray, c) for c in clients]
test_sets = {k: jax.tree.map(jnp.asarray, v) for k, v in test_sets.items()}

# 2. The task model (the paper's MLP classifier) + its loss.
task = simple.SYNTH_MLP
params = simple.init(jax.random.PRNGKey(0), task)
loss_fn = lambda p, b: simple.loss_fn(p, b, task)
acc_fn = jax.jit(lambda p, b: simple.accuracy(p, b, task))

# 3. StoCFL: τ controls cluster granularity, λ the global-knowledge pull.
#    Any registered strategy ("fedavg", "ifca", ...) runs through the same
#    init -> run_round loop.
state = engine.init(
    "stocfl", loss_fn, params, clients,
    engine.EngineConfig(tau=0.5, lam=0.05, lr=0.1, local_steps=5, sample_rate=0.2),
    eval_fn=acc_fn,
)
state = engine.run(state, rounds=30, log_every=5)

# 4. Results.
assign = state.clusters.assignment()
ids = sorted(assign)
ari = adjusted_rand_index([assign[i] for i in ids], [true_cluster[i] for i in ids])
res = engine.evaluate(state, test_sets, true_cluster)
print(f"\ndiscovered clusters : {state.clusters.n_clusters()} (true: 4, K was never given)")
print(f"cluster recovery ARI: {ari:.3f}")
print(f"cluster-model acc   : {res['cluster_avg']:.4f}")
print(f"global-model acc    : {res['global_avg']:.4f}")
assert ari > 0.9 and res["cluster_avg"] > res["global_avg"]
print("OK")
