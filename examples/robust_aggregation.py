"""Byzantine-robust StoCFL (paper §3.4 pluggable G(·) + §5 future work).

One client in a rotated federation is label-poisoned; FedAvg-style mean
aggregation of ω absorbs the poison, while a coordinate-median G(·) keeps
both the global and cluster models healthy — without touching the paper's
clustering or bi-level machinery.

  PYTHONPATH=src python examples/robust_aggregation.py
"""
import jax
import jax.numpy as jnp

from repro.core import StoCFL, StoCFLConfig
from repro.data import rotated
from repro.models import simple

task = simple.SYNTH_MLP
loss_fn = lambda p, b: simple.loss_fn(p, b, task)
acc_fn = jax.jit(lambda p, b: simple.accuracy(p, b, task))

clients, tc, tests = rotated(n_clusters=2, n_clients=16, n_per=64, seed=0)
clients = [jax.tree.map(jnp.asarray, c) for c in clients]
tests = {k: jax.tree.map(jnp.asarray, v) for k, v in tests.items()}
clients[3] = {"x": clients[3]["x"], "y": (clients[3]["y"] + 5) % 10}   # poison

for agg in ("mean", "median", "trimmed_mean"):
    params = simple.init(jax.random.PRNGKey(0), task)
    tr = StoCFL(loss_fn, params, clients,
                StoCFLConfig(tau=0.5, lam=0.05, lr=0.1, local_steps=3,
                             sample_rate=1.0, seed=0, aggregator=agg),
                eval_fn=acc_fn)
    tr.fit(10)
    res = tr.evaluate(tests, tc)
    print(f"G(.) = {agg:13s} cluster_acc={res['cluster_avg']:.4f} "
          f"global_acc={res['global_avg']:.4f} K~={tr.state.n_clusters()}")
