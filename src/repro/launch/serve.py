"""Cluster-model serving driver — on the functional engine API.

StoCFL serving = hold a ``ServerState``, route each request to its
cluster's personalized model (§4.4 inference: nearest cluster mean by Ψ
cosine via ``engine.infer``), then batched prefill + greedy decode with
the per-arch KV cache / SSM state. Cluster reference Ψ's are registered
through ``engine.join`` — the same dynamic-membership transition a
training server uses.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \\
      --requests 8 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.configs import get_config
from repro.core.extractor import llm_leaf_filter
from repro.data import synthetic_lm_batch
from repro.models import build


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--clusters", type=int, default=2)
    ap.add_argument("--tau", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build(cfg)
    key = jax.random.PRNGKey(args.seed)

    # --- a serving ServerState: K cluster models (stand-ins for a trained
    # checkpoint — a real deployment would `load_server_state` here), with
    # each cluster's reference Ψ registered via the join transition.
    params0 = model.init(key)
    st = engine.init("stocfl", model.loss_fn, params0, [],
                     engine.EngineConfig(tau=args.tau, seed=args.seed,
                                         project_dim=8192),
                     leaf_filter=llm_leaf_filter)
    cluster_models = {}
    for k in range(args.clusters):
        # cluster reference Ψ from a healthy token sample of the domain
        ref = jax.tree.map(jnp.asarray,
                           synthetic_lm_batch(cfg, 256, 8, seed=100 + k, domain=k))
        st, cid = engine.join(st, ref)
        cluster_models[st.client_root(cid)] = model.init(jax.random.fold_in(key, k))
    st = st.replace(models=cluster_models)

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode)

    # --- requests: route by Ψ similarity, then batched prefill+decode
    t0 = time.time()
    n_tokens = 0
    for r in range(args.requests):
        dom = r % args.clusters
        batch = jax.tree.map(jnp.asarray,
                             synthetic_lm_batch(cfg, args.prompt_len, 1, seed=r, domain=dom))
        # route on a domain-sized history sample (a real system would keep a
        # running Ψ per client); the prompt alone is too thin at 24 tokens
        hist = jax.tree.map(jnp.asarray,
                            synthetic_lm_batch(cfg, 256, 8, seed=1000 + r, domain=dom))
        inf = engine.infer(st, hist)
        root = inf["cluster"] if inf["cluster"] is not None else inf["seed_from"]
        params = inf["model"]

        logits, cache = prefill(params, batch)
        # right-size the cache for generation
        full_cache = model.make_cache(1, args.prompt_len + args.gen)
        full_cache = jax.tree.map(
            lambda full, got: full.at[tuple(slice(0, s) for s in got.shape)].set(got)
            if full.shape != got.shape else got, full_cache, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks = [int(tok[0])]
        for i in range(args.gen - 1):
            logits, full_cache = decode(params, tok, full_cache, jnp.int32(args.prompt_len + i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            toks.append(int(tok[0]))
        n_tokens += len(toks)
        print(f"req {r}: domain={dom} -> cluster={root} "
              f"(cos={inf['similarity']:.3f}) tokens={toks[:8]}...")
    dt = time.time() - t0
    print(json.dumps({"requests": args.requests, "tokens": n_tokens,
                      "wall_s": round(dt, 2), "tok_per_s": round(n_tokens / dt, 2)}))


if __name__ == "__main__":
    main()
