"""Cluster-model serving driver — a thin CLI over ``repro.serve``.

StoCFL serving = hold a ``ServerState``, route each client to its
cluster's personalized model (§4.4 inference: nearest cluster mean by Ψ
cosine, cached per client), then serve tokens. The actual engine lives
in ``repro.serve``: continuous batching over a fixed-slot decode state
(``ServeEngine``, the default) or the debugged one-at-a-time loop
(``--sequential``, ``serve.SequentialLoop``). This module only builds
the state, fabricates a request stream, and times it — with the first
compile SEPARATED from the timed region (a warmup wave at identical
shapes pays every compile; ``reset()`` keeps the compiled programs and
the routing cache, then the timed wave runs compile-free).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \\
      --requests 8 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine, serve
from repro.configs import get_config
from repro.core.extractor import llm_leaf_filter
from repro.data import synthetic_lm_batch
from repro.models import build


def build_parser() -> argparse.ArgumentParser:
    """The serve CLI. ``--smoke`` and ``--full`` are a proper
    mutually-exclusive pair (smoke is the default): the old parser
    defaulted ``smoke=True`` on a bare ``store_true`` flag, so passing
    ``--smoke`` was a no-op and nothing could assert it was set."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    size = ap.add_mutually_exclusive_group()
    size.add_argument("--smoke", dest="smoke", action="store_true",
                      help="smoke-sized config (default)")
    size.add_argument("--full", dest="smoke", action="store_false",
                      help="full-sized config")
    ap.set_defaults(smoke=True)
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--sequential", action="store_true",
                    help="serve one request at a time (debugged legacy "
                         "loop) instead of continuous batching")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--clusters", type=int, default=2)
    ap.add_argument("--slots", type=int, default=4,
                    help="decode lanes per cluster group")
    ap.add_argument("--tau", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=0)
    return ap


def build_server_state(cfg, model, clusters: int, tau: float, seed: int):
    """A serving ``ServerState``: K cluster models (stand-ins for a
    trained checkpoint — a real deployment would ``load_server_state``
    here), each cluster's reference Ψ registered via the ``join``
    transition so routing has real cluster means to cosine against."""
    key = jax.random.PRNGKey(seed)
    params0 = model.init(key)
    st = engine.init("stocfl", model.loss_fn, params0, [],
                     engine.EngineConfig(tau=tau, seed=seed,
                                         project_dim=8192),
                     leaf_filter=llm_leaf_filter)
    cluster_models = {}
    for k in range(clusters):
        ref = jax.tree.map(
            jnp.asarray,
            synthetic_lm_batch(cfg, 256, 8, seed=100 + k, domain=k))
        st, cid = engine.join(st, ref)
        cluster_models[st.client_root(cid)] = model.init(
            jax.random.fold_in(key, k))
    return st.replace(models=cluster_models)


def make_requests(cfg, n: int, prompt_len: int, gen: int, clusters: int,
                  seed_base: int = 0):
    """A synthetic request stream: request r comes from domain
    ``r % clusters`` with a domain-matched Ψ-routing history (the
    prompt alone is too thin to route on)."""
    reqs = []
    for r in range(n):
        dom = r % clusters
        prompt = np.asarray(
            synthetic_lm_batch(cfg, prompt_len, 1, seed=seed_base + r,
                               domain=dom)["tokens"][0], np.int32)
        hist = jax.tree.map(
            jnp.asarray,
            synthetic_lm_batch(cfg, 256, 8, seed=1000 + seed_base + r,
                               domain=dom))
        reqs.append(serve.Request(rid=seed_base + r,
                                  client_id=f"client-{seed_base + r}",
                                  prompt=prompt, gen=gen, history=hist))
    return reqs


def main():
    args = build_parser().parse_args()
    cfg = get_config(args.arch, smoke=args.smoke)
    model = build(cfg)
    st = build_server_state(cfg, model, args.clusters, args.tau, args.seed)
    max_len = args.prompt_len + args.gen

    if args.sequential:
        loop = serve.SequentialLoop(model, st, max_len=max_len,
                                    max_gen=args.gen)
        warm = make_requests(cfg, 1, args.prompt_len, args.gen,
                             args.clusters, seed_base=10_000)
        t0 = time.time()
        loop.serve(warm[0])                       # pays every compile
        first_compile_s = time.time() - t0
        reqs = make_requests(cfg, args.requests, args.prompt_len, args.gen,
                             args.clusters)
        t0 = time.time()
        results = [loop.serve(r) for r in reqs]
        wall = time.time() - t0
        mode, stats = "sequential", {"router_hits": loop.router.hits,
                                     "router_misses": loop.router.misses}
    else:
        eng = serve.ServeEngine(
            model, st, serve.ServeConfig(slots=args.slots, max_len=max_len,
                                         max_gen=args.gen))
        warm = make_requests(cfg, min(args.requests, args.slots),
                             args.prompt_len, args.gen, args.clusters,
                             seed_base=10_000)
        t0 = time.time()
        eng.submit_many(warm)
        eng.run()                                 # pays every compile
        first_compile_s = time.time() - t0
        eng.reset()                               # keeps compiled programs
        reqs = make_requests(cfg, args.requests, args.prompt_len, args.gen,
                             args.clusters)
        t0 = time.time()
        eng.submit_many(reqs)
        results = list(eng.run().values())
        wall = time.time() - t0
        mode, stats = "continuous", eng.stats()

    for res in sorted(results, key=lambda r: r.rid):
        print(f"req {res.rid}: cluster={res.cluster} "
              f"(cos={res.similarity:.3f}) "
              f"tokens={[int(t) for t in res.tokens[:8]]}...")
    n_tokens = sum(len(r.tokens) for r in results)
    print(json.dumps({"mode": mode, "requests": len(results),
                      "tokens": n_tokens,
                      "first_compile_s": round(first_compile_s, 2),
                      "wall_s": round(wall, 4),
                      "tok_per_s": round(n_tokens / max(wall, 1e-9), 2),
                      **stats}))


if __name__ == "__main__":
    main()
