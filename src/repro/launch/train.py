"""End-to-end training driver on the functional engine API.

Any registered strategy (stocfl, fedavg, fedprox, ditto, ifca, cfl) runs
through the same ``engine.init -> engine.run_round`` loop; StoCFL adds
clustering metrics, checkpointing of the full ``ServerState``, and §4.4
inference. ``--mesh`` places the vmapped cohort step on a client-axis
mesh over the local devices (the sharded scanned engine — docs/SHARDING.md). ``--churn`` swaps the static loop for the
§5 dynamic-federation simulator (``repro.sim``): Poisson joins/leaves/
stragglers or a replayed JSON trace, e.g.

      PYTHONPATH=src python -m repro.launch.train --setting rotated \\
          --rounds 50 --arena --churn join=1.0,leave=0.5,straggle=0.1

Two modes:
  classification (paper-faithful, default): cross-device federation on a
    synthetic Non-IID setting with the paper's MLP task model.

      PYTHONPATH=src python -m repro.launch.train --setting rotated \\
          --rounds 100 --algo stocfl

  LLM (substrate path): federated pretraining of an assigned architecture
    (reduced via --smoke) on domain-clustered synthetic token streams;
    clients ride the vmapped cohort axis exactly as on the production mesh.

      PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \\
          --rounds 10 --clients 8 --domains 2
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.checkpoint import save_server_state, wait_pending
from repro.core import adjusted_rand_index
from repro.data import make_federation, synthetic_lm_batch
from repro.models import build, simple
from repro.configs import get_config
from repro.launch.mesh import make_client_mesh


def _engine_cfg(args) -> engine.EngineConfig:
    cluster_backend = args.cluster_backend
    rng_backend = "numpy"
    if getattr(args, "scan_rounds", False):
        # the fused loop needs device sampling; StoCFL additionally
        # needs the device partition (run_rounds preconditions)
        rng_backend = "device"
        if args.algo == "stocfl" and cluster_backend != "device":
            print("--scan-rounds: forcing --cluster-backend device")
            cluster_backend = "device"
    async_cfg = None
    if getattr(args, "async_mode", False):
        async_cfg = engine.AsyncConfig(staleness_decay=args.staleness_decay,
                                       staleness_cap=args.staleness_cap)
    return engine.EngineConfig(
        tau=args.tau, lam=args.lam, lr=args.lr, local_steps=args.local_steps,
        sample_rate=1.0 if args.algo == "cfl" else args.sample_rate,
        seed=args.seed, mu=args.lam, cohort_chunk=args.cohort_chunk,
        cluster_backend=cluster_backend, rng_backend=rng_backend,
        fused_step=args.fused_step, dtype=args.dtype, async_cfg=async_cfg)


def _churn_timeline(args, n_clusters: int):
    """Build the --churn Timeline (trace path or Poisson spec) plus the
    setting's client factory for Join events."""
    from repro.data.synthetic import SETTING_FACTORIES
    from repro.sim import Timeline
    tl = Timeline.from_spec(args.churn, rounds=args.rounds, seed=args.seed,
                            n_clusters=n_clusters)
    factory = None
    if args.setting in SETTING_FACTORIES:
        factory = SETTING_FACTORIES[args.setting](n_clusters=n_clusters,
                                                  seed=args.seed)
    elif any(k == "join" for k in tl.counts()):
        raise SystemExit(f"--churn with joins needs a client factory; "
                         f"setting {args.setting!r} has none "
                         f"(see repro.data.synthetic.SETTING_FACTORIES)")
    return tl, factory


def run_classification(args) -> dict:
    clients_np, true_cluster, test_sets = make_federation(
        args.setting, n_clients=args.clients, seed=args.seed)
    clients = [{"x": jnp.asarray(c["x"]), "y": jnp.asarray(c["y"])} for c in clients_np]
    test_sets = {k: {"x": jnp.asarray(v["x"]), "y": jnp.asarray(v["y"])}
                 for k, v in test_sets.items()}

    task = simple.SYNTH_MLP if args.task == "synth_mlp" else simple.MNIST_MLP
    key = jax.random.PRNGKey(args.seed)
    params = simple.init(key, task)
    loss = lambda p, b: simple.loss_fn(p, b, task)
    evalf = jax.jit(lambda p, b: simple.accuracy(p, b, task))

    mesh = make_client_mesh() if args.mesh else None
    t0 = time.time()
    arena = args.arena or args.scan_rounds   # scans gather from the arena
    st = engine.init(args.algo, loss, params, clients, _engine_cfg(args),
                     eval_fn=evalf, mesh=mesh, arena=arena)
    out = {"algo": args.algo, "rounds": args.rounds}
    if args.churn:
        from repro.sim import simulate
        tl, factory = _churn_timeline(args, n_clusters=len(test_sets))
        st, log = simulate(st, tl, rounds=args.rounds,
                           client_factory=factory, seed=args.seed,
                           cohort_quantum=args.cohort_quantum,
                           eval_every=max(args.rounds // 10, 1),
                           test_sets=test_sets, true_cluster=true_cluster,
                           scan_spans=args.scan_rounds,
                           async_mode=args.async_mode)
        out["churn"] = {"timeline": tl.counts(),
                        "joined": len(log.joined),
                        "departed": len(log.departed),
                        "final_gap": log.records[-1].get("gap")}
        # joined clients need latent-cluster labels for evaluate()
        true_cluster = list(true_cluster) + [
            log.joined[cid] if log.joined[cid] is not None else -1
            for cid in sorted(log.joined)]
        if args.save_log:
            with open(args.save_log, "w") as f:
                json.dump(log.to_json(), f, indent=1)
    elif args.async_mode:
        for t in range(args.rounds):
            st, rec = engine.run_round_async(st)
            if t % max(args.rounds // 10, 1) == 0:
                print(f"round {t}: {rec}")
    elif args.scan_rounds:
        st = engine.run_rounds(st, args.rounds)   # ONE jitted lax.scan
        for t, rec in enumerate(st.history):
            if t % max(args.rounds // 10, 1) == 0:
                print(f"round {t}: {rec}")
    else:
        st = engine.run(st, args.rounds, log_every=max(args.rounds // 10, 1))
    res = engine.evaluate(st, test_sets, true_cluster)
    out.update({"cluster_avg_acc": res["cluster_avg"],
                "wall_s": round(time.time() - t0, 1)})
    if st.clusters is not None:
        assign = st.clusters.assignment()
        ids = sorted(assign)
        out["ari"] = adjusted_rand_index([assign[c] for c in ids],
                                         [true_cluster[c] for c in ids])
        out["n_clusters"] = st.clusters.n_clusters()
        out["global_avg_acc"] = res["global_avg"]
    if args.save:
        # async: the JSON summary below overlaps the checkpoint write;
        # wait_pending() barriers before the process exits
        save_server_state(args.save, st, block=False)
    print(json.dumps(out, indent=1))
    wait_pending()
    return out


def run_llm(args) -> dict:
    cfg = get_config(args.arch, smoke=args.smoke)
    model = build(cfg)
    seq, per_client = args.seq_len, args.batch
    clients = []
    true_cluster = []
    for i in range(args.clients):
        dom = i % args.domains
        clients.append(synthetic_lm_batch(cfg, seq, per_client, seed=i, domain=dom))
        true_cluster.append(dom)
    clients = [jax.tree.map(jnp.asarray, c) for c in clients]

    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    from repro.core.extractor import llm_leaf_filter
    ecfg = engine.EngineConfig(tau=args.tau, lam=args.lam, lr=args.lr,
                               local_steps=args.local_steps,
                               sample_rate=args.sample_rate, seed=args.seed,
                               project_dim=8192, cohort_chunk=args.cohort_chunk,
                               cluster_backend=args.cluster_backend,
                               fused_step=args.fused_step, dtype=args.dtype)
    mesh = make_client_mesh() if args.mesh else None
    st = engine.init("stocfl", model.loss_fn, params, clients, ecfg,
                     leaf_filter=llm_leaf_filter, mesh=mesh, arena=args.arena)
    t0 = time.time()
    for t in range(args.rounds):
        st, rec = engine.run_round(st)
        loss0 = float(model.loss_fn(st.omega, clients[0]))
        print(f"round {t}: clusters={rec['n_clusters']} omega_loss={loss0:.4f}")
    assign = st.clusters.assignment()
    ids = sorted(assign)
    ari = adjusted_rand_index([assign[c] for c in ids], [true_cluster[c] for c in ids])
    out = {"arch": cfg.name, "ari": ari, "n_clusters": st.clusters.n_clusters(),
           "rounds": args.rounds, "wall_s": round(time.time() - t0, 1)}
    if args.save:
        save_server_state(args.save, st, block=False)
    print(json.dumps(out, indent=1))
    wait_pending()
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--setting", default="rotated",
                    choices=["pathological", "rotated", "shifted", "hybrid", "femnist"])
    ap.add_argument("--task", default="synth_mlp")
    ap.add_argument("--algo", default="stocfl",
                    choices=sorted(engine.list_strategies()))
    ap.add_argument("--arch", default=None, help="LLM mode: assigned arch id")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", action="store_true",
                    help="shard the engine over a (\"clients\",) mesh of the local devices (docs/SHARDING.md)")
    ap.add_argument("--arena", action="store_true",
                    help="pack client shards into a device-resident arena "
                         "(cohort = one gather instead of a per-round restack)")
    ap.add_argument("--cluster-backend", default="numpy",
                    choices=["numpy", "device"],
                    help="StoCFL partition backend: host ClusterState "
                         "(fallback) or the jitted device union-find "
                         "(core.device_clustering)")
    ap.add_argument("--scan-rounds", action="store_true",
                    help="run the whole round loop as ONE jitted lax.scan "
                         "(engine.run_rounds): on-device cohort sampling, "
                         "no per-round host dispatch; implies --arena and "
                         "rng_backend=device (and cluster-backend device "
                         "for stocfl). Under --churn, event-free spans "
                         "are scanned (sim scan_spans)")
    ap.add_argument("--cohort-chunk", type=int, default=0,
                    help="max clients per vmapped step; larger cohorts run "
                         "in lax.map chunks with flat memory (0 = unchunked)")
    ap.add_argument("--fused-step", action="store_true",
                    help="route the bilevel inner step through the fused "
                         "prox kernel (kernels.prox_update: one flat "
                         "in-place update instead of a per-leaf chain); "
                         "jnp oracle off-TPU, bitwise-identical in fp32")
    ap.add_argument("--dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="compute dtype for client params/grads/batches; "
                         "Ψ-embeddings, cluster means and the Eq. 2 "
                         "objective always stay float32")
    ap.add_argument("--compile-cache", nargs="?", const="auto", default=None,
                    metavar="DIR",
                    help="persist compiled XLA executables to DIR (bare "
                         "flag: $JAX_COMPILATION_CACHE_DIR or "
                         "~/.cache/repro-jax-cache) so warm restarts skip "
                         "the compile tax")
    ap.add_argument("--async", dest="async_mode", action="store_true",
                    help="async buffered aggregation (engine."
                         "run_round_async): delayed client deltas land in "
                         "a device-resident buffer and flush as staleness-"
                         "weighted merges; bitwise equal to the sync loop "
                         "at zero delay (docs/ASYNC.md). Supported by "
                         "stocfl/fedavg/fedprox; under --churn, Straggle "
                         "victims report back late instead of dropping")
    ap.add_argument("--staleness-decay", type=float, default=1.0,
                    help="async merge-weight decay γ (weight = "
                         "count · γ^staleness; 1.0 = pure count weighting)")
    ap.add_argument("--staleness-cap", type=int, default=4,
                    help="max rounds a buffered delta may age before it is "
                         "dropped instead of merged")
    ap.add_argument("--churn", default=None,
                    help="dynamic-federation mode (§5): a JSON trace path, "
                         "or Poisson churn 'join=2.0,leave=1.5,straggle=0.1' "
                         "(see repro.sim.Timeline.from_spec)")
    ap.add_argument("--cohort-quantum", type=int, default=0,
                    help="under --churn, truncate each cohort to a multiple "
                         "of this so the set of compiled cohort shapes stays "
                         "bounded as the population drifts (0 = off)")
    ap.add_argument("--save-log", default=None,
                    help="under --churn, write the per-round simulator log "
                         "(SimLog.to_json) to this path")
    ap.add_argument("--clients", type=int, default=80)
    ap.add_argument("--domains", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--tau", type=float, default=0.5)
    ap.add_argument("--lam", type=float, default=0.05)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--sample-rate", type=float, default=0.2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--save", default=None)
    args = ap.parse_args()
    if args.async_mode and args.scan_rounds:
        raise SystemExit("--async is host-orchestrated (the delta buffer "
                         "bookkeeping lives on the host) and cannot be "
                         "fused with --scan-rounds")
    if args.compile_cache is not None:
        from repro.utils.cache import enable_compilation_cache
        path = enable_compilation_cache(
            None if args.compile_cache == "auto" else args.compile_cache)
        print(f"compilation cache: {path}")
    if args.arch:
        run_llm(args)
    else:
        run_classification(args)


if __name__ == "__main__":
    main()
