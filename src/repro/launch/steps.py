"""Mesh-aware step builders: the jit-able programs the launcher, the
serving path and the multi-pod dry-run lower.

train_4k lowers the PAPER-FAITHFUL StoCFL round step: clients ride the
(pod, data) axes, both bi-level gradients are taken, the fused prox update
applies, and the data-parallel gradient mean IS the server Aggregate
(FedAvg ≡ all-reduce over the client axis).

prefill/decode lower cluster-model serving.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.kernels import ops
from repro.models.registry import Model, decode_specs
from repro.sharding import ShardCtx, param_shardings


# ---------------------------------------------------------------- helpers
def batch_shardings(specs: dict, mesh, ctx: ShardCtx):
    """Shard every batch leaf's leading (batch) dim over the client axes."""
    def one(x):
        nd = len(x.shape)
        spec = ctx.resolve(["batch"] + [None] * (nd - 1))
        axes = spec[0] if isinstance(spec[0], tuple) else (spec[0],)
        n = 1
        for a in axes:
            if a:
                n *= mesh.shape[a]
        if x.shape[0] % n != 0:
            spec = P(*([None] * nd))
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, specs)


_CACHE_RULES_HINT = """Cache sharding: leading layer axis replicated, batch
dim over client axes, the *sequence* dim of attention caches over the model
axis (flash-decode layout: each model shard owns a contiguous KV slab; XLA
partitions the attention einsums and inserts the softmax collectives)."""


def cache_shardings(cache_specs, mesh, ctx: ShardCtx):
    def one(kp, x):
        nd = len(x.shape)
        name = str(kp[-1].key) if hasattr(kp[-1], "key") else str(kp[-1])
        # layout per leaf kind: (L, B, S, ...) attention caches; (L, B, ...) ssm
        if name in ("k", "v", "c_kv", "k_rope"):
            logical = [None, "batch", "tp"] + [None] * (nd - 3)
        elif name == "h":
            logical = [None, "batch", "tp"] + [None] * (nd - 3)
        elif name == "conv":
            logical = [None, "batch", None, "tp"][:nd]
        else:
            logical = [None, "batch"] + [None] * (nd - 2)
        spec = ctx.resolve(logical)
        fixed = []
        for dim, ax in zip(x.shape, spec):
            if ax is None:
                fixed.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            fixed.append(ax if dim % n == 0 else None)
        return NamedSharding(mesh, P(*fixed))

    return jax.tree_util.tree_map_with_path(one, cache_specs)


# ---------------------------------------------------------------- steps
def stocfl_train_step(model: Model, lr: float = 0.1, lam: float = 0.05):
    """One bi-level StoCFL round over the sharded client cohort."""

    def step(theta, omega, batch):
        loss_t, g_t = jax.value_and_grad(model.loss_fn)(theta, batch)
        loss_o, g_o = jax.value_and_grad(model.loss_fn)(omega, batch)
        theta2, omega2 = ops.prox_update_tree(theta, omega, g_t, g_o, lr, lam, backend="jnp")
        return theta2, omega2, {"loss_theta": loss_t, "loss_omega": loss_o}

    return step


def lm_train_step(model: Model, lr: float = 1e-3):
    """Plain data-parallel LM step (baseline / non-FL substrate path)."""

    def step(params, batch):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        params = jax.tree.map(lambda p, g: (p - lr * g).astype(p.dtype), params, grads)
        return params, {"loss": loss}

    return step


def prefill_step(model: Model):
    def step(params, batch):
        return model.prefill(params, batch)

    return step


def decode_step(model: Model):
    def step(params, token, cache, pos):
        return model.decode(params, token, cache, pos)

    return step


def repr_step(model: Model):
    """Ψ extraction as an SPMD program: anchor gradient, L2-normalized
    leaf-wise (global norm), returned as a parameter-shaped pytree."""

    def step(anchor, batch):
        g = jax.grad(model.loss_fn)(anchor, batch)
        sq = jax.tree.reduce(
            lambda a, x: a + jnp.sum(jnp.square(x.astype(jnp.float32))), g, jnp.float32(0.0))
        inv = jax.lax.rsqrt(sq + 1e-24)
        return jax.tree.map(lambda x: (x.astype(jnp.float32) * inv), g)

    return step


# ---------------------------------------------------------------- lowering
def lower_step(model: Model, shape, mesh, kind: str, lr=0.1, lam=0.05,
               donate: bool = True, serve_params_tp_only: bool = False):
    """Build shardings and lower the right step for (model, shape, mesh).

    serve_params_tp_only: serving layout — params sharded on the model axis
    only (weights stay resident; no per-step fsdp regather). §Perf #2.

    Returns (lowered, arg_specs) — call .compile() on the result."""
    ctx = ShardCtx(mesh)
    pspecs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if serve_params_tp_only:
        pctx = ShardCtx(mesh, {**ctx.logical_map, "fsdp": None})
        pshard = param_shardings(pspecs, mesh, pctx)
    else:
        pshard = param_shardings(pspecs, mesh, ctx)

    if kind == "train":
        specs = model.input_specs(shape)
        bshard = batch_shardings(specs, mesh, ctx)
        fn = stocfl_train_step(model, lr, lam)
        with ctx:
            lowered = jax.jit(
                fn,
                in_shardings=(pshard, pshard, bshard),
                out_shardings=(pshard, pshard, NamedSharding(mesh, P())),
                donate_argnums=(0, 1) if donate else (),
            ).lower(pspecs, pspecs, specs)
        return lowered, (pspecs, pspecs, specs)

    if kind == "prefill":
        specs = model.input_specs(shape)
        bshard = batch_shardings(specs, mesh, ctx)
        cache_spec = jax.eval_shape(lambda: model.make_cache(shape.global_batch, shape.seq_len))
        cshard = cache_shardings(cache_spec, mesh, ctx)
        fn = prefill_step(model)
        with ctx:
            lowered = jax.jit(
                fn,
                in_shardings=(pshard, bshard),
                out_shardings=(NamedSharding(mesh, P()), cshard),
            ).lower(pspecs, specs)
        return lowered, (pspecs, specs)

    if kind == "decode":
        dspecs = decode_specs(model, shape)
        cshard = cache_shardings(dspecs["cache"], mesh, ctx)
        tshard = batch_shardings({"token": dspecs["token"]}, mesh, ctx)["token"]
        fn = decode_step(model)
        with ctx:
            lowered = jax.jit(
                fn,
                in_shardings=(pshard, tshard, cshard, NamedSharding(mesh, P())),
                out_shardings=(NamedSharding(mesh, P()), cshard),
                donate_argnums=(2,) if donate else (),
            ).lower(pspecs, dspecs["token"], dspecs["cache"], dspecs["pos"])
        return lowered, (pspecs, dspecs)

    if kind == "repr":
        specs = model.input_specs(shape)
        bshard = batch_shardings(specs, mesh, ctx)
        fn = repr_step(model)
        with ctx:
            lowered = jax.jit(
                fn, in_shardings=(pshard, bshard),
            ).lower(pspecs, specs)
        return lowered, (pspecs, specs)

    raise ValueError(f"unknown step kind {kind}")
