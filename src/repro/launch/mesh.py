"""Production mesh construction (TPU v5e target).

Function, not module-level constant — importing this module never touches
jax device state. Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model); the pod axis
carries cross-pod data parallelism (DCN-grade collectives in production).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 (dryrun.py does this)")
    import numpy as np
    dev_array = np.array(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_client_mesh(n: int = 0):
    """1-D ``("clients",)`` mesh for the sharded scanned engine
    (``engine.run_rounds`` under ``engine.init(..., mesh=...)``): arena
    rows, cohort gathers and the per-cohort-slot training partition over
    this axis, cross-client aggregations all-reduce across it. n=0 uses
    every local device; otherwise the first n. On CPU,
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` provides the
    devices (real multi-device semantics — the mesh parity battery runs
    exactly this way)."""
    import numpy as np
    devices = jax.devices()
    n = len(devices) if n <= 0 else min(n, len(devices))
    return jax.sharding.Mesh(np.array(devices[:n]), ("clients",))


def make_cohort_mesh(n: int = 0):
    """1-D client-axis mesh for the engine's cohort step: the vmapped
    per-client bi-level updates shard over ("data",) — each device owns a
    slice of the sampled cohort. n=0 uses every local device; otherwise
    the first n."""
    import numpy as np
    devices = jax.devices()
    n = len(devices) if n <= 0 else min(n, len(devices))
    return jax.sharding.Mesh(np.array(devices[:n]), ("data",))


def make_host_mesh(model_parallel: int = 1):
    """Tiny mesh over the real local devices (CPU smoke / examples)."""
    import numpy as np
    devices = jax.devices()
    mp = min(model_parallel, len(devices))
    dp = len(devices) // mp
    dev_array = np.array(devices[: dp * mp]).reshape(dp, mp)
    return jax.sharding.Mesh(dev_array, ("data", "model"))
