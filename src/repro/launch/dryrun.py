import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
combination against the production mesh, prove the sharding config is
coherent, and extract the roofline terms from the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
Results: one JSON per run under results/dryrun/.
"""
import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config
from repro.models import INPUT_SHAPES, build
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import lower_step
from repro.utils import trees as tree_utils

# ----------------------------------------------------------- HW constants
PEAK_FLOPS = 197e12          # bf16 per chip (TPU v5e)
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

# whisper-medium × long_500k lowers fine as a pure stress shape (524k
# decoder self-cache), but is model-meaningless (448-token real context) —
# kept in the table with that caveat (DESIGN.md §4). No hard skips.
SKIPS = {}

_COLL_RE = re.compile(
    r"(\w[\w\.\-]*)\s*=\s*[^=]*?\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )
_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|f64|s64|c64)\[([0-9,]*)\]")
_BYTES = {"f64": 8, "s64": 8, "c64": 8, "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "s8": 1, "u8": 1, "pred": 1}


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by every collective op in the post-SPMD HLO.

    Compiled HLO operands are untyped (%names), so we size each op by its
    RESULT type (the region between '=' and the op mnemonic) — i.e. bytes
    received per device. '-start' async ops carry an (operand, result)
    tuple; we halve those. Ring all-reduce moves ~2× its result — we record
    the result convention uniformly and note it in EXPERIMENTS.md."""
    out = {k: 0 for k in ("all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute")}
    counts = {k: 0 for k in out}
    line_re = re.compile(
        r"=\s*(.*?)\s*(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
        r"(-start|-done)?\(")
    for line in hlo_text.splitlines():
        m = line_re.search(line)
        if not m:
            continue
        kind, variant = m.group(2), m.group(3)
        if variant == "-done":
            continue                      # counted at -start
        result_region = m.group(1)
        nbytes = 0
        for tm in _SHAPE_RE.finditer(result_region):
            dt, dims = tm.group(1), tm.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _BYTES[dt]
        if variant == "-start":
            nbytes //= 2                  # tuple carries operand + result
        out[kind] += nbytes
        counts[kind] += 1
    out["counts"] = counts
    out["total"] = sum(v for k, v in out.items() if isinstance(v, int))
    return out


def model_flops(cfg, model, shape, kind: str) -> float:
    """6·N_active·tokens (train; ×2 for the bi-level pair) or 2·N_active·tokens."""
    pspecs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    total = tree_utils.tree_size(pspecs)
    expert = sum(
        int(__import__("numpy").prod(l.shape))
        for p, l in jax.tree_util.tree_flatten_with_path(pspecs)[0]
        if "experts" in "/".join(str(getattr(k, "key", k)) for k in p)
    )
    active = total - expert + (expert * cfg.moe_top_k // max(cfg.n_experts, 1) if cfg.n_experts else 0)
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 2 * 6.0 * active * tokens          # bi-level: θ and ω both trained
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    tokens = shape.global_batch                   # decode: one token per sequence
    return 2.0 * active * tokens


def pick_kind(shape) -> str:
    return {"train": "train", "prefill": "prefill", "decode": "decode"}[shape.kind]


# ----------------------------------------------------------- cost probes
# XLA's cost_analysis counts while-loop bodies ONCE (trip count ignored),
# so the full-depth scan lowering under-reports flops/bytes/collectives.
# We therefore lower small-depth FULLY-UNROLLED probes at identical widths/
# shapes/sharding and extrapolate exactly (costs are affine in depth).

def _measure(cfg, shape, mesh, kind, lr, lam, serve_tp_only=False) -> dict:
    model = build(cfg)
    lowered, _ = lower_step(model, shape, mesh, kind, lr=lr, lam=lam,
                            serve_params_tp_only=serve_tp_only)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    out = {"flops": float(cost.get("flops", 0.0)),
           "bytes": float(cost.get("bytes accessed", 0.0)),
           "coll_total": float(coll["total"])}
    for k in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute"):
        out[f"coll_{k}"] = float(coll[k])
    return out


def _lin(a: dict, b: dict, sa: float, sb: float) -> dict:
    return {k: sa * a[k] + sb * b[k] for k in a}


def probe_metrics(cfg0, shape, mesh, kind, lr, lam, serve_tp_only=False) -> dict:
    """Extrapolated per-device cost metrics at full depth."""
    base = dict(scan_unroll=True)
    at = cfg0.arch_type
    L = cfg0.n_layers
    if at in ("ssm", "hybrid") and shape.seq_len > 512:
        # cap unrolled seq-scan chunks at 4: the selective-scan recurrence is
        # <2% of mamba flops (projections dominate), so chunk-size distortion
        # is negligible while keeping the probe HLO compilable.
        base["ssm_chunk"] = max(shape.seq_len // 4, 128)
    if at == "audio":
        f22 = _measure(cfg0.with_(n_layers=2, n_enc_layers=2, **base), shape, mesh, kind, lr, lam, serve_tp_only)
        f42 = _measure(cfg0.with_(n_layers=2, n_enc_layers=4, **base), shape, mesh, kind, lr, lam, serve_tp_only)
        f24 = _measure(cfg0.with_(n_layers=4, n_enc_layers=2, **base), shape, mesh, kind, lr, lam, serve_tp_only)
        enc = _lin(f42, f22, 0.5, -0.5)
        dec = _lin(f24, f22, 0.5, -0.5)
        out = _lin(f22, enc, 1.0, cfg0.n_enc_layers - 2)
        return _lin(out, dec, 1.0, L - 2)
    if at == "hybrid":
        # exact 3-probe plan, all shallow: m from an attn-free pair
        # (attn_every > L disables the shared block), s from one 2-layer
        # group. full(L, every=g) = o + L·m + (L//g)·s.
        fA = _measure(cfg0.with_(n_layers=2, attn_every=64, **base), shape, mesh, kind, lr, lam, serve_tp_only)
        fB = _measure(cfg0.with_(n_layers=4, attn_every=64, **base), shape, mesh, kind, lr, lam, serve_tp_only)
        fC = _measure(cfg0.with_(n_layers=2, attn_every=2, **base), shape, mesh, kind, lr, lam, serve_tp_only)
        m = _lin(fB, fA, 0.5, -0.5)
        s_blk = _lin(fC, fA, 1.0, -1.0)
        n_groups = L // cfg0.attn_every
        out = _lin(fA, m, 1.0, L - 2)
        return _lin(out, s_blk, 1.0, n_groups)
    if at == "moe" and cfg0.moe_layer_start > 0:
        s0 = cfg0.moe_layer_start
        f2 = _measure(cfg0.with_(n_layers=s0 + 1, **base), shape, mesh, kind, lr, lam, serve_tp_only)
        f3 = _measure(cfg0.with_(n_layers=s0 + 2, **base), shape, mesh, kind, lr, lam, serve_tp_only)
        body = _lin(f3, f2, 1.0, -1.0)
        return _lin(f2, body, 1.0, (L - s0) - 1)
    # linear families: dense, moe(start=0), ssm, vlm
    f2 = _measure(cfg0.with_(n_layers=2, **base), shape, mesh, kind, lr, lam, serve_tp_only)
    f4 = _measure(cfg0.with_(n_layers=4, **base), shape, mesh, kind, lr, lam, serve_tp_only)
    body = _lin(f4, f2, 0.5, -0.5)
    return _lin(f2, body, 1.0, L - 2)


def variant_config(arch: str, shape_name: str, smoke=False):
    """Apply the long_500k sub-quadratic variant for attention archs."""
    cfg = get_config(arch, smoke=smoke)
    variant = "baseline"
    if shape_name == "long_500k" and cfg.arch_type in ("dense", "moe", "vlm"):
        cfg = cfg.with_(sliding_window=8192)
        variant = "sliding8k"
    return cfg, variant


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            lr=0.1, lam=0.05, probe: bool = True, mesh_shape=None,
            overrides=None, serve_tp_only: bool = False, tag_suffix: str = "") -> dict:
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    tag = f"{arch}_{shape_name}_{mesh_name}{tag_suffix}"
    if (arch, shape_name) in SKIPS:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": SKIPS[(arch, shape_name)]}
        _write(out_dir, tag, rec)
        print(f"[dryrun] SKIP {tag}: {rec['reason']}")
        return rec

    cfg, variant = variant_config(arch, shape_name)
    if overrides:
        cfg = cfg.with_(**overrides)
        variant += "+" + ",".join(f"{k}={v}" for k, v in overrides.items())
    model = build(cfg)
    if mesh_shape:
        import numpy as _np
        n = 1
        for d in mesh_shape:
            n *= d
        mesh = jax.sharding.Mesh(
            _np.array(jax.devices()[:n]).reshape(mesh_shape), ("data", "model"))
        variant += f"+mesh{'x'.join(map(str, mesh_shape))}"
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    kind = pick_kind(shape)

    t0 = time.time()
    lowered, _ = lower_step(model, shape, mesh, kind, lr=lr, lam=lam,
                            serve_params_tp_only=serve_tp_only)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        mem_d = {"error": str(e)}

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    # Cost metrics: probe-extrapolated (exact in depth) when enabled,
    # else raw loop-counted-once values (marked accordingly).
    t0 = time.time()
    if probe:
        met = probe_metrics(cfg, shape, mesh, kind, lr, lam,
                            serve_tp_only=serve_tp_only)
        cost_src = "probe_extrapolated"
    else:
        met = {"flops": flops, "bytes": bytes_acc, "coll_total": float(coll["total"])}
        for k in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute"):
            met[f"coll_{k}"] = float(coll[k])
        cost_src = "loop_counted_once"
    t_probe = time.time() - t0

    compute_s = met["flops"] / PEAK_FLOPS
    memory_s = met["bytes"] / HBM_BW
    collective_s = met["coll_total"] / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, model, shape, kind)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "variant": variant,
        "kind": kind, "status": "ok", "n_devices": int(n_dev),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "probe_s": round(t_probe, 2), "cost_source": cost_src,
        "flops_per_device": met["flops"], "bytes_per_device": met["bytes"],
        "collective_bytes_per_device": {k[5:]: v for k, v in met.items() if k.startswith("coll_")},
        "raw_loop_once": {"flops": flops, "bytes": bytes_acc, "coll": coll},
        "memory": mem_d,
        "terms": terms, "dominant": dominant,
        "model_flops_global": mf,
        "model_flops_per_device": mf / n_dev,
        "useful_flops_ratio": (mf / n_dev) / met["flops"] if met["flops"] else None,
        "hlo_bytes": len(hlo),
    }
    _write(out_dir, tag, rec)
    print(f"[dryrun] OK {tag}: dominant={dominant} "
          f"compute={compute_s*1e3:.2f}ms memory={memory_s*1e3:.2f}ms "
          f"collective={collective_s*1e3:.2f}ms "
          f"(lower {t_lower:.1f}s compile {t_compile:.1f}s probe {t_probe:.1f}s)")
    return rec


def _write(out_dir, tag, rec):
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip unrolled cost probes (compile-proof only)")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--resume", action="store_true",
                    help="skip (arch, shape, mesh) combos with existing JSON")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.mesh == "both" else [args.mesh == "multi"]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'multi' if mp else 'single'}.json"
                if args.resume and os.path.exists(os.path.join(args.out, tag)):
                    continue
                try:
                    run_one(arch, shape, mp, args.out, probe=not args.no_probe)
                except Exception as e:
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"[dryrun] FAIL {arch} {shape} multi={mp}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nALL DRY-RUNS PASSED")


if __name__ == "__main__":
    main()
