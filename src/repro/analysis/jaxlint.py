"""AST-based JAX hazard linter for the repro codebase.

The compiled-program set, device residency, and RNG discipline are
*correctness surfaces* in this reproduction — a silent host sync inside
the scanned round body or a reused PRNG key regresses exactly the
properties the parity and churn batteries pin. This linter makes those
properties checkable statically, so they gate CI instead of relying on
per-PR spot checks.

Rules
-----
R1  PRNG key reuse: a key (``jax.random.PRNGKey`` / ``split`` /
    ``fold_in`` result, or a ``key``-named parameter) consumed by more
    than one ``jax.random.*`` call without an intervening
    ``split``/``fold_in`` reassignment. Same-key draws are correlated —
    the ``sampler.py``/``models`` split idiom, now enforced.
R2  Host sync in traced/hot code: ``.item()``, ``.tolist()``,
    ``float()``/``int()``/``bool()`` on a device value, ``np.*``
    coercions (``asarray``/``array``/...), or ``jax.device_get`` inside
    a function reachable from a jitted entry point (scan step bodies,
    ``*_impl`` transitions, kernels) or marked ``# jaxlint: hot-path``.
    Each is a device→host round-trip (or a trace error) on the path the
    scan-vs-eager and zero-transfer batteries protect.
R3  Python control flow on a traced value: ``if``/``while``/``for``
    over a device value inside traced code — a trace-time
    ``TracerBoolConversionError`` at best, a silently baked-in branch at
    worst. Use ``lax.cond``/``lax.select``/``jnp.where``.
R4  Module-scope ``jnp.``/``jax.random.`` computation: initializes the
    backend (and compiles) at import time, before ``JAX_PLATFORMS`` /
    flags / test harnesses can intervene.
R5  Bare float literal in kernel arithmetic: in ``kernels/`` files, a
    Python float literal as a direct arithmetic operand promotes the
    expression through weak-f32 — silent upcasts in Pallas tiles. Cast
    through the operand dtype instead (``jnp.float32(0.5)``,
    ``x.dtype``-typed constants), or waive where fp32 accumulate is the
    point.

Waivers
-------
An intentional hazard is *annotated, not silenced*::

    w = np.asarray(x)  # jaxlint: disable=R2 — host merge path by design

The waiver comment sits on the offending line (or the line above, or
the ``def`` line to cover a whole function) and MUST carry a
justification after the rule list (``—``, ``--`` or ``:`` separated);
``--strict`` fails on reason-less waivers. ``# jaxlint: hot-path`` on a
``def`` line opts that function (and everything it calls) into the R2
host-sync scope even when it is not reachable from a jitted entry point
— used for per-round host-side code like ``ClusterBank`` scatters.

Entry points: functions passed to ``jax.jit``/``vmap``/``pmap``/
``lax.scan``/``lax.map``/``lax.cond``/``pl.pallas_call`` (or decorated
with jit), functions whose name matches ``step``/``scan_fn``/``core``/
``*_impl``/``*_kernel``, and — transitively — every same-module
function they call, nested defs included.

API: ``lint_paths(paths)`` returns a ``LintReport``; the CLI wrapper is
``scripts/lint_jax.py`` (``--strict`` gates CI).
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import io
import os
import re
import tokenize
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = ["Finding", "Waiver", "LintReport", "RULES",
           "lint_source", "lint_file", "lint_paths"]

RULES: Dict[str, str] = {
    "R1": "PRNG key reused without split/fold_in",
    "R2": "host sync inside traced/hot-path code",
    "R3": "Python control flow on a traced value",
    "R4": "module-scope jnp/jax.random computation at import time",
    "R5": "bare float literal in kernel arithmetic (dtype widening)",
}

# function names that mark a def as a traced entry point even when it is
# only called through a first-class reference (scan bodies are returned,
# not decorated)
_ENTRY_NAME_PATTERNS = ("step", "scan_fn", "core", "*_impl", "*_kernel",
                        "kernel")
# jax transforms whose callable argument executes under trace
_TRANSFORM_CALLS = {
    ("jax", "jit"), ("jax", "vmap"), ("jax", "pmap"), ("jax", "grad"),
    ("jax", "value_and_grad"), ("jax", "checkpoint"), ("jax", "remat"),
    ("lax", "scan"), ("lax", "map"), ("lax", "cond"), ("lax", "switch"),
    ("lax", "while_loop"), ("lax", "fori_loop"), ("lax", "associative_scan"),
    ("pl", "pallas_call"), ("pallas", "pallas_call"),
}
_TRANSFORM_BARE = {"jit", "pallas_call", "pjit", "shard_map"}
# jax.random consumers for R1 (first positional argument is the key)
_KEY_CONSUMERS = {
    "normal", "uniform", "bernoulli", "randint", "choice", "permutation",
    "categorical", "gumbel", "truncated_normal", "laplace", "exponential",
    "beta", "gamma", "poisson", "dirichlet", "split", "fold_in", "bits",
}
_KEY_REFRESHERS = {"split", "fold_in", "PRNGKey", "key", "wrap_key_data"}
# numpy-side coercions that force a device→host copy when fed a jax array
_NP_SYNC_FUNCS = {
    "asarray", "array", "copy", "fromiter", "atleast_1d", "atleast_2d",
    "unique", "nonzero", "asanyarray", "ascontiguousarray", "save", "savez",
}
_METHOD_SYNCS = {"item", "tolist", "to_py"}

_WAIVER_RE = re.compile(
    r"#\s*jaxlint:\s*disable=([A-Z0-9,\s]+?)"
    r"(?:\s*(?:—|--|–|:)\s*(.*))?$")
_HOT_RE = re.compile(r"#\s*jaxlint:\s*hot-path\b")


@dataclasses.dataclass
class Finding:
    """One lint hit: rule id, location, message, and — when an inline
    waiver covers it — the recorded justification."""
    rule: str
    path: str
    line: int
    col: int
    message: str
    waived: bool = False
    waiver_reason: Optional[str] = None

    def format(self) -> str:
        """``path:line:col: RULE message`` (``[waived: reason]`` suffix
        when an inline waiver covers the finding)."""
        tag = f" [waived: {self.waiver_reason}]" if self.waived else ""
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"{self.message}{tag}")


@dataclasses.dataclass
class Waiver:
    """One inline ``# jaxlint: disable=...`` annotation (rule set,
    justification, and whether any finding actually matched it)."""
    path: str
    line: int
    rules: Tuple[str, ...]
    reason: str
    used: bool = False


@dataclasses.dataclass
class LintReport:
    """Aggregated lint result over a path set.

    ``findings`` carries every hit (waived ones included, flagged);
    ``waivers`` is the full waiver inventory — the CI artifact that
    keeps intentional hazards auditable.
    """
    findings: List[Finding] = dataclasses.field(default_factory=list)
    waivers: List[Waiver] = dataclasses.field(default_factory=list)

    @property
    def errors(self) -> List[Finding]:
        """Unwaived findings — the set ``--strict`` gates on."""
        return [f for f in self.findings if not f.waived]

    def reasonless_waivers(self) -> List[Waiver]:
        """Waivers with no justification text (strict mode rejects
        them: an unexplained waiver is a silenced finding)."""
        return [w for w in self.waivers if not w.reason.strip()]

    def unused_waivers(self) -> List[Waiver]:
        """Waivers no finding matched — stale annotations worth pruning
        (reported, not gated: rules evolve)."""
        return [w for w in self.waivers if not w.used]

    def to_json(self) -> dict:
        """JSON document (findings + waiver inventory) for the CI
        artifact."""
        return {
            "findings": [dataclasses.asdict(f) for f in self.findings],
            "waivers": [dataclasses.asdict(w) for w in self.waivers],
            "summary": {
                "files_with_findings":
                    len({f.path for f in self.findings}),
                "errors": len(self.errors),
                "waived": sum(1 for f in self.findings if f.waived),
                "waivers": len(self.waivers),
                "unused_waivers": len(self.unused_waivers()),
            },
        }


# ===================================================================== tokens
def _scan_comments(source: str):
    """(waivers by line, hot-path-marked lines) from the token stream —
    comments are invisible to ``ast``, so waiver/hot markers are read
    off ``tokenize``."""
    waivers: Dict[int, Waiver] = {}
    hot_lines: Set[int] = set()
    try:
        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type != tokenize.COMMENT:
                continue
            line = tok.start[0]
            m = _WAIVER_RE.search(tok.string)
            if m:
                rules = tuple(r.strip() for r in m.group(1).split(",")
                              if r.strip())
                waivers[line] = Waiver(path="", line=line, rules=rules,
                                       reason=(m.group(2) or "").strip())
            if _HOT_RE.search(tok.string):
                hot_lines.add(line)
    except tokenize.TokenError:
        pass
    return waivers, hot_lines


# ============================================================= AST utilities
def _dotted(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """``a.b.c`` attribute chains as a name tuple (None for anything
    dynamic)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def _is_transform_call(call: ast.Call) -> bool:
    dn = _dotted(call.func)
    if not dn:
        return False
    if len(dn) >= 2 and tuple(dn[-2:]) in _TRANSFORM_CALLS:
        return True
    return dn[-1] in _TRANSFORM_BARE


_FN_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def _own_nodes(fn_node: ast.AST) -> Iterable[ast.AST]:
    """Walk a function's body WITHOUT descending into nested function
    definitions (each nested def is analyzed in its own scope)."""
    stack = [fn_node]
    first = True
    while stack:
        node = stack.pop()
        if not first and isinstance(node, _FN_NODES):
            continue
        first = False
        yield node
        stack.extend(ast.iter_child_nodes(node))


def _assigned_names(target: ast.AST) -> List[str]:
    names = []
    for sub in ast.walk(target):
        if isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Store):
            names.append(sub.id)
    return names


# ================================================================ call graph
class _FnInfo:
    """One function/lambda definition: AST node, qualname, nesting, and
    the simple names it calls (same-module resolution only)."""

    def __init__(self, node, qualname: str, parent: Optional["_FnInfo"]):
        self.node = node
        self.qualname = qualname
        self.parent = parent
        self.calls: Set[str] = set()
        self.refs: Set[str] = set()   # names referenced (incl. as args)
        self.hot = False
        self.entry = False


class _Indexer(ast.NodeVisitor):
    """Collect every function def with qualnames, per-function call and
    reference sets, and entry-point marks (jit decorators, transform
    callable arguments, entry name patterns, hot-path comments)."""

    def __init__(self, hot_lines: Set[int]):
        self.fns: Dict[ast.AST, _FnInfo] = {}
        self.by_name: Dict[str, List[_FnInfo]] = {}
        self.stack: List[_FnInfo] = []
        self.hot_lines = hot_lines
        self.pending_entry_nodes: Set[ast.AST] = set()
        self.entry_names: Set[str] = set()

    def _enter(self, node, name: str):
        qual = (self.stack[-1].qualname + "." + name if self.stack else name)
        info = _FnInfo(node, qual, self.stack[-1] if self.stack else None)
        probe = {node.lineno, node.lineno - 1}
        if isinstance(getattr(node, "body", None), list) and node.body:
            probe.add(node.body[0].lineno - 1)
        if probe & self.hot_lines:
            info.hot = True
        if any(fnmatch.fnmatch(name, pat) for pat in _ENTRY_NAME_PATTERNS):
            info.entry = True
        if node in self.pending_entry_nodes:
            info.entry = True
        for deco in getattr(node, "decorator_list", []):
            target = deco.func if isinstance(deco, ast.Call) else deco
            dn = _dotted(target)
            sub_dns = [
                _dotted(a) for a in getattr(deco, "args", [])]
            if (dn and ("jit" in dn or "pallas_call" in dn)) or any(
                    d and "jit" in d for d in sub_dns if d):
                info.entry = True
        self.fns[node] = info
        self.by_name.setdefault(name, []).append(info)
        self.stack.append(info)

    def visit_FunctionDef(self, node):
        self._enter(node, node.name)
        self.generic_visit(node)
        self.stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node):
        self._enter(node, "<lambda>")
        self.generic_visit(node)
        self.stack.pop()

    def visit_Call(self, node):
        if self.stack:
            dn = _dotted(node.func)
            if dn:
                self.stack[-1].calls.add(dn[-1])
        if _is_transform_call(node):
            # the callable argument(s) execute under trace
            args = list(node.args) + [kw.value for kw in node.keywords]
            for arg in args:
                if isinstance(arg, _FN_NODES):
                    self.pending_entry_nodes.add(arg)
                else:
                    dn = _dotted(arg)
                    if dn:
                        self.entry_names.add(dn[-1])
        self.generic_visit(node)

    def finish(self):
        """Resolve by-name entry marks collected during the walk (a
        transform may reference a function defined later)."""
        for name in self.entry_names:
            for info in self.by_name.get(name, []):
                info.entry = True


def _closure(idx: _Indexer, roots: List[_FnInfo]) -> Set[_FnInfo]:
    """Transitive same-module call closure from ``roots`` (nested defs
    reached through calls or first-class references)."""
    seen: Set[_FnInfo] = set()
    work = list(roots)
    while work:
        info = work.pop()
        if info in seen:
            continue
        seen.add(info)
        for name in info.calls | info.refs:
            for callee in idx.by_name.get(name, []):
                if callee not in seen:
                    work.append(callee)
    return seen


# ============================================================ device tracking
_DEVICE_ROOTS = {"jnp", "lax"}
_DEVICE_JAX_SUBMODULES = {"random", "lax", "ops", "nn", "numpy", "scipy"}
# attribute reads that yield static Python metadata, not array values
_STATIC_ATTRS = {"shape", "dtype", "ndim", "size", "sharding"}
# conventionally host-static parameter names (orchestrator/config
# objects threaded through builders, never traced)
_STATIC_PARAM_NAMES = {"self", "cls", "ctx", "cfg", "config"}
# annotations marking a parameter as a static Python scalar/flag
_STATIC_PARAM_ANNOTATIONS = {"bool", "int", "str"}


def _device_call(call: ast.Call) -> bool:
    dn = _dotted(call.func)
    if not dn:
        return False
    if dn[0] in _DEVICE_ROOTS:
        return True
    return dn[0] == "jax" and len(dn) > 1 and \
        dn[1] in _DEVICE_JAX_SUBMODULES


def _expr_is_device(node: ast.AST, device_vars: Set[str]) -> bool:
    """Conservatively: does this expression (syntactically) produce or
    contain a traced/device value? ``x.shape``-style static metadata
    reads are pruned — ``int(parent.shape[0])`` is not a sync."""
    stack = [node]
    while stack:
        sub = stack.pop()
        if isinstance(sub, ast.Attribute) and sub.attr in _STATIC_ATTRS:
            continue
        if isinstance(sub, ast.Name) and sub.id in device_vars:
            return True
        if isinstance(sub, ast.Call) and _device_call(sub):
            return True
        stack.extend(ast.iter_child_nodes(sub))
    return False


def _is_identity_test(test: ast.AST) -> bool:
    """``x is None`` / ``x is not None`` — identity checks never force
    a tracer bool conversion; they are the idiomatic static-arg
    dispatch inside jitted code."""
    return isinstance(test, ast.Compare) and all(
        isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops)


# ================================================================== rules
class _Linter:
    def __init__(self, path: str, source: str, tree: ast.Module,
                 kernel_file: bool):
        self.path = path
        self.source = source
        self.tree = tree
        self.kernel_file = kernel_file
        self.findings: List[Finding] = []
        waivers, hot_lines = _scan_comments(source)
        for w in waivers.values():
            w.path = path
        self.waivers = waivers
        self.idx = _Indexer(hot_lines)
        # record first-class references so `lax.cond(p, observe, ...)`
        # and plain `f = step` link the call graph
        self.idx.visit(tree)
        self.idx.finish()
        for info in self.idx.fns.values():
            for sub in _own_nodes(info.node):
                if isinstance(sub, ast.Name) and \
                        isinstance(sub.ctx, ast.Load):
                    if sub.id in self.idx.by_name:
                        info.refs.add(sub.id)
        self.traced = _closure(
            self.idx, [i for i in self.idx.fns.values() if i.entry])
        self.hot = _closure(
            self.idx, [i for i in self.idx.fns.values() if i.hot])

    # ------------------------------------------------------------- report
    def add(self, rule: str, node: ast.AST, message: str):
        self.findings.append(Finding(
            rule=rule, path=self.path, line=node.lineno,
            col=getattr(node, "col_offset", 0), message=message))

    # ----------------------------------------------------------------- R1
    def check_r1(self):
        """Per-function source-order scan: key-typed names consumed
        twice without a ``split``/``fold_in`` refresh between."""
        for info in self.idx.fns.values():
            node = info.node
            key_vars: Set[str] = set()
            args = getattr(node, "args", None)
            if args is not None:
                for a in list(args.args) + list(args.kwonlyargs):
                    if a.arg == "key" or a.arg.endswith("_key") \
                            or a.arg == "rng":
                        key_vars.add(a.arg)
            events = []     # (line, col, kind, payload)
            for sub in _own_nodes(node):
                if isinstance(sub, ast.Assign) and \
                        isinstance(sub.value, ast.Call):
                    dn = _dotted(sub.value.func)
                    if dn and dn[-1] in _KEY_REFRESHERS:
                        names = []
                        for t in sub.targets:
                            names += _assigned_names(t)
                        events.append((sub.lineno, sub.col_offset,
                                       "refresh", (names, sub.value)))
                if isinstance(sub, ast.Call):
                    dn = _dotted(sub.func)
                    if (dn and dn[-1] in _KEY_CONSUMERS
                            and ("random" in dn or len(dn) == 1)
                            and sub.args
                            and isinstance(sub.args[0], ast.Name)):
                        events.append((sub.lineno, sub.args[0].col_offset,
                                       "consume", (sub.args[0].id, sub,
                                                   dn[-1])))
            consumed: Dict[str, ast.AST] = {}
            for line, col, kind, payload in sorted(
                    events, key=lambda e: (e[0], e[1])):
                if kind == "refresh":
                    names, _call = payload
                    key_vars.update(names)
                    for n in names:
                        consumed.pop(n, None)
                else:
                    kname, call, fn_name = payload
                    if kname not in key_vars:
                        continue
                    if fn_name in _KEY_REFRESHERS:
                        continue    # split(key) alone is not a draw
                    if kname in consumed:
                        self.add("R1", call,
                                 f"key {kname!r} consumed again without "
                                 f"split/fold_in (draws correlate; "
                                 f"first use at line "
                                 f"{consumed[kname].lineno})")
                    else:
                        consumed[kname] = call

    # ------------------------------------------------------------- R2 + R3
    def check_r2_r3(self):
        for info in set(self.traced) | set(self.hot):
            fn_node = info.node
            traced_fn = info in self.traced
            hot_fn = info in self.hot
            device_vars: Set[str] = set()
            if traced_fn:
                args = getattr(fn_node, "args", None)
                if args is not None:
                    for a in list(args.args) + list(args.kwonlyargs):
                        if a.arg in _STATIC_PARAM_NAMES:
                            continue
                        ann = getattr(a, "annotation", None)
                        if isinstance(ann, ast.Name) and \
                                ann.id in _STATIC_PARAM_ANNOTATIONS:
                            continue
                        device_vars.add(a.arg)
            for stmt in sorted(
                    (s for s in _own_nodes(fn_node)
                     if isinstance(s, (ast.Assign, ast.For, ast.If,
                                       ast.While, ast.Call))),
                    key=lambda s: (s.lineno, s.col_offset)):
                if isinstance(stmt, ast.Assign):
                    if _expr_is_device(stmt.value, device_vars):
                        for t in stmt.targets:
                            device_vars.update(_assigned_names(t))
                elif isinstance(stmt, ast.For):
                    # only direct device iterables: `for i in idx` /
                    # `for v in jnp.arange(n)`. Composites like
                    # `zip(names, arrays)` iterate a static-length
                    # container of tracers, which is fine.
                    it = stmt.iter
                    direct_device = (
                        (isinstance(it, ast.Name) and
                         it.id in device_vars)
                        or (isinstance(it, ast.Call) and
                            _device_call(it))
                        or (isinstance(it, ast.Attribute) and
                            it.attr not in _STATIC_ATTRS and
                            _expr_is_device(it, device_vars)))
                    if traced_fn and direct_device:
                        self.add("R3", stmt,
                                 "Python for-loop over a traced value "
                                 "(unrolls at trace time or fails) — "
                                 "use lax.scan/lax.map")
                elif isinstance(stmt, (ast.If, ast.While)):
                    if traced_fn and \
                            not _is_identity_test(stmt.test) and \
                            _expr_is_device(stmt.test, device_vars):
                        self.add("R3", stmt,
                                 "Python branch on a traced value "
                                 "(TracerBoolConversionError or baked-"
                                 "in branch) — use lax.cond/jnp.where")
                else:
                    self._check_sync_call(stmt, device_vars, traced_fn,
                                          hot_fn, fn_node)

    def _check_sync_call(self, call: ast.Call, device_vars: Set[str],
                         traced_fn: bool, hot_fn: bool, fn_node):
        where = ("inside traced code" if traced_fn
                 else "on the hot path")
        if isinstance(call.func, ast.Attribute) and \
                call.func.attr in _METHOD_SYNCS:
            if traced_fn or hot_fn or \
                    _expr_is_device(call.func.value, device_vars):
                self.add("R2", call,
                         f".{call.func.attr}() forces a device→host "
                         f"sync {where}")
            return
        dn = _dotted(call.func)
        if not dn:
            return
        name = dn[-1]
        # float()/int()/bool() host coercions
        if dn == (name,) and name in ("float", "int", "bool") and call.args:
            arg = call.args[0]
            if isinstance(arg, ast.Constant):
                return
            if _expr_is_device(arg, device_vars):
                self.add("R2", call,
                         f"{name}() on a device value blocks and copies "
                         f"to host {where} — keep it a jnp scalar or "
                         "hoist out of the hot path")
            elif hot_fn and not traced_fn and self._inside_loop(call,
                                                                fn_node):
                self.add("R2", call,
                         f"{name}() in a per-element Python loop {where}"
                         " — vectorize (np.fromiter / one asarray over "
                         "the whole sequence)")
            return
        # np.* coercions
        if dn[0] in ("np", "numpy") and name in _NP_SYNC_FUNCS:
            if traced_fn:
                self.add("R2", call,
                         f"np.{name}() inside traced code — a traced "
                         "operand raises TracerArrayConversionError; a "
                         "device operand silently syncs to host")
            elif hot_fn:
                self.add("R2", call,
                         f"np.{name}() on the hot path forces a "
                         "device→host copy when fed a jax array")
            return
        # jax.device_get
        if name == "device_get" and (traced_fn or hot_fn):
            self.add("R2", call,
                     f"jax.device_get is an explicit host transfer "
                     f"{where} — move it off the per-round path")

    def _inside_loop(self, node: ast.AST, fn_node) -> bool:
        for parent in _own_nodes(fn_node):
            if isinstance(parent, (ast.For, ast.While, ast.ListComp,
                                   ast.GeneratorExp, ast.SetComp,
                                   ast.DictComp)) and parent is not node:
                if any(sub is node for sub in ast.walk(parent)):
                    return True
        return False

    # ----------------------------------------------------------------- R4
    def check_r4(self):
        for stmt in self.tree.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef, ast.Import, ast.ImportFrom)):
                continue
            if isinstance(stmt, ast.If):
                # `if __name__ == "__main__":` runs at script exec, not
                # import — out of R4's scope
                t = stmt.test
                if isinstance(t, ast.Compare) and \
                        isinstance(t.left, ast.Name) and \
                        t.left.id == "__name__":
                    continue
            for sub in ast.walk(stmt):
                if isinstance(sub, _FN_NODES):
                    continue
                if not isinstance(sub, ast.Call):
                    continue
                dn = _dotted(sub.func)
                if not dn:
                    continue
                if dn[0] == "jnp" or (dn[0] == "jax" and len(dn) > 1
                                      and dn[1] in ("numpy", "random")):
                    self.add("R4", sub,
                             f"module-scope {'.'.join(dn)}() runs at "
                             "import: initializes the backend and "
                             "compiles before flags/harnesses can "
                             "intervene — build lazily")

    # ----------------------------------------------------------------- R5
    def check_r5(self):
        if not self.kernel_file:
            return
        for info in self.traced:
            for sub in _own_nodes(info.node):
                if not isinstance(sub, ast.BinOp):
                    continue
                for side in (sub.left, sub.right):
                    if isinstance(side, ast.Constant) and \
                            isinstance(side.value, float):
                        other = sub.right if side is sub.left else sub.left
                        if isinstance(other, ast.Constant):
                            continue
                        self.add("R5", side,
                                 f"bare float literal {side.value!r} in "
                                 "kernel arithmetic promotes through "
                                 "weak-f32 — cast via the operand dtype "
                                 "(jnp.float32(...) / x.dtype)")

    # ================================================================ driver
    def run(self) -> Tuple[List[Finding], List[Waiver]]:
        self.check_r1()
        self.check_r2_r3()
        self.check_r4()
        self.check_r5()
        # de-dup (a node can be reached through several scopes)
        uniq = {}
        for f in self.findings:
            uniq.setdefault((f.rule, f.line, f.col, f.message), f)
        self.findings = sorted(uniq.values(),
                               key=lambda f: (f.line, f.col, f.rule))
        self._apply_waivers()
        return self.findings, list(self.waivers.values())

    def _def_cover(self) -> Dict[int, ast.AST]:
        """line -> innermost def whose def-line waiver covers it."""
        cover: Dict[int, ast.AST] = {}
        for fn_node in self.idx.fns:
            if not hasattr(fn_node, "body"):
                continue
            end = getattr(fn_node, "end_lineno", fn_node.lineno)
            for line in range(fn_node.lineno, end + 1):
                prev = cover.get(line)
                if prev is None or fn_node.lineno > prev.lineno:
                    cover[line] = fn_node
        return cover

    def _apply_waivers(self):
        cover = self._def_cover()
        for f in self.findings:
            for line in (f.line, f.line - 1):
                w = self.waivers.get(line)
                if w and f.rule in w.rules:
                    f.waived, f.waiver_reason, w.used = True, w.reason, True
                    break
            if f.waived:
                continue
            fn = cover.get(f.line)
            if fn is not None:
                for line in (fn.lineno, fn.lineno - 1):
                    w = self.waivers.get(line)
                    if w and f.rule in w.rules:
                        f.waived, f.waiver_reason, w.used = \
                            True, w.reason, True
                        break


# ================================================================ public API
def lint_source(source: str, path: str = "<string>") -> Tuple[
        List[Finding], List[Waiver]]:
    """Lint one source string; returns ``(findings, waivers)`` with
    waivers already applied (waived findings stay in the list,
    marked)."""
    tree = ast.parse(source, filename=path)
    kernel_file = ("kernels" in path.replace("\\", "/").split("/")
                   or os.path.basename(path).endswith("_kernel.py"))
    return _Linter(path, source, tree, kernel_file).run()


def lint_file(path: str) -> Tuple[List[Finding], List[Waiver]]:
    """Lint one file (see ``lint_source``)."""
    with open(path) as f:
        src = f.read()
    return lint_source(src, path)


def lint_paths(paths: Sequence[str]) -> LintReport:
    """Lint every ``*.py`` under ``paths`` (files or directories) into
    one ``LintReport``. Walks directories recursively, skipping
    ``__pycache__``."""
    report = LintReport()
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, names in os.walk(p):
                dirs[:] = [d for d in dirs if d != "__pycache__"]
                files += [os.path.join(root, n) for n in sorted(names)
                          if n.endswith(".py")]
        else:
            files.append(p)
    for path in files:
        findings, waivers = lint_file(path)
        report.findings += findings
        report.waivers += waivers
    return report
