"""Runtime sanitizers: composable context managers that turn the
repo's device-residency and compile-set claims into hard failures.

Three guards, one per correctness surface:

- ``compile_budget(n)`` — counts XLA backend compiles inside the block
  (via ``jax.monitoring``'s ``backend_compile_duration`` event, which
  fires exactly once per XLA compilation, cache hits excluded) and
  raises ``CompileBudgetExceeded`` on overrun. With ``log_names=True``
  it additionally flips ``jax_log_compiles`` and captures the
  ``jit(<name>)`` labels from the dispatch log so an overrun names the
  offending programs. This is what pins the ROADMAP compile-tax item:
  under pow2 shape quantization a churn timeline must stay within
  O(log population) distinct programs, not O(rounds).
- ``no_transfer()`` — zero implicit host↔device transfers inside the
  block (``jax.transfer_guard("disallow")``), generalizing the one-off
  proof in ``tests/test_device_clustering.py`` to any code region.
  Explicit escapes (``jax.device_put``, ``np.asarray(arr)`` on a
  committed array) still fail — that is the point.
- ``nan_guard()`` — flips ``jax_debug_nans`` for the block, so any
  NaN/Inf produced inside a jitted computation re-runs op-by-op and
  raises at the producing primitive instead of poisoning the round
  loop silently.

All three restore prior global state on exit and nest/compose freely::

    with sanitize.no_transfer(), sanitize.compile_budget(4) as log:
        state = engine.run_rounds(...)
    assert log.count <= 4
"""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import re
from typing import Iterator, List, Optional

import jax

__all__ = ["CompileLog", "CompileBudgetExceeded", "compile_budget",
           "no_transfer", "nan_guard"]

# fires once per XLA backend compilation (jax._src.dispatch wraps every
# backend.compile in record_event_duration_secs with this key)
_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
# fires once per persistent-compilation-cache hit. NOTE the compile
# event above wraps compile_or_get_cached, so it fires for EVERY
# compile request, served-from-cache or not — ``count`` is "programs
# requested", and ``cache_hits`` says how many of those skipped the
# actual XLA compile (warm process: cache_hits == count)
_CACHE_HIT_EVENT = "/jax/compilation_cache/cache_hits"
_LOG_NAME_RE = re.compile(
    r"Finished XLA compilation of (\S+) in [\d.e+-]+ sec")


class CompileBudgetExceeded(AssertionError):
    """Raised when a ``compile_budget(n)`` block triggers more than
    ``n`` XLA compilations."""


@dataclasses.dataclass
class CompileLog:
    """Live compile tally for a ``compile_budget`` block: ``count`` is
    authoritative (monitoring event, one per XLA compile); ``names``
    lists ``jit(<label>)`` strings when ``log_names=True`` captured
    them (diagnostic only — the log line and the event are emitted by
    different layers)."""
    budget: Optional[int] = None
    count: int = 0
    cache_hits: int = 0     # persistent-compilation-cache serves (no XLA run)
    names: List[str] = dataclasses.field(default_factory=list)

    def describe(self) -> str:
        """Human-readable tally, naming compiled programs when
        known."""
        head = f"{self.count} XLA compile(s)"
        if self.budget is not None:
            head += f" (budget {self.budget})"
        if self.names:
            head += ": " + ", ".join(self.names)
        return head


class _LogHandler(logging.Handler):
    def __init__(self, log: CompileLog):
        super().__init__(level=logging.DEBUG)
        self._log = log

    def emit(self, record):
        m = _LOG_NAME_RE.search(record.getMessage())
        if m:
            self._log.names.append(m.group(1))


def _unregister_duration_listener(cb) -> None:
    # jax's public monitoring API (0.4.x) registers but never exposes
    # removal; use the private hook with a manual fallback so stacked
    # budgets don't double count
    mon = jax.monitoring
    try:
        from jax._src import monitoring as _m
        _m._unregister_event_duration_listener_by_callback(cb)
        return
    except Exception:
        pass
    try:  # pragma: no cover - fallback for layout changes
        mon._event_duration_secs_listeners.remove(cb)
    except Exception:
        pass


def _unregister_event_listener(cb) -> None:
    # same story for the plain (no-duration) event listeners, which
    # carry the persistent-cache hit counter
    mon = jax.monitoring
    try:
        from jax._src import monitoring as _m
        _m._unregister_event_listener_by_callback(cb)
        return
    except Exception:
        pass
    try:  # pragma: no cover - fallback for layout changes
        mon._event_listeners.remove(cb)
    except Exception:
        pass


@contextlib.contextmanager
def compile_budget(budget: Optional[int] = None, *,
                   log_names: bool = False) -> Iterator[CompileLog]:
    """Count XLA compiles in the block; raise ``CompileBudgetExceeded``
    if they exceed ``budget`` (``None`` = just count). The yielded
    ``CompileLog`` updates live, so callers can also assert mid-block
    or record counts into benchmarks. ``log.cache_hits`` separately
    tallies persistent-compilation-cache serves; a served request STILL
    fires the compile event (the event wraps compile_or_get_cached), so
    the warm-start assertion is ``cache_hits == count`` — every program
    requested, none actually compiled."""
    log = CompileLog(budget=budget)

    def _on_event(event: str, duration: float, **kw) -> None:
        if event == _COMPILE_EVENT:
            log.count += 1

    def _on_hit(event: str, **kw) -> None:
        if event == _CACHE_HIT_EVENT:
            log.cache_hits += 1

    jax.monitoring.register_event_duration_secs_listener(_on_event)
    jax.monitoring.register_event_listener(_on_hit)
    handler = None
    prev_log_compiles = None
    logger = logging.getLogger("jax._src.dispatch")
    if log_names:
        prev_log_compiles = jax.config.jax_log_compiles
        jax.config.update("jax_log_compiles", True)
        handler = _LogHandler(log)
        logger.addHandler(handler)
    try:
        yield log
    finally:
        _unregister_duration_listener(_on_event)
        _unregister_event_listener(_on_hit)
        if handler is not None:
            logger.removeHandler(handler)
            jax.config.update("jax_log_compiles", prev_log_compiles)
    if budget is not None and log.count > budget:
        raise CompileBudgetExceeded(
            f"compile budget exceeded: {log.describe()}")


@contextlib.contextmanager
def no_transfer() -> Iterator[None]:
    """Disallow implicit host↔device transfers inside the block.

    Any device→host sync (``float(arr)``, ``np.asarray(arr)``,
    ``.item()``) or implicit host→device upload raises — the runtime
    twin of the linter's R2 rule, and the guard the per-strategy
    zero-transfer battery runs the scanned round step under."""
    with jax.transfer_guard("disallow"):
        yield


@contextlib.contextmanager
def nan_guard() -> Iterator[None]:
    """Fail loudly on NaN/Inf from any jitted computation inside the
    block (``jax_debug_nans``); prior flag state is restored on
    exit."""
    prev = jax.config.jax_debug_nans
    jax.config.update("jax_debug_nans", True)
    try:
        yield
    finally:
        jax.config.update("jax_debug_nans", prev)
