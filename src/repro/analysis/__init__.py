"""Correctness tooling for the reproduction: a static JAX hazard
linter and runtime sanitizers, both CI gates.

``repro.analysis.jaxlint`` is an AST pass over ``src/repro`` with five
rules (R1 PRNG key reuse, R2 host sync in traced/hot code, R3 Python
control flow on traced values, R4 module-scope jnp computation, R5
dtype-widening literals in kernels) and an inline waiver syntax that
keeps intentional hazards annotated, not silenced. ``sanitize``
provides composable runtime context managers — ``compile_budget`` (pin
the XLA compile count), ``no_transfer`` (zero host↔device transfers),
``nan_guard`` (fail on NaN/Inf) — used by the per-strategy compile-set
pinning and zero-transfer batteries in ``tests/``.

See ``docs/ANALYSIS.md`` for rules, examples, and the sanitizer API.
"""
from repro.analysis.jaxlint import (Finding, LintReport, RULES, Waiver,
                                    lint_file, lint_paths, lint_source)
from repro.analysis.sanitize import (CompileBudgetExceeded, CompileLog,
                                     compile_budget, nan_guard, no_transfer)

__all__ = [
    "Finding", "Waiver", "LintReport", "RULES",
    "lint_source", "lint_file", "lint_paths",
    "compile_budget", "CompileBudgetExceeded", "CompileLog",
    "no_transfer", "nan_guard",
]
