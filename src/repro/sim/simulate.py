"""The dynamic-federation simulation loop (paper §5 at scale).

``simulate(state, timeline, rounds)`` interleaves a ``Timeline``'s
events with ``engine.run_round``: joins route new clients through
``engine.join`` (Ψ-inference against the live partition), departures
through ``engine.leave`` (partition + arena stay consistent), drift
rewrites client shards in place, and availability windows / stragglers
constrain each round's cohort *before* it trains. Every transition is
the engine's own pure API — the simulator adds no second code path, it
only drives the one that exists. Both clustering backends churn the
same way: with ``cluster_backend="device"`` a join grows the union-find
capacity pow2-amortized and a leave tombstones the departed row's
``live`` bit exactly like an arena row (``core.device_clustering``).

The loop records a per-round log (population, cohort, wall time, event
markers, cluster count) plus the §5 joined-client accuracy trajectory:
at each eval point, the routed-model accuracy of newly-joined clients
vs. a sample of incumbents — the "accuracy recovers to the incumbents'
level" curve the paper's dynamic experiment plots.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.engine.registry import get_strategy
from repro.sim.events import Delay, Drift, Join, Leave, Straggle
from repro.sim.timeline import Timeline


@dataclasses.dataclass
class SimLog:
    """What a simulation run recorded.

    ``records``: one dict per round — ``t``, ``events`` (short labels),
    ``n_registered`` / ``n_live`` population, ``cohort`` size actually
    trained, ``sec_train`` (the ``run_round`` call alone) and
    ``sec_round`` (+ event application) wall times, ``skipped`` (no
    available cohort), ``scanned`` (the round ran inside a fused
    ``run_rounds`` span — per-round times are then the span average),
    plus ``n_clusters`` and — at eval points — ``joined_acc`` /
    ``incumbent_acc`` / ``gap``. ``joined``: cid -> latent cluster of
    every client that joined mid-run; ``departed``: cids that left.
    """
    records: List[dict] = dataclasses.field(default_factory=list)
    joined: Dict[int, Optional[int]] = dataclasses.field(default_factory=dict)
    departed: List[int] = dataclasses.field(default_factory=list)

    def curve(self, key: str):
        """(rounds, values) trajectory of a recorded metric, skipping
        rounds where it was not measured."""
        ts = [r["t"] for r in self.records if r.get(key) is not None]
        vs = [r[key] for r in self.records if r.get(key) is not None]
        return ts, vs

    def to_json(self) -> dict:
        """JSON-able view (the ``BENCH_churn.json`` event-log schema)."""
        return {"records": self.records,
                "joined": {str(k): v for k, v in self.joined.items()},
                "departed": list(self.departed)}


def routed_model(state, cid: int):
    """The model the server would serve client ``cid`` today: its
    cluster's model when the strategy tracks a partition (StoCFL Ψ /
    CFL membership), its personal model (Ditto), the argmin-local-loss
    hypothesis (IFCA — the paper's own routing rule, since IFCA keeps no
    persistent assignment), the global ω otherwise (§4.4 routing)."""
    if state.clusters is not None and cid in state.clusters.reps:
        return state.cluster_model(state.clusters.uf.find(int(cid)))
    if state.members is not None:
        for k, group in enumerate(state.members):
            if cid in group:
                return state.models.get(k, state.omega)
    if cid in state.personal:
        return state.personal[cid]
    if len(state.models):                    # IFCA: hypotheses, no partition
        batch = state.ctx.clients[int(cid)]
        losses = {m: float(state.ctx.loss_fn(state.models[m], batch))
                  for m in state.models}
        return state.models[min(losses, key=losses.get)]
    return state.omega


def routed_accuracy(state, cids, tc_of: Dict[int, int], test_sets) -> Optional[float]:
    """Mean routed-model accuracy over ``cids`` (each evaluated on its
    latent cluster's held-out set per ``tc_of``); None when no cid has a
    known latent cluster. The §5 recovery metric for both newcomers and
    incumbents."""
    fn = state.ctx.eval_fn
    accs = [float(fn(routed_model(state, c), test_sets[tc_of[c]]))
            for c in cids if tc_of.get(c) is not None and tc_of[c] in test_sets]
    return float(np.mean(accs)) if accs else None


def _resolve_leave(state, ev: Leave, rng) -> Optional[int]:
    live = [i for i in range(state.n_clients) if i not in state.left]
    if ev.cid is not None:
        return int(ev.cid) if int(ev.cid) in live else None
    if len(live) <= 1:          # never empty the federation
        return None
    return int(rng.choice(live))


def _scannable(state) -> bool:
    """Whether this state can run event-free spans through
    ``engine.run_rounds`` — delegates to the engine's own precondition
    predicate (``engine.scan_blockers``), so the silent eager fallback
    can never drift from what ``run_rounds`` would actually reject."""
    return engine.scan_blockers(state) is None


def simulate(state, timeline: Timeline, rounds: Optional[int] = None,
             client_factory: Optional[Callable] = None,
             drift_fn: Optional[Callable] = None, seed: int = 0,
             cohort_quantum: int = 0, eval_every: int = 0,
             test_sets: Optional[dict] = None,
             true_cluster: Optional[Any] = None,
             incumbent_sample: int = 64, scan_spans: bool = False,
             async_mode: bool = False):
    """Drive ``rounds`` engine rounds through a churn ``Timeline``.

    Args:
      state: a fresh or mid-run ``ServerState`` (any strategy).
      timeline: the event schedule (``repro.sim.Timeline``).
      rounds: how many rounds to run (default: ``timeline.horizon + 1``).
      client_factory: ``(cluster, rng) -> batch`` building a joining
        client's dataset (required for ``Join`` events without an
        explicit ``batch``) — e.g. ``repro.data.rotated_factory(...)``.
      drift_fn: ``(batch, rng, strength) -> batch`` data-drift hook
        (default ``repro.data.drift_batch``).
      seed: simulator rng (leave victims, stragglers, drift, factory
        draws) — disjoint from the engine's cohort-sampling rng, so a
        timeline replays identically over different strategies.
        Full-participation strategies (CFL) train their whole partition
        every round, so availability windows, stragglers, and
        ``cohort_quantum`` do not apply to them (the round's log carries
        an explicit marker instead of a fabricated cohort size).
      cohort_quantum: truncate each sampled cohort to a multiple of this
        (0 = off). Under churn the population — hence the sampled cohort
        size — drifts every round, and every new cohort shape is a fresh
        XLA compile; quantizing keeps the set of shapes (so compiles)
        bounded while participation stays within one quantum of nominal.
      eval_every: record the §5 joined-vs-incumbent routed accuracy every
        this many rounds (0 = never; needs ``test_sets`` + an engine
        ``eval_fn``).
      test_sets: {latent cluster id: held-out batch}.
      true_cluster: latent cluster per *initial* client (joined clients
        carry theirs on the ``Join`` event).
      incumbent_sample: cap on incumbents evaluated per eval point.
      scan_spans: compile event-free spans (no events, no availability
        window, no eval point, no cohort quantum) into
        ``engine.run_rounds`` scans, pow2-chunked so the set of
        compiled scan lengths stays O(log span) under irregular event
        gaps — the per-round host dispatch
        disappears for exactly the rounds that don't need it, and the
        trajectory stays bitwise identical to the eager loop (the
        scan-vs-eager battery pins this under churn). When the engine
        carries a client-axis mesh, the scanned spans run SPMD over it
        unchanged (the mesh parity battery covers churn boundaries —
        docs/SHARDING.md). Needs the
        run_rounds preconditions (arena + device rng; device partition
        for StoCFL); states that don't meet them fall back to eager
        rounds silently.
      async_mode: drive every round through ``engine.run_round_async``
        instead of ``run_round`` (needs an async-capable strategy —
        stocfl / fedavg / fedprox). Latency comes from the same event
        machinery: a ``Straggle`` at round ``t`` no longer drops its
        victims from the cohort — each one reports back one round LATE
        (same seeded rng draw as the sync drop, so a timeline replays
        identically) — and ``Delay`` events add ``ev.rounds`` of latency
        to their ``cids`` (or the whole cohort). Per-round records gain
        the flush bookkeeping (``merged`` / ``dropped_stale`` /
        ``in_flight``). Mutually exclusive with ``scan_spans`` (the
        buffer is host-orchestrated, not scannable — spans fall back to
        eager async rounds).

    Returns:
      (final ``ServerState``, ``SimLog``).
    """
    rng = np.random.default_rng(seed)
    rounds = timeline.horizon + 1 if rounds is None else int(rounds)
    log = SimLog()
    tc_of: Dict[int, Optional[int]] = (
        {i: int(c) for i, c in enumerate(true_cluster)}
        if true_cluster is not None else {})
    incumbents = list(range(state.n_clients))
    if len(incumbents) > incumbent_sample:
        incumbents = [int(i) for i in
                      rng.choice(incumbents, incumbent_sample, replace=False)]
    if drift_fn is None:
        from repro.data.synthetic import drift_batch
        drift_fn = drift_batch
    strat = get_strategy(state.strategy)
    eval_on = bool(eval_every and test_sets is not None
                   and state.ctx.eval_fn is not None)

    def _plain(t2: int) -> bool:
        """True when round ``t2`` has no event, no availability window
        and no eval point — i.e. it can ride a scanned span."""
        if timeline.at(t2) or timeline.unavailable(t2):
            return False
        return not (eval_on and (t2 % eval_every == 0 or t2 == rounds - 1))

    t = 0
    while t < rounds:
        # ---- event-free span: one run_rounds scan instead of N eager
        # dispatches (identical trajectory; see scan_spans docs)
        if scan_spans and not async_mode and cohort_quantum <= 1:
            span = 0
            while t + span < rounds and _plain(t + span):
                span += 1
            # _scannable (an O(n_clients) precondition walk) only runs
            # once an actual >=2-round span exists — event-heavy phases
            # never pay it per round
            if span >= 2 and _scannable(state):
                t1 = time.time()
                # pow2-chunked scans (largest chunk first): distinct
                # compiled scan lengths stay O(log span) across the
                # whole run instead of one compile per distinct gap
                # between events — composition is exact
                # (run_rounds(a); run_rounds(b) ≡ run_rounds(a+b), see
                # the parity battery)
                ran = 0
                while ran < span:
                    chunk = 1 << ((span - ran).bit_length() - 1)
                    state = engine.run_rounds(state, chunk)
                    ran += chunk
                jax.block_until_ready(state.omega)
                dt = round((time.time() - t1) / span, 4)
                for i, met in enumerate(state.history[-span:]):
                    rec = {"t": t + i, "events": [], "scanned": True,
                           "n_registered": state.n_clients,
                           "n_live": state.n_clients - len(state.left),
                           "cohort": int(met.get("sampled", 0)),
                           "skipped": bool(met.get("skipped", False)),
                           "had_events": False,
                           "sec_train": dt, "sec_round": dt}
                    if "n_clusters" in met:
                        rec["n_clusters"] = met["n_clusters"]
                    log.records.append(rec)
                t += span
                continue

        evs = timeline.at(t)
        labels, drop_rate, delay_evs = [], 0.0, []
        t0 = time.time()
        for ev in evs:
            if isinstance(ev, Join):
                batch = ev.batch
                if batch is None:
                    if client_factory is None:
                        raise ValueError("Join without batch needs a "
                                         "client_factory")
                    batch = client_factory(ev.cluster, rng)
                batch = jax.tree.map(jnp.asarray, batch)
                state, cid = engine.join(state, batch)
                tc_of[cid] = ev.cluster
                log.joined[cid] = ev.cluster
                labels.append(f"join:{cid}")
            elif isinstance(ev, Leave):
                cid = _resolve_leave(state, ev, rng)
                if cid is None:
                    labels.append("leave:skipped")
                    continue
                state = engine.leave(state, cid)
                log.departed.append(cid)
                labels.append(f"leave:{cid}")
            elif isinstance(ev, Straggle):
                drop_rate = max(drop_rate, float(ev.rate))
                labels.append(f"straggle:{ev.rate}")
            elif isinstance(ev, Delay):
                if async_mode:
                    delay_evs.append(ev)
                    labels.append(f"delay:{ev.rounds}")
                else:
                    labels.append("delay:inapplicable-sync")
            elif isinstance(ev, Drift):
                cids = ev.cids if ev.cids is not None else tuple(
                    i for i in range(state.n_clients) if i not in state.left)
                for c in cids:
                    nb = jax.tree.map(
                        jnp.asarray,
                        drift_fn(state.ctx.clients[c], rng, ev.strength))
                    state.ctx.clients[c] = nb
                    if state.ctx.arena is not None:
                        state.ctx.arena = state.ctx.arena.update(c, nb)
                labels.append(f"drift:{len(cids)}")
            else:
                raise TypeError(f"unknown event {ev!r}")

        # ---- cohort: availability -> sampling -> stragglers -> quantum
        busy = timeline.unavailable(t)
        if strat.full_participation:
            # full-participation strategies (CFL) train their whole
            # partition regardless of the cohort argument — availability,
            # stragglers, and quantization cannot apply, and pretending
            # otherwise would log cohort sizes that never trained
            ids = np.array([i for i in range(state.n_clients)
                            if i not in state.left])
            delays = np.zeros(len(ids), np.int64)
            if busy or drop_rate > 0:
                labels.append("full-participation:cohort-events-inapplicable")
        else:
            adv, ids = engine.sample_clients(state, unavailable=busy)
            state = engine.advance_rng(state, adv)
            delays = np.zeros(len(ids), np.int64)
            if drop_rate > 0 and len(ids):
                # one seeded draw either way, so a timeline replays
                # identically sync vs async
                straggled = rng.random(len(ids)) < drop_rate
                victims = [int(c) for c in np.asarray(ids)[straggled]]
                if victims:
                    labels.append("straggle-victims:" +
                                  ",".join(str(c) for c in victims))
                if async_mode:
                    delays[straggled] += 1   # report back late, not never
                else:
                    ids = ids[~straggled]
                    delays = delays[~straggled]
            for ev in delay_evs:
                hit = (np.ones(len(ids), bool) if ev.cids is None
                       else np.isin(np.asarray(ids), np.asarray(ev.cids)))
                delays[hit] += int(ev.rounds)
            if cohort_quantum > 1 and len(ids) > cohort_quantum:
                ids = ids[: (len(ids) // cohort_quantum) * cohort_quantum]
                delays = delays[: len(ids)]

        rec: dict = {"t": t, "events": labels,
                     "n_registered": state.n_clients,
                     "n_live": state.n_clients - len(state.left),
                     "cohort": int(len(ids)), "skipped": len(ids) == 0,
                     "had_events": bool(labels)}
        if len(ids) == 0:
            rec["sec_round"] = round(time.time() - t0, 4)
            log.records.append(rec)
            t += 1
            continue
        t1 = time.time()
        if async_mode:
            state, metrics = engine.run_round_async(state, ids, delays=delays)
        else:
            state, metrics = engine.run_round(state, ids)
        jax.block_until_ready(state.omega)
        t2 = time.time()
        rec["sec_train"] = round(t2 - t1, 4)     # run_round alone
        rec["sec_round"] = round(t2 - t0, 4)     # + event application
        if "n_clusters" in metrics:
            rec["n_clusters"] = metrics["n_clusters"]
        if async_mode:
            for k in ("merged", "dropped_stale", "dropped_left", "in_flight",
                      "max_staleness"):
                if k in metrics:
                    rec[k] = int(metrics[k])

        # ---- §5 joined-vs-incumbent routed-accuracy trajectory
        if (eval_every and test_sets is not None
                and state.ctx.eval_fn is not None
                and (t % eval_every == 0 or t == rounds - 1)):
            alive_inc = [c for c in incumbents if c not in state.left]
            rec["incumbent_acc"] = routed_accuracy(state, alive_inc, tc_of,
                                                   test_sets)
            alive_join = [c for c in log.joined if c not in state.left]
            rec["joined_acc"] = routed_accuracy(state, alive_join, tc_of,
                                                test_sets)
            if rec["incumbent_acc"] is not None and rec["joined_acc"] is not None:
                rec["gap"] = round(rec["incumbent_acc"] - rec["joined_acc"], 5)
        log.records.append(rec)
        t += 1
    return state, log
