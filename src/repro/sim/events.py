"""Typed events for the dynamic-federation simulator (paper §5).

StoCFL's headline claim is support for "an arbitrary proportion of
client participation and newly joined clients for a varying FL system";
these event types are the vocabulary a ``Timeline`` drives the engine
with. Each event is a frozen dataclass carrying the round it fires at
(``t``) plus its payload; ``Availability`` is a *window*, not a
round-event — it constrains when a client may be sampled at all.

Events serialize to/from plain dicts (``to_dict`` / ``event_from_dict``)
so timelines round-trip through JSON trace files; a ``Join`` carrying an
in-memory ``batch`` payload is the one thing that cannot (hand it a
``cluster`` id and let the simulator's ``client_factory`` build the data
instead).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Join:
    """A new client enters the federation at round ``t`` (§5 joins).

    ``batch`` is the client's dataset; leave it ``None`` and set
    ``cluster`` (its latent distribution id) to have the simulator build
    the data via its ``client_factory(cluster, rng)`` — the only form
    that survives a trace-file round-trip.
    """
    t: int
    cluster: Optional[int] = None
    batch: Any = None


@dataclasses.dataclass(frozen=True)
class Leave:
    """Client ``cid`` departs at round ``t`` (§5 departures).

    ``cid=None`` means "a uniformly random live client", resolved by the
    simulator's seeded rng at fire time — the form stochastic churn
    generators emit.
    """
    t: int
    cid: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class Straggle:
    """Stragglers at round ``t``: each sampled client independently drops
    out of the cohort with probability ``rate`` *after* sampling — the
    cross-device reality that a sampled device may never report back.
    """
    t: int
    rate: float


@dataclasses.dataclass(frozen=True)
class Drift:
    """Distribution drift at round ``t``: the data of ``cids`` (``None``
    = every live client) is rewritten by the simulator's ``drift_fn``
    (see ``repro.data.synthetic.drift_batch``) with the given
    ``strength``. The clients' Ψ representations are NOT re-extracted —
    like the real system, the server only learns about drift through the
    training signal.
    """
    t: int
    cids: Optional[Tuple[int, ...]] = None
    strength: float = 0.05


@dataclasses.dataclass(frozen=True)
class Delay:
    """Async report-back latency at round ``t`` (``simulate(...,
    async_mode=True)`` only): the cohort members in ``cids`` (``None`` =
    the whole cohort) return their trained contribution ``rounds``
    rounds late — the delta sits in the engine's ``AsyncBuffer`` and
    merges at its arrival flush with weight ``count · γ^staleness``.
    Delays accumulate with Straggle-induced latency in the same round.
    """
    t: int
    rounds: int = 1
    cids: Optional[Tuple[int, ...]] = None


@dataclasses.dataclass(frozen=True)
class Availability:
    """Client ``cid`` is only available for sampling in rounds
    ``start <= t < end``. A client with no window is always available; a
    client with several is available inside any of them.
    """
    cid: int
    start: int
    end: int


_KINDS = {"join": Join, "leave": Leave, "straggle": Straggle,
          "drift": Drift, "availability": Availability, "delay": Delay}


def to_dict(ev) -> dict:
    """Serialize an event to a plain JSON-able dict (``kind`` + fields)."""
    kind = type(ev).__name__.lower()
    if kind not in _KINDS:
        raise TypeError(f"not a simulator event: {ev!r}")
    d = dataclasses.asdict(ev)
    if kind == "join":
        if d.pop("batch", None) is not None:
            raise ValueError("Join events carrying an in-memory batch "
                             "cannot be serialized; use cluster= instead")
    if kind in ("drift", "delay") and d["cids"] is not None:
        d["cids"] = list(d["cids"])
    return {"kind": kind, **{k: v for k, v in d.items() if v is not None}}


def event_from_dict(d: dict):
    """Inverse of ``to_dict``: build the typed event a trace row names."""
    d = dict(d)
    kind = d.pop("kind")
    if kind not in _KINDS:
        raise ValueError(f"unknown event kind {kind!r} "
                         f"(expected one of {sorted(_KINDS)})")
    if kind in ("drift", "delay") and d.get("cids") is not None:
        d["cids"] = tuple(int(c) for c in d["cids"])
    return _KINDS[kind](**d)
