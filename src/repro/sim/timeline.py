"""Event timelines: what happens to the federation, round by round.

A ``Timeline`` is an immutable schedule of typed events
(``repro.sim.events``) plus availability windows. Three ways to build
one:

  explicit      ``Timeline([Join(t=3, cluster=1), Leave(t=5, cid=7)])``
  stochastic    ``Timeline.from_poisson(rounds=50, join_rate=2.0,
                leave_rate=1.5, n_clusters=4, seed=0)`` — Poisson
                arrivals/departures, the standard open-system churn model
  trace file    ``Timeline.from_trace("churn.json")`` — replayable JSON,
                written by ``to_trace`` (schema documented there)

``Timeline.from_spec`` parses the ``train.py --churn`` mini-language:
either a path to a trace file, or ``"join=2.0,leave=1.5,straggle=0.1"``
key=value pairs forwarded to ``from_poisson``.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

from repro.sim.events import (Availability, Drift, Join, Leave, Straggle,
                              event_from_dict, to_dict)


class Timeline:
    """An immutable, per-round schedule of federation events.

    ``events``: any iterable of event dataclasses (rounds need not be
    contiguous or sorted — they are bucketed by ``t``); ``windows``:
    ``Availability`` constraints. The simulator asks ``at(t)`` for the
    round's events and ``unavailable(t)`` for the clients it must not
    sample that round.
    """

    def __init__(self, events: Iterable = (), windows: Sequence[Availability] = ()):
        self._by_round: Dict[int, List] = {}
        n = 0
        for ev in events:
            if isinstance(ev, Availability):
                raise TypeError("Availability is a window, not a round "
                                "event — pass it via windows=")
            self._by_round.setdefault(int(ev.t), []).append(ev)
            n += 1
        self._n_events = n
        self.windows: Tuple[Availability, ...] = tuple(windows)

    # --------------------------------------------------------------- views
    def at(self, t: int) -> tuple:
        """Events firing at round ``t`` (in insertion order)."""
        return tuple(self._by_round.get(int(t), ()))

    def unavailable(self, t: int) -> frozenset:
        """Cids whose availability windows exclude round ``t``. Clients
        with no window are never in this set."""
        windowed: Dict[int, bool] = {}
        for w in self.windows:
            ok = windowed.get(w.cid, False) or (w.start <= t < w.end)
            windowed[w.cid] = ok
        return frozenset(cid for cid, ok in windowed.items() if not ok)

    @property
    def horizon(self) -> int:
        """Last round anything happens (max event ``t`` / window end)."""
        ts = list(self._by_round) + [w.end - 1 for w in self.windows]
        return max(ts) if ts else 0

    def __len__(self) -> int:
        return self._n_events

    def events(self) -> list:
        """All events, ordered by round then insertion order."""
        return [ev for t in sorted(self._by_round)
                for ev in self._by_round[t]]

    def counts(self) -> Dict[str, int]:
        """{event kind: count} — the quick shape of a churn schedule."""
        out: Dict[str, int] = {}
        for ev in self.events():
            k = type(ev).__name__.lower()
            out[k] = out.get(k, 0) + 1
        return out

    def __repr__(self) -> str:
        kinds = ", ".join(f"{k}={v}" for k, v in sorted(self.counts().items()))
        return (f"Timeline(horizon={self.horizon}, {kinds or 'empty'}, "
                f"windows={len(self.windows)})")

    # ------------------------------------------------------------ builders
    @classmethod
    def from_poisson(cls, rounds: int, join_rate: float = 0.0,
                     leave_rate: float = 0.0, straggle: float = 0.0,
                     drift_every: int = 0, drift_strength: float = 0.05,
                     n_clusters: int = 0, seed: int = 0,
                     start: int = 1) -> "Timeline":
        """Open-system stochastic churn: per round ``t >= start``, the
        number of joins ~ Poisson(``join_rate``) and departures ~
        Poisson(``leave_rate``) — arrivals get a uniform latent
        ``cluster`` in ``[0, n_clusters)`` (0 leaves it unset), departures
        pick their victim at simulation time. ``straggle`` > 0 adds a
        per-round dropout event at that rate; ``drift_every`` > 0 drifts
        every live client's data each that-many rounds. Deterministic in
        ``seed``. ``start`` defaults to 1 so round 0 can onboard the
        initial federation undisturbed.
        """
        rng = np.random.default_rng(seed)
        evs: List = []
        for t in range(start, rounds):
            for _ in range(int(rng.poisson(join_rate))):
                cluster = int(rng.integers(n_clusters)) if n_clusters else None
                evs.append(Join(t=t, cluster=cluster))
            for _ in range(int(rng.poisson(leave_rate))):
                evs.append(Leave(t=t))
            if straggle > 0:
                evs.append(Straggle(t=t, rate=float(straggle)))
            if drift_every > 0 and t % drift_every == 0:
                evs.append(Drift(t=t, strength=float(drift_strength)))
        return cls(evs)

    @classmethod
    def from_trace(cls, path: str) -> "Timeline":
        """Load a JSON trace written by ``to_trace``."""
        with open(path) as f:
            doc = json.load(f)
        events = [event_from_dict(d) for d in doc.get("events", [])]
        windows = [Availability(int(c), int(s), int(e))
                   for c, s, e in doc.get("windows", [])]
        return cls(events, windows)

    def to_trace(self, path: str) -> None:
        """Write the replayable JSON trace: ``{"events": [{"kind": ...,
        "t": ..., ...}, ...], "windows": [[cid, start, end], ...]}`` —
        the schema ``from_trace`` reads and EXPERIMENTS.md documents."""
        doc = {"events": [to_dict(ev) for ev in self.events()],
               "windows": [[w.cid, w.start, w.end] for w in self.windows]}
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)

    @classmethod
    def from_spec(cls, spec: str, rounds: int, seed: int = 0,
                  n_clusters: int = 0) -> "Timeline":
        """Parse the ``train.py --churn`` argument: a trace-file path, or
        ``key=value`` pairs (``join``, ``leave``, ``straggle``,
        ``drift_every``, ``drift_strength``, ``seed``, ``start``)
        forwarded to ``from_poisson`` — e.g.
        ``--churn join=2.0,leave=1.5,straggle=0.1``."""
        if os.path.exists(spec):
            return cls.from_trace(spec)
        kw: Dict[str, float] = {}
        for part in spec.split(","):
            if not part.strip():
                continue
            if "=" not in part:
                raise ValueError(f"bad --churn component {part!r} "
                                 "(expected key=value or a trace path)")
            k, v = part.split("=", 1)
            kw[k.strip()] = float(v)
        kw.setdefault("seed", seed)
        kw.setdefault("n_clusters", n_clusters)
        rename = {"join": "join_rate", "leave": "leave_rate"}
        kw = {rename.get(k, k): v for k, v in kw.items()}
        for k in ("seed", "n_clusters", "drift_every", "start"):
            if k in kw:
                kw[k] = int(kw[k])
        return cls.from_poisson(rounds=rounds, **kw)
