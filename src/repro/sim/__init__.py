"""repro.sim — event-driven dynamic-federation simulator (paper §5).

The engine (``repro.engine``) gives the churn *primitives* — pure
``join`` / ``leave`` / ``infer`` transitions and an arena that grows and
compacts — and this package drives them over time: a ``Timeline`` of
typed events (``Join``, ``Leave``, ``Straggle``, ``Drift``, ``Delay``,
``Availability`` windows) generated stochastically
(``Timeline.from_poisson``), replayed from a JSON trace
(``Timeline.from_trace``), or written explicitly, and a
``simulate(state, timeline, rounds)`` loop that interleaves events with
``engine.run_round`` while recording the §5 joined-client accuracy
trajectory. See ``docs/ARCHITECTURE.md`` for where this layer sits.
"""
from repro.sim.events import (Availability, Delay, Drift, Join,  # noqa: F401
                              Leave, Straggle, event_from_dict, to_dict)
from repro.sim.simulate import (SimLog, routed_accuracy,  # noqa: F401
                                routed_model, simulate)
from repro.sim.timeline import Timeline  # noqa: F401

__all__ = [
    "Availability", "Delay", "Drift", "Join", "Leave", "Straggle", "Timeline",
    "SimLog", "simulate", "routed_model", "routed_accuracy",
    "event_from_dict", "to_dict",
]
