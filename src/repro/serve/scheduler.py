"""Request scheduler: per-cluster FIFO queues + a host mirror of every
slot's emit budget.

The scheduler owns NO device state — it is the pure-host bookkeeping
half of the serving engine. Each routed cluster group gets a FIFO queue
and a free-slot list; ``next_group`` carves the head of a queue into an
admissible prefill group (equal prompt length, at most the free-slot
count); ``occupy``/``release`` track lane ownership.

The host mirror is what makes the data plane sync-free: greedy decode
with a known ``gen`` budget finishes at a PREDICTABLE step, so the
scheduler counts each active slot's remaining tokens down host-side
(``tick``) and knows exactly when a slot finishes without ever reading a
device array. The only device→host transfer a request causes is its
final ``harvest``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["Request", "SlotScheduler"]


@dataclasses.dataclass
class Request:
    """One serving request: ``rid`` (unique id), ``client_id`` (routing
    -cache key), ``prompt`` (1-D int32 token array), ``gen`` (tokens to
    emit, ≥1, including the prefill's first token), and optionally
    ``history`` — the client's Ψ-routing batch, required only the first
    time a ``client_id`` is seen (reconnects route from the cache)."""
    rid: Any
    client_id: Any
    prompt: np.ndarray
    gen: int
    history: Optional[dict] = None


@dataclasses.dataclass
class _Running:
    req: Request
    remaining: int          # decode steps left (gen - 1 at admission)


class SlotScheduler:
    """Host-side admission + slot bookkeeping for ``clusters × slots``
    lanes. Invariant: every lane is in exactly one of ``free[k]`` or
    ``running[(k, s)]``; queued requests are in ``queues[k]``."""

    def __init__(self, clusters: int, slots: int):
        self.clusters = clusters
        self.slots = slots
        self.queues: List[Deque[Request]] = [deque() for _ in range(clusters)]
        self.free: List[List[int]] = [list(range(slots))
                                      for _ in range(clusters)]
        self.running: Dict[Tuple[int, int], _Running] = {}

    # ---- admission ----------------------------------------------------
    def enqueue(self, k: int, req: Request) -> None:
        """Queue ``req`` on cluster group ``k`` (FIFO)."""
        self.queues[k].append(req)

    def next_group(self, k: int) -> Tuple[List[Request], List[int]]:
        """Carve the next admissible prefill group off queue ``k``:
        the longest head-run of equal-prompt-length requests that fits
        in the free slots (equal lengths keep the grouped prefill
        un-padded and exact; FIFO order is preserved — a different
        prompt length ends the group rather than jumping the queue).
        Returns ``(requests, slot_ids)`` — empty when nothing fits."""
        q, free = self.queues[k], self.free[k]
        if not q or not free:
            return [], []
        plen = len(q[0].prompt)
        group: List[Request] = []
        while q and len(group) < len(free) and len(q[0].prompt) == plen:
            group.append(q.popleft())
        slots = [free.pop(0) for _ in group]
        return group, slots

    def occupy(self, k: int, s: int, req: Request) -> None:
        """Record ``req`` as running on lane ``(k, s)`` with
        ``gen - 1`` decode steps left in its host-mirror counter."""
        self.running[(k, s)] = _Running(req, req.gen - 1)

    # ---- progress -----------------------------------------------------
    def pending(self) -> int:
        """Requests still queued (all clusters)."""
        return sum(len(q) for q in self.queues)

    def min_remaining(self) -> int:
        """Decode steps until the NEXT slot finishes — the burst size
        the engine runs before it re-checks admission. 0 when idle."""
        if not self.running:
            return 0
        return min(r.remaining for r in self.running.values())

    def tick(self, n: int) -> List[Tuple[int, int, Request]]:
        """Advance the host mirror by ``n`` decode steps and return the
        lanes that finished — the engine harvests exactly these. No
        device reads: the mirror alone decides completion."""
        done = []
        for (k, s), r in list(self.running.items()):
            r.remaining -= n
            if r.remaining <= 0:
                done.append((k, s, r.req))
        return done

    def release(self, k: int, s: int) -> None:
        """Return lane ``(k, s)`` to the free list (free-on-finish)."""
        self.running.pop((k, s), None)
        self.free[k].append(s)

    def find(self, rid: Any) -> Optional[Tuple[int, int]]:
        """Locate the lane running request ``rid`` (None if not
        running — queued or already finished)."""
        for (k, s), r in self.running.items():
            if r.req.rid == rid:
                return (k, s)
        return None

    def emitted(self, k: int, s: int) -> int:
        """Tokens lane ``(k, s)`` has emitted so far, from the host
        mirror (``gen - remaining``) — what an eviction harvests."""
        r = self.running[(k, s)]
        return r.req.gen - r.remaining
