"""Sequential serving baseline — the FIXED version of the legacy
``launch/serve.py`` request loop.

One request at a time, but with the serve.py bug backlog repaired so
the continuous-batching speedup measured against it is real batching
win, not bug tax:

- **no per-request cache allocation** — ONE decode cache template is
  allocated at construction and recycled through every request (the
  prefill prefix is embedded by a jitted donated ``dynamic_update_slice``
  — stale suffix from the previous request is dead: attention reads are
  masked to the live prefix and decode writes each position before
  attending to it, SSM/conv state is fully overwritten);
- **no per-token host sync** — tokens accumulate in an on-device output
  buffer inside the jitted step (the old loop's ``int(tok[0])`` forced a
  device→host round trip per token); each request does exactly ONE
  device→host transfer, at the end;
- routing goes through the same cached ``Router`` as the batched engine.

``benchmarks/serve_bench.py`` times this loop against ``ServeEngine`` —
same model, same routes, same token budget — so the BENCH_serve numbers
isolate continuous batching itself.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.engine import RequestResult
from repro.serve.router import Router
from repro.serve.scheduler import Request
from repro.serve.slots import make_prefill

__all__ = ["SequentialLoop"]


class SequentialLoop:
    """One-request-at-a-time greedy serving over a ``ServerState``,
    with the cache template, the output buffer, and all three jitted
    programs (prefill, embed, step) hoisted out of the request loop.
    ``serve(req)`` routes, prefills, decodes ``req.gen`` tokens, and
    returns a ``RequestResult`` after a single device→host transfer."""

    def __init__(self, model, state, max_len: int, max_gen: int):
        self.model = model
        self.state = state
        self.max_len = max_len
        self.max_gen = max_gen
        self.router = Router(state)
        self._prefill = make_prefill(model)
        # the ONE decode cache (batch 1) + output buffer, recycled
        # (donated) through every request
        self._template = model.make_cache(1, max_len)
        self._out = jnp.zeros((max_gen,), jnp.int32)

        def seq_embed_impl(template, got):
            return jax.tree.map(
                lambda f, g: jax.lax.dynamic_update_slice(
                    f, g.astype(f.dtype), (0,) * f.ndim),
                template, got)

        def seq_step_impl(params, tok, cache, pos, out, i):
            logits, cache = model.decode(params, tok, cache, pos)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            return nxt, cache, out.at[i].set(nxt[0])

        self._embed = jax.jit(seq_embed_impl, donate_argnums=(0,))
        self._step = jax.jit(seq_step_impl, donate_argnums=(2, 4))
        self.n_requests = 0
        self.n_tokens = 0

    def serve(self, req: Request) -> RequestResult:
        """Serve one request to completion (greedy, ``req.gen`` tokens
        including the prefill's first token)."""
        P = len(req.prompt)
        if req.gen < 1 or req.gen > self.max_gen:
            raise ValueError(f"gen={req.gen} outside [1, {self.max_gen}]")
        if P + req.gen - 1 > self.max_len:
            raise ValueError(f"prompt {P} + gen {req.gen} - 1 exceeds "
                             f"max_len={self.max_len}")
        rt = self.router.route(req.client_id, req.history)
        if rt.root is None:
            raise ValueError("no cluster to serve from")
        params = self.state.cluster_model(rt.root)

        batch = {"tokens": jnp.asarray(
            np.asarray(req.prompt, np.int32)[None])}
        tok, got = self._prefill(params, batch)
        cache = self._embed(self._template, got)
        out = self._out.at[0].set(tok[0])
        for i in range(1, req.gen):
            tok, cache, out = self._step(params, tok, cache,
                                         jnp.int32(P + i - 1), out,
                                         jnp.int32(i))
        # recycle the live buffers for the next request
        self._template, self._out = cache, out
        row = np.asarray(jax.device_get(out))[:req.gen]
        self.n_requests += 1
        self.n_tokens += req.gen
        return RequestResult(rid=req.rid, cluster=rt.root,
                             similarity=rt.similarity, accepted=rt.accepted,
                             tokens=row)
