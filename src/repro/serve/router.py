"""Ψ-routing with a per-client routing cache.

StoCFL §4.4 serving routes an unseen client to the nearest cluster by
Ψ-cosine and serves that cluster's personalized model. Routing costs a
gradient-based Ψ extraction over the client's history — far too much to
pay per request — so the ``Router`` computes it ONCE per client and
caches the decision: a reconnecting client hits the cache and goes
straight to its cluster's queue; only genuinely new clients run the
extractor, and those run BATCHED through ``engine.infer_batch`` (one
vmapped Ψ pass + one ``(J, K̃)`` similarity matmul for the whole
admission wave, instead of J sequential ``engine.infer`` calls).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro import engine as _engine

__all__ = ["Route", "Router"]


@dataclasses.dataclass(frozen=True)
class Route:
    """A routing decision for one client: ``root`` is the cluster the
    client is served from (§4.4: the τ-accepted cluster when similarity
    clears ``tau``, else still the nearest root — serving always picks
    SOME personalized model), ``similarity`` the Ψ-cosine against that
    cluster's mean, ``accepted`` whether it cleared τ (below-τ clients
    are served best-effort from the nearest cluster, exactly like
    ``engine.infer``'s ``seed_from``)."""
    root: Optional[int]
    similarity: float
    accepted: bool


class Router:
    """Per-client route cache over ``engine.infer`` / ``infer_batch``.

    ``route(client_id, history)`` returns the cached ``Route`` when the
    client has been seen (``history`` may then be ``None``);
    ``route_many`` routes a whole admission wave, running the Ψ
    extractor only for the cache misses — in one batched call.
    ``hits``/``misses`` count cache behavior for the serve stats."""

    def __init__(self, state):
        self.state = state
        self._cache: Dict[Any, Route] = {}
        self.hits = 0
        self.misses = 0

    @staticmethod
    def _to_route(inf: dict) -> Route:
        root = inf["cluster"] if inf["cluster"] is not None else inf["seed_from"]
        return Route(root=root, similarity=float(inf["similarity"]),
                     accepted=inf["cluster"] is not None)

    def route(self, client_id, history=None) -> Route:
        """Route one client: cache hit returns instantly; a miss runs
        ``engine.infer`` on ``history`` and caches the decision."""
        return self.route_many([(client_id, history)])[0]

    def route_many(self, items: Sequence[Tuple[Any, Any]]) -> List[Route]:
        """Route ``[(client_id, history), ...]``: cached clients are
        served from the cache; the misses (which MUST carry a history
        batch) go through ONE ``engine.infer_batch`` call."""
        routes: List[Optional[Route]] = []
        miss_idx, miss_hist = [], []
        for i, (cid, hist) in enumerate(items):
            cached = self._cache.get(cid)
            if cached is not None:
                self.hits += 1
                routes.append(cached)
                continue
            if hist is None:
                raise ValueError(
                    f"client {cid!r} has no cached route and no history "
                    "batch to route on")
            self.misses += 1
            routes.append(None)
            miss_idx.append(i)
            miss_hist.append(hist)
        if miss_idx:
            for i, inf in zip(miss_idx,
                              _engine.infer_batch(self.state, miss_hist)):
                r = self._to_route(inf)
                self._cache[items[i][0]] = r
                routes[i] = r
        return routes
