"""Fixed-slot decode state: the serving engine's preallocated data plane.

One ``DecodeSlots`` pytree holds EVERYTHING the decode loop touches — a
(clusters × slots_per_cluster) grid of KV/SSM cache lanes allocated once
at engine construction (``alloc_slots``, shaped by
``models.registry.serve_cache_specs``), plus per-slot bookkeeping
(last token, context length, active mask, emit budget) and a device
output buffer tokens land in as they are generated. Three jitted
transitions move requests through it:

- ``make_prefill``   — grouped prefill: one forward over a cluster's
  admission batch, returning first tokens + the prefill cache.
- ``make_insert``    — admit: copy request ``j`` of a prefill group into
  lane ``(k, s)`` (``dynamic_update_slice`` into the slot cache's
  ``[0, prompt_len)`` prefix — attention caches overwrite their prefix,
  SSM/conv states overwrite entirely) and arm the slot's counters.
  ``j``/``k``/``s``/lengths are traced operands, so ONE compiled insert
  serves every slot at a given group shape.
- ``make_decode_step`` — the single decode transition: every active slot
  across every cluster group advances one token in one XLA program.
  Heterogeneous cluster models batch as a cluster-axis ``vmap`` over the
  stacked params; heterogeneous per-slot positions batch as a slot-axis
  ``vmap`` over each model's scalar-``pos`` ``decode`` (the
  ``dynamic_update_slice`` at a traced position lowers to a batched
  scatter). Generated tokens are written into the on-device ``out``
  buffer — NO per-token host sync; ``harvest`` transfers a finished
  slot's row to the host exactly once per request.

Inactive lanes still execute (fixed shapes are the point) but their
bookkeeping is masked and their cache writes land at their frozen final
position, which a reused slot's insert+decode never reads: attention
reads are masked to ``[0, pos]`` and every decode writes position ``pos``
before attending to it, so a recycled lane's stale suffix is dead by
construction.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import serve_cache_specs

__all__ = ["DecodeSlots", "alloc_slots", "make_decode_step", "make_insert",
           "make_prefill", "harvest"]


class DecodeSlots(NamedTuple):
    """The serving engine's device-resident decode state (a pytree).

    ``caches`` leaves are ``(K, ...) = (clusters,) + make_cache(slots,
    max_len).shape`` — cluster k's slot s is the cache's own batch lane
    ``[k, :, s]``. The bookkeeping grids are ``(K, slots)``: ``token``
    (last emitted token, the next decode input), ``pos`` (tokens already
    cached — the absolute position the next decode writes), ``active``
    (slot is mid-generation), ``remaining`` (tokens still to emit),
    ``emitted`` (tokens emitted so far, = the next ``out`` column).
    ``out`` is the ``(K, slots, max_gen)`` device output buffer."""
    caches: Any
    token: jnp.ndarray
    pos: jnp.ndarray
    active: jnp.ndarray
    remaining: jnp.ndarray
    emitted: jnp.ndarray
    out: jnp.ndarray


def alloc_slots(model, clusters: int, slots: int, max_len: int,
                max_gen: int) -> DecodeSlots:
    """Allocate the fixed-slot decode state ONCE: zeroed cache lanes for
    ``clusters × slots`` concurrent requests of context budget
    ``max_len`` and emit budget ``max_gen`` (shapes from
    ``registry.serve_cache_specs``). Everything after this is
    insert-on-admit / free-on-finish — no per-request allocation."""
    specs = serve_cache_specs(model, clusters, slots, max_len)
    caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs)

    # distinct buffers per field — donation forbids aliased operands
    def z():
        return jnp.zeros((clusters, slots), jnp.int32)

    return DecodeSlots(caches=caches, token=z(), pos=z(),
                       active=jnp.zeros((clusters, slots), bool),
                       remaining=z(), emitted=z(),
                       out=jnp.zeros((clusters, slots, max_gen), jnp.int32))


def make_prefill(model):
    """Jitted grouped prefill: ``(params, batch) -> (first tokens (B,),
    prefill cache)``. The greedy first token is taken on device so the
    admission path never syncs; XLA's jit cache keys on the (bucketed)
    group shape, so steady-state admissions compile nothing."""
    def serve_prefill_impl(params, batch):
        logits, cache = model.prefill(params, batch)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return jax.jit(serve_prefill_impl)


def make_insert(model):
    """Build the jitted admit transition: copy request ``j`` of a
    prefill group into lane ``(k, s)`` and arm the slot.

    ``j``, ``k``, ``s``, ``prompt_len`` and ``gen`` are traced int32
    operands — one compiled program per prefill-group shape covers every
    slot. The slot's caches take the prefill prefix via
    ``dynamic_update_slice`` at the lane origin (attention leaves
    overwrite ``[0, prompt_len)`` of the seq axis; SSM state/conv leaves
    overwrite their full extent), ``out[k, s, 0]`` takes the prefill's
    greedy token, and the counters start at ``pos = prompt_len``,
    ``emitted = 1``, ``remaining = gen - 1``. The previous slots value is
    donated — admission recycles the lane buffers in place."""
    def serve_insert_impl(sl: DecodeSlots, gcache, gtok, j, k, s,
                          prompt_len, gen):
        cache_j = jax.tree.map(
            lambda x: jax.lax.dynamic_index_in_dim(x, j, axis=1,
                                                   keepdims=False), gcache)
        tok_j = jax.lax.dynamic_index_in_dim(gtok, j, axis=0, keepdims=False)

        def put(full, got):
            src = jnp.expand_dims(jnp.expand_dims(got, 0), 2)
            start = (k, 0, s) + (0,) * (full.ndim - 3)
            return jax.lax.dynamic_update_slice(full, src.astype(full.dtype),
                                                start)

        return DecodeSlots(
            caches=jax.tree.map(put, sl.caches, cache_j),
            token=sl.token.at[k, s].set(tok_j),
            pos=sl.pos.at[k, s].set(prompt_len),
            active=sl.active.at[k, s].set(gen > 1),
            remaining=sl.remaining.at[k, s].set(gen - 1),
            emitted=sl.emitted.at[k, s].set(1),
            out=sl.out.at[k, s, 0].set(tok_j),
        )

    return jax.jit(serve_insert_impl, donate_argnums=(0,))


def make_decode_step(model, donate: bool = True):
    """Build the jitted one-token transition ``(stacked_params, slots)
    -> slots'`` — the serving engine's whole decode data plane as ONE
    XLA program.

    Cluster heterogeneity is a leading-axis ``vmap`` over the stacked
    cluster params (every personalized model advances its own slot
    block); per-slot position heterogeneity is an inner ``vmap`` over
    the model's scalar-``pos`` ``decode`` step, which turns the cache
    update into a batched scatter and the causal mask into a per-lane
    ``valid_len``. Active lanes append their greedy token to ``out`` and
    advance their counters; inactive lanes are masked (their compute is
    discarded — fixed shapes buy zero recompiles). With ``donate`` the
    previous slots value is donated, so the steady-state loop mutates
    the preallocated lanes in place instead of reallocating."""
    def one_slot(params, tok, cache, p):
        cache = jax.tree.map(lambda x: jnp.expand_dims(x, 1), cache)
        logits, nc = model.decode(params, tok[None], cache, p)
        return logits[0], jax.tree.map(lambda x: jnp.squeeze(x, 1), nc)

    slot_lanes = jax.vmap(one_slot, in_axes=(None, 0, 1, 0), out_axes=(0, 1))
    group_lanes = jax.vmap(slot_lanes, in_axes=(0, 0, 0, 0), out_axes=(0, 0))

    def serve_step_impl(stacked_params, sl: DecodeSlots):
        logits, caches = group_lanes(stacked_params, sl.token, sl.caches,
                                     sl.pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        act = sl.active
        k = jnp.arange(act.shape[0])[:, None]
        s = jnp.arange(act.shape[1])[None, :]
        col = jnp.where(act, sl.emitted, 0)
        keep = sl.out[k, s, col]
        adv = act.astype(jnp.int32)
        return DecodeSlots(
            caches=caches,
            token=jnp.where(act, nxt, sl.token),
            pos=sl.pos + adv,
            active=act & (sl.remaining > 1),
            remaining=sl.remaining - adv,
            emitted=sl.emitted + adv,
            out=sl.out.at[k, s, col].set(jnp.where(act, nxt, keep)),
        )

    return jax.jit(serve_step_impl, donate_argnums=(1,) if donate else ())


def harvest(sl: DecodeSlots, k: int, s: int) -> np.ndarray:
    """Pull lane ``(k, s)``'s output row to the host — the request's ONE
    device→host transfer (the caller slices to its known emit count).
    Everything before this point stayed on device."""
    return np.asarray(jax.device_get(sl.out[k, s]))
