"""repro.serve — continuous-batching cluster-routed serving engine.

StoCFL's §4.4 inference surface, productionized: route each client to
its cluster's personalized model ONCE (Ψ-cosine, per-client cache —
``router``), admit requests into a fixed ``clusters × slots`` grid of
preallocated KV/SSM cache lanes (``slots``), and advance every active
lane of every cluster model with ONE jitted decode step per token
(continuous batching: slots free on finish and refill from the queues
mid-flight — ``scheduler`` + ``engine``). ``baseline`` holds the
debugged sequential loop the benchmarks compare against; ``docs/
SERVING.md`` has the scheduler contract and the decode-state memory
model.

    from repro import serve
    eng = serve.ServeEngine(model, state, serve.ServeConfig(slots=8))
    eng.submit_many([serve.Request(rid=i, client_id=c, prompt=p, gen=16,
                                   history=h) for ...])
    results = eng.run()      # {rid: RequestResult}
"""
from repro.serve.baseline import SequentialLoop
from repro.serve.engine import RequestResult, ServeConfig, ServeEngine
from repro.serve.router import Route, Router
from repro.serve.scheduler import Request, SlotScheduler
from repro.serve.slots import (DecodeSlots, alloc_slots, harvest,
                               make_decode_step, make_insert, make_prefill)

__all__ = [
    "ServeEngine", "ServeConfig", "RequestResult",
    "Request", "SlotScheduler",
    "Router", "Route",
    "DecodeSlots", "alloc_slots", "make_decode_step", "make_insert",
    "make_prefill", "harvest",
    "SequentialLoop",
]
