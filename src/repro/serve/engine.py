"""The continuous-batching cluster-routed serving engine.

``ServeEngine`` glues the three layers together: the ``Router`` decides
WHICH cluster serves a client (Ψ-cosine, cached per client), the
``SlotScheduler`` decides WHEN (FIFO admission into a fixed
``clusters × slots`` lane grid, free-on-finish), and the ``DecodeSlots``
transitions from ``serve.slots`` do the work (grouped prefill → jitted
insert → ONE jitted decode step advancing every active lane of every
cluster model together).

The loop shape is continuous batching: admit everything that fits, run
decode bursts exactly until the next slot frees (the scheduler's host
mirror knows when — greedy decode with a fixed ``gen`` budget finishes
deterministically, so no device polling), harvest the finished lanes
(ONE device→host transfer per request), re-admit, repeat. Prefill group
sizes are pow2-bucketed so the steady-state compile set is
O(log slots) per prompt length, not O(requests).

Heterogeneous cluster models are served from ONE decode program: the
per-cluster personalized params are stacked on a leading axis and the
decode step vmaps over it, so a batch window mixes clusters freely —
each lane attends with its own cluster's weights. With a mesh, the
stacked params and the decode state are pinned cluster-major via
``sharding.place_decode_state``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.router import Route, Router
from repro.serve.scheduler import Request, SlotScheduler
from repro.serve.slots import (alloc_slots, harvest, make_decode_step,
                               make_insert, make_prefill)

__all__ = ["ServeConfig", "RequestResult", "ServeEngine"]

_TOKEN_ARCHS = ("dense", "moe", "ssm", "hybrid")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving knobs: ``slots`` concurrent lanes per cluster group,
    ``max_len`` cache context budget per lane (prompt + generated),
    ``max_gen`` output-buffer budget (tokens emitted per request),
    ``bucket`` pads prefill groups to pow2 sizes to bound the compile
    set, ``donate`` donates the decode state through the step so the
    steady-state loop updates the preallocated lanes in place."""
    slots: int = 8
    max_len: int = 128
    max_gen: int = 32
    bucket: bool = True
    donate: bool = True


@dataclasses.dataclass
class RequestResult:
    """What a finished request gets back: the serving ``cluster`` root,
    the routing ``similarity``, ``accepted`` (cleared τ), the emitted
    ``tokens`` (host int32, length ``gen`` — or fewer if ``evicted``)."""
    rid: Any
    cluster: int
    similarity: float
    accepted: bool
    tokens: np.ndarray
    evicted: bool = False


class ServeEngine:
    """Continuous-batching serving over a trained ``ServerState``.

    ``submit``/``submit_many`` route and enqueue requests; ``run``
    drives admission + decode bursts until everything queued has
    finished and returns ``{rid: RequestResult}`` for the requests that
    completed during the call; ``evict`` force-finishes a running
    request (partial tokens, lane freed); ``reset`` drops all lane and
    scheduler state but keeps the compiled programs and the routing
    cache, so a warmup wave pays every compile and the timed wave pays
    none; ``stats`` reports counters (admissions, prefill groups,
    decode steps, router hits/misses)."""

    def __init__(self, model, state, cfg: ServeConfig = ServeConfig(),
                 mesh=None):
        if model.cfg.arch_type not in _TOKEN_ARCHS:
            raise ValueError(
                f"serve engine is token-LM only (dense/moe/ssm/hybrid), "
                f"got arch_type={model.cfg.arch_type!r}")
        window = getattr(model.cfg, "sliding_window", None)
        if window and cfg.max_len > window:
            raise ValueError(
                f"max_len={cfg.max_len} exceeds the model's sliding "
                f"window ({window}); the modular cache layout would wrap")
        if not state.models:
            raise ValueError("ServerState has no cluster models to serve")
        self.model = model
        self.cfg = cfg
        self.mesh = mesh
        self.router = Router(state)
        self.roots = sorted(state.models.keys())
        self._root_to_k = {r: k for k, r in enumerate(self.roots)}
        self._params_list = [state.cluster_model(r) for r in self.roots]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *self._params_list)
        self._prefill = make_prefill(model)
        self._insert = make_insert(model)
        self._step = make_decode_step(model, donate=cfg.donate)
        self.sl = alloc_slots(model, len(self.roots), cfg.slots,
                              cfg.max_len, cfg.max_gen)
        if mesh is not None:
            from repro.sharding import place_decode_state
            stacked = place_decode_state(stacked, mesh)
            self.sl = place_decode_state(self.sl, mesh)
        self._stacked = stacked
        self.sched = SlotScheduler(len(self.roots), cfg.slots)
        self._routes: Dict[Any, Route] = {}
        self.results: Dict[Any, RequestResult] = {}
        self.stats_ = {"admitted": 0, "prefill_groups": 0,
                       "decode_steps": 0, "harvested": 0, "evicted": 0}

    # ---- intake -------------------------------------------------------
    def submit(self, req: Request) -> Route:
        """Route one request and enqueue it on its cluster's queue."""
        return self.submit_many([req])[0]

    def submit_many(self, reqs: List[Request]) -> List[Route]:
        """Route an admission wave (cache misses batched through ONE
        ``engine.infer_batch`` pass) and enqueue every request on its
        routed cluster group's FIFO."""
        for req in reqs:
            if req.gen < 1 or req.gen > self.cfg.max_gen:
                raise ValueError(f"req {req.rid}: gen={req.gen} outside "
                                 f"[1, max_gen={self.cfg.max_gen}]")
            if len(req.prompt) + req.gen - 1 > self.cfg.max_len:
                raise ValueError(
                    f"req {req.rid}: prompt {len(req.prompt)} + gen "
                    f"{req.gen} - 1 exceeds max_len={self.cfg.max_len}")
        routes = self.router.route_many(
            [(r.client_id, r.history) for r in reqs])
        for req, rt in zip(reqs, routes):
            if rt.root is None:
                raise ValueError(
                    f"req {req.rid}: no cluster to serve from "
                    "(empty clustering state)")
            self._routes[req.rid] = rt
            self.sched.enqueue(self._root_to_k[rt.root], req)
        return routes

    # ---- serving loop -------------------------------------------------
    @staticmethod
    def _pow2(n: int) -> int:
        return 1 << (n - 1).bit_length()

    def _admit_all(self) -> None:
        """Fill every free lane: grouped prefill per (cluster, prompt
        length) off the queue heads, pow2-padded, then one jitted
        insert per admitted request."""
        for k in range(len(self.roots)):
            while True:
                group, slot_ids = self.sched.next_group(k)
                if not group:
                    break
                plen = len(group[0].prompt)
                bs = self._pow2(len(group)) if self.cfg.bucket else len(group)
                toks = np.stack(
                    [np.asarray(r.prompt, np.int32) for r in group]
                    + [np.asarray(group[-1].prompt, np.int32)]
                    * (bs - len(group)))
                gtok, gcache = self._prefill(self._params_list[k],
                                             {"tokens": jnp.asarray(toks)})
                for j, (req, s) in enumerate(zip(group, slot_ids)):
                    self.sl = self._insert(
                        self.sl, gcache, gtok, jnp.int32(j), jnp.int32(k),
                        jnp.int32(s), jnp.int32(plen), jnp.int32(req.gen))
                    self.sched.occupy(k, s, req)
                self.stats_["prefill_groups"] += 1
                self.stats_["admitted"] += len(group)

    def _decode_burst(self, n: int) -> None:
        """Run ``n`` jitted decode steps back to back — the sync-free
        inner loop: nothing here touches the host (the sanitizer
        battery runs it under ``sanitize.no_transfer``)."""
        for _ in range(n):
            self.sl = self._step(self._stacked, self.sl)
        self.stats_["decode_steps"] += n

    def _harvest_lane(self, k: int, s: int, req: Request,
                      emitted: int, evicted: bool = False) -> RequestResult:
        rt = self._routes[req.rid]
        row = harvest(self.sl, k, s)[:emitted]
        res = RequestResult(rid=req.rid, cluster=rt.root,
                            similarity=rt.similarity, accepted=rt.accepted,
                            tokens=row, evicted=evicted)
        self.results[req.rid] = res
        self.sched.release(k, s)
        self.stats_["harvested" if not evicted else "evicted"] += 1
        return res

    def run(self) -> Dict[Any, RequestResult]:
        """Drain the queues: admit → decode until the next finish →
        harvest → re-admit, until nothing is queued or running. Returns
        the results that finished during THIS call (also accumulated in
        ``self.results``)."""
        out: Dict[Any, RequestResult] = {}
        while self.sched.pending() or self.sched.running:
            self._admit_all()
            for k, s, req in self.sched.tick(0):      # gen == 1 finishes
                out[req.rid] = self._harvest_lane(k, s, req, req.gen)
            n = self.sched.min_remaining()
            if n == 0:
                continue
            self._decode_burst(n)
            for k, s, req in self.sched.tick(n):
                out[req.rid] = self._harvest_lane(k, s, req, req.gen)
        return out

    def evict(self, rid: Any) -> Optional[RequestResult]:
        """Force-finish request ``rid``: a running request is harvested
        at its current emit count (partial tokens, ``evicted=True``) and
        its lane is deactivated and freed; a queued request is dropped
        with zero tokens. Returns None when ``rid`` is unknown or
        already finished."""
        loc = self.sched.find(rid)
        if loc is not None:
            k, s = loc
            req = self.sched.running[(k, s)].req
            emitted = self.sched.emitted(k, s)
            self.sl = self.sl._replace(
                active=self.sl.active.at[k, s].set(False),
                remaining=self.sl.remaining.at[k, s].set(0))
            return self._harvest_lane(k, s, req, emitted, evicted=True)
        for k, q in enumerate(self.sched.queues):
            for req in list(q):
                if req.rid == rid:
                    q.remove(req)
                    rt = self._routes[req.rid]
                    res = RequestResult(
                        rid=rid, cluster=rt.root, similarity=rt.similarity,
                        accepted=rt.accepted,
                        tokens=np.zeros((0,), np.int32), evicted=True)
                    self.results[rid] = res
                    self.stats_["evicted"] += 1
                    return res
        return None

    def reset(self) -> None:
        """Drop lane + scheduler + result state but KEEP the compiled
        programs and the routing cache — a warmup wave pays every
        compile, then ``reset()`` + the timed wave pays none (the
        serve-bench first-compile separation)."""
        self.sl = alloc_slots(self.model, len(self.roots), self.cfg.slots,
                              self.cfg.max_len, self.cfg.max_gen)
        if self.mesh is not None:
            from repro.sharding import place_decode_state
            self.sl = place_decode_state(self.sl, self.mesh)
        self.sched = SlotScheduler(len(self.roots), self.cfg.slots)
        self._routes = {}
        self.results = {}
        for key in self.stats_:
            self.stats_[key] = 0

    def stats(self) -> dict:
        """Counters for the serve loop + router cache behavior."""
        return dict(self.stats_, router_hits=self.router.hits,
                    router_misses=self.router.misses,
                    clusters=len(self.roots), slots=self.cfg.slots)
