"""StoCFL trainer — DEPRECATED class shim over ``repro.engine``.

New code should use the functional engine API directly:

    from repro import engine
    state = engine.init("stocfl", loss_fn, params, clients,
                        engine.EngineConfig(tau=0.5, lam=0.05), eval_fn=acc)
    state, rec = engine.run_round(state)

This class keeps the original object surface (``.round()``, ``.fit()``,
``.state``, ``.models``, ``.omega``, join/leave/infer) for existing
callers and checkpoints; every method delegates to the engine's pure
transitions, with the ``ServerState`` held as the single source of truth.

Degenerations (paper §3.4): τ=1 → Ditto; τ=−1 → FedProx-family;
λ=0 → conventional CFL; λ=0 ∧ τ=−1 → FedAvg.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

# Module-object import only: repro.engine imports repro.core (clustering,
# bilevel), which imports this shim — binding the module and resolving
# attributes at call time keeps the cycle harmless.
from repro import engine


@dataclasses.dataclass
class StoCFLConfig:
    tau: float = 0.5
    lam: float = 0.05
    lr: float = 0.1
    local_steps: int = 5
    sample_rate: float = 0.1
    project_dim: Optional[int] = None
    seed: int = 0
    aggregator: str = "mean"      # G(·): mean | median | trimmed_mean | krum


class StoCFL:
    """loss_fn(params, batch)->scalar; clients: list of batch dicts
    (equal-shaped local datasets; the cohort update is vmapped)."""

    def __init__(self, loss_fn: Callable, init_params, clients: Sequence[dict],
                 cfg: StoCFLConfig, eval_fn: Optional[Callable] = None,
                 leaf_filter: Optional[Callable] = None):
        self.cfg = cfg
        ecfg = engine.EngineConfig(
            tau=cfg.tau, lam=cfg.lam, lr=cfg.lr, local_steps=cfg.local_steps,
            sample_rate=cfg.sample_rate, seed=cfg.seed,
            aggregator=cfg.aggregator, project_dim=cfg.project_dim)
        self._st = engine.init("stocfl", loss_fn, init_params, clients, ecfg,
                               eval_fn=eval_fn, leaf_filter=leaf_filter)

    # ---------------------------------------------------------- state views
    @property
    def server_state(self) -> engine.ServerState:
        """The underlying engine state (pytree; checkpoint/shard this)."""
        return self._st

    @property
    def omega(self):
        """The global model ω."""
        return self._st.omega

    @omega.setter
    def omega(self, value):
        self._st = self._st.replace(omega=value)

    @property
    def models(self):
        """Cluster models (``ClusterBank``, Mapping-compatible)."""
        return self._st.models

    @models.setter
    def models(self, value):
        from repro.engine.bank import ClusterBank
        self._st = self._st.replace(models=ClusterBank.from_dict(dict(value)))

    @property
    def state(self):
        """The Ψ-clustering bookkeeping (``ClusterState``-shaped)."""
        return self._st.clusters

    @property
    def history(self):
        """Per-round metric records."""
        return list(self._st.history)

    @history.setter
    def history(self, value):
        self._st = self._st.replace(history=tuple(value))

    @property
    def clients(self):
        """The registered client datasets (the context's world)."""
        return self._st.ctx.clients

    @property
    def n(self) -> int:
        """Registered client count (departed included)."""
        return self._st.n_clients

    @property
    def sizes(self) -> np.ndarray:
        """Per-client sample counts (aggregation weights)."""
        return np.asarray(self._st.sizes)

    @property
    def init_params(self):
        """ω₀ — initialization and lazy cluster-model default."""
        return self._st.ctx.init_params

    @property
    def anchor(self):
        """The frozen Ψ anchor ψ = ω₀ (paper §4.2)."""
        return self._st.ctx.init_params

    @property
    def loss_fn(self):
        """The local objective f_i(params, batch) -> scalar."""
        return self._st.ctx.loss_fn

    @property
    def eval_fn(self):
        """Optional accuracy fn used by ``evaluate``."""
        return self._st.ctx.eval_fn

    @property
    def extractor(self):
        """The Ψ distribution extractor (§3.1)."""
        return self._st.ctx.extractor

    # ------------------------------------------------------------- models
    def cluster_model(self, root: int):
        """θ_k for a cluster root (ω₀ until first aggregate)."""
        return self._st.cluster_model(root)

    # ------------------------------------------------------------- rounds
    def round(self, client_ids: Optional[Sequence[int]] = None) -> dict:
        """One server round (sampled cohort unless ``client_ids``)."""
        self._st, rec = engine.run_round(self._st, client_ids)
        return rec

    def fit(self, rounds: int, log_every: int = 0):
        """Run ``rounds`` rounds with optional progress printing."""
        for t in range(rounds):
            rec = self.round()
            if log_every and t % log_every == 0:
                print(f"round {t}: clusters={rec['n_clusters']} obj={rec['objective']:.3f}")
        return self

    # ------------------------------------------------------------- eval
    def client_root(self, cid: int) -> int:
        """Union-find root (= cluster id) of an observed client."""
        return self._st.client_root(cid)

    def evaluate(self, test_sets, true_cluster):
        """Paper §4.2 held-out evaluation via the learned partition."""
        return engine.evaluate(self._st, test_sets, true_cluster)

    # ------------------------------------------------------------- §4.4 / §5
    def join_client(self, batch) -> int:
        """§5 dynamic join (Ψ-inference placement); returns the new id."""
        self._st, cid = engine.join(self._st, batch)
        return cid

    def leave_client(self, cid: int) -> None:
        """§5 departure: stop sampling ``cid``, repair the partition."""
        self._st = engine.leave(self._st, cid)

    def sample_clients(self) -> np.ndarray:
        """Draw one round's cohort (advances the stored rng)."""
        adv, ids = engine.sample_clients(self._st)
        self._st = engine.advance_rng(self._st, adv)
        return ids

    def infer_new_client(self, batch):
        """Cluster inference for a newly-joined client (§4.4)."""
        return engine.infer(self._st, batch)
