"""StoCFL trainer — Algorithm 1 end-to-end (host orchestration).

Simulates the federated system: the sampled cohort's bi-level updates run
as a single vmapped/jitted computation (clients on the leading axis — the
production mesh's client axis), the clustering service consumes Ψ
representations, and cluster-model merges follow partition merges.

Degenerations (paper §3.4): τ=1 → Ditto; τ=−1 → FedProx-family;
λ=0 → conventional CFL; λ=0 ∧ τ=−1 → FedAvg.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bilevel
from repro.core.aggregators import AGGREGATORS
from repro.core.clustering import ClusterState
from repro.core.extractor import make_extractor
from repro.utils import trees


@dataclasses.dataclass
class StoCFLConfig:
    tau: float = 0.5
    lam: float = 0.05
    lr: float = 0.1
    local_steps: int = 5
    sample_rate: float = 0.1
    project_dim: Optional[int] = None
    seed: int = 0
    aggregator: str = "mean"      # G(·): mean | median | trimmed_mean | krum


class StoCFL:
    """loss_fn(params, batch)->scalar; clients: list of batch dicts
    (equal-shaped local datasets; the cohort update is vmapped)."""

    def __init__(self, loss_fn: Callable, init_params, clients: Sequence[dict],
                 cfg: StoCFLConfig, eval_fn: Optional[Callable] = None,
                 leaf_filter: Optional[Callable] = None):
        self.loss_fn = loss_fn
        self.cfg = cfg
        self.clients = list(clients)
        self.n = len(clients)
        self.eval_fn = eval_fn                        # (params, batch) -> metric
        self.rng = np.random.default_rng(cfg.seed)

        self.omega = init_params
        self.init_params = init_params
        self.anchor = init_params                     # ψ = ω₀ (paper §4.2)
        self.state = ClusterState(cfg.tau)
        self.models: Dict[int, object] = {}           # root -> θ_k (lazy: default ω₀)
        self.sizes = np.array([int(np.shape(jax.tree.leaves(c)[0])[0]) for c in clients])

        self.extractor = make_extractor(loss_fn, self.anchor, cfg.project_dim,
                                        leaf_filter=leaf_filter)
        self.cohort_update = bilevel.make_cohort_update(
            loss_fn, cfg.lr, cfg.lam, cfg.local_steps, backend="jnp")
        self.history: List[dict] = []

    # ------------------------------------------------------------- models
    def cluster_model(self, root: int):
        return self.models.get(root, self.init_params)

    def _merge_models(self, merges):
        for keep, absorb in merges:
            m_keep = self.models.pop(keep, self.init_params)
            m_abs = self.models.pop(absorb, self.init_params)
            self.models[keep] = trees.tree_weighted_mean([m_keep, m_abs], [1.0, 1.0])

    # ------------------------------------------------------------- rounds
    def round(self, client_ids: Optional[Sequence[int]] = None) -> dict:
        cfg = self.cfg
        if client_ids is None:
            client_ids = self.sample_clients()
        client_ids = np.asarray(client_ids)

        # --- stochastic client clustering (lines 5-13)
        new_ids = [int(c) for c in client_ids if c not in self.state.seen]
        if new_ids:
            reps = [np.asarray(self.extractor(self.clients[c])) for c in new_ids]
            self.state.observe(new_ids, reps)
        merges = self.state.merge_round()
        if merges:
            self._merge_models(merges)

        # --- bi-level CFL (lines 14-19): one SPMD cohort step
        roots = [self.state.uf.find(int(c)) for c in client_ids]
        thetas = jax.tree.map(lambda *xs: jnp.stack(xs),
                              *[self.cluster_model(r) for r in roots])
        batches = jax.tree.map(lambda *xs: jnp.stack(xs),
                               *[self.clients[int(c)] for c in client_ids])
        thetas_i, omegas_i = self.cohort_update(thetas, self.omega, batches)

        w = self.sizes[client_ids].astype(np.float32)
        self.omega = AGGREGATORS[self.cfg.aggregator](omegas_i, w)

        for root in sorted(set(roots)):
            idx = [i for i, r in enumerate(roots) if r == root]
            sel = jax.tree.map(lambda x: x[np.array(idx)], thetas_i)
            self.models[root] = bilevel.aggregate_stacked(sel, w[np.array(idx)])

        rec = {
            "n_clusters": self.state.n_clusters(),
            "objective": self.state.objective(),
            "sampled": len(client_ids),
        }
        self.history.append(rec)
        return rec

    def fit(self, rounds: int, log_every: int = 0):
        for t in range(rounds):
            rec = self.round()
            if log_every and t % log_every == 0:
                print(f"round {t}: clusters={rec['n_clusters']} obj={rec['objective']:.3f}")
        return self

    # ------------------------------------------------------------- eval
    def client_root(self, cid: int) -> int:
        return self.state.uf.find(int(cid))

    def evaluate(self, test_sets: Dict[int, dict], true_cluster: Sequence[int]):
        """test_sets: true-cluster-id -> batch; true_cluster[i] = ground
        truth cluster of client i. Each true cluster is evaluated with the
        model of the learned cluster holding most of its clients; the
        global model ω is evaluated on everything."""
        assert self.eval_fn is not None
        assign = self.state.assignment()
        out, glob = {}, {}
        for tc, batch in test_sets.items():
            roots = [assign[c] for c in assign if true_cluster[c] == tc]
            if roots:
                root = max(set(roots), key=roots.count)
                model = self.cluster_model(root)
            else:
                model = self.omega
            out[tc] = float(self.eval_fn(model, batch))
            glob[tc] = float(self.eval_fn(self.omega, batch))
        return {"cluster": out, "cluster_avg": float(np.mean(list(out.values()))),
                "global": glob, "global_avg": float(np.mean(list(glob.values())))}

    # ------------------------------------------------------------- §4.4 / §5
    def join_client(self, batch) -> int:
        """Dynamic join (paper §5 future work): register a new client,
        infer its cluster via Ψ (or open a fresh cluster seeded from the
        nearest), and include it in future sampling rounds."""
        cid = self.n
        self.clients.append(batch)
        self.n += 1
        self.sizes = np.append(self.sizes,
                               int(np.shape(jax.tree.leaves(batch)[0])[0]))
        rep = np.asarray(self.extractor(batch))
        # infer against the PRE-EXISTING clusters, then register
        root, sim = self.state.infer(rep) if self.state.reps else (None, 0.0)
        if root is None and self.state.reps:
            roots, means = self.state.cluster_means()
            near = roots[int(np.argmax(
                (means / (np.linalg.norm(means, axis=1, keepdims=True) + 1e-12))
                @ (rep / (np.linalg.norm(rep) + 1e-12))))]
        else:
            near = root
        self.state.observe([cid], [rep])
        if root is not None:
            keep, absorb = min(root, cid), max(root, cid)
            self.state.uf.union(keep, absorb)
            # cid inherits the cluster model (no merge needed: cid had none)
        elif near is not None:
            # opens a new cluster, seeded from the nearest cluster's model
            self.models[self.state.uf.find(cid)] = self.cluster_model(near)
        return cid

    def leave_client(self, cid: int) -> None:
        """Dynamic leave: drop the client's Ψ from the clustering state;
        its cluster keeps its model (knowledge persists, §5)."""
        self.state.reps.pop(cid, None)
        self.state.seen.discard(cid)
        self._left = getattr(self, "_left", set())
        self._left.add(int(cid))

    def sample_clients(self) -> np.ndarray:
        m = max(int(round(self.cfg.sample_rate * self.n)), 1)
        left = getattr(self, "_left", set())
        pool = np.array([i for i in range(self.n) if i not in left])
        return self.rng.choice(pool, size=min(m, len(pool)), replace=False)

    # ------------------------------------------------------------- §4.4
    def infer_new_client(self, batch):
        """Cluster inference for a newly-joined client (§4.4)."""
        rep = np.asarray(self.extractor(batch))
        root, sim = self.state.infer(rep)
        if root is None:
            # new cluster seeded from the nearest cluster's model
            roots, means = self.state.cluster_means()
            near = roots[int(np.argmax(
                (means / (np.linalg.norm(means, axis=1, keepdims=True) + 1e-12))
                @ (rep / (np.linalg.norm(rep) + 1e-12))))]
            return {"cluster": None, "seed_from": near, "similarity": sim,
                    "model": self.cluster_model(near)}
        return {"cluster": root, "seed_from": root, "similarity": sim,
                "model": self.cluster_model(root)}
