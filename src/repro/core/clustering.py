"""Stochastic federated client clustering (paper §3.2, Algorithm 1 l.4-13).

Server-side state over client distribution representations Ψ(D_i):
  - partition C (union-find over client ids), initially singletons;
  - per round: observe Ψ of newly-participating clients, recompute cluster
    mean representations, build the pairwise cosine matrix M (Pallas
    ``cosine_sim`` kernel on TPU), greedily merge every pair with
    M_ij ≥ τ (transitively, via union-find);
  - objective (Eq. 2): Σ_{i<j} cos(Ψ̃_i, Ψ̃_j) — decreases as merging
    removes similar pairs;
  - new-client inference (§4.4): nearest cluster if best cosine ≥ τ, else
    a fresh cluster seeded from the nearest cluster's model.

This is plain host-side logic (numpy); only the similarity matrix is a
device computation. It is the reference implementation and the shimmed
FALLBACK: ``core.device_clustering`` runs the same partition semantics
as jitted device transitions (``EngineConfig.cluster_backend="device"``),
and the parity battery in ``tests/test_device_clustering.py`` holds the
two to the same answers.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.kernels import ops


class UnionFind:
    """Host union-find over client ids (path-halving find, smaller-root-
    wins union — the semantics the device pointer-halving kernel
    mirrors, see ``kernels.ops.resolve_roots``)."""

    def __init__(self):
        self.parent: Dict[int, int] = {}

    def add(self, i: int):
        """Register ``i`` as a singleton (no-op when already present)."""
        self.parent.setdefault(i, i)

    def find(self, i: int) -> int:
        """Root of ``i``'s cluster, compressing the path as it walks."""
        p = self.parent
        while p[i] != i:
            p[i] = p[p[i]]
            i = p[i]
        return i

    def union(self, a: int, b: int) -> bool:
        """Merge a's and b's clusters; returns True when they were
        distinct. The smaller root id always wins, so every root is its
        cluster's minimum member id (an invariant ``remove`` and the
        device backend both rely on)."""
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return False
        if rb < ra:
            ra, rb = rb, ra
        self.parent[rb] = ra          # deterministic: smaller id wins
        return True


class ClusterState:
    """The StoCFL server's clustering bookkeeping."""

    def __init__(self, tau: float):
        self.tau = float(tau)
        self.uf = UnionFind()
        self.reps: Dict[int, np.ndarray] = {}       # client id -> Ψ(D_i)
        self.seen: set = set()                      # P in Algorithm 1

    def copy(self) -> "ClusterState":
        """Shallow-structural copy (reps arrays shared — they are never
        mutated in place). Lets the engine's pure transitions fork the
        clustering bookkeeping without touching the input state."""
        new = ClusterState(self.tau)
        new.uf.parent = dict(self.uf.parent)
        new.reps = dict(self.reps)
        new.seen = set(self.seen)
        return new

    # ------------------------------------------------------------- observe
    def observe(self, client_ids: Sequence[int], reps) -> List[int]:
        """Record Ψ for newly-seen clients. Returns the new ids."""
        new = []
        for cid, rep in zip(client_ids, reps):
            self.uf.add(int(cid))
            if cid not in self.seen:
                self.reps[int(cid)] = np.asarray(rep, dtype=np.float32)
                self.seen.add(int(cid))
                new.append(int(cid))
        return new

    # ------------------------------------------------------------- views
    def clusters(self) -> Dict[int, List[int]]:
        """root -> sorted member client ids (only observed clients)."""
        out: Dict[int, List[int]] = {}
        for cid in sorted(self.reps):
            out.setdefault(self.uf.find(cid), []).append(cid)
        return out

    def cluster_means(self) -> Tuple[List[int], np.ndarray]:
        """Ψ̃ per cluster: (roots, (K̃, D) matrix of member means).

        Vectorized (segment-sum over the stacked rep matrix) — the
        per-cluster Python mean loop was O(N) host work per round, a wall
        when thousands of singletons arrive in round 1."""
        cids = sorted(self.reps)
        per = np.fromiter((self.uf.find(c) for c in cids), np.int64, len(cids))
        roots, inv = np.unique(per, return_inverse=True)
        R = np.stack([self.reps[c] for c in cids])
        mat = np.zeros((len(roots), R.shape[1]), np.float32)
        np.add.at(mat, inv, R)
        mat /= np.bincount(inv).astype(np.float32)[:, None]
        return [int(r) for r in roots], mat

    def assignment(self) -> Dict[int, int]:
        """{client id: cluster root} over observed clients."""
        return {cid: self.uf.find(cid) for cid in self.reps}

    def n_clusters(self) -> int:
        """Current cluster count K̃."""
        return len(self.clusters())

    # ------------------------------------------------------------- merging
    def similarity_matrix(self, pad_to: int = 64) -> Tuple[List[int], np.ndarray]:
        """(roots, K̃×K̃ cosine matrix over cluster means).

        The device computation is padded to a multiple of ``pad_to`` rows
        (zero rows: norm-guarded to similarity 0, sliced off before
        return). Under churn (§5) the cluster count drifts every round,
        and an exact-shape kernel would recompile per K̃ — quantizing the
        shape bounds the compile set the same way the TPU Pallas kernel's
        internal 128-padding already does."""
        roots, means = self.cluster_means()
        k = len(roots)
        if pad_to and k % pad_to:
            kp = -(-k // pad_to) * pad_to
            means = np.concatenate(
                [means, np.zeros((kp - k, means.shape[1]), means.dtype)])
        M = np.asarray(ops.pairwise_cosine(means))
        if M.shape[0] > k and (M[k:, :].any() or M[:k, k:].any()):
            # pad rows are zero-Ψ ghosts whose similarities must be
            # exact 0 — the kernels' norm guard makes them so, the
            # cos(0,0) diagonal included. Should a kernel/guard change
            # ever leak nonzero similarity into the pad block, scrub it
            # here so no scan (this class's or a caller keeping the
            # padded matrix) can turn a ghost into an off-by-pad merge.
            M = M.copy()                     # device output is read-only
            M[k:, :] = 0.0
            M[:, k:] = 0.0
        M = M[:k, :k]
        return roots, M

    def merge_round(self) -> List[Tuple[int, int]]:
        """One greedy merge pass (Algorithm 1, lines 10-13).

        Returns the list of (root_kept, root_absorbed) merges actually
        performed — the trainer uses it to merge cluster models."""
        if len(self.reps) < 2:
            return []
        roots, M = self.similarity_matrix()
        # vectorized pair scan: threshold the whole matrix at once, then
        # union only the qualifying pairs in the same row-major order the
        # original O(K̃²) Python loop visited them (merge list unchanged).
        iu, ju = np.nonzero(np.triu(M >= self.tau, k=1))
        merges = []
        for i, j in zip(iu.tolist(), ju.tolist()):
            ra, rb = self.uf.find(roots[i]), self.uf.find(roots[j])
            if ra != rb:
                keep, absorb = min(ra, rb), max(ra, rb)
                self.uf.union(keep, absorb)
                merges.append((keep, absorb))
        return merges

    # ------------------------------------------------------------- metrics
    def objective(self) -> float:
        """Eq. 2: Σ_{i<j} cos(Ψ̃^{(i)}, Ψ̃^{(j)}) over current clusters."""
        if self.n_clusters() < 2:
            return 0.0
        _, M = self.similarity_matrix()
        iu = np.triu_indices(M.shape[0], k=1)
        return float(np.sum(M[iu]))

    # ------------------------------------------------------------- departure
    def remove(self, cid: int) -> Dict[int, int]:
        """Drop a departed client from reps/seen AND the union-find so
        ``cluster_means()``/``assignment()`` and root lookups stay
        consistent. Each affected cluster is re-rooted at its smallest
        remaining member id; returns {old_root: new_root} for clusters
        whose root changed, so callers can remap cluster-model keys.
        (A cluster emptied by the departure simply disappears from the
        partition; its model is the caller's to keep or drop.)"""
        cid = int(cid)
        groups: Dict[int, List[int]] = {}
        for i in self.uf.parent:
            groups.setdefault(self.uf.find(i), []).append(i)
        self.reps.pop(cid, None)
        self.seen.discard(cid)
        if cid not in self.uf.parent:
            return {}
        parent: Dict[int, int] = {}
        remap: Dict[int, int] = {}
        for root, members in groups.items():
            members = [m for m in members if m != cid]
            if not members:
                continue
            new_root = min(members)
            if new_root != root:
                remap[root] = new_root
            for m in members:
                parent[m] = new_root
        self.uf.parent = parent
        return remap

    # ------------------------------------------------------------- inference
    def nearest(self, rep) -> Tuple[Optional[int], Optional[int], float]:
        """Shared nearest-cluster-by-Ψ lookup (§4.4).

        Returns (root or None, nearest_root, best cosine): root is the
        nearest cluster iff its cosine clears τ; nearest_root is the
        nearest cluster regardless (the seed donor when opening a fresh
        cluster). Both None when no client has been observed yet."""
        if not self.reps:
            return None, None, 0.0
        roots, means = self.cluster_means()
        rep = np.asarray(rep, np.float32)
        rn = rep / (np.linalg.norm(rep) + 1e-12)
        mn = means / (np.linalg.norm(means, axis=1, keepdims=True) + 1e-12)
        sims = mn @ rn
        best = int(np.argmax(sims))
        root = roots[best] if sims[best] >= self.tau else None
        return root, roots[best], float(sims[best])

    def infer(self, rep) -> Tuple[Optional[int], float]:
        """§4.4: nearest cluster for a new client's Ψ.

        Returns (root or None, best cosine). None ⇒ caller should open a
        new cluster (seeding its model from the nearest cluster)."""
        root, _, sim = self.nearest(rep)
        return root, sim


def adjusted_rand_index(labels_a: Sequence[int], labels_b: Sequence[int]) -> float:
    """ARI between two clusterings (for validating cluster recovery)."""
    a = np.asarray(labels_a)
    b = np.asarray(labels_b)
    n = len(a)
    ua, ia = np.unique(a, return_inverse=True)
    ub, ib = np.unique(b, return_inverse=True)
    cont = np.zeros((len(ua), len(ub)), dtype=np.int64)
    np.add.at(cont, (ia, ib), 1)
    comb = lambda x: x * (x - 1) // 2
    sum_ij = comb(cont).sum()
    sum_a = comb(cont.sum(axis=1)).sum()
    sum_b = comb(cont.sum(axis=0)).sum()
    total = comb(n)
    expected = sum_a * sum_b / total if total else 0.0
    max_idx = (sum_a + sum_b) / 2
    if max_idx == expected:
        return 1.0
    return float((sum_ij - expected) / (max_idx - expected))
