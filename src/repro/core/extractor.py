"""Distribution extractor Ψ (paper §3.1).

Ψ(D) = Normalize(∂ℓ(ψ; D)/∂ψ): the L2-normalized gradient of a *frozen*
anchor model ψ over a client's local dataset — a representation of the
local data distribution. The anchor is never optimized; the paper sets
ψ = ω₀ (the FL initialization), which we follow by default.

For LLM-scale anchors the full-gradient representation is |θ|-dimensional;
``project_dim`` enables a sparse Johnson-Lindenstrauss sketch (signed
feature hashing) so the server-side clustering state is O(project_dim) per
client. This is a beyond-paper optimization — OFF by default.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.utils import trees


def _jl_sketch(vec, dim: int, seed: int = 0):
    """Signed-bucket projection: preserves cosine in expectation."""
    n = vec.shape[0]
    key = jax.random.PRNGKey(seed)
    kb, ks = jax.random.split(key)
    buckets = jax.random.randint(kb, (n,), 0, dim)
    signs = jax.random.rademacher(ks, (n,), dtype=jnp.float32)
    return jax.ops.segment_sum(vec * signs, buckets, num_segments=dim)


def make_extractor(loss_fn: Callable, anchor_params,
                   project_dim: Optional[int] = None,
                   batched: bool = False,
                   leaf_filter: Optional[Callable[[str], bool]] = None) -> Callable:
    """Returns Ψ: batch -> normalized representation vector.

    loss_fn(params, batch) -> scalar. If ``batched``, the returned fn maps
    a stacked client batch (leading client axis) to stacked representations
    via vmap — the SPMD path used when clients ride the mesh's data axis.

    leaf_filter("path/to/leaf") -> bool restricts Ψ to a parameter subset.
    For LLM anchors the data-distribution signal concentrates in the
    embedding/lm_head gradients (token marginals); the body gradient is
    per-token noise that drowns the cosine signal (see examples/
    federated_llm.py) — ``llm_leaf_filter`` keeps only those rows.
    """
    grad_fn = jax.grad(loss_fn)

    def psi(batch):
        g = grad_fn(anchor_params, batch)
        if leaf_filter is not None:
            flat = jax.tree_util.tree_flatten_with_path(g)[0]
            kept = [jnp.ravel(v) for kp, v in flat
                    if leaf_filter("/".join(str(getattr(k, "key", k)) for k in kp))]
            vec = jnp.concatenate([x.astype(jnp.float32) for x in kept])
        else:
            vec = trees.tree_flatten_vector(g)
        if project_dim:
            vec = _jl_sketch(vec, project_dim)
        norm = jnp.linalg.norm(vec)
        return jnp.where(norm > 0, vec / norm, vec)

    psi = jax.jit(psi)
    if batched:
        return jax.jit(jax.vmap(lambda b: psi(b)))
    return psi


def representation(loss_fn, anchor_params, batch, project_dim=None):
    """One-shot Ψ(D) (convenience, non-jitted caller side)."""
    return make_extractor(loss_fn, anchor_params, project_dim)(batch)


def llm_leaf_filter(path: str) -> bool:
    """Ψ restricted to the distribution-bearing vocab matrices."""
    return ("embed" in path) or ("lm_head" in path)
