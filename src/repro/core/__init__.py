"""StoCFL — the paper's primary contribution as a composable JAX module."""
from repro.core.clustering import ClusterState, adjusted_rand_index  # noqa: F401
from repro.core.extractor import make_extractor, representation  # noqa: F401
from repro.core.stocfl import StoCFL, StoCFLConfig  # noqa: F401
from repro.core.baselines import CFLSattler, Ditto, FLConfig, FedAvg, FedProx, IFCA  # noqa: F401
