"""repro.core — the paper's math as composable JAX modules.

Ψ distribution extractor (§3.1), stochastic client clustering (§3.2:
host ``ClusterState`` and its device-resident twin ``DeviceClusters``),
the bi-level cohort update (§3.3, ``repro.core.bilevel``), robust
aggregators, and the deprecated class shims (``StoCFL`` + baselines)
over ``repro.engine``.
"""
from repro.core.clustering import (ClusterState, UnionFind,  # noqa: F401
                                   adjusted_rand_index)
from repro.core.device_clustering import (DeviceClusters,  # noqa: F401
                                          DeviceClusterState,
                                          make_cluster_state)
from repro.core.extractor import make_extractor, representation  # noqa: F401
from repro.core.stocfl import StoCFL, StoCFLConfig  # noqa: F401
from repro.core.baselines import CFLSattler, Ditto, FLConfig, FedAvg, FedProx, IFCA  # noqa: F401

__all__ = [
    "ClusterState", "UnionFind", "adjusted_rand_index",
    "DeviceClusters", "DeviceClusterState", "make_cluster_state",
    "make_extractor", "representation",
    "StoCFL", "StoCFLConfig",
    "CFLSattler", "Ditto", "FLConfig", "FedAvg", "FedProx", "IFCA",
]
