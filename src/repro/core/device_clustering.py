"""Device-resident stochastic clustering core (Algorithm 1 on device).

The numpy ``ClusterState`` keeps the partition in a Python ``UnionFind``
dict and pays a device→host sync plus an O(K̃²) Python pair scan every
``merge_round`` — fine at tens of clusters, a wall at the ROADMAP's
million-client scale. This module is the same math as one jitted device
program:

  ``DeviceClusterState``  a pytree of three pow2-capacity-padded arrays:
      ``parent``  (capacity,) int32  union-find pointers, row i ↔ client
                  id i; kept FULLY path-compressed (every entry is a
                  root), so root lookup is one vectorized gather
      ``live``    (capacity,) bool   observed and not departed; a
                  departure flips the bit (an arena-style tombstone) —
                  the row's rep stays allocated and is reused on re-join
      ``rep``     (capacity, D) f32  the Ψ(D_i) bank

  transitions (pure, jitted once per pow2 capacity):
      ``observe``      scatter new Ψ rows + self-rooted parents (update
                       count pow2-quantized through a dropped pad index)
      ``merge_round``  cluster means by segment-sum over roots → fused
                       masked-cosine-τ candidate kernel
                       (``kernels.merge_pairs``) → connected components
                       of the candidate graph by min-label propagation
                       with pointer jumping (O(log K̃) steps) → new fully
                       compressed ``parent``
      ``union`` / ``remove``   the §5 join/leave repairs
      ``nearest`` / ``objective``   §4.4 inference and the Eq. 2 metric

The partition semantics are EXACTLY the numpy path's: a merge pass
unions every pair of live clusters with cos(Ψ̃_i, Ψ̃_j) ≥ τ transitively,
i.e. the new partition is the connected components of the τ-threshold
graph over pre-merge cluster means, and every root is its cluster's
smallest member id (the numpy ``keep = min(ra, rb)`` rule). That
equivalence is what the parity battery in
``tests/test_device_clustering.py`` pins down.

``DeviceClusters`` wraps the pytree in the host-facing ``ClusterState``
API (``observe`` / ``merge_round`` / ``nearest`` / ``infer`` /
``remove`` / ``clusters`` / ``assignment`` / ``uf.find``), so the
engine's strategies run unchanged on either backend
(``EngineConfig.cluster_backend``). The wrapper maintains host *mirrors*
of ``parent``/``live`` — pure bookkeeping, refreshed from the small int
arrays a mutating transition already returns — so per-round host
traffic is O(K̃) index ints for the bank keys, never the Ψ matrix, and
the clustering math itself runs transfer-free (see the transfer-guard
test). See ``docs/CLUSTERING.md`` for the full memory model.
"""
from __future__ import annotations

import functools
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _pow2(n: int) -> int:
    """Smallest power of two >= n (capacity quantum, as in ClusterBank)."""
    return 1 if n <= 1 else 1 << (int(n) - 1).bit_length()


class DeviceClusterState(NamedTuple):
    """The clustering server as a device pytree (row i ↔ client id i)."""

    parent: jax.Array   # (capacity,) int32, fully compressed union-find
    live: jax.Array     # (capacity,) bool, observed ∧ not departed
    rep: jax.Array      # (capacity, D) float32 Ψ bank (dead rows zeroed)


def init_state(capacity: int, dim: int) -> DeviceClusterState:
    """Fresh all-singleton state: every row self-rooted, nothing live."""
    cap = _pow2(capacity)
    return DeviceClusterState(
        parent=jnp.arange(cap, dtype=jnp.int32),
        live=jnp.zeros((cap,), bool),
        rep=jnp.zeros((cap, dim), jnp.float32))


def grow(state: DeviceClusterState, capacity: int) -> DeviceClusterState:
    """Double (pow2) the row capacity — the churn-cheap analogue of
    ``ClientArena.grow``: new rows are self-rooted, dead, zero-Ψ."""
    old = state.parent.shape[0]
    cap = _pow2(max(capacity, old))
    if cap == old:
        return state
    return DeviceClusterState(
        parent=jnp.concatenate(
            [state.parent, jnp.arange(old, cap, dtype=jnp.int32)]),
        live=jnp.concatenate([state.live, jnp.zeros((cap - old,), bool)]),
        rep=jnp.concatenate(
            [state.rep,
             jnp.zeros((cap - old, state.rep.shape[1]), jnp.float32)]))


# ----------------------------------------------------------- jitted math
def _cluster_means(state: DeviceClusterState):
    """(root, means, counts): per-row resolved root (dead rows → the
    scratch segment ``cap``), per-root-row member-mean Ψ̃ and member
    count (zero for non-root rows)."""
    cap = state.parent.shape[0]
    root = ops.resolve_roots(state.parent)
    seg = jnp.where(state.live, root, cap)
    sums = jax.ops.segment_sum(
        jnp.where(state.live[:, None], state.rep, 0.0), seg,
        num_segments=cap + 1)[:cap]
    counts = jax.ops.segment_sum(
        state.live.astype(jnp.float32), seg, num_segments=cap + 1)[:cap]
    means = sums / jnp.maximum(counts, 1.0)[:, None]
    return root, means, counts


def component_labels(adj, steps: Optional[int] = None):
    """Connected-component labels of a 0/1 adjacency matrix: each node's
    label converges to the smallest node id in its component.

    Min-label propagation with pointer jumping, run to a FIXED POINT
    (``lax.while_loop`` until a full pass changes no label): per pass
    every node takes the min over its neighbours' labels, then follows
    its own label's label (``label <- label[label]``). At a fixed point
    adjacent nodes hold equal labels (each is ≤ the other's), labels
    never leave their component, and the common value must be the
    component minimum — so the exit condition IS the correctness proof.
    The jumping makes well-ordered graphs close in O(log N) passes; a
    fixed step count alone is NOT safe (an adversarially permuted chain
    needs more — the regression tests pin this), which is why the
    data-dependent loop is the default. ``steps`` forces an explicit
    pass count instead (tests/benchmarks only). All shapes static: this
    is the jittable union of Algorithm 1's whole merge pass."""
    n = adj.shape[0]
    ids = jnp.arange(n, dtype=jnp.int32)

    def one_pass(label):
        neigh = jnp.min(jnp.where(adj > 0, label[None, :], n), axis=1)
        label = jnp.minimum(label, neigh.astype(label.dtype))
        return jnp.take(label, label)

    if steps is not None:
        return jax.lax.fori_loop(0, steps, lambda _, l: one_pass(l), ids)
    return jax.lax.while_loop(
        lambda c: jnp.any(c[0] != c[1]),
        lambda c: (c[1], one_pass(c[1])),
        (jnp.full((n,), -1, jnp.int32), ids))[1]


@functools.lru_cache(maxsize=None)
def _jit_cluster_means():
    """Jitted ``_cluster_means`` (memoized wrapper, one compile per
    capacity)."""
    return jax.jit(_cluster_means)


@functools.lru_cache(maxsize=None)
def _jit_observe():
    """(state, idx (P,), reps (P, D)) -> state'. Pad idx entries point at
    ``capacity`` and are dropped by the scatter, so the compiled shape
    set is quantized in P (pow2) like ``ClusterBank.put``."""

    def run(state, idx, reps):
        return DeviceClusterState(
            parent=state.parent.at[idx].set(idx.astype(state.parent.dtype),
                                            mode="drop"),
            live=state.live.at[idx].set(True, mode="drop"),
            rep=state.rep.at[idx].set(reps.astype(state.rep.dtype),
                                      mode="drop"))

    return jax.jit(run)


def merge_round_impl(state: DeviceClusterState, tau: float, k_max: int):
    """Traceable body of one fused merge pass:
    ``(state, tau, static k_max) -> (state', roots (k_max,), new_roots
    (k_max,), counts (k_max,))``.

    One device program for Algorithm 1 lines 10-13: means → live-root
    compaction → fused masked-cosine-τ candidates → components →
    compressed parents. ``k_max`` (static, the caller's pow2-quantized
    live-cluster bound ≤ capacity) sizes the candidate matrix: the
    pairwise work is O(k_max²), not O(capacity²), so a settled
    4096-capacity federation with 4 clusters pays a 4-row scan — the
    compaction happens on device (``jnp.nonzero`` with a static size),
    so nothing crosses the host boundary. The three returned k_max-row
    arrays (pre-merge live roots ascending, their post-merge roots,
    their member counts; pads = capacity / 0) are ALL the host needs to
    re-key the host-indexed ``ClusterBank`` and refresh its mirror —
    O(K̃) ints, never a capacity-length array, never the Ψ matrix.

    The resulting partition is identical for ANY sufficient ``k_max``
    (pads are masked out of the candidate kernel and isolated in the
    component graph) — which is why the ``run_rounds`` scan can inline
    this with the static ``k_max = capacity`` while the eager wrapper
    compacts to the live-cluster count, and still land bitwise-equal
    parents."""
    cap = state.parent.shape[0]
    ids = jnp.arange(cap, dtype=jnp.int32)
    root, means, counts = _cluster_means(state)
    # live-root rows, ascending (so compact row order = root-id
    # order and a min row index IS the min root id); pads → cap
    (rows,) = jnp.nonzero(counts > 0, size=k_max, fill_value=cap)
    rows = rows.astype(jnp.int32)
    means_ext = jnp.concatenate(
        [means, jnp.zeros((1, means.shape[1]), means.dtype)])
    counts_c = jnp.take(jnp.concatenate([counts, jnp.zeros(1)]), rows)
    adj = ops.merge_pairs(jnp.take(means_ext, rows, axis=0),
                          counts_c > 0, tau)
    # steady-state rounds have no candidate pair at all — skip the
    # O(log K̃) propagation entirely instead of running it on an
    # empty graph (the common case once the partition settles)
    label = jax.lax.cond(jnp.any(adj > 0), component_labels,
                         lambda a: jnp.arange(a.shape[0],
                                              dtype=jnp.int32), adj)
    # back to root-id space: compact row i's cluster re-roots at the
    # root id of its component's min row; scatter builds the
    # {old root: new root} map over all capacity rows
    new_root_c = jnp.where(rows < cap, jnp.take(rows, label),
                           jnp.int32(cap))
    mapped = ids.at[rows].set(new_root_c, mode="drop")
    new_root = jnp.take(mapped, root, mode="clip")
    parent = jnp.where(state.live, new_root, ids)
    return (DeviceClusterState(parent=parent, live=state.live,
                               rep=state.rep),
            rows, new_root_c, counts_c)


@functools.lru_cache(maxsize=None)
def _jit_merge_round(tau: float, k_max: int):
    """Jitted ``merge_round_impl`` (one compile per (τ, k_max))."""
    return jax.jit(functools.partial(merge_round_impl, tau=tau, k_max=k_max))


@functools.lru_cache(maxsize=None)
def _jit_union():
    """(state, a, b) -> state': merge a's and b's clusters, smaller root
    wins (the §4.4 join placement)."""

    def run(state, a, b):
        root = ops.resolve_roots(state.parent)
        ra, rb = root[a], root[b]
        keep, absorb = jnp.minimum(ra, rb), jnp.maximum(ra, rb)
        return state._replace(parent=jnp.where(root == absorb, keep, root))

    return jax.jit(run)


@functools.lru_cache(maxsize=None)
def _jit_remove():
    """(state, cid) -> (state', old_root, new_root, n_left): tombstone a
    departed client's row and re-root its cluster at the smallest
    remaining member (``new_root == capacity`` when none remain)."""

    def run(state, cid):
        cap = state.parent.shape[0]
        ids = jnp.arange(cap, dtype=jnp.int32)
        root = ops.resolve_roots(state.parent)
        r = root[cid]
        stay = state.live & (root == r) & (ids != cid)
        n_left = jnp.sum(stay)
        new_root = jnp.min(jnp.where(stay, ids, cap))
        parent = jnp.where(stay, new_root.astype(root.dtype), root)
        parent = parent.at[cid].set(cid)
        return (DeviceClusterState(parent=parent,
                                   live=state.live.at[cid].set(False),
                                   rep=state.rep.at[cid].set(0.0)),
                r, new_root, n_left)

    return jax.jit(run)


@functools.lru_cache(maxsize=None)
def _jit_nearest():
    """(state, query) -> (best root, best cosine, live-cluster count):
    §4.4 nearest-cluster-by-Ψ, dead rows masked to −inf."""

    def run(state, query):
        _, means, counts = _cluster_means(state)
        qn = query / (jnp.linalg.norm(query) + 1e-12)
        mn = means / (jnp.linalg.norm(means, axis=1, keepdims=True) + 1e-12)
        sims = jnp.where(counts > 0, mn @ qn, -jnp.inf)
        best = jnp.argmax(sims)
        return best, sims[best], jnp.sum(counts > 0)

    return jax.jit(run)


def objective_impl(state: DeviceClusterState, k_max: int):
    """Traceable Eq. 2 objective Σ_{i<j} cos(Ψ̃_i, Ψ̃_j) over live
    clusters (0 with fewer than two). ``k_max`` (static live-cluster
    bound) compacts the pairwise work to O(k_max²), same as the merge
    pass — a settled big-capacity federation pays a K̃′² matrix, not a
    capacity² one. The ``run_rounds`` scan inlines this with
    ``k_max = capacity``."""
    cap = state.parent.shape[0]
    _, means, counts = _cluster_means(state)
    (rows,) = jnp.nonzero(counts > 0, size=k_max, fill_value=cap)
    means_ext = jnp.concatenate(
        [means, jnp.zeros((1, means.shape[1]), means.dtype)])
    mc = jnp.take(means_ext, rows, axis=0).astype(jnp.float32)
    live_c = jnp.take(jnp.concatenate([counts, jnp.zeros(1)]), rows) > 0
    norms = jnp.linalg.norm(mc, axis=1, keepdims=True)
    mn = jnp.where(norms > 0, mc / norms, 0.0)
    M = mn @ mn.T
    k_ids = jnp.arange(k_max)
    pairs = (live_c[:, None] & live_c[None, :]
             & (k_ids[:, None] < k_ids[None, :]))
    return jnp.sum(jnp.where(pairs, M, 0.0))


@functools.lru_cache(maxsize=None)
def _jit_objective(k_max: int):
    """Jitted ``objective_impl`` (one compile per k_max)."""
    return jax.jit(functools.partial(objective_impl, k_max=k_max))


def objective_closed_impl(state: DeviceClusterState):
    """Eq. 2 as the closed form ``(‖Σ m̂‖² − Σ ‖m̂‖²)/2`` over the live
    clusters' normalized means — O(capacity·D), no pairwise matrix and
    no live-cluster compaction, so the reduction SHAPE depends only on
    the (pow2) capacity. That shape-stability is why the engine's
    per-round objective metric uses this form on the device backend:
    the eager loop and the ``run_rounds`` scan then record bitwise-equal
    trajectories, while the cost stays linear in capacity instead of
    the pairwise k_max². (Same quantity as ``objective_impl`` up to
    float association; exact 0.0 with fewer than two clusters.)"""
    _, means, counts = _cluster_means(state)
    norms = jnp.linalg.norm(means, axis=1, keepdims=True)
    mn = jnp.where((counts[:, None] > 0) & (norms > 0), means / norms, 0.0)
    s = jnp.sum(mn, axis=0)
    return (jnp.sum(s * s) - jnp.sum(mn * mn)) / 2.0


@functools.lru_cache(maxsize=None)
def _jit_objective_closed():
    """Jitted ``objective_closed_impl`` (one compile per capacity)."""
    return jax.jit(objective_closed_impl)


def objective_closed(state: DeviceClusterState) -> float:
    """Host wrapper for ``objective_closed_impl`` (the engine's eager
    device-backend metric call)."""
    return float(_jit_objective_closed()(state))


# public jitted-transition aliases (the DeviceClusterState-level API)
def observe(state: DeviceClusterState, idx, reps) -> DeviceClusterState:
    """Record Ψ rows for client ids ``idx`` (pad entries = capacity are
    dropped); rows become live, self-rooted singletons."""
    return _jit_observe()(state, idx, reps)


def merge_round(state: DeviceClusterState, tau: float,
                k_max: Optional[int] = None):
    """One fused merge pass; returns (state', pre-merge live roots,
    their post-merge roots, their member counts) — three k_max-row
    device arrays (pads = capacity / 0).

    ``k_max`` (static) bounds the live-cluster count and sizes the
    O(k_max²) candidate matrix; default: the full capacity (always
    safe). Callers that track K̃ pass its pow2 quantization."""
    cap = int(state.parent.shape[0])
    k_max = cap if k_max is None else min(_pow2(k_max), cap)
    return _jit_merge_round(float(tau), k_max)(state)


def nearest(state: DeviceClusterState, query):
    """(best root row, best cosine, live-cluster count) for a Ψ query."""
    return _jit_nearest()(state, query)


def infer(state: DeviceClusterState, query, tau: float):
    """§4.4 as device values: (best root, cosine, cleared-τ flag)."""
    best, sim, n = nearest(state, query)
    return best, sim, (n > 0) & (sim >= tau)


# ================================================================ wrapper
class _RepsView:
    """Read-only mapping view of the Ψ bank keyed by live client id —
    the ``ClusterState.reps`` surface (membership tests, checkpoint
    iteration) without materializing a host dict."""

    def __init__(self, owner: "DeviceClusters"):
        self._o = owner

    def __contains__(self, cid) -> bool:
        """True when ``cid`` has been observed and has not departed."""
        return int(cid) in self._o.seen

    def __iter__(self):
        """Live client ids, ascending."""
        return iter(sorted(self._o.seen))

    def __len__(self) -> int:
        """Number of live observed clients."""
        return len(self._o.seen)

    def __getitem__(self, cid) -> np.ndarray:
        """One client's Ψ row (pulled to host)."""
        if int(cid) not in self._o.seen:
            raise KeyError(cid)
        return np.asarray(self._o._state.rep[int(cid)])

    def items(self):
        """(cid, Ψ row) pairs — the checkpoint-save iteration."""
        return ((c, self[c]) for c in self)


class _UFView:
    """``ClusterState.uf``-shaped view: ``find`` reads the host parent
    mirror (the device array is always fully compressed, so the mirror
    IS the root table); ``union`` runs the jitted device transition."""

    def __init__(self, owner: "DeviceClusters"):
        self._o = owner

    def find(self, i: int) -> int:
        """Root (= cluster id) of client ``i``."""
        return int(self._o._parent[int(i)])

    def union(self, a: int, b: int) -> bool:
        """Merge a's and b's clusters (smaller root wins); True if they
        were distinct."""
        return self._o._union(int(a), int(b))

    @property
    def parent(self) -> Dict[int, int]:
        """{observed client id: root} — the numpy ``UnionFind.parent``
        dict surface (host mirror; for checkpoint/tests)."""
        return {int(c): int(self._o._parent[c]) for c in sorted(self._o.seen)}


class DeviceClusters:
    """Host-facing wrapper: the ``ClusterState`` API over a
    ``DeviceClusterState`` pytree.

    Drop-in for the numpy backend everywhere the engine touches the
    partition. Mutating methods replace ``self._state`` with the jitted
    transition's output (arrays are immutable, so ``copy()`` is O(1)
    structural sharing, exactly like ``ClusterState.copy``); the host
    mirrors (``_parent`` ndarray, ``seen`` set) are refreshed from the
    transition's small integer outputs so reads (``uf.find``,
    ``clusters()``, ``assignment()``) never touch the device."""

    def __init__(self, tau: float, capacity: int = 0, dim: int = 0):
        self.tau = float(tau)
        self._capacity_hint = max(int(capacity), 1)
        self._state: Optional[DeviceClusterState] = None
        if dim:
            self._state = init_state(self._capacity_hint, int(dim))
        self.seen: set = set()
        self._parent = np.arange(self.capacity, dtype=np.int64)

    # ----------------------------------------------------------- plumbing
    @property
    def capacity(self) -> int:
        """Allocated union-find rows (power of two; grows on demand)."""
        if self._state is None:
            return _pow2(self._capacity_hint)
        return int(self._state.parent.shape[0])

    @property
    def state(self) -> Optional[DeviceClusterState]:
        """The underlying device pytree (None until first ``observe``)."""
        return self._state

    @property
    def uf(self) -> _UFView:
        """Union-find view (``find`` / ``union`` / ``parent``)."""
        return _UFView(self)

    @property
    def reps(self) -> _RepsView:
        """Mapping view of live clients' Ψ rows."""
        return _RepsView(self)

    def copy(self) -> "DeviceClusters":
        """Structural copy: device arrays shared (immutable), host
        mirrors duplicated — the engine's pure-transition fork."""
        new = object.__new__(DeviceClusters)
        new.tau = self.tau
        new._capacity_hint = self._capacity_hint
        new._state = self._state
        new.seen = set(self.seen)
        new._parent = self._parent.copy()
        return new

    def _ensure(self, n_ids: int, dim: int) -> None:
        """Allocate/grow so row ``n_ids - 1`` exists (pow2 capacity)."""
        if self._state is None:
            self._state = init_state(max(self._capacity_hint, n_ids),
                                     int(dim))
        elif n_ids > self.capacity:
            self._state = grow(self._state, n_ids)
        if len(self._parent) < self.capacity:
            self._parent = np.concatenate(
                [self._parent,
                 np.arange(len(self._parent), self.capacity)])

    def _union(self, a: int, b: int) -> bool:
        ra, rb = int(self._parent[a]), int(self._parent[b])
        if ra == rb:
            return False
        self._state = _jit_union()(self._state, jnp.int32(a), jnp.int32(b))
        keep, absorb = min(ra, rb), max(ra, rb)
        self._parent[self._parent == absorb] = keep
        return True

    # ------------------------------------------------------------ observe
    def observe(self, client_ids: Sequence[int], reps) -> List[int]:
        """Record Ψ for newly-seen clients (one quantized device
        scatter; already-seen ids are skipped). Returns the new ids."""
        new, take, batch_seen = [], [], set()
        for i, cid in enumerate(client_ids):
            cid = int(cid)
            if cid not in self.seen and cid not in batch_seen:
                new.append(cid)
                take.append(i)
                batch_seen.add(cid)
        if not new:
            return []
        if hasattr(reps, "ndim") and getattr(reps, "ndim", 0) == 2:
            rows = [reps[i] for i in take]
        else:
            reps = list(reps)
            rows = [reps[i] for i in take]
        stacked = jnp.stack([jnp.asarray(r, jnp.float32) for r in rows])
        self._ensure(max(new) + 1, stacked.shape[1])
        cap = self.capacity
        p = _pow2(len(new))
        idx = np.full(p, cap, np.int32)          # pad writes are dropped
        idx[: len(new)] = new
        if p > len(new):
            stacked = jnp.concatenate(
                [stacked, jnp.zeros((p - len(new), stacked.shape[1]),
                                    stacked.dtype)])
        self._state = observe(self._state, jnp.asarray(idx), stacked)
        self.seen.update(new)
        self._parent[new] = new
        return new

    # -------------------------------------------------------------- views
    def clusters(self) -> Dict[int, List[int]]:
        """root -> sorted member client ids (live clients only)."""
        out: Dict[int, List[int]] = {}
        for cid in sorted(self.seen):
            out.setdefault(int(self._parent[cid]), []).append(cid)
        return out

    def assignment(self) -> Dict[int, int]:
        """{client id: root} over live observed clients."""
        return {cid: int(self._parent[cid]) for cid in self.seen}

    def n_clusters(self) -> int:
        """Live cluster count."""
        return len({int(self._parent[c]) for c in self.seen})

    def cluster_means(self) -> Tuple[List[int], np.ndarray]:
        """(sorted roots, (K̃, D) member-mean matrix) — host pull of the
        device segment means, numpy-API-shaped for tests/tools."""
        roots = sorted({int(self._parent[c]) for c in self.seen})
        _, means, _ = _jit_cluster_means()(self._state)
        return roots, np.asarray(means)[np.asarray(roots, np.int64)]

    def similarity_matrix(self) -> Tuple[List[int], np.ndarray]:
        """(sorted roots, K̃×K̃ cosine matrix over cluster means)."""
        roots, means = self.cluster_means()
        m32 = means.astype(np.float32)
        norms = np.linalg.norm(m32, axis=1, keepdims=True)
        mn = np.where(norms > 0, m32 / np.maximum(norms, 1e-30), 0.0)
        return roots, mn @ mn.T

    # ------------------------------------------------------------- merging
    def merge_round(self) -> List[Tuple[int, int]]:
        """One fused device merge pass (Algorithm 1 lines 10-13).

        Returns (root_kept, root_absorbed) merges in the NORMALIZED form
        (component_min, member): the same final partition as the numpy
        scan (both are the τ-graph's transitive closure), and the same
        downstream ``ClusterBank.merge`` result bitwise — the bank
        reconstructs merge GROUPS from the list's own transitive
        closure, so any list with the same closure aggregates
        identically (pinned by the chain-topology test). The list
        itself can differ from the numpy scan's visit order on
        chain-topology graphs where a scan's intermediate keep is not
        the component min. Host traffic: the two k_max-row root arrays
        the jitted pass returns — O(K̃) ints, independent of capacity."""
        if len(self.seen) < 2:
            return []
        st, rows, new_roots, _counts = merge_round(self._state, self.tau,
                                                   k_max=self.n_clusters())
        self._state = st
        cap = self.capacity
        rows = np.asarray(rows).astype(np.int64)
        new_roots = np.asarray(new_roots).astype(np.int64)
        valid = rows < cap
        rows, new_roots = rows[valid], new_roots[valid]
        merges = [(int(f), int(r)) for r, f in zip(rows, new_roots)
                  if f != r]
        # mirror refresh: every live client's pre-merge root is one of
        # ``rows`` (ascending), so one searchsorted maps it to its
        # post-merge root — no capacity-length device pull
        live = np.fromiter(self.seen, np.int64, len(self.seen))
        pre = self._parent[live]
        self._parent[live] = new_roots[np.searchsorted(rows, pre)]
        return sorted(merges)

    # ------------------------------------------------------------- metrics
    def objective(self) -> float:
        """Eq. 2: Σ_{i<j} cos(Ψ̃^{(i)}, Ψ̃^{(j)}) over live clusters
        (pairwise form, compacted to the pow2 live-cluster count; the
        engine's per-round metric instead uses the shape-stable
        ``objective_closed`` so eager and scanned loops agree
        bitwise)."""
        k = self.n_clusters()
        if k < 2:
            return 0.0
        k_max = min(_pow2(k), self.capacity)
        return float(_jit_objective(k_max)(self._state))

    # ----------------------------------------------------------- departure
    def remove(self, cid: int) -> Dict[int, int]:
        """Tombstone a departed client's row (§5) and re-root its
        cluster at the smallest remaining member. Returns
        {old_root: new_root} when the root changed (the bank re-key)."""
        cid = int(cid)
        if cid not in self.seen:
            return {}
        st, r, new_root, n_left = _jit_remove()(self._state, jnp.int32(cid))
        self._state = st
        self.seen.discard(cid)
        r, new_root, n_left = int(r), int(new_root), int(n_left)
        remap = {}
        if n_left and new_root != r:
            self._parent[self._parent == r] = new_root
            remap = {r: new_root}
        # the departed row itself re-roots to cid AFTER the remap mask,
        # so the mirror never reports it as a member of the re-rooted
        # cluster (it must match the device array exactly)
        self._parent[cid] = cid
        return remap

    # ----------------------------------------------------------- inference
    def nearest(self, rep) -> Tuple[Optional[int], Optional[int], float]:
        """§4.4 nearest-cluster-by-Ψ: (root above τ or None, nearest
        root regardless, best cosine)."""
        if not self.seen:
            return None, None, 0.0
        best, sim, _n = nearest(self._state, jnp.asarray(rep, jnp.float32))
        best, sim = int(best), float(sim)
        return (best if sim >= self.tau else None), best, sim

    def infer(self, rep) -> Tuple[Optional[int], float]:
        """§4.4: (nearest root above τ or None, best cosine)."""
        root, _, sim = self.nearest(rep)
        return root, sim

    # -------------------------------------------------------- serialization
    def arrays(self) -> Dict[str, np.ndarray]:
        """Host copies of the pytree (checkpoint payload); empty state
        serializes as zero-capacity arrays."""
        if self._state is None:
            return {"parent": np.zeros(0, np.int32),
                    "live": np.zeros(0, bool),
                    "rep": np.zeros((0, 0), np.float32)}
        return {"parent": np.asarray(self._state.parent),
                "live": np.asarray(self._state.live),
                "rep": np.asarray(self._state.rep)}

    @classmethod
    def from_arrays(cls, tau: float, parent, live, rep) -> "DeviceClusters":
        """Rebuild from checkpointed arrays (exact mirror restore)."""
        out = cls(tau, capacity=max(len(parent), 1))
        if len(parent):
            out._state = DeviceClusterState(
                parent=jnp.asarray(parent, jnp.int32),
                live=jnp.asarray(live, bool),
                rep=jnp.asarray(rep, jnp.float32))
            out.seen = {int(i) for i in np.nonzero(np.asarray(live))[0]}
            out._parent = np.asarray(parent).astype(np.int64).copy()
        return out

    def __repr__(self) -> str:
        return (f"DeviceClusters(tau={self.tau}, capacity={self.capacity}, "
                f"live={len(self.seen)}, k={self.n_clusters()})")


def make_cluster_state(tau: float, backend: str = "numpy",
                       capacity: int = 0):
    """Factory for the engine: ``"numpy"`` → host ``ClusterState``
    (shimmed fallback), ``"device"`` → ``DeviceClusters``."""
    if backend == "device":
        return DeviceClusters(tau, capacity=capacity)
    if backend == "numpy":
        from repro.core.clustering import ClusterState
        return ClusterState(tau)
    raise ValueError(f"unknown cluster_backend {backend!r} "
                     "(expected 'numpy' or 'device')")
