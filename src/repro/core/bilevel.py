"""Bi-level clustered FL optimization (paper §3.3, Algorithm 1 l.14-23).

Client procedure (lines 20-23), E local steps, fused prox kernel:
    θ ← θ − η (∇f_i(θ) + λ (θ − ω))
    ω ← ω − η ∇f_i(ω)
Server (lines 17-19): ω ← Aggregate([ωᵢ]) over all sampled clients;
θ_k ← FedAvg([θᵢ], i ∈ c_k) per cluster.

``make_client_update`` returns a jitted, vmappable function — the whole
sampled cohort executes as ONE SPMD computation with clients stacked on
the leading axis (the mesh's client/data axis in production).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.utils import trees


def make_client_update(loss_fn: Callable, lr: float, lam: float,
                       local_steps: int = 1, backend: str = "auto"):
    """loss_fn(params, batch) -> scalar.

    Returns client_update(theta, omega, batch) -> (theta_i, omega_i):
    E = local_steps full-batch SGD steps of the bi-level objective."""
    grad_fn = jax.grad(loss_fn)

    def client_update(theta, omega, batch):
        def step(carry, _):
            th, om = carry
            g_t = grad_fn(th, batch)
            g_o = grad_fn(om, batch)
            th, om = ops.prox_update_tree(th, om, g_t, g_o, lr, lam, backend=backend)
            return (th, om), None

        (th, om), _ = jax.lax.scan(step, (theta, omega), None, length=local_steps)
        return th, om

    return client_update


def make_cohort_update(loss_fn, lr, lam, local_steps=1, backend: str = "auto"):
    """vmapped cohort step: thetas stacked per client, omega shared.

    thetas: pytree with leading client axis; batches: stacked client
    batches. Returns (thetas_i, omegas_i) both with client axis."""
    cu = make_client_update(loss_fn, lr, lam, local_steps, backend)
    return jax.jit(jax.vmap(cu, in_axes=(0, None, 0)))


def aggregate(trees_list, weights):
    """Server Aggregate/FedAvg: sample-count weighted mean."""
    return trees.tree_weighted_mean(trees_list, weights)


def aggregate_stacked(stacked, weights):
    """Weighted mean over the leading client axis of a stacked pytree."""
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.sum(w)

    def mean_leaf(x):
        wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.sum(x * wb, axis=0).astype(x.dtype)

    return jax.tree.map(mean_leaf, stacked)


def local_sgd(loss_fn, params, batch, lr, steps, prox_to=None, lam=0.0):
    """Generic E-step local SGD (shared by FedAvg/FedProx/Ditto/IFCA/CFL).

    prox_to: optional reference params for a FedProx/Ditto prox term."""
    grad_fn = jax.grad(loss_fn)

    def step(p, _):
        g = grad_fn(p, batch)
        if prox_to is not None:
            g = jax.tree.map(lambda gi, pi, ri: gi + lam * (pi - ri), g, p, prox_to)
        p = jax.tree.map(lambda pi, gi: (pi - lr * gi).astype(pi.dtype), p, g)
        return p, None

    out, _ = jax.lax.scan(step, params, None, length=steps)
    return out
