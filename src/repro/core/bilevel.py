"""Bi-level clustered FL optimization (paper §3.3, Algorithm 1 l.14-23).

Client procedure (lines 20-23), E local steps, fused prox kernel:
    θ ← θ − η (∇f_i(θ) + λ (θ − ω))
    ω ← ω − η ∇f_i(ω)
Server (lines 17-19): ω ← Aggregate([ωᵢ]) over all sampled clients;
θ_k ← FedAvg([θᵢ], i ∈ c_k) per cluster.

``make_client_update`` returns a jitted, vmappable function — the whole
sampled cohort executes as ONE SPMD computation with clients stacked on
the leading axis (the mesh's client/data axis in production).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.utils import trees


def make_client_update(loss_fn: Callable, lr: float, lam: float,
                       local_steps: int = 1, backend: str = "auto"):
    """loss_fn(params, batch) -> scalar.

    Returns client_update(theta, omega, batch) -> (theta_i, omega_i):
    E = local_steps full-batch SGD steps of the bi-level objective."""
    grad_fn = jax.grad(loss_fn)

    def client_update(theta, omega, batch):
        def step(carry, _):
            th, om = carry
            g_t = grad_fn(th, batch)
            g_o = grad_fn(om, batch)
            th, om = ops.prox_update_tree(th, om, g_t, g_o, lr, lam, backend=backend)
            return (th, om), None

        (th, om), _ = jax.lax.scan(step, (theta, omega), None, length=local_steps)
        return th, om

    return client_update


def make_cohort_update(loss_fn, lr, lam, local_steps=1, backend: str = "auto"):
    """vmapped cohort step: thetas stacked per client, omega shared.

    thetas: pytree with leading client axis; batches: stacked client
    batches. Returns (thetas_i, omegas_i) both with client axis."""
    cu = make_client_update(loss_fn, lr, lam, local_steps, backend)
    return jax.jit(jax.vmap(cu, in_axes=(0, None, 0)))


def chunk_map(fn, in_axes, chunk: int, donate=None):
    """Memory-flat cohort execution: run a vmapped per-client ``fn`` over
    the cohort in fixed-size chunks via ``lax.map``.

    ``in_axes`` mirrors the vmap spec (0 = stacked per-client arg, None =
    shared/broadcast arg). Cohorts of ≤ ``chunk`` clients run unchunked;
    larger ones are padded to a chunk multiple (repeating leading rows —
    the pad outputs are sliced off) and reshaped to ``(n_chunks, chunk,
    ...)`` so ``lax.map`` executes one chunk at a time with reused
    buffers: peak activation memory is O(chunk), not O(cohort), which is
    what lets 100% participation at thousands of clients fit. The wrapper
    is jitted so the whole chunk loop is one XLA program; ``donate``
    argument positions (default: every stacked arg) are donated off-CPU
    so their buffers are recycled in place — pass a narrower tuple when
    the caller reuses a stacked input after the call.

    ``chunk <= 0`` disables chunking (returns ``fn`` unchanged).
    """
    if not chunk or chunk <= 0:
        return fn
    mapped_pos = tuple(i for i, ax in enumerate(in_axes) if ax == 0)
    donate = mapped_pos if donate is None else tuple(donate)

    def wrapper(*args):
        C = jax.tree.leaves(args[mapped_pos[0]])[0].shape[0]
        if C <= chunk:
            return fn(*args)
        n_chunks = -(-C // chunk)
        pad = n_chunks * chunk - C

        def prep(tree):
            def one(x):
                if pad:
                    x = jnp.concatenate([x, x[:pad]], axis=0)
                return x.reshape((n_chunks, chunk) + x.shape[1:])

            return jax.tree.map(one, tree)

        stacked = tuple(prep(args[i]) for i in mapped_pos)

        def body(chunks):
            full = list(args)
            for p, c in zip(mapped_pos, chunks):
                full[p] = c
            return fn(*full)

        outs = jax.lax.map(body, stacked)
        return jax.tree.map(
            lambda x: x.reshape((n_chunks * chunk,) + x.shape[2:])[:C], outs)

    if jax.default_backend() == "cpu":      # donation unimplemented on CPU
        donate = ()
    return jax.jit(wrapper, donate_argnums=donate)


def aggregate(trees_list, weights):
    """Server Aggregate/FedAvg: sample-count weighted mean."""
    return trees.tree_weighted_mean(trees_list, weights)


def aggregate_segments(stacked, weights, segment_ids, num_segments: int):
    """Per-cluster FedAvg as ONE batched op: weighted mean over rows of a
    stacked pytree grouped by ``segment_ids`` (cohort row -> cluster
    index). Replaces the per-root Python gather/aggregate loop — the
    server side of the round stays a fixed number of device ops no matter
    how many clusters the cohort spans."""
    w = jnp.asarray(weights, jnp.float32)
    seg = jnp.asarray(segment_ids)
    denom = jax.ops.segment_sum(w, seg, num_segments=num_segments)
    wn = w / denom[seg]

    def leaf(x):
        wb = wn.reshape((-1,) + (1,) * (x.ndim - 1))
        return jax.ops.segment_sum(x * wb, seg,
                                   num_segments=num_segments).astype(x.dtype)

    return jax.tree.map(leaf, stacked)


def aggregate_stacked(stacked, weights):
    """Weighted mean over the leading client axis of a stacked pytree."""
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.sum(w)

    def mean_leaf(x):
        wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.sum(x * wb, axis=0).astype(x.dtype)

    return jax.tree.map(mean_leaf, stacked)


def local_sgd(loss_fn, params, batch, lr, steps, prox_to=None, lam=0.0):
    """Generic E-step local SGD (shared by FedAvg/FedProx/Ditto/IFCA/CFL).

    prox_to: optional reference params for a FedProx/Ditto prox term."""
    grad_fn = jax.grad(loss_fn)

    def step(p, _):
        g = grad_fn(p, batch)
        if prox_to is not None:
            g = jax.tree.map(lambda gi, pi, ri: gi + lam * (pi - ri), g, p, prox_to)
        p = jax.tree.map(lambda pi, gi: (pi - lr * gi).astype(pi.dtype), p, g)
        return p, None

    out, _ = jax.lax.scan(step, params, None, length=steps)
    return out
