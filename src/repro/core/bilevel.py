"""Bi-level clustered FL optimization (paper §3.3, Algorithm 1 l.14-23).

Client procedure (lines 20-23), E local steps, fused prox kernel:
    θ ← θ − η (∇f_i(θ) + λ (θ − ω))
    ω ← ω − η ∇f_i(ω)
Server (lines 17-19): ω ← Aggregate([ωᵢ]) over all sampled clients;
θ_k ← FedAvg([θᵢ], i ∈ c_k) per cluster.

``make_client_update`` returns a jitted, vmappable function — the whole
sampled cohort executes as ONE SPMD computation with clients stacked on
the leading axis (the mesh's client/data axis in production).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.utils import trees


def flat_spec(tree):
    """Static unflatten recipe for ``flatten_tree``: (treedef, shapes,
    dtypes, split points). Computed once per trace — under vmap the
    per-client (unbatched) shapes are captured, so the adapter composes
    with ``jax.vmap`` / ``chunk_map`` transparently."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(l.shape for l in leaves)
    dtypes = tuple(l.dtype for l in leaves)
    sizes = [functools.reduce(lambda a, b: a * b, s, 1) for s in shapes]
    splits = tuple(sum(sizes[:i + 1]) for i in range(len(sizes) - 1))
    return treedef, shapes, dtypes, splits


def flatten_tree(tree):
    """Concatenate all leaves into one 1-D vector (the fused-kernel view)."""
    return jnp.concatenate([jnp.ravel(l) for l in jax.tree.leaves(tree)])


def unflatten_tree(vec, spec):
    treedef, shapes, dtypes, splits = spec
    parts = jnp.split(vec, splits)
    return jax.tree.unflatten(
        treedef,
        [p.reshape(s).astype(d) for p, s, d in zip(parts, shapes, dtypes)])


def make_client_update(loss_fn: Callable, lr: float, lam: float,
                       local_steps: int = 1, backend: str = "auto",
                       fused: bool = False):
    """loss_fn(params, batch) -> scalar.

    Returns client_update(theta, omega, batch) -> (theta_i, omega_i):
    E = local_steps full-batch SGD steps of the bi-level objective.

    ``fused=True`` flattens θ/ω ONCE, runs the E-step scan on the flat
    vectors with the fused ``prox_update_flat`` kernel (jnp oracle
    off-TPU — same f32-accumulate formula, so fused/tree agree bitwise
    in fp32), and unflattens once at the end. Grads still see the
    original pytree via a per-step unflatten view."""
    grad_fn = jax.grad(loss_fn)

    if fused:
        def client_update(theta, omega, batch):
            spec = flat_spec(theta)
            th_f = flatten_tree(theta)
            om_f = flatten_tree(omega)

            def step(carry, _):
                thf, omf = carry
                g_t = flatten_tree(grad_fn(unflatten_tree(thf, spec), batch))
                g_o = flatten_tree(grad_fn(unflatten_tree(omf, spec), batch))
                thf, omf = ops.prox_update_flat(thf, omf, g_t, g_o, lr, lam,
                                                backend=backend)
                return (thf, omf), None

            (th_f, om_f), _ = jax.lax.scan(step, (th_f, om_f), None,
                                           length=local_steps)
            return unflatten_tree(th_f, spec), unflatten_tree(om_f, spec)

        return client_update

    def client_update(theta, omega, batch):
        def step(carry, _):
            th, om = carry
            g_t = grad_fn(th, batch)
            g_o = grad_fn(om, batch)
            th, om = ops.prox_update_tree(th, om, g_t, g_o, lr, lam, backend=backend)
            return (th, om), None

        (th, om), _ = jax.lax.scan(step, (theta, omega), None, length=local_steps)
        return th, om

    return client_update


def make_cohort_update(loss_fn, lr, lam, local_steps=1, backend: str = "auto",
                       fused: bool = False, donate: bool = True):
    """vmapped cohort step: thetas stacked per client, omega shared.

    thetas: pytree with leading client axis; batches: stacked client
    batches. Returns (thetas_i, omegas_i) both with client axis.

    Off-CPU the stacked cohort buffers (thetas, batches) are donated:
    both are per-round temporaries at every call site (thetas are
    gathered from the bank/rows, batches from the arena), so their HBM
    recycles into the outputs and the cohort step allocates nothing
    net. Pass ``donate=False`` if a caller reuses either after the
    call. CPU ignores donation; the knob resolves when the cohort fn is
    built, which is per-EngineContext (not per-import)."""
    cu = make_client_update(loss_fn, lr, lam, local_steps, backend, fused=fused)
    dn = (0, 2) if (donate and jax.default_backend() != "cpu") else ()
    return jax.jit(jax.vmap(cu, in_axes=(0, None, 0)), donate_argnums=dn)


def chunk_map(fn, in_axes, chunk: int, donate=None):
    """Memory-flat cohort execution: run a vmapped per-client ``fn`` over
    the cohort in fixed-size chunks via ``lax.map``.

    ``in_axes`` mirrors the vmap spec (0 = stacked per-client arg, None =
    shared/broadcast arg). Cohorts of ≤ ``chunk`` clients run unchunked;
    larger ones are padded to a chunk multiple (repeating leading rows —
    the pad outputs are sliced off) and reshaped to ``(n_chunks, chunk,
    ...)`` so ``lax.map`` executes one chunk at a time with reused
    buffers: peak activation memory is O(chunk), not O(cohort), which is
    what lets 100% participation at thousands of clients fit. The wrapper
    is jitted so the whole chunk loop is one XLA program; ``donate``
    argument positions (default: every stacked arg) are donated off-CPU
    so their buffers are recycled in place — pass a narrower tuple when
    the caller reuses a stacked input after the call.

    ``chunk <= 0`` disables chunking (returns ``fn`` unchanged).
    """
    if not chunk or chunk <= 0:
        return fn
    mapped_pos = tuple(i for i, ax in enumerate(in_axes) if ax == 0)
    donate = mapped_pos if donate is None else tuple(donate)

    def wrapper(*args):
        C = jax.tree.leaves(args[mapped_pos[0]])[0].shape[0]
        if C <= chunk:
            return fn(*args)
        n_chunks = -(-C // chunk)
        pad = n_chunks * chunk - C

        def prep(tree):
            def one(x):
                if pad:
                    x = jnp.concatenate([x, x[:pad]], axis=0)
                return x.reshape((n_chunks, chunk) + x.shape[1:])

            return jax.tree.map(one, tree)

        stacked = tuple(prep(args[i]) for i in mapped_pos)

        def body(chunks):
            full = list(args)
            for p, c in zip(mapped_pos, chunks):
                full[p] = c
            return fn(*full)

        outs = jax.lax.map(body, stacked)
        return jax.tree.map(
            lambda x: x.reshape((n_chunks * chunk,) + x.shape[2:])[:C], outs)

    if jax.default_backend() == "cpu":      # donation unimplemented on CPU
        donate = ()
    return jax.jit(wrapper, donate_argnums=donate)


def aggregate(trees_list, weights):
    """Server Aggregate/FedAvg: sample-count weighted mean."""
    return trees.tree_weighted_mean(trees_list, weights)


def aggregate_segments(stacked, weights, segment_ids, num_segments: int):
    """Per-cluster FedAvg as ONE batched op: weighted mean over rows of a
    stacked pytree grouped by ``segment_ids`` (cohort row -> cluster
    index). Replaces the per-root Python gather/aggregate loop — the
    server side of the round stays a fixed number of device ops no matter
    how many clusters the cohort spans."""
    w = jnp.asarray(weights, jnp.float32)
    seg = jnp.asarray(segment_ids)
    denom = jax.ops.segment_sum(w, seg, num_segments=num_segments)
    wn = w / denom[seg]

    def leaf(x):
        wb = wn.reshape((-1,) + (1,) * (x.ndim - 1))
        return jax.ops.segment_sum(x * wb, seg,
                                   num_segments=num_segments).astype(x.dtype)

    return jax.tree.map(leaf, stacked)


def aggregate_stacked(stacked, weights):
    """Weighted mean over the leading client axis of a stacked pytree."""
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.sum(w)

    def mean_leaf(x):
        wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.sum(x * wb, axis=0).astype(x.dtype)

    return jax.tree.map(mean_leaf, stacked)


def local_sgd(loss_fn, params, batch, lr, steps, prox_to=None, lam=0.0,
              fused: bool = False, backend: str = "auto"):
    """Generic E-step local SGD (shared by FedAvg/FedProx/Ditto/IFCA/CFL).

    prox_to: optional reference params for a FedProx/Ditto prox term.
    ``fused=True`` runs the step loop on the flattened vector through
    ``prox_update_flat`` (θ-output only; the reference is the prox
    anchor, or θ itself with λ=0 for plain SGD — algebraically the same
    expression tree as the unfused path, so fp32 stays bitwise)."""
    grad_fn = jax.grad(loss_fn)

    if fused:
        spec = flat_spec(params)
        ref_f = None if prox_to is None else flatten_tree(prox_to)

        def fstep(pf, _):
            g_f = flatten_tree(grad_fn(unflatten_tree(pf, spec), batch))
            ref = pf if ref_f is None else ref_f
            lam_eff = 0.0 if ref_f is None else lam
            pf, _unused = ops.prox_update_flat(pf, ref, g_f, g_f, lr, lam_eff,
                                               backend=backend)
            return pf, None

        out_f, _ = jax.lax.scan(fstep, flatten_tree(params), None, length=steps)
        return unflatten_tree(out_f, spec)

    def step(p, _):
        g = grad_fn(p, batch)
        if prox_to is not None:
            g = jax.tree.map(lambda gi, pi, ri: gi + lam * (pi - ri), g, p, prox_to)
        p = jax.tree.map(lambda pi, gi: (pi - lr * gi).astype(pi.dtype), p, g)
        return p, None

    out, _ = jax.lax.scan(step, params, None, length=steps)
    return out
