"""Pluggable global-objective aggregators for ω (paper §3.4: "StoCFL is
free to select the global objective G(·) … the cluster model could inherit
the convergence benefit (e.g., robustness or fairness)"), plus the §5
future-work Byzantine screen.

All operate on a stacked client-update pytree (leading client axis) and a
weight vector; all are jit-safe.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import trees


def mean_aggregate(stacked, weights):
    """FedAvg: sample-size-weighted mean (the paper's default G)."""
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.sum(w)

    def leaf(x):
        wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.sum(x * wb, axis=0).astype(x.dtype)

    return jax.tree.map(leaf, stacked)


def median_aggregate(stacked, weights=None):
    """Coordinate-wise median — robust to < 50% arbitrary clients."""
    return jax.tree.map(lambda x: jnp.median(x, axis=0).astype(x.dtype), stacked)


def trimmed_mean_aggregate(stacked, weights=None, trim_frac: float = 0.2):
    """Coordinate-wise α-trimmed mean."""
    def leaf(x):
        n = x.shape[0]
        k = min(int(n * trim_frac), (n - 1) // 2)
        xs = jnp.sort(x, axis=0)
        sel = xs[k : n - k] if n - 2 * k > 0 else xs
        return jnp.mean(sel, axis=0).astype(x.dtype)

    return jax.tree.map(leaf, stacked)


def krum_select(stacked, weights=None, f: int = 1):
    """Krum: return the single client update closest to its n−f−2 nearest
    neighbours (Blanchard et al.) — Byzantine-tolerant selection."""
    flats = jax.vmap(trees.tree_flatten_vector)(stacked)      # (n, d)
    n = flats.shape[0]
    d2 = jnp.sum((flats[:, None, :] - flats[None, :, :]) ** 2, axis=-1)
    d2 = d2 + jnp.eye(n) * 1e30
    m = max(n - f - 2, 1)
    scores = jnp.sum(jnp.sort(d2, axis=1)[:, :m], axis=1)
    best = jnp.argmin(scores)
    return jax.tree.map(lambda x: x[best], stacked)


AGGREGATORS = {
    "mean": mean_aggregate,
    "median": median_aggregate,
    "trimmed_mean": trimmed_mean_aggregate,
    "krum": krum_select,
}


def byzantine_distance_screen(reps: np.ndarray, tau_screen: float = 0.0):
    """§5 future-work sketch: flag clients whose Ψ is anomalously far from
    EVERY cluster mean (cosine below tau_screen to all clusters) — those
    join no benign cluster and can be quarantined. Returns a boolean keep
    mask over rows of `reps` given cluster `means`."""
    def screen(means: np.ndarray):
        rn = reps / (np.linalg.norm(reps, axis=1, keepdims=True) + 1e-12)
        mn = means / (np.linalg.norm(means, axis=1, keepdims=True) + 1e-12)
        sims = rn @ mn.T                                  # (n, K)
        return sims.max(axis=1) >= tau_screen

    return screen
