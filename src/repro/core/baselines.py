"""Baselines the paper compares against (§4.2): FedAvg, FedProx, Ditto,
IFCA (hypothesis clustering), CFL (Sattler recursive bi-partitioning).

All share the cohort-vmapped local-SGD primitive so comparisons are
apples-to-apples with StoCFL's trainer.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bilevel
from repro.utils import trees


@dataclasses.dataclass
class FLConfig:
    lr: float = 0.1
    local_steps: int = 5
    sample_rate: float = 0.1
    seed: int = 0
    mu: float = 0.05          # FedProx / Ditto prox weight


class _Base:
    def __init__(self, loss_fn, init_params, clients, cfg: FLConfig, eval_fn=None):
        self.loss_fn = loss_fn
        self.cfg = cfg
        self.clients = list(clients)
        self.n = len(clients)
        self.eval_fn = eval_fn
        self.rng = np.random.default_rng(cfg.seed)
        self.init_params = init_params
        self.sizes = np.array([int(np.shape(jax.tree.leaves(c)[0])[0]) for c in clients])

    def sample(self):
        m = max(int(round(self.cfg.sample_rate * self.n)), 1)
        return self.rng.choice(self.n, size=m, replace=False)

    def _stack(self, ids):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *[self.clients[int(c)] for c in ids])

    def fit(self, rounds: int):
        for _ in range(rounds):
            self.round()
        return self


class FedAvg(_Base):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.global_params = self.init_params
        cfg = self.cfg
        self._update = jax.jit(jax.vmap(
            lambda p, b: bilevel.local_sgd(self.loss_fn, p, b, cfg.lr, cfg.local_steps),
            in_axes=(None, 0)))

    def round(self, ids=None):
        ids = self.sample() if ids is None else np.asarray(ids)
        outs = self._update(self.global_params, self._stack(ids))
        self.global_params = bilevel.aggregate_stacked(outs, self.sizes[ids].astype(np.float32))

    def evaluate(self, test_sets: Dict[int, dict], true_cluster=None):
        accs = {k: float(self.eval_fn(self.global_params, b)) for k, b in test_sets.items()}
        return {"cluster_avg": float(np.mean(list(accs.values()))), "per": accs}


class FedProx(FedAvg):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        cfg = self.cfg
        self._update = jax.jit(jax.vmap(
            lambda p, b: bilevel.local_sgd(self.loss_fn, p, b, cfg.lr, cfg.local_steps,
                                           prox_to=p, lam=cfg.mu),
            in_axes=(None, 0)))
        # NOTE: prox_to=p (the broadcast global) is constant through the scan
        # because local_sgd closes over the *initial* params for the prox.


class Ditto(_Base):
    """Global FedAvg + per-client personal models with prox to global."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.global_params = self.init_params
        self.personal = {i: self.init_params for i in range(self.n)}
        cfg = self.cfg
        self._gupd = jax.jit(jax.vmap(
            lambda p, b: bilevel.local_sgd(self.loss_fn, p, b, cfg.lr, cfg.local_steps),
            in_axes=(None, 0)))
        self._pupd = jax.jit(jax.vmap(
            lambda v, g, b: bilevel.local_sgd(self.loss_fn, v, b, cfg.lr, cfg.local_steps,
                                              prox_to=g, lam=cfg.mu),
            in_axes=(0, None, 0)))

    def round(self, ids=None):
        ids = self.sample() if ids is None else np.asarray(ids)
        batches = self._stack(ids)
        g_outs = self._gupd(self.global_params, batches)
        v_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *[self.personal[int(c)] for c in ids])
        v_outs = self._pupd(v_stack, self.global_params, batches)
        self.global_params = bilevel.aggregate_stacked(g_outs, self.sizes[ids].astype(np.float32))
        for j, c in enumerate(ids):
            self.personal[int(c)] = jax.tree.map(lambda x: x[j], v_outs)

    def evaluate(self, test_sets: Dict[int, dict], true_cluster: Sequence[int]):
        """Per true cluster: average of its clients' personal models' acc."""
        out = {}
        for tc, batch in test_sets.items():
            members = [i for i in range(self.n) if true_cluster[i] == tc]
            accs = [float(self.eval_fn(self.personal[i], batch)) for i in members[:8]]
            out[tc] = float(np.mean(accs)) if accs else float(self.eval_fn(self.global_params, batch))
        return {"cluster_avg": float(np.mean(list(out.values()))), "per": out}


class IFCA(_Base):
    """Ghosh et al. 2020: M̃ hypothesis models, clients pick argmin loss."""

    def __init__(self, loss_fn, init_params, clients, cfg, eval_fn=None,
                 n_models: int = 4, init_key=0):
        super().__init__(loss_fn, init_params, clients, cfg, eval_fn)
        keys = jax.random.split(jax.random.PRNGKey(init_key), n_models)
        # perturb around init: IFCA needs distinct initializations
        self.models = [jax.tree.map(
            lambda x, k=k: x + 0.1 * jax.random.normal(jax.random.fold_in(k, 0), x.shape, x.dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, init_params) for k in keys]
        self.n_models = n_models
        cfg = self.cfg
        self._upd = jax.jit(jax.vmap(
            lambda p, b: bilevel.local_sgd(self.loss_fn, p, b, cfg.lr, cfg.local_steps),
            in_axes=(0, 0)))

    def _choose(self, batch):
        losses = [float(self.loss_fn(m, batch)) for m in self.models]
        return int(np.argmin(losses))

    def round(self, ids=None):
        ids = self.sample() if ids is None else np.asarray(ids)
        choices = [self._choose(self.clients[int(c)]) for c in ids]
        stacked_params = jax.tree.map(lambda *xs: jnp.stack(xs),
                                      *[self.models[ch] for ch in choices])
        outs = self._upd(stacked_params, self._stack(ids))
        for m in range(self.n_models):
            idx = [j for j, ch in enumerate(choices) if ch == m]
            if idx:
                sel = jax.tree.map(lambda x: x[np.array(idx)], outs)
                self.models[m] = bilevel.aggregate_stacked(
                    sel, self.sizes[ids[np.array(idx)]].astype(np.float32))

    def evaluate(self, test_sets: Dict[int, dict], true_cluster=None):
        out = {}
        for tc, batch in test_sets.items():
            accs = [float(self.eval_fn(m, batch)) for m in self.models]
            out[tc] = float(np.max(accs))     # best-model (oracle assignment)
        return {"cluster_avg": float(np.mean(list(out.values()))), "per": out}


class CFLSattler(_Base):
    """Sattler et al. 2020a: full participation; recursively bi-partition a
    cluster near stationarity: ‖mean Δ‖ < eps_rel · max‖Δᵢ‖ and
    max‖Δᵢ‖ > eps2 (relative form — scale-free across tasks/lrs).

    Bi-partition: seeds = least-similar pair by update-cosine, greedy
    assignment to the more similar seed (the standard approximation of the
    min-cross-similarity split)."""

    def __init__(self, loss_fn, init_params, clients, cfg, eval_fn=None,
                 eps_rel: float = 0.35, eps2: float = 0.01):
        super().__init__(loss_fn, init_params, clients, cfg, eval_fn)
        self.eps_rel, self.eps2 = eps_rel, eps2
        self.clusters: List[List[int]] = [list(range(self.n))]
        self.models = [self.init_params]
        cfg = self.cfg
        self._upd = jax.jit(jax.vmap(
            lambda p, b: bilevel.local_sgd(self.loss_fn, p, b, cfg.lr, cfg.local_steps),
            in_axes=(None, 0)))

    def round(self, ids=None):
        new_clusters, new_models = [], []
        for members, model in zip(self.clusters, self.models):
            outs = self._upd(model, self._stack(members))
            deltas = jax.tree.map(lambda o, m: o - m, outs, model)
            flat = np.stack([np.asarray(trees.tree_flatten_vector(
                jax.tree.map(lambda x: x[j], deltas))) for j in range(len(members))])
            new_model = bilevel.aggregate_stacked(outs, self.sizes[np.array(members)].astype(np.float32))
            mean_norm = float(np.linalg.norm(flat.mean(axis=0)))
            max_norm = float(np.linalg.norm(flat, axis=1).max())
            if len(members) > 2 and max_norm > self.eps2 and mean_norm < self.eps_rel * max_norm:
                sims = (flat / (np.linalg.norm(flat, axis=1, keepdims=True) + 1e-12))
                M = sims @ sims.T
                i, j = np.unravel_index(np.argmin(M), M.shape)
                c1 = [m for idx, m in enumerate(members) if M[idx, i] >= M[idx, j]]
                c2 = [m for m in members if m not in c1]
                if c1 and c2:
                    new_clusters += [c1, c2]
                    new_models += [new_model, new_model]
                    continue
            new_clusters.append(members)
            new_models.append(new_model)
        self.clusters, self.models = new_clusters, new_models

    def cluster_of(self, cid: int) -> int:
        for k, c in enumerate(self.clusters):
            if cid in c:
                return k
        return 0

    def evaluate(self, test_sets: Dict[int, dict], true_cluster: Sequence[int]):
        out = {}
        for tc, batch in test_sets.items():
            ks = [self.cluster_of(i) for i in range(self.n) if true_cluster[i] == tc]
            k = max(set(ks), key=ks.count)
            out[tc] = float(self.eval_fn(self.models[k], batch))
        return {"cluster_avg": float(np.mean(list(out.values()))), "per": out,
                "n_clusters": len(self.clusters)}
