"""Baselines the paper compares against (§4.2) — DEPRECATED class shims.

The actual methods live in ``repro.engine.strategies`` as registry
entries ("fedavg", "fedprox", "ditto", "ifca", "cfl") over the same
vmapped cohort primitives as StoCFL, so comparisons are apples-to-apples
by construction. These classes keep the original object surface for
existing callers; new code should use the functional engine API:

    state = engine.init("fedavg", loss_fn, params, clients, cfg, eval_fn=acc)
    state, rec = engine.run_round(state)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

# Module-object import only (see stocfl.py: engine<->core import cycle).
from repro import engine


@dataclasses.dataclass
class FLConfig:
    lr: float = 0.1
    local_steps: int = 5
    sample_rate: float = 0.1
    seed: int = 0
    mu: float = 0.05          # FedProx / Ditto prox weight


class _EngineShim:
    """Common shell: holds one ``ServerState``, delegates every method."""

    strategy: str = ""

    def __init__(self, loss_fn, init_params, clients, cfg: FLConfig,
                 eval_fn=None, **extra):
        self.cfg = cfg
        ecfg = engine.EngineConfig(lr=cfg.lr, local_steps=cfg.local_steps,
                                   sample_rate=cfg.sample_rate, seed=cfg.seed,
                                   mu=cfg.mu, **extra)
        self._st = engine.init(self.strategy, loss_fn, init_params, clients,
                               ecfg, eval_fn=eval_fn)

    # ---------------------------------------------------------- state views
    @property
    def server_state(self) -> engine.ServerState:
        return self._st

    @property
    def clients(self):
        return self._st.ctx.clients

    @property
    def n(self) -> int:
        return self._st.n_clients

    @property
    def sizes(self) -> np.ndarray:
        return np.asarray(self._st.sizes)

    @property
    def init_params(self):
        return self._st.ctx.init_params

    @property
    def loss_fn(self):
        return self._st.ctx.loss_fn

    @property
    def eval_fn(self):
        return self._st.ctx.eval_fn

    # ------------------------------------------------------------- driving
    def sample(self) -> np.ndarray:
        adv, ids = engine.sample_clients(self._st)
        self._st = engine.advance_rng(self._st, adv)
        return ids

    def round(self, ids: Optional[Sequence[int]] = None):
        self._st, rec = engine.run_round(self._st, ids)
        return rec

    def fit(self, rounds: int):
        for _ in range(rounds):
            self.round()
        return self

    def evaluate(self, test_sets, true_cluster=None):
        return engine.evaluate(self._st, test_sets, true_cluster)


class FedAvg(_EngineShim):
    """Single-global-model FedAvg (the λ=0 ∧ τ=−1 degeneration)."""
    strategy = "fedavg"

    @property
    def global_params(self):
        """The global model ω."""
        return self._st.omega

    @global_params.setter
    def global_params(self, value):
        self._st = self._st.replace(omega=value)


class FedProx(FedAvg):
    """FedAvg with a prox term to the broadcast global (μ = cfg.mu)."""
    strategy = "fedprox"


class Ditto(FedAvg):
    """Global FedAvg + per-client personal models with prox to global."""
    strategy = "ditto"

    @property
    def personal(self):
        """{client id: personal model} (prox-to-global, τ=1 regime)."""
        return self._st.personal


class IFCA(_EngineShim):
    """Ghosh et al. 2020: M̃ hypothesis models, clients pick argmin loss."""
    strategy = "ifca"

    def __init__(self, loss_fn, init_params, clients, cfg, eval_fn=None,
                 n_models: int = 4, init_key: int = 0):
        super().__init__(loss_fn, init_params, clients, cfg, eval_fn=eval_fn,
                         n_models=n_models, init_key=init_key)
        self.n_models = n_models

    @property
    def models(self):
        """The M̃ hypothesis models, index-ordered."""
        return [self._st.models[m] for m in range(self.n_models)]


class CFLSattler(_EngineShim):
    """Sattler et al. 2020a recursive bi-partitioning (full participation)."""
    strategy = "cfl"

    def __init__(self, loss_fn, init_params, clients, cfg, eval_fn=None,
                 eps_rel: float = 0.35, eps2: float = 0.01):
        super().__init__(loss_fn, init_params, clients, cfg, eval_fn=eval_fn,
                         eps_rel=eps_rel, eps2=eps2)
        self.eps_rel, self.eps2 = eps_rel, eps2

    @property
    def clusters(self):
        """Member client-id lists, one per current cluster."""
        return [list(m) for m in self._st.members]

    @property
    def models(self):
        """Per-cluster models, index-aligned with ``clusters``."""
        return [self._st.models[k] for k in range(len(self._st.members))]

    def cluster_of(self, cid: int) -> int:
        """Index of the cluster client ``cid`` belongs to."""
        from repro.engine.registry import get_strategy
        return get_strategy("cfl").cluster_of(self._st, cid)
