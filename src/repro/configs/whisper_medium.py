"""whisper-medium [audio] — enc-dec, conv/mel frontend STUBBED
[arXiv:2212.04356]. 24 encoder + 24 decoder layers, d_model=1024."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="whisper-medium",
    arch_type="audio",
    n_layers=24,              # decoder layers
    n_enc_layers=24,
    enc_seq=1500,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    source="arXiv:2212.04356",
)


def smoke():
    return FULL.with_(n_layers=2, n_enc_layers=2, enc_seq=64, d_model=128,
                      n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=512,
                      remat=False)
