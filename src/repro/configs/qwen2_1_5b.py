"""qwen2-1.5b [dense] — GQA kv=2, QKV bias [arXiv:2407.10671]."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2-1.5b",
    arch_type="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1000000.0,
    source="arXiv:2407.10671",
)


def smoke():
    return FULL.with_(n_layers=2, d_model=192, n_heads=6, n_kv_heads=2,
                      d_ff=384, vocab_size=512, remat=False)
