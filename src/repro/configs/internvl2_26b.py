"""internvl2-26b [vlm] — InternViT (STUBBED) + InternLM2-20B-class backbone
[arXiv:2404.16821]. Inputs are precomputed patch embeddings."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="internvl2-26b",
    arch_type="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    n_patches=1024,
    source="arXiv:2404.16821",
)


def smoke():
    return FULL.with_(n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
                      d_ff=512, vocab_size=512, n_patches=16, remat=False)
