"""Architecture configs assigned to this paper (+ the paper's own tasks).

Each module exposes FULL (exact assigned config) and smoke() (reduced
same-family variant: <=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "phi35_moe_42b",
    "llama3_8b",
    "whisper_medium",
    "internlm2_1_8b",
    "falcon_mamba_7b",
    "internvl2_26b",
    "zamba2_1_2b",
    "granite_3_8b",
    "deepseek_v2_236b",
    "qwen2_1_5b",
]

# CLI ids (the assignment's spelling) -> module names
CLI_ALIASES = {
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "llama3-8b": "llama3_8b",
    "whisper-medium": "whisper_medium",
    "internlm2-1.8b": "internlm2_1_8b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "internvl2-26b": "internvl2_26b",
    "zamba2-1.2b": "zamba2_1_2b",
    "granite-3-8b": "granite_3_8b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "qwen2-1.5b": "qwen2_1_5b",
}


def get_config(arch: str, smoke: bool = False, **overrides):
    mod_name = CLI_ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg = mod.smoke() if smoke else mod.FULL
    return cfg.with_(**overrides) if overrides else cfg


def all_configs(smoke: bool = False):
    return {a: get_config(a, smoke=smoke) for a in ARCH_IDS}
