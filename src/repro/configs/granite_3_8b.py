"""granite-3-8b [dense] — GQA [hf:ibm-granite/granite-3.0-2b-base]."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="granite-3-8b",
    arch_type="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab_size=49155,
    source="hf:ibm-granite/granite-3.0-2b-base",
)


def smoke():
    return FULL.with_(n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
                      d_ff=512, vocab_size=512, remat=False)
