"""internlm2-1.8b [dense] — GQA [arXiv:2403.17297]."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="internlm2-1.8b",
    arch_type="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92544,
    source="arXiv:2403.17297",
)


def smoke():
    return FULL.with_(n_layers=2, d_model=256, n_heads=8, n_kv_heads=4,
                      d_ff=512, vocab_size=512, remat=False)
