"""zamba2-1.2b [hybrid] — Mamba2 core + shared attention blocks
[arXiv:2411.15242]. Shared GQA block applied every 6 core layers; its KV
cache uses a 4096 sliding window so the hybrid runs long_500k natively."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_head_dim=64,
    ssm_version=2,
    attn_every=6,
    sliding_window=4096,
    source="arXiv:2411.15242",
)


def smoke():
    return FULL.with_(n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
                      d_ff=256, vocab_size=512, ssm_state=16, ssm_head_dim=32,
                      attn_every=2, sliding_window=64, remat=False)
