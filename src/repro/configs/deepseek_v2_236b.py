"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434]. Layer 0 is dense (moe_layer_start=1)."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=12288,              # dense-layer / shared-path ffn
    vocab_size=102400,
    n_experts=160,
    n_shared_experts=2,
    moe_top_k=6,
    moe_d_ff=1536,
    moe_layer_start=1,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    source="arXiv:2405.04434",
)


def smoke():
    return FULL.with_(n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
                      d_ff=512, vocab_size=512, n_experts=4, moe_top_k=2, capacity_factor=4.0,
                      n_shared_experts=1, moe_d_ff=128, moe_layer_start=1,
                      kv_lora_rank=64, qk_rope_dim=16, qk_nope_dim=32,
                      v_head_dim=32, remat=False)
