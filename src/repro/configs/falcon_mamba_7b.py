"""falcon-mamba-7b [ssm] — Mamba1, attention-free [arXiv:2410.05355]."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="falcon-mamba-7b",
    arch_type="ssm",
    n_layers=64,
    d_model=4096,
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    ssm_version=1,
    source="arXiv:2410.05355",
)


def smoke():
    return FULL.with_(n_layers=2, d_model=128, vocab_size=512, ssm_state=16,
                      remat=False)
