"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2, GQA kv=8
[hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    arch_type="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    n_experts=16,
    moe_top_k=2,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)


def smoke():
    return FULL.with_(n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
                      d_ff=256, vocab_size=512, n_experts=4, moe_top_k=2, capacity_factor=4.0,
                      remat=False)
