"""Functional engine API: pure transitions over ``ServerState``.

    state = engine.init("stocfl", loss_fn, params, clients, cfg, eval_fn=acc)
    state, rec = engine.run_round(state)            # samples internally
    state, rec = engine.run_round(state, [0, 3, 7]) # or explicit cohort
    state, cid = engine.join(state, new_batch)      # §5 dynamic membership
    state = engine.leave(state, cid)
    engine.evaluate(state, test_sets, true_cluster)
    engine.infer(state, unseen_batch)               # §4.4 cluster inference

Every transition returns a NEW state; the input is never mutated (the one
deliberate exception: ``join``/``leave`` update the context's client
list/arena — the context is the world, not the state). Client sampling
draws from the numpy bit-generator state stored IN the state, so a
checkpointed run resumes bit-exactly. ``repro.sim.simulate`` drives these
same transitions over a churn timeline — there is no second code path.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.extractor import make_extractor
from repro.engine.registry import get_strategy
from repro.engine.state import EngineConfig, EngineContext, ServerState


def init(strategy: str, loss_fn, init_params, clients,
         cfg: Optional[EngineConfig] = None, eval_fn=None,
         leaf_filter=None, mesh=None, arena: bool = False) -> ServerState:
    """Build the static context and the strategy's initial ``ServerState``.

    Args:
      strategy: registered strategy name (``engine.list_strategies()``) —
        ``"stocfl"`` (Algorithm 1) or one of the paper's §4 baselines.
      loss_fn: ``(params, batch) -> scalar`` local objective f_i.
      init_params: ω₀ — also the frozen Ψ anchor (§3.1) and the lazy
        cluster-model default θ_k.
      clients: list of client datasets (pytrees with a shared leading
        example axis).
      cfg: ``EngineConfig`` hyperparameters (strategy-specific subset).
        ``cfg.cluster_backend="device"`` keeps StoCFL's partition as a
        jitted device union-find (``core.device_clustering``) — the
        clustering step then runs with no per-round host round-trip.
      eval_fn: optional ``(params, batch) -> accuracy`` used by
        ``evaluate`` and the simulator's §5 recovery tracking.
      leaf_filter: optional Ψ restriction to a parameter subset (LLM
        anchors: ``extractor.llm_leaf_filter``).
      mesh: optional jax Mesh; cohort steps are placed on its client axis.
      arena: pack all client shards into a device-resident
        ``ClientArena`` so each round's cohort is one gather instead of a
        per-round Python restack (ragged shard sizes are pad-and-masked;
        the loss must then honor the batch's ``"mask"`` leaf).
        ``cfg.cohort_chunk`` bounds how many clients one vmapped step
        executes — see ``bilevel.chunk_map``.

    Returns:
      The strategy's initial ``ServerState`` (round 0, nothing trained).
    """
    cfg = cfg or EngineConfig()
    ctx = EngineContext(loss_fn=loss_fn, init_params=init_params,
                        clients=list(clients), cfg=cfg, eval_fn=eval_fn,
                        leaf_filter=leaf_filter, mesh=mesh)
    if arena:
        from repro.data.arena import ClientArena
        ctx.arena = ClientArena.from_clients(ctx.clients)
    strat = get_strategy(strategy)
    if strat.needs_extractor:
        ctx.extractor = make_extractor(loss_fn, init_params, cfg.project_dim,
                                       leaf_filter=leaf_filter)
    return strat.init_state(ctx)


def sample_clients(state: ServerState, unavailable=frozenset()):
    """Draw one round's cohort without replacement (§3.3 "arbitrary
    proportion of client participation").

    The cohort size is ``cfg.sample_rate`` × the LIVE population
    (registered minus departed), drawn from the generator state stored in
    ``state`` — pure and checkpoint-exact. ``unavailable`` removes
    additional clients from the pool for this draw only (the simulator's
    availability windows, §5).

    Returns:
      (advanced rng bit-generator state, sampled client id array).
    """
    cfg = state.ctx.cfg
    rng = state.rng()
    pool = np.array([i for i in range(state.n_clients)
                     if i not in state.left and i not in unavailable])
    live = state.n_clients - len(state.left)
    m = max(int(round(cfg.sample_rate * live)), 1)
    ids = rng.choice(pool, size=min(m, len(pool)), replace=False)
    return rng.bit_generator.state, ids


def run_round(state: ServerState, client_ids: Optional[Sequence[int]] = None):
    """One server round: ``(state, client_ids?) -> (state', metrics)``.

    With ``client_ids=None`` the cohort is sampled internally (advancing
    the state's rng; full-participation strategies take every live
    client). An explicit cohort skips sampling and leaves the rng
    untouched — the hook the simulator uses to apply availability
    windows and straggler dropout before training. ``metrics`` is the
    strategy's per-round record (appended to ``state.history``).
    """
    strat = get_strategy(state.strategy)
    rng_state = state.rng_state
    if client_ids is None:
        if strat.full_participation:
            client_ids = np.array([i for i in range(state.n_clients)
                                   if i not in state.left])
        else:
            rng_state, client_ids = sample_clients(state)
    client_ids = np.asarray(client_ids)
    if client_ids.size == 0:
        raise ValueError("run_round needs a non-empty cohort "
                         "(no clients sampled — all departed?)")
    state, rec = strat.round(state.ctx, state, client_ids)
    state = state.replace(round=state.round + 1, rng_state=rng_state,
                          history=state.history + (dict(rec),))
    return state, rec


def run(state: ServerState, rounds: int, log_every: int = 0) -> ServerState:
    """Convenience loop: ``rounds`` × ``run_round`` with optional progress
    printing every ``log_every`` rounds. Returns the final state (per-round
    metrics accumulate in ``state.history``)."""
    for t in range(rounds):
        state, rec = run_round(state)
        if log_every and t % log_every == 0:
            extras = "".join(f" {k}={v:.3f}" if isinstance(v, float) else f" {k}={v}"
                             for k, v in rec.items())
            print(f"round {t}:{extras}")
    return state


def evaluate(state: ServerState, test_sets, true_cluster=None) -> dict:
    """Strategy-appropriate held-out evaluation (paper §4.2 protocol).

    Args:
      test_sets: ``{latent cluster id: batch}`` held-out sets.
      true_cluster: latent cluster per client id — used by clustered
        strategies to route each test set through the learned cluster
        holding most of that latent cluster's clients.

    Returns:
      Dict with at least ``cluster_avg`` (mean per-cluster accuracy);
      StoCFL adds per-cluster and global-model numbers.
    """
    return get_strategy(state.strategy).evaluate(state.ctx, state,
                                                 test_sets, true_cluster)


def join(state: ServerState, batch):
    """Register a newly-arrived client (§5 dynamic membership).

    Appends ``batch`` to the context's client list (and arena, amortized
    O(1) via capacity doubling), assigns the next client id, and lets the
    strategy place the newcomer — StoCFL runs Ψ-inference against the
    existing partition (§4.4), joining the nearest cluster above τ or
    opening a fresh one seeded from the nearest cluster's model.

    Returns:
      (state', new client id).
    """
    return get_strategy(state.strategy).join(state.ctx, state, batch)


def leave(state: ServerState, cid: int) -> ServerState:
    """Remove a client from the federation (§5 departures).

    The client stops being sampled, the clustering partition drops it
    consistently (clusters keep their models — knowledge persists), and
    its arena row is tombstoned (reclaimed in bulk once enough rows die).
    Returns the new state.
    """
    return get_strategy(state.strategy).leave(state.ctx, state, cid)


def infer(state: ServerState, batch) -> dict:
    """Cluster inference for an UNSEEN client (§4.4), without joining:
    which cluster would serve this data, at what Ψ-cosine similarity,
    with which model. Returns ``{"cluster", "seed_from", "similarity",
    "model"}``; raises for strategies with no inference rule."""
    return get_strategy(state.strategy).infer(state.ctx, state, batch)
