"""Functional engine API: pure transitions over ``ServerState``.

    state = engine.init("stocfl", loss_fn, params, clients, cfg, eval_fn=acc)
    state, rec = engine.run_round(state)            # samples internally
    state, rec = engine.run_round(state, [0, 3, 7]) # or explicit cohort
    state, cid = engine.join(state, new_batch)      # §5 dynamic membership
    state = engine.leave(state, cid)
    engine.evaluate(state, test_sets, true_cluster)
    engine.infer(state, unseen_batch)               # §4.4 cluster inference

Every transition returns a NEW state; the input is never mutated (the one
deliberate exception: ``join``/``leave`` update the context's client
list/arena — the context is the world, not the state). Client sampling
draws from the rng stored IN the state — the numpy bit-generator under
``rng_backend="numpy"`` (compatibility mode), a device threefry key
under ``rng_backend="device"`` — so a checkpointed run resumes
bit-exactly either way. ``run_rounds`` collapses a whole multi-round
span into ONE jitted ``lax.scan`` (on-device sampling included) and is
bit-faithful to the eager ``run_round`` loop; ``repro.sim.simulate``
drives these same transitions over a churn timeline — there is no
second code path.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.extractor import make_extractor
from repro.engine import sampler
from repro.engine.registry import get_strategy
from repro.engine.state import EngineConfig, EngineContext, ServerState


def init(strategy: str, loss_fn, init_params, clients,
         cfg: Optional[EngineConfig] = None, eval_fn=None,
         leaf_filter=None, mesh=None, arena: bool = False) -> ServerState:
    """Build the static context and the strategy's initial ``ServerState``.

    Args:
      strategy: registered strategy name (``engine.list_strategies()``) —
        ``"stocfl"`` (Algorithm 1) or one of the paper's §4 baselines.
      loss_fn: ``(params, batch) -> scalar`` local objective f_i.
      init_params: ω₀ — also the frozen Ψ anchor (§3.1) and the lazy
        cluster-model default θ_k.
      clients: list of client datasets (pytrees with a shared leading
        example axis).
      cfg: ``EngineConfig`` hyperparameters (strategy-specific subset).
        ``cfg.cluster_backend="device"`` keeps StoCFL's partition as a
        jitted device union-find (``core.device_clustering``) — the
        clustering step then runs with no per-round host round-trip.
      eval_fn: optional ``(params, batch) -> accuracy`` used by
        ``evaluate`` and the simulator's §5 recovery tracking.
      leaf_filter: optional Ψ restriction to a parameter subset (LLM
        anchors: ``extractor.llm_leaf_filter``).
      mesh: optional jax Mesh; cohort steps are placed on its client axis.
      arena: pack all client shards into a device-resident
        ``ClientArena`` so each round's cohort is one gather instead of a
        per-round Python restack (ragged shard sizes are pad-and-masked;
        the loss must then honor the batch's ``"mask"`` leaf).
        ``cfg.cohort_chunk`` bounds how many clients one vmapped step
        executes — see ``bilevel.chunk_map``.

    Returns:
      The strategy's initial ``ServerState`` (round 0, nothing trained).
    """
    cfg = cfg or EngineConfig()
    # Ψ stays anchored at the ORIGINAL fp32 params even in bf16 mode:
    # the anchor is frozen (§3.1), so embeddings/means/Eq. 2 keep full
    # precision while params/grads/batches run in cfg.dtype
    psi_anchor = init_params
    if cfg.dtype != "float32":
        dt = _np_like_dtype(cfg.dtype)
        init_params = _cast_floating(init_params, dt)
        clients = [_cast_floating(c, dt) for c in clients]
    ctx = EngineContext(loss_fn=loss_fn, init_params=init_params,
                        clients=list(clients), cfg=cfg, eval_fn=eval_fn,
                        leaf_filter=leaf_filter, mesh=mesh)
    if arena:
        from repro.data.arena import ClientArena
        from repro.sharding import specs as shard_specs
        # mesh-aligned row capacity: the packed leading axis must divide
        # the client-axis device count for the arena rows to shard (the
        # pad rows are zeroed spare capacity, never gathered); the
        # pow2-doubling grow preserves the alignment thereafter
        cap = (shard_specs.align_cohort_chunk(len(ctx.clients), mesh)
               if mesh is not None else None)
        ctx.arena = ClientArena.from_clients(ctx.clients, capacity=cap)
        if mesh is not None:
            ctx.arena = ctx.arena.place(mesh)
    strat = get_strategy(strategy)
    if strat.needs_extractor:
        ctx.extractor = make_extractor(loss_fn, psi_anchor, cfg.project_dim,
                                       leaf_filter=leaf_filter)
    return strat.init_state(ctx)


def _np_like_dtype(name: str):
    import jax.numpy as jnp
    dt = jnp.dtype(name)
    if not jnp.issubdtype(dt, jnp.floating):
        raise ValueError(f"EngineConfig.dtype must be a float dtype, got {name!r}")
    return dt


def _cast_floating(tree, dt):
    """Cast every floating leaf of a pytree to ``dt`` (ints/bools — labels,
    masks, counters — keep their dtype)."""
    import jax
    import jax.numpy as jnp

    def leaf(x):
        x = jnp.asarray(x)
        return x.astype(dt) if jnp.issubdtype(x.dtype, jnp.floating) else x

    return jax.tree.map(leaf, tree)


def sample_clients(state: ServerState, unavailable=frozenset()):
    """Draw one round's cohort without replacement (§3.3 "arbitrary
    proportion of client participation").

    The cohort size is ``cfg.sample_rate`` × the LIVE population
    (registered minus departed), drawn from the rng stored in ``state``
    — pure and checkpoint-exact. ``unavailable`` removes additional
    clients from the pool for this draw only (the simulator's
    availability windows, §5). Under ``rng_backend="device"`` the draw
    is the on-device threefry sampler (``engine.sampler.draw_cohort``,
    size ⌈rate·live⌉) — the SAME traceable draw the ``run_rounds`` scan
    inlines, so eager and scanned loops sample identical cohorts.

    Returns:
      (advanced rng: bit-generator state dict or device key, sampled
      client id array) — thread the first element back with
      ``advance_rng``.
    """
    cfg = state.ctx.cfg
    if cfg.rng_backend == "device":
        pool = sampler.cohort_pool(state.n_clients, state.left, unavailable,
                                   capacity=sampler.pool_capacity(
                                       state.n_clients))
        live = state.n_clients - len(state.left)
        m = sampler.cohort_size(cfg.sample_rate, live, int(pool.sum()))
        if m == 0:
            return state.rng_key, np.zeros(0, np.int64)
        key, ids = sampler.draw_cohort(state.rng_key, pool, m)
        return key, np.asarray(ids).astype(np.int64)
    rng = state.rng()
    pool = np.array([i for i in range(state.n_clients)
                     if i not in state.left and i not in unavailable])
    live = state.n_clients - len(state.left)
    m = max(int(round(cfg.sample_rate * live)), 1)
    ids = rng.choice(pool, size=min(m, len(pool)), replace=False)
    return rng.bit_generator.state, ids


def advance_rng(state: ServerState, rng) -> ServerState:
    """Store an advanced sampling rng back into the state — the dict
    bit-generator state (numpy backend) or the split device key (device
    backend), i.e. whatever ``sample_clients`` returned first."""
    if state.ctx.cfg.rng_backend == "device":
        return state.replace(rng_key=rng)
    return state.replace(rng_state=rng)


def run_round(state: ServerState, client_ids: Optional[Sequence[int]] = None):
    """One server round: ``(state, client_ids?) -> (state', metrics)``.

    With ``client_ids=None`` the cohort is sampled internally (advancing
    the state's rng; full-participation strategies take every live
    client). An explicit cohort skips sampling and leaves the rng
    untouched — the hook the simulator uses to apply availability
    windows and straggler dropout before training. ``metrics`` is the
    strategy's per-round record (appended to ``state.history``).
    """
    strat = get_strategy(state.strategy)
    rng_state, rng_key = state.rng_state, state.rng_key
    if client_ids is None:
        if strat.full_participation:
            client_ids = np.array([i for i in range(state.n_clients)
                                   if i not in state.left])
        elif state.ctx.cfg.rng_backend == "device":
            rng_key, client_ids = sample_clients(state)
        else:
            rng_state, client_ids = sample_clients(state)
    client_ids = np.asarray(client_ids)
    if client_ids.size == 0:
        raise ValueError("run_round needs a non-empty cohort "
                         "(no clients sampled — all departed or "
                         "unavailable?); the scanned loop handles this "
                         "as a skipped no-op round instead "
                         "(see run_rounds)")
    state, rec = strat.round(state.ctx, state, client_ids)
    state = state.replace(round=state.round + 1, rng_state=rng_state,
                          rng_key=rng_key,
                          history=state.history + (dict(rec),))
    return state, rec


def run(state: ServerState, rounds: int, log_every: int = 0) -> ServerState:
    """Convenience loop: ``rounds`` × ``run_round`` with optional progress
    printing every ``log_every`` rounds. Returns the final state (per-round
    metrics accumulate in ``state.history``)."""
    for t in range(rounds):
        state, rec = run_round(state)
        if log_every and t % log_every == 0:
            extras = "".join(f" {k}={v:.3f}" if isinstance(v, float) else f" {k}={v}"
                             for k, v in rec.items())
            print(f"round {t}:{extras}")
    return state


def scan_blockers(state: ServerState) -> Optional[str]:
    """Why this state cannot run through ``run_rounds`` — a readable
    reason string, or None when it can. The single predicate behind
    both ``run_rounds``' host-side precondition errors and the
    simulator's silent eager fallback (``simulate(scan_spans=True)``):
    the scan needs a device arena (cohort gathers must be traceable),
    device rng for sampled strategies, the device clustering backend
    for StoCFL, and every live client resident in the arena."""
    from repro.engine.strategies import Strategy

    strat = get_strategy(state.strategy)
    ctx = state.ctx
    if type(strat).scan_round is Strategy.scan_round:
        return (f"strategy {state.strategy!r} has no scannable round "
                "step (Strategy.scan_round not implemented) — use the "
                "eager run_round loop")
    if ctx.arena is None:
        return ("run_rounds needs engine.init(..., arena=True): "
                "the scanned round body gathers cohorts on device")
    if not strat.full_participation and state.rng_key is None:
        return ("run_rounds needs EngineConfig(rng_backend='device'): "
                "the scan samples cohorts from the threefry key in "
                "ServerState.rng_key (the numpy bit-generator cannot "
                "be traced)")
    if state.strategy == "stocfl" and ctx.cfg.cluster_backend != "device":
        return ("run_rounds('stocfl') needs "
                "EngineConfig(cluster_backend='device'): the host "
                "ClusterState cannot ride a lax.scan carry")
    bad = [c for c in range(state.n_clients) if c not in state.left
           and ctx.arena.rows[c] < 0]
    if bad:
        return (f"live clients {bad} were compacted out of the arena — "
                "rebuild it before scanning")
    return None


def run_rounds(state: ServerState, rounds: int,
               unavailable=frozenset()) -> ServerState:
    """The whole multi-round loop as ONE jitted ``lax.scan`` — the
    fused counterpart of ``rounds`` × ``run_round``.

    Each scanned round samples its cohort on device
    (``engine.sampler.draw``), gathers client shards from the arena,
    runs the strategy's round math, and aggregates — with NO host
    round-trip between rounds. The carry is fixed-shape (model pytrees,
    stacked banks, ``DeviceClusterState``, the PRNG key), per-round
    metrics stack as scan outputs and land in ``state.history`` exactly
    as the eager loop would have recorded them. The result is
    bit-faithful to ``run_round``: the scan-vs-eager parity battery
    (``tests/test_round_scan.py``) pins bitwise-equal final states for
    every registered strategy, through churn boundaries and checkpoint
    resume.

    Requirements (checked eagerly, see the raised messages):
    ``arena=True``, ``rng_backend="device"`` for sampled strategies, and
    ``cluster_backend="device"`` for StoCFL. Population changes cannot
    happen inside a scan — call ``join``/``leave`` between ``run_rounds``
    calls (the simulator scans exactly the event-free spans).

    ``unavailable`` holds a constant set of clients out of every scanned
    draw. If it empties the pool entirely, the rounds become no-op
    rounds recorded as ``{"skipped": True}`` metrics (the eager path
    raises instead — a scan cannot). Availability does not apply to
    full-participation strategies (CFL trains its whole partition —
    same rule as the eager loop and the simulator).

    With ``engine.init(..., mesh=...)`` the scanned span runs SPMD over
    the mesh's client axes: arena rows are resident shards, gathered
    cohorts and per-cohort-slot training partition over the devices,
    and cross-client aggregations lower to per-shard partial reductions
    plus an all-reduce (docs/SHARDING.md; parity pinned by
    ``tests/test_mesh_engine.py`` at mesh sizes {1, 2, 4, 8}).

    Returns the state after ``rounds`` rounds.
    """
    rounds = int(rounds)
    if rounds <= 0:
        return state
    program = scan_program(state, rounds, unavailable)
    if program is None:
        # all departed/unavailable: the eager path raises per round; the
        # scanned path records the span as skipped no-op rounds
        recs = tuple({"skipped": True, "sampled": 0} for _ in range(rounds))
        return state.replace(round=state.round + rounds,
                             history=state.history + recs)
    fn, carry0, consts, finalize = program
    carry, ys = fn(carry0, consts)
    return finalize(state, carry, ys, int(rounds))


def scan_program(state: ServerState, rounds: int, unavailable=frozenset()):
    """Prepare (but do not run) the jitted multi-round scan behind
    ``run_rounds``: returns ``(fn, carry0, consts, finalize)``, or None
    when the pool is empty (``run_rounds`` records those as skipped
    rounds).

    ``fn(carry0, consts) -> (carry, ys)`` is the cached jitted program
    — all device-resident operands in, all device-resident results out;
    ``finalize(state, carry, ys, rounds)`` is the only host hand-off
    (history records, rebuilt banks). The split exists so the runtime
    sanitizers can make claims about the scan itself: the zero-transfer
    battery warms ``fn`` up, then re-invokes it under
    ``analysis.sanitize.no_transfer()`` to prove the scanned span never
    touches the host, and the compile-budget battery counts ``fn``'s
    XLA compiles across a churn timeline. Raises ``ValueError`` (see
    ``scan_blockers``) when the state cannot scan.
    """
    import jax

    strat = get_strategy(state.strategy)
    ctx = state.ctx
    rounds = int(rounds)
    blocker = scan_blockers(state)
    if blocker is not None:
        raise ValueError(blocker)
    live = state.n_clients - len(state.left)
    # the pool is pow2-padded EXACTLY like the eager sample_clients
    # draw: both paths feed the same uniform shape, so scan-vs-eager
    # cohorts stay bitwise identical while the compiled-program set
    # stays O(log population) under churn
    capw = sampler.pool_capacity(state.n_clients)
    if strat.full_participation:
        pool = sampler.cohort_pool(state.n_clients, state.left, (),
                                   capacity=capw)
        m = int(pool.sum())
    else:
        pool = sampler.cohort_pool(state.n_clients, state.left, unavailable,
                                   capacity=capw)
        m = sampler.cohort_size(ctx.cfg.sample_rate, live, int(pool.sum()))
    if m == 0:
        return None
    carry0, consts, step, finalize, statics = strat.scan_round(
        ctx, state, pool, m)
    structure = jax.tree.structure((carry0, consts))
    shapes = tuple((tuple(l.shape), str(l.dtype))
                   for l in jax.tree.leaves((carry0, consts)))
    # statics are the values the step BAKES INTO ITS TRACE beyond the
    # carry/const shapes (arena raggedness, merge bounds, …) — they must
    # key the cache, or a flipped static would silently reuse a stale
    # compiled scan. The mesh fingerprint is a static too: the step
    # bakes with_sharding_constraint(mesh) into its trace, so a context
    # whose mesh changed must not reuse the old program
    from repro.sharding import specs as shard_specs
    statics = statics + (shard_specs.mesh_fingerprint(ctx.mesh),)
    cache_key = (f"scan:{state.strategy}:{rounds}:{m}:"
                 f"{hash((str(structure), shapes, statics))}")

    def build():
        def scan_fn(c0, cs):
            return jax.lax.scan(lambda c, _: step(c, cs), c0, None,
                                length=rounds)
        # donate the carry off-CPU: the prior state's model/bank/partition
        # buffers roll straight into the scan's carry allocation, so a
        # steady-state span allocates nothing net. Callers already treat
        # the input state as consumed (run_rounds returns the successor
        # state and the parity battery rebinds it); CPU ignores donation,
        # so skip it there to keep compiles warning-free.
        donate = () if jax.default_backend() == "cpu" else (0,)
        return jax.jit(scan_fn, donate_argnums=donate)

    return ctx.jit(cache_key, build), carry0, consts, finalize


def scan_history(ys, rounds: int):
    """Convert stacked per-round scan metrics (``{key: (rounds,) array}``)
    into the eager loop's history records (one ``{key: int|float}`` dict
    per round, same key set and value types as ``run_round``'s)."""
    host = {k: np.asarray(v) for k, v in ys.items()}
    recs = []
    for t in range(rounds):
        rec = {}
        for k, v in host.items():
            x = v[t]
            rec[k] = int(x) if np.issubdtype(x.dtype, np.integer) else float(x)
        recs.append(rec)
    return tuple(recs)


def evaluate(state: ServerState, test_sets, true_cluster=None) -> dict:
    """Strategy-appropriate held-out evaluation (paper §4.2 protocol).

    Args:
      test_sets: ``{latent cluster id: batch}`` held-out sets.
      true_cluster: latent cluster per client id — used by clustered
        strategies to route each test set through the learned cluster
        holding most of that latent cluster's clients.

    Returns:
      Dict with at least ``cluster_avg`` (mean per-cluster accuracy);
      StoCFL adds per-cluster and global-model numbers.
    """
    return get_strategy(state.strategy).evaluate(state.ctx, state,
                                                 test_sets, true_cluster)


def join(state: ServerState, batch):
    """Register a newly-arrived client (§5 dynamic membership).

    Appends ``batch`` to the context's client list (and arena, amortized
    O(1) via capacity doubling), assigns the next client id, and lets the
    strategy place the newcomer — StoCFL runs Ψ-inference against the
    existing partition (§4.4), joining the nearest cluster above τ or
    opening a fresh one seeded from the nearest cluster's model.

    Returns:
      (state', new client id).
    """
    return get_strategy(state.strategy).join(state.ctx, state, batch)


def leave(state: ServerState, cid: int) -> ServerState:
    """Remove a client from the federation (§5 departures).

    The client stops being sampled, the clustering partition drops it
    consistently (clusters keep their models — knowledge persists), and
    its arena row is tombstoned (reclaimed in bulk once enough rows die).
    Returns the new state.
    """
    return get_strategy(state.strategy).leave(state.ctx, state, cid)


def infer(state: ServerState, batch) -> dict:
    """Cluster inference for an UNSEEN client (§4.4), without joining:
    which cluster would serve this data, at what Ψ-cosine similarity,
    with which model. Returns ``{"cluster", "seed_from", "similarity",
    "model"}``; raises for strategies with no inference rule."""
    return get_strategy(state.strategy).infer(state.ctx, state, batch)


def infer_batch(state: ServerState, batches) -> list:
    """Batched §4.4 cluster inference: ONE Ψ-extraction + nearest pass
    for many unseen-client batches. All batches must share one pytree
    structure and leaf shapes — they are stacked on a new leading axis,
    the Ψ extractor runs once under ``vmap``, and a single cluster-means
    snapshot scores every (rep, cluster) pair. Returns one
    ``infer``-shaped dict per batch, in submission order; strategies
    without a vectorized rule fall back to a per-batch ``infer`` loop.
    This is the serving router's fast path
    (``repro.serve.Router.route_many``): routing cost amortizes to one
    extractor call per admission wave instead of one per request."""
    return get_strategy(state.strategy).infer_many(state.ctx, state,
                                                   list(batches))
