"""Functional engine API: pure transitions over ``ServerState``.

    state = engine.init("stocfl", loss_fn, params, clients, cfg, eval_fn=acc)
    state, rec = engine.run_round(state)            # samples internally
    state, rec = engine.run_round(state, [0, 3, 7]) # or explicit cohort
    state, cid = engine.join(state, new_batch)      # §5 dynamic membership
    state = engine.leave(state, cid)
    engine.evaluate(state, test_sets, true_cluster)
    engine.infer(state, unseen_batch)               # §4.4 cluster inference

Every transition returns a NEW state; the input is never mutated (the one
deliberate exception: ``join`` appends the new client's dataset to the
context's client list — the context is the world, not the state). Client
sampling draws from the numpy bit-generator state stored IN the state, so
a checkpointed run resumes bit-exactly.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.extractor import make_extractor
from repro.engine.registry import get_strategy
from repro.engine.state import EngineConfig, EngineContext, ServerState


def init(strategy: str, loss_fn, init_params, clients,
         cfg: Optional[EngineConfig] = None, eval_fn=None,
         leaf_filter=None, mesh=None, arena: bool = False) -> ServerState:
    """Build the static context and the strategy's initial ``ServerState``.

    ``arena=True`` packs all client shards into a device-resident
    ``ClientArena`` so each round's cohort is one gather instead of a
    per-round Python restack (ragged shard sizes are pad-and-masked; the
    loss must then honor the batch's ``"mask"`` leaf). ``cfg.cohort_chunk``
    bounds how many clients one vmapped step executes — see
    ``bilevel.chunk_map``."""
    cfg = cfg or EngineConfig()
    ctx = EngineContext(loss_fn=loss_fn, init_params=init_params,
                        clients=list(clients), cfg=cfg, eval_fn=eval_fn,
                        leaf_filter=leaf_filter, mesh=mesh)
    if arena:
        from repro.data.arena import ClientArena
        ctx.arena = ClientArena.from_clients(ctx.clients)
    strat = get_strategy(strategy)
    if strat.needs_extractor:
        ctx.extractor = make_extractor(loss_fn, init_params, cfg.project_dim,
                                       leaf_filter=leaf_filter)
    return strat.init_state(ctx)


def sample_clients(state: ServerState):
    """Draw one round's cohort; returns (advanced rng_state, client ids)."""
    cfg = state.ctx.cfg
    rng = state.rng()
    m = max(int(round(cfg.sample_rate * state.n_clients)), 1)
    pool = np.array([i for i in range(state.n_clients) if i not in state.left])
    ids = rng.choice(pool, size=min(m, len(pool)), replace=False)
    return rng.bit_generator.state, ids


def run_round(state: ServerState, client_ids: Optional[Sequence[int]] = None):
    """One server round: (state, client_ids?) -> (state', metrics)."""
    strat = get_strategy(state.strategy)
    rng_state = state.rng_state
    if client_ids is None:
        if strat.full_participation:
            client_ids = np.array([i for i in range(state.n_clients)
                                   if i not in state.left])
        else:
            rng_state, client_ids = sample_clients(state)
    client_ids = np.asarray(client_ids)
    if client_ids.size == 0:
        raise ValueError("run_round needs a non-empty cohort "
                         "(no clients sampled — all departed?)")
    state, rec = strat.round(state.ctx, state, client_ids)
    state = state.replace(round=state.round + 1, rng_state=rng_state,
                          history=state.history + (dict(rec),))
    return state, rec


def run(state: ServerState, rounds: int, log_every: int = 0) -> ServerState:
    """Convenience loop over ``run_round``."""
    for t in range(rounds):
        state, rec = run_round(state)
        if log_every and t % log_every == 0:
            extras = "".join(f" {k}={v:.3f}" if isinstance(v, float) else f" {k}={v}"
                             for k, v in rec.items())
            print(f"round {t}:{extras}")
    return state


def evaluate(state: ServerState, test_sets, true_cluster=None) -> dict:
    return get_strategy(state.strategy).evaluate(state.ctx, state,
                                                 test_sets, true_cluster)


def join(state: ServerState, batch):
    """Register a new client; returns (state', cid)."""
    return get_strategy(state.strategy).join(state.ctx, state, batch)


def leave(state: ServerState, cid: int) -> ServerState:
    """Remove a client from sampling AND the partition, consistently."""
    return get_strategy(state.strategy).leave(state.ctx, state, cid)


def infer(state: ServerState, batch) -> dict:
    """Cluster inference for an unseen client (§4.4), without joining."""
    return get_strategy(state.strategy).infer(state.ctx, state, batch)
