"""Async buffered aggregation with staleness-weighted cluster merges.

The synchronous engine (``engine.run_round``) is a global barrier: every
sampled client trains and reports back inside one round. This module
removes the barrier while keeping the engine's bitwise standard intact:
clients drawn into a cohort at round *t* DISPATCH immediately (Ψ
handshake + local training start) and their trained contribution lands
in a fixed-capacity device-resident delta buffer with an arrival round
``t + delay``; every round the server FLUSHES the arrived entries as one
staleness-weighted merge (weight = ``count · γ^staleness``) through the
exact same aggregation functions the synchronous round calls.

The contract that makes this testable (``tests/test_async_agg.py``):

    zero delay + flush-every-round  ≡  engine.run_round, BITWISE,

for every async-capable strategy (stocfl / fedavg / fedprox), with or
without a client-axis mesh. The construction guarantees it rather than
approximating it:

* dispatch runs the synchronous round's pre-aggregation half (StoCFL's
  observe → merge_round → cluster-model merge → bi-level cohort step;
  FedAvg/FedProx's broadcast + local SGD) on the same compiled cohort
  programs, so the buffered rows are bit-identical to the rows the sync
  round would have aggregated;
* the buffer is pure memory movement — pow2-padded rows scattered in at
  dispatch (``.at[slots].set``) and gathered out at flush (``take``),
  both bit-preserving;
* a flush merges entries in dispatch (seq) order — the draw order — at
  EXACT width, calling ``bilevel.aggregate_stacked`` /
  ``aggregate_segments`` / ``AGGREGATORS[cfg.aggregator]`` on the same
  shapes the sync round uses; and ``γ^0 · w = w`` holds bitwise (any
  float to the zeroth power is exactly 1.0).

Two-phase protocol. The Ψ handshake is instantaneous at dispatch: a new
client's embedding is written to the buffer's Ψ rows and union-find
``observe`` / ``merge_round`` read it right there — clustering proceeds
without waiting on any outstanding delta, faithful to Algorithm 1's
cluster-then-broadcast structure. Only the heavy training result is
delayed; at its flush the delta re-roots through the CURRENT partition
(``find(cid)``), so merges that happened while it was in flight are
honored.

Memory model (same arena discipline as ``data.ClientArena``): row
capacity is pow2-quantized and doubles on overflow, so the compiled
scatter/gather program set stays O(log capacity); a steady-state async
round (constant cohort, constant delay) compiles ZERO new XLA programs
after warmup (pinned by ``tests/test_compile_budget.py``). On a mesh,
buffer rows are pinned to the client axis exactly like arena rows
(``sharding.place_buffer_rows``). See ``docs/ASYNC.md``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["AsyncConfig", "AsyncBuffer", "FlushBatch", "run_round_async",
           "staleness_weights"]


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Knobs of the async buffered-aggregation loop (attach as
    ``EngineConfig.async_cfg``).

    ``staleness_decay`` is γ: a delta dispatched at round ``t_d`` and
    merged at round ``t`` contributes with weight
    ``count · γ^(t - t_d)``. γ=1 recovers pure count weighting (total
    merge weight conserved vs the sync round); γ<1 discounts stale
    work. ``staleness_cap`` bounds how stale a merged delta may be —
    entries older than the cap are dropped, never merged (the
    bounded-staleness invariant), and entries whose delay already
    exceeds the cap are dropped at the first flush after dispatch.
    ``buffer_capacity`` fixes the delta buffer's row count (0 = auto:
    pow2 of ``cohort · (cap + 2)``); either way the capacity is pow2-
    quantized and doubles on overflow. ``flush_every`` merges the
    arrived entries every N rounds (1 — the default, and the sync-limit
    contract's requirement — flushes at the end of every round)."""
    staleness_decay: float = 1.0
    staleness_cap: int = 4
    buffer_capacity: int = 0
    flush_every: int = 1


class _Entry(NamedTuple):
    """Host bookkeeping for one in-flight contribution (aux data of the
    buffer pytree: slot row, client id, dispatch/arrival rounds, the
    insertion sequence number that fixes merge order, and the host-side
    f32 sample-count weight)."""
    slot: int
    cid: int
    dispatch: int
    arrival: int
    seq: int
    weight: float


@dataclasses.dataclass(frozen=True)
class FlushBatch:
    """One flush's merged entries, stacked in dispatch (seq) order —
    exactly the draw order, so a zero-delay flush presents the same
    rows in the same order as the synchronous aggregation.

    ``payload`` / ``aux`` are the gathered device rows (leading axis =
    entries); ``weight`` is the host f32 sample-count vector (the same
    bits ``strategies._weights`` would produce); ``staleness[i] =
    flush_round - dispatch_round`` of entry i."""
    payload: Any
    aux: Any
    cids: np.ndarray
    weight: np.ndarray
    staleness: np.ndarray

    @property
    def n(self) -> int:
        """Number of merged entries in this flush."""
        return int(len(self.cids))


def _pow2(n: int) -> int:
    n = int(n)
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def staleness_weights(weight, staleness, decay) -> np.ndarray:
    """Effective merge weights ``w · γ^s`` as host f32.

    At ``s = 0`` the factor is exactly 1.0 (IEEE ``x**0 == 1.0`` for
    every finite γ) and ``w · 1.0`` is bit-exact, which is the float-
    level half of the sync-limit contract; for γ ∈ [0, 1] the weights
    are monotone non-increasing in staleness and at γ = 1 the total
    merge weight equals the synchronous round's (both pinned by
    ``tests/test_async_properties.py``)."""
    w = np.asarray(weight, np.float32)
    s = np.asarray(staleness, np.float32)
    return (w * np.float32(decay) ** s).astype(np.float32)


# ------------------------------------------------- jitted row movement
# One program per (row shapes, capacity, width) — all pow2/steady-state
# quantized, so the compiled set is bounded (compile-budget pinned).
@jax.jit
def _scatter_rows(rows, slots, updates):
    return jax.tree.map(lambda r, u: r.at[slots].set(u.astype(r.dtype)),
                        rows, updates)


@jax.jit
def _gather_rows(rows, idx):
    return jax.tree.map(lambda r: jnp.take(r, idx, axis=0), rows)


@functools.partial(jax.jit, static_argnames=("capacity",))
def _zeros_rows(updates, capacity):
    return jax.tree.map(
        lambda u: jnp.zeros((capacity,) + u.shape[1:], u.dtype), updates)


@functools.partial(jax.jit, static_argnames=("capacity",))
def _grow_rows(rows, capacity):
    return jax.tree.map(
        lambda r: jnp.zeros((capacity,) + r.shape[1:], r.dtype)
        .at[: r.shape[0]].set(r), rows)


@dataclasses.dataclass(frozen=True)
class AsyncBuffer:
    """Fixed-capacity device-resident delta buffer (a registered pytree).

    Device children: ``payload`` (trained per-client model rows — StoCFL
    θᵢ, FedAvg/FedProx local params), ``aux`` (strategy extra — StoCFL
    ωᵢ rows), ``psi`` (fp32 Ψ-embedding rows, the handshake surface the
    union-find observes from). Each leaf has a pow2 ``capacity`` leading
    row axis, scattered at dispatch and gathered at flush by the row-
    movement jits above — the same arena discipline as ``ClientArena``
    (pow2 rows, doubling growth, spare rows are dead zeros). Host aux
    data: the in-flight ``_Entry`` tuple (seq-ordered) and the insertion
    counter. All transitions are pure (``dataclasses.replace``)."""
    capacity: int
    payload: Any = None
    aux: Any = None
    psi: Any = None
    entries: Tuple[_Entry, ...] = ()
    next_seq: int = 0

    # -------------------------------------------------------- lifecycle
    @classmethod
    def fresh(cls, capacity: int) -> "AsyncBuffer":
        """An empty buffer with pow2-quantized row capacity; device
        rows materialize lazily at the first write (their shapes come
        from the first contribution)."""
        return cls(capacity=_pow2(capacity))

    def replace(self, **kw) -> "AsyncBuffer":
        """``dataclasses.replace`` shorthand — the one way transitions
        derive a new buffer from an old one."""
        return dataclasses.replace(self, **kw)

    @property
    def in_flight(self) -> int:
        """Entries currently buffered (dispatched, not yet flushed)."""
        return len(self.entries)

    # --------------------------------------------------------- reserve
    def reserve(self, cids: Sequence[int], dispatch: int,
                arrivals: Sequence[int], weights: Sequence[float]):
        """Assign one buffer row per dispatched client; returns
        ``(buffer', slots)``.

        Slots are the lowest free rows in ascending order, entries are
        appended in cohort (draw) order with consecutive seq numbers —
        on an empty buffer the slots are ``0..m-1``, so a zero-delay
        flush gathers the dispatch stack back identically. Doubles the
        pow2 capacity when the free rows run out (amortized O(1), like
        the arena)."""
        m = len(cids)
        occupied = {e.slot for e in self.entries}
        cap = self.capacity
        while cap - len(occupied) < m:
            cap *= 2
        buf = self if cap == self.capacity else self._grow(cap)
        free = [s for s in range(cap) if s not in occupied][:m]
        new = tuple(_Entry(slot=int(s), cid=int(c), dispatch=int(dispatch),
                           arrival=int(a), seq=self.next_seq + i,
                           weight=float(w))
                    for i, (s, c, a, w) in enumerate(
                        zip(free, cids, arrivals, weights)))
        return (buf.replace(entries=buf.entries + new,
                            next_seq=self.next_seq + m),
                np.asarray(free, np.int32))

    def _grow(self, capacity: int) -> "AsyncBuffer":
        grow = lambda t: None if t is None else _grow_rows(t, capacity=capacity)
        return self.replace(capacity=capacity, payload=grow(self.payload),
                            aux=grow(self.aux), psi=grow(self.psi))

    # ---------------------------------------------------------- Ψ rows
    def write_psi(self, slots, rows) -> "AsyncBuffer":
        """Scatter the dispatch handshake's Ψ embeddings into the fp32
        Ψ rows (created on first use; clustering reads them back with
        ``read_psi`` — the buffer IS the observe data path)."""
        rows = jnp.asarray(rows, jnp.float32)
        psi = self.psi
        if psi is None:
            psi = _zeros_rows(rows, capacity=self.capacity)
        return self.replace(
            psi=_scatter_rows(psi, jnp.asarray(slots), rows))

    def read_psi(self, slots):
        """Gather Ψ rows back (bit-identical to what ``write_psi``
        stored) — what StoCFL's ``observe`` is fed from."""
        return _gather_rows(self.psi, jnp.asarray(slots))

    # ----------------------------------------------------- delta rows
    def write(self, slots, payload, aux=None) -> "AsyncBuffer":
        """Scatter a dispatch's trained contribution rows (leading axis
        = cohort) into the buffer. Pure memory movement: the gathered
        flush rows are bit-identical to ``payload``/``aux``."""
        slots = jnp.asarray(slots)
        p = self.payload
        if p is None:
            p = _zeros_rows(payload, capacity=self.capacity)
        p = _scatter_rows(p, slots, payload)
        a = self.aux
        if aux is not None:
            if a is None:
                a = _zeros_rows(aux, capacity=self.capacity)
            a = _scatter_rows(a, slots, aux)
        return self.replace(payload=p, aux=a)

    # ------------------------------------------------------------ flush
    def flush(self, t: int, staleness_cap: int, left=frozenset()):
        """End-of-round merge boundary: split the in-flight entries at
        round ``t`` into merged / kept / dropped.

        Returns ``(buffer', FlushBatch | None, drops)``. Merged: arrived
        (``arrival <= t``), not departed, staleness ``t - dispatch <=
        staleness_cap`` — gathered in seq (dispatch) order. Dropped
        stale: arrived entries over the cap, plus entries whose delay
        alone already exceeds the cap (they could never merge — freed
        at the first flush after dispatch, which is what bounds buffer
        occupancy by ``cohort · (cap + 1)``). Dropped left: in-flight
        entries of departed clients. Everything else stays buffered."""
        merge, keep, stale, gone = [], [], [], []
        for e in self.entries:                   # seq order == draw order
            if e.arrival <= t:
                if int(e.cid) in left:
                    gone.append(e)
                elif t - e.dispatch > staleness_cap:
                    stale.append(e)
                else:
                    merge.append(e)
            elif e.arrival - e.dispatch > staleness_cap:
                stale.append(e)                  # hopeless: cap-exceeding delay
            elif int(e.cid) in left:
                gone.append(e)
            else:
                keep.append(e)
        drops = {"stale": len(stale), "left": len(gone)}
        buf = self.replace(entries=tuple(keep))
        if not merge:
            return buf, None, drops
        idx = jnp.asarray(np.asarray([e.slot for e in merge], np.int32))
        payload = _gather_rows(self.payload, idx)
        aux = None if self.aux is None else _gather_rows(self.aux, idx)
        batch = FlushBatch(
            payload=payload, aux=aux,
            cids=np.asarray([e.cid for e in merge], np.int64),
            weight=np.asarray([e.weight for e in merge], np.float32),
            staleness=np.asarray([t - e.dispatch for e in merge], np.int64))
        return buf, batch, drops

    # ------------------------------------------------------------- mesh
    def place(self, mesh) -> "AsyncBuffer":
        """Pin every device row bank to the mesh's client axis (same
        rule as arena rows: the pow2 row capacity divides the pow2 mesh
        whenever capacity ≥ devices — ``sharding.place_buffer_rows``).
        No-op without a mesh."""
        if mesh is None:
            return self
        from repro.sharding import specs
        pl = lambda t: None if t is None else specs.place_buffer_rows(t, mesh)
        return self.replace(payload=pl(self.payload), aux=pl(self.aux),
                            psi=pl(self.psi))


def _flatten_buffer(b: AsyncBuffer):
    children = (b.payload, b.aux, b.psi)
    aux = (b.capacity, b.entries, b.next_seq)
    return children, aux


def _unflatten_buffer(aux, children):
    payload, a, psi = children
    capacity, entries, next_seq = aux
    return AsyncBuffer(capacity=capacity, payload=payload, aux=a, psi=psi,
                       entries=entries, next_seq=next_seq)


jax.tree_util.register_pytree_node(AsyncBuffer, _flatten_buffer,
                                   _unflatten_buffer)


# =================================================================== loop
def _auto_capacity(m: int, acfg: AsyncConfig) -> int:
    if acfg.buffer_capacity:
        return _pow2(acfg.buffer_capacity)
    return _pow2(max(m * (int(acfg.staleness_cap) + 2), 1))


def run_round_async(state, client_ids: Optional[Sequence[int]] = None,
                    delays=None):
    """One async server round: dispatch the cohort, buffer its delayed
    contributions, flush what has arrived.

    The asynchronous counterpart of ``engine.run_round`` — same
    signature plus ``delays``, same rng threading (explicit cohorts
    skip sampling and leave the rng untouched), same history append.
    ``delays`` gives each cohort member's report-back latency in rounds
    (scalar broadcasts; default 0). At ``delays = 0`` with
    ``flush_every = 1`` the round is BITWISE equal to ``run_round`` —
    the sync-limit contract (``tests/test_async_agg.py``).

    Per round, with ``t = state.round``:

    1. sample/accept the cohort and reserve one buffer row per member;
    2. ``strategy.async_dispatch``: the sync round's pre-aggregation
       half — for StoCFL the Ψ handshake writes embedding rows into the
       buffer and ``observe``/``merge_round`` read them back (clustering
       never waits on an outstanding delta), then the bi-level cohort
       step trains from the post-merge cluster models — and the trained
       rows are scattered into the buffer with arrival ``t + delay``;
    3. flush (every ``flush_every``-th round): entries with ``arrival <=
       t`` and staleness ``<= staleness_cap`` are gathered in dispatch
       order and handed to ``strategy.async_merge`` with weights
       ``count · γ^staleness`` (``staleness_weights``); stale and
       departed-client entries are dropped and counted.

    The per-round record extends the strategy's metrics with the async
    bookkeeping: ``merged``, ``dropped_stale``, ``dropped_left``,
    ``in_flight``, ``max_staleness``. Raises ``NotImplementedError``
    for strategies without async hooks (ditto / ifca / cfl) and
    ``ValueError`` on an empty cohort, mirroring ``run_round``.
    """
    from repro.engine.api import sample_clients
    from repro.engine.registry import get_strategy

    ctx = state.ctx
    acfg = ctx.cfg.async_cfg or AsyncConfig()
    strat = get_strategy(state.strategy)
    if not getattr(strat, "supports_async", False):
        raise NotImplementedError(
            f"strategy {state.strategy!r} has no async hooks "
            "(async_dispatch/async_merge) — async buffered aggregation "
            "supports stocfl, fedavg and fedprox")
    rng_state, rng_key = state.rng_state, state.rng_key
    if client_ids is None:
        if ctx.cfg.rng_backend == "device":
            rng_key, client_ids = sample_clients(state)
        else:
            rng_state, client_ids = sample_clients(state)
    client_ids = np.asarray(client_ids)
    if client_ids.size == 0:
        raise ValueError("run_round_async needs a non-empty cohort "
                         "(no clients sampled — all departed or "
                         "unavailable?)")
    m = int(client_ids.size)
    if delays is None:
        delays = np.zeros(m, np.int64)
    else:
        delays = np.broadcast_to(np.asarray(delays, np.int64), (m,))
    t = int(state.round)

    # ---- dispatch: reserve rows, run the strategy's pre-agg half
    from repro.engine.strategies import _sizes_np
    weights = _sizes_np(state.sizes)[client_ids]
    buf = state.buffer
    if buf is None:
        buf = AsyncBuffer.fresh(_auto_capacity(m, acfg)).place(ctx.mesh)
    buf, slots = buf.reserve(client_ids, t, t + delays, weights)
    state, buf = strat.async_dispatch(ctx, state, client_ids, buf, slots)

    # ---- flush: staleness-weighted merge of the arrived entries
    rec: dict = {"sampled": m}
    if (t + 1) % max(int(acfg.flush_every), 1) == 0:
        buf, batch, drops = buf.flush(t, int(acfg.staleness_cap),
                                      state.left)
        if batch is not None:
            if ctx.mesh is not None:
                from repro.sharding import specs
                batch = dataclasses.replace(
                    batch,
                    payload=specs.place_buffer_rows(batch.payload, ctx.mesh),
                    aux=(None if batch.aux is None else
                         specs.place_buffer_rows(batch.aux, ctx.mesh)))
            w_eff = staleness_weights(batch.weight, batch.staleness,
                                      acfg.staleness_decay)
            state, srec = strat.async_merge(ctx, state, batch, w_eff)
            rec.update(srec)
        rec.update(
            merged=0 if batch is None else batch.n,
            dropped_stale=drops["stale"], dropped_left=drops["left"],
            max_staleness=(0 if batch is None else
                           int(batch.staleness.max(initial=0))))
    rec["in_flight"] = buf.in_flight
    state = state.replace(buffer=buf, round=t + 1, rng_state=rng_state,
                          rng_key=rng_key,
                          history=state.history + (dict(rec),))
    return state, rec
