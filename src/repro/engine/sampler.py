"""On-device cohort sampling (threefry, without replacement).

The host sampler (``engine.sample_clients`` under
``rng_backend="numpy"``) draws each round's cohort from a numpy
bit-generator — a per-round host round-trip that the fully-jitted
multi-round loop (``engine.run_rounds``) cannot afford. This module is
the device replacement: the sampling state is a jax threefry PRNG key
stored in ``ServerState.rng_key``, and one draw is

    key' , sub = split(key)
    u            = uniform(sub, (n_clients,))      masked to +inf off-pool
    cohort       = argsort(u)[:m]                  (distinct by construction)

which is an exact without-replacement draw of ``m`` clients from the
pool (every pool subset of size m is equally likely; the cohort ORDER is
the uniform-rank order). ``m = ⌈sample_rate · live⌉`` is sized by the
LIVE population (registered minus departed) and clipped to the pool
(live minus unavailable) — both host-static between churn events, which
is what lets ``lax.scan`` carry a fixed cohort shape.

The same traceable ``draw`` is used by BOTH paths: the eager
``run_round`` calls the jitted wrapper once per round, the scanned
``run_rounds`` inlines it into the round body — so an eager loop and a
scanned loop starting from the same key sample identical cohorts in the
same order, which is what the scan-vs-eager parity battery pins down.
``rng_backend="numpy"`` remains the compatibility mode (bit-exact with
all pre-scan checkpoints and the legacy-trainer parity tests).
"""
from __future__ import annotations

import functools
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["cohort_pool", "cohort_size", "draw", "draw_cohort",
           "pool_capacity"]


def pool_capacity(n_clients: int) -> int:
    """Power-of-two pool quantum for ``n_clients`` registered ids.

    The draw uniform's shape — and with it every compiled program the
    pool feeds (the eager ``draw_cohort`` jit, the whole ``run_rounds``
    scan) — follows the pool length. Quantizing that length to the next
    power of two means a churning federation crosses O(log population)
    distinct pool shapes instead of recompiling on every join; the
    compile-budget battery (``tests/test_compile_budget.py``) pins
    exactly this.

    Deliberately NOT mesh-aligned: pow2 already divides the pow2 mesh
    sizes the sharded engine runs (whenever capacity ≥ device count),
    and a mesh-dependent pool shape would fork the draw sequence and
    break sharded-vs-single-device parity (docs/SHARDING.md §padding;
    pinned by ``tests/test_shard_properties.py``)."""
    n = int(n_clients)
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def cohort_pool(n_clients: int, left: Iterable[int],
                unavailable: Iterable[int] = (),
                capacity: int = None) -> np.ndarray:
    """Boolean draw-pool mask over client ids: registered, not departed,
    not unavailable this round (the simulator's availability windows).

    ``capacity`` (>= ``n_clients``) pads the mask with permanently-False
    slots for unregistered ids — the engine passes
    ``pool_capacity(n_clients)`` so pool-shaped programs compile per
    power-of-two population bracket, not per join. Padding never changes
    WHICH ids can be drawn, but it does change the uniform draw's shape,
    so eager and scanned paths must pad identically (they both go
    through the engine, which always pads)."""
    cap = int(n_clients if capacity is None else capacity)
    assert cap >= int(n_clients), "pool capacity below population"
    pool = np.zeros(cap, bool)
    pool[:int(n_clients)] = True
    for c in left:
        if 0 <= int(c) < n_clients:
            pool[int(c)] = False
    for c in unavailable:
        if 0 <= int(c) < n_clients:
            pool[int(c)] = False
    return pool


def cohort_size(sample_rate: float, n_live: int, pool_size: int) -> int:
    """Cohort size ``m = ⌈sample_rate · live⌉`` clipped to the pool
    (0 when the pool is empty — the caller's skipped-round case)."""
    if pool_size <= 0 or n_live <= 0:
        return 0
    m = int(np.ceil(float(sample_rate) * int(n_live)))
    return min(max(m, 0), int(pool_size))


def draw(key, pool_mask, m: int):
    """One traceable without-replacement draw: ``(key, (n,) bool mask,
    static m) -> (key', (m,) int32 cohort)``. Off-pool ids get +inf sort
    keys, so they are drawn only if the pool is smaller than ``m`` —
    callers clip ``m`` to the pool (``cohort_size``) so that never
    happens. Inlined by the scanned round body; jitted standalone by
    ``draw_cohort`` for the eager path."""
    key, sub = jax.random.split(key)
    u = jax.random.uniform(sub, pool_mask.shape)
    u = jnp.where(pool_mask, u, jnp.inf)
    return key, jnp.argsort(u)[:m].astype(jnp.int32)


@functools.lru_cache(maxsize=None)
def _jit_draw(n: int, m: int):
    """One compile per (population, cohort) shape pair."""
    return jax.jit(functools.partial(draw, m=m))


def draw_cohort(key, pool_mask, m: int):
    """Jitted ``draw`` (the eager ``run_round`` entrypoint): returns
    ``(advanced key, (m,) int32 cohort ids)``."""
    pool_mask = jnp.asarray(pool_mask)
    return _jit_draw(int(pool_mask.shape[0]), int(m))(key, pool_mask)
