"""The six federated strategies as thin definitions over shared machinery.

The paper frames StoCFL as a family that degenerates into the baselines
(§3.4: τ=1 → Ditto, τ=−1 → FedProx-family, λ=0 → CFL, λ=0 ∧ τ=−1 →
FedAvg); this module makes that literal: every method is a ``Strategy``
over the same vmapped cohort primitives (``bilevel.local_sgd`` /
``bilevel.make_cohort_update``), the same weighted aggregation, and the
same pure ``ServerState`` transitions — so benchmarks compare methods,
not orchestration code.

Scale substrate: when the context carries a ``ClientArena``, cohort data
is ONE device gather (``arena.gather``) and cluster models are batched
through the stacked ``ClusterBank`` (gather in, segment-sum aggregate
out) — per-round host work is O(1) in cohort size. Without an arena the
legacy per-round Python restack path runs instead (the pre-arena
behavior, kept as the fallback and as the benchmark baseline). Cohorts
larger than ``cfg.cohort_chunk`` execute in lax.map chunks with flat
memory (``bilevel.chunk_map``), which is what sustains 100%
participation at thousands of clients.

All transitions are pure: they copy the containers they change and return
a new ``ServerState``. Host-side control flow (partition bookkeeping,
model selection) stays in numpy; the per-round math is one jitted SPMD
computation with clients on the leading axis, optionally placed on the
mesh's client axis (``EngineContext.mesh``).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bilevel
from repro.core import device_clustering as devclust
from repro.core.aggregators import AGGREGATORS
from repro.core.device_clustering import make_cluster_state
from repro.engine import sampler as cohort_sampler
from repro.engine.bank import ClusterBank, _pow2 as bank_pow2
from repro.engine.registry import register
from repro.engine.state import (EngineContext, ServerState, fresh_rng_key,
                                fresh_rng_state)
from repro.sharding import specs
from repro.utils import trees


# --------------------------------------------------------------------- shared
def client_sizes(clients) -> tuple:
    return tuple(int(np.shape(jax.tree.leaves(c)[0])[0]) for c in clients)


def _stack(ctx: EngineContext, ids) -> dict:
    """Legacy cohort data path: per-round Python restack of the host
    client list (the arena-less fallback)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs),
                        *[ctx.clients[int(c)] for c in ids])


def _batches(ctx: EngineContext, ids):
    """Cohort data: one arena gather, or the legacy per-round restack."""
    if ctx.arena is not None:
        return ctx.arena.gather(ids)
    return _stack(ctx, ids)


def _chunk(ctx: EngineContext) -> int:
    """Effective cohort chunk: the config knob, mesh-aligned so chunks
    shard evenly over the client axis."""
    return specs.align_cohort_chunk(int(ctx.cfg.cohort_chunk or 0), ctx.mesh)


def _append_to_arena(ctx: EngineContext, batch) -> None:
    if ctx.arena is not None:
        ctx.arena = ctx.arena.append(batch)


def _retire_from_arena(ctx: EngineContext, cid: int) -> None:
    """Tombstone a departed client's arena row (compacted in bulk once
    enough rows die — see ``ClientArena.tombstone``)."""
    if ctx.arena is not None:
        ctx.arena = ctx.arena.tombstone(int(cid))


@functools.lru_cache(maxsize=512)
def _sizes_np(sizes: tuple) -> np.ndarray:
    """Per-client sample counts as a host f32 vector, one conversion per
    distinct size tuple (the eager path calls per round; sizes only
    change on membership events)."""
    # jaxlint: disable=R2 — sizes is a host int tuple, converted once (cached)
    return np.asarray(sizes, np.float32)


def _weights(state: ServerState, ids) -> np.ndarray:  # jaxlint: hot-path
    # jaxlint: disable=R2 — eager-path weights are host-side by design
    return _sizes_np(state.sizes)[np.asarray(ids)]


# ------------------------------------------------------- scan scaffolding
def _arena_consts(ctx: EngineContext) -> dict:  # jaxlint: hot-path
    """The arena's device operands for a scanned round body. Passed as
    scan ARGUMENTS (not closed over), so the compiled scan cached on the
    context never embeds stale arrays — after churn rebuilds the arena,
    the next ``run_rounds`` call feeds the fresh buffers through the
    same compiled program. The cid→row map rides the arena's cached
    device copy (``ClientArena.device_rows``) instead of a fresh upload
    per span."""
    ar = ctx.arena
    return {"packed": ar.packed, "amask": ar.mask,
            "rowmap": ar.device_rows}


def _gather_scan(consts: dict, ids, ragged: bool, mesh=None):
    """Traceable cohort gather from ``_arena_consts`` operands — the
    same takes (and the same ragged ``"mask"`` leaf) as
    ``ClientArena.gather``, so scanned batches are bitwise-identical to
    the eager path's. With a mesh, the arena rows are resident shards
    (``ClientArena.place``), the take is a cross-shard gather, and the
    gathered batch is re-constrained onto the client axes so the
    per-client training that follows partitions over the devices."""
    idx = jnp.take(consts["rowmap"], ids)
    batch = jax.tree.map(lambda x: jnp.take(x, idx, axis=0),
                         consts["packed"])
    if ragged:
        batch = dict(batch)
        batch["mask"] = jnp.take(consts["amask"], idx, axis=0)
    return specs.constrain_cohort(batch, mesh)


@functools.lru_cache(maxsize=512)
def _sizes_f32_upload(sizes: tuple):
    arr = np.zeros(cohort_sampler.pool_capacity(len(sizes)), np.float32)
    # jaxlint: disable=R2 — one upload per distinct size tuple, cached
    arr[: len(sizes)] = np.asarray(sizes, np.float32)
    return jnp.asarray(arr)


def _sizes_f32(state: ServerState):  # jaxlint: hot-path
    """Per-client sample counts as a device f32 vector (the scanned
    counterpart of ``_weights``), uploaded once per distinct size tuple
    — repeat rounds/spans over a stable federation reuse the cached
    device array instead of re-uploading every consts build. Padded to
    the pow2 population bracket (``sampler.pool_capacity``): scan
    consts shapes, like the pool itself, must not recompile per join.
    Padding rows are 0-weight and belong to unregistered ids — never
    drawn, never taken."""
    return _sizes_f32_upload(tuple(state.sizes))


def _row_mask(mask, leaf):
    """Broadcast a (rows,) bool mask against a (rows, ...) leaf."""
    return mask.reshape((-1,) + (1,) * (leaf.ndim - 1))


def _scan_history(ys, rounds: int) -> tuple:
    """Stacked scan metrics -> eager-style history records (delegates to
    ``engine.api.scan_history``; local alias avoids an import cycle at
    module load)."""
    from repro.engine.api import scan_history
    return scan_history(ys, rounds)


def _place(ctx: EngineContext, tree, replicated: bool = False):
    """Place a cohort input on the client-axis mesh, when one is active."""
    if ctx.mesh is None:
        return tree
    if replicated:
        return specs.place_replicated(tree, ctx.mesh)
    return specs.place_cohort(tree, ctx.mesh)


def _constrain(ctx: EngineContext, tree):
    """Trace-time cohort constraint (``sharding.constrain_cohort``) —
    the in-step counterpart of ``_place`` for values produced INSIDE
    the scanned round body (gathered batches, per-cohort model stacks,
    scatter-updated carries). No-op without a mesh."""
    return specs.constrain_cohort(tree, ctx.mesh)


def _scan_consts(ctx: EngineContext, consts: dict) -> dict:
    """Pin the scan's const operands to the mesh: arena buffers keep
    their row sharding (leading capacity axis over the client devices —
    a no-op device_put when ``ClientArena.place`` already placed them),
    everything else (pool mask, sizes, row map, ω₀) replicates. Without
    a mesh this is the identity, so the single-device scan's operands
    are untouched."""
    if ctx.mesh is None:
        return consts
    out = {}
    for k, v in consts.items():
        if k in ("packed", "amask"):
            out[k] = specs.place_cohort(v, ctx.mesh)
        else:
            out[k] = specs.place_replicated(v, ctx.mesh)
    return out


def merge_cluster_models(models, merges, counts, init_params):
    """Merge θ along partition merges, each side weighted by its member
    count — a 10-client cluster absorbing a singleton moves by 1/11, not
    1/2. ``counts`` is the pre-merge {root: n_members} snapshot; cascaded
    merges within one round accumulate correctly.

    ``ClusterBank`` inputs take the batched gather/segment-sum path
    (``bank.merge``); plain dicts keep the original sequential pairwise
    means (same math — the cascade IS the flat count-weighted mean)."""
    if isinstance(models, ClusterBank):
        return models.merge(merges, counts, init_params)
    models = dict(models)
    counts = dict(counts)
    for keep, absorb in merges:
        m_keep = models.pop(keep, init_params)
        m_abs = models.pop(absorb, init_params)
        n_k = float(counts.get(keep, 1))
        n_a = float(counts.get(absorb, 1))
        models[keep] = trees.tree_weighted_mean([m_keep, m_abs], [n_k, n_a])
        counts[keep] = n_k + n_a
    return models


class Strategy:
    """Protocol every federated method implements.

    ``init_state(ctx)`` builds the initial ``ServerState``;
    ``round(ctx, state, client_ids)`` is one pure server round;
    ``evaluate`` / ``join`` / ``leave`` / ``infer`` are the serving-side
    transitions. Register implementations with ``@register("name")``.
    """

    name = "base"
    needs_extractor = False
    full_participation = False
    supports_async = False

    # ------------------------------------------------------------ lifecycle
    def init_state(self, ctx: EngineContext) -> ServerState:
        """Round-0 ``ServerState``: ω = ω₀, empty bank, fresh sampling
        rng (the numpy bit-generator, plus a device threefry key under
        ``rng_backend="device"``)."""
        key = (fresh_rng_key(ctx.cfg.seed)
               if ctx.cfg.rng_backend == "device" else None)
        return ServerState(ctx=ctx, strategy=self.name, round=0,
                           rng_state=fresh_rng_state(ctx.cfg.seed),
                           sizes=client_sizes(ctx.clients), left=frozenset(),
                           omega=ctx.init_params, models=ClusterBank.empty(),
                           personal={}, rng_key=key)

    def round(self, ctx: EngineContext, state: ServerState, client_ids):
        """One pure server round over the sampled cohort:
        ``(ctx, state, client_ids) -> (state', metrics dict)``."""
        raise NotImplementedError

    def scan_round(self, ctx: EngineContext, state: ServerState,
                   pool: np.ndarray, m: int):
        """The strategy's round as a scannable step for
        ``engine.run_rounds``.

        Returns ``(carry0, consts, step, finalize, statics)``:
        ``carry0`` is the fixed-shape scan carry built from ``state``
        (PRNG key, model pytrees, stacked banks, device partition),
        ``consts`` the round-invariant device operands (arena buffers,
        draw pool, sample counts) that are threaded as scan ARGUMENTS
        so cached compilations never go stale, ``step(carry, consts) ->
        (carry', metrics)`` one traceable round (bit-faithful to
        ``round``), ``finalize(state, carry, ys, rounds)`` the host
        conversion back to a ``ServerState``, and ``statics`` a
        hashable tuple of every value the step bakes into its TRACE
        beyond the carry/const shapes (arena raggedness, merge bounds) —
        ``run_rounds`` keys its compiled-scan cache on it. ``pool`` is
        the boolean draw-pool mask, ``m`` the static cohort size."""
        raise NotImplementedError(
            f"strategy {self.name!r} has no scannable round step")

    # ------------------------------------------------------------ serving
    def evaluate(self, ctx, state, test_sets, true_cluster=None) -> dict:
        """Held-out evaluation; the base serves every test set with ω."""
        accs = {k: float(ctx.eval_fn(state.omega, b)) for k, b in test_sets.items()}
        return {"cluster_avg": float(np.mean(list(accs.values()))), "per": accs}

    def join(self, ctx, state, batch):
        """Register a new client (§5): append its data to the world
        (client list + arena) and its size to the state; returns
        ``(state', cid)``. Subclasses add placement (Ψ-inference, model
        seeding)."""
        cid = len(ctx.clients)
        ctx.clients.append(batch)
        _append_to_arena(ctx, batch)
        sizes = state.sizes + (int(np.shape(jax.tree.leaves(batch)[0])[0]),)
        return state.replace(sizes=sizes), cid

    def leave(self, ctx, state, cid):
        """Departure (§5): stop sampling ``cid`` and tombstone its arena
        row. Subclasses additionally repair their partition."""
        _retire_from_arena(ctx, cid)
        return state.replace(left=state.left | {int(cid)})

    def infer(self, ctx, state, batch) -> dict:
        """Cluster inference for unseen data (§4.4) — clustered
        strategies only."""
        raise NotImplementedError(f"strategy {self.name!r} has no cluster inference")

    def infer_many(self, ctx, state, batches) -> list:
        """Batched ``infer`` — one result dict per batch, in order. The
        base implementation loops ``infer``; strategies with a
        vectorizable Ψ rule (StoCFL) override it with a single stacked
        extraction + one nearest-cluster pass (``engine.infer_batch``)."""
        return [self.infer(ctx, state, b) for b in batches]

    # ------------------------------------------------------------ async
    def async_dispatch(self, ctx, state, client_ids, buf, slots):
        """Async round's pre-aggregation half: run this strategy's
        clustering + local-training work for the dispatched cohort and
        scatter the trained rows into the buffer's reserved ``slots``;
        ``(ctx, state, client_ids, buf, slots) -> (state', buf')``.
        Only strategies with ``supports_async = True`` implement it."""
        raise NotImplementedError(
            f"strategy {self.name!r} has no async dispatch hook")

    def async_merge(self, ctx, state, batch, weights):
        """Async round's aggregation half: merge one ``FlushBatch`` of
        arrived contributions under the staleness-effective ``weights``
        (host f32, dispatch order) through the SAME aggregation
        functions the synchronous round calls;
        ``(ctx, state, batch, weights) -> (state', metrics dict)``."""
        raise NotImplementedError(
            f"strategy {self.name!r} has no async merge hook")


# --------------------------------------------------------------------- stocfl
@register("stocfl")
class StoCFLStrategy(Strategy):
    """Algorithm 1: stochastic Ψ-clustering + bi-level cohort update."""

    needs_extractor = True
    supports_async = True

    def init_state(self, ctx):
        """Adds the Ψ-clustering bookkeeping: the host ``ClusterState``
        or, with ``cfg.cluster_backend="device"``, the jitted
        ``DeviceClusters`` union-find (same partition semantics, no
        per-round host round-trip — see ``core.device_clustering``)."""
        clusters = make_cluster_state(ctx.cfg.tau, ctx.cfg.cluster_backend,
                                      capacity=len(ctx.clients))
        return super().init_state(ctx).replace(clusters=clusters)

    def _cohort(self, ctx):
        cfg = ctx.cfg
        fused = bool(cfg.fused_step)
        # fused routes through the flat kernel dispatch ("auto": Pallas
        # on TPU, jnp oracle elsewhere); the tree path pins "jnp" so big
        # jitted graphs never embed interpret-mode per-leaf kernels
        return ctx.jit(f"stocfl_cohort:{fused}", lambda: bilevel.chunk_map(
            bilevel.make_cohort_update(ctx.loss_fn, cfg.lr, cfg.lam,
                                       cfg.local_steps,
                                       backend="auto" if fused else "jnp",
                                       fused=fused),
            (0, None, 0), _chunk(ctx)))

    def round(self, ctx, state, client_ids):
        cfg = ctx.cfg
        client_ids = np.asarray(client_ids)
        clusters = state.clusters.copy()

        # --- stochastic client clustering (Algorithm 1 lines 5-13)
        new_ids = [int(c) for c in client_ids if c not in clusters.seen]
        if new_ids:
            # extractor outputs stay device arrays: the numpy backend
            # converts internally (the old host sync); the device backend
            # scatters them straight into its Ψ bank with no round-trip.
            # With an arena, Ψ reads the SAME padded+masked arena row
            # the scanned loop extracts from (bitwise-identical to the
            # raw shard for equal-size shards) — one consistent Ψ
            # source, so ragged federations stay scan-vs-eager exact
            if ctx.arena is not None:
                reps = [ctx.extractor(jax.tree.map(
                    lambda x: x[0], ctx.arena.gather([c])))
                    for c in new_ids]
            else:
                reps = [ctx.extractor(ctx.clients[c]) for c in new_ids]
            clusters.observe(new_ids, reps)
        counts = {r: len(m) for r, m in clusters.clusters().items()}
        merges = clusters.merge_round()
        models = merge_cluster_models(state.models, merges, counts, ctx.init_params)

        # --- bi-level CFL (lines 14-19): one SPMD cohort step
        roots = np.fromiter((clusters.uf.find(int(c)) for c in client_ids),
                            np.int64, len(client_ids))
        if ctx.arena is not None:
            thetas = models.take(roots, ctx.init_params)     # one gather
        else:                       # legacy per-client Python model stack
            thetas = jax.tree.map(lambda *xs: jnp.stack(xs),
                                  *[models.get(int(r), ctx.init_params)
                                    for r in roots])
        batches = _batches(ctx, client_ids)
        thetas = _place(ctx, thetas)
        batches = _place(ctx, batches)
        omega = _place(ctx, state.omega, replicated=True)
        thetas_i, omegas_i = self._cohort(ctx)(thetas, omega, batches)

        w = _weights(state, client_ids)
        omega = AGGREGATORS[cfg.aggregator](omegas_i, w)
        uroots, seg = np.unique(roots, return_inverse=True)
        # pow2-padded segment count: the per-round unique-cluster count
        # drifts under churn, and an exact count would recompile the
        # segment-sum + scatter every round (pad rows are zero, discarded
        # by put's scratch row)
        agg = bilevel.aggregate_segments(thetas_i, w, seg,
                                         bank_pow2(len(uroots)))
        models = models.put([int(r) for r in uroots], agg)

        if isinstance(clusters, devclust.DeviceClusters):
            # shape-stable closed form: the exact float the scanned loop
            # records (see objective_closed_impl)
            objective = devclust.objective_closed(clusters.state)
        else:
            objective = clusters.objective()
        rec = {"n_clusters": clusters.n_clusters(),
               "objective": objective,
               "sampled": len(client_ids)}
        return state.replace(omega=omega, models=models, clusters=clusters), rec

    # ------------------------------------------------------------ async
    def async_dispatch(self, ctx, state, client_ids, buf, slots):
        """The sync round's pre-aggregation half with the Ψ handshake
        routed through the buffer: new clients' embeddings are scattered
        into the buffer's Ψ rows and ``observe``/``merge_round`` read
        them back (clustering never waits on a delta), then the bi-level
        cohort step trains from the post-merge cluster models and the
        (θᵢ, ωᵢ) stacks land in the reserved buffer slots. Line-for-line
        the same clustering + training calls as ``round`` — that is what
        makes the zero-delay flush bitwise."""
        client_ids = np.asarray(client_ids)
        clusters = state.clusters.copy()

        # --- stochastic client clustering (Algorithm 1 lines 5-13)
        new_pos = [i for i, c in enumerate(client_ids)
                   if int(c) not in clusters.seen]
        if new_pos:
            new_ids = [int(client_ids[i]) for i in new_pos]
            if ctx.arena is not None:
                reps = [ctx.extractor(jax.tree.map(
                    lambda x: x[0], ctx.arena.gather([c])))
                    for c in new_ids]
            else:
                reps = [ctx.extractor(ctx.clients[c]) for c in new_ids]
            # the buffer IS the observe data path: Ψ rows in, Ψ rows out
            # (pure scatter/gather — the read-back is bit-identical)
            new_slots = np.asarray(slots)[new_pos]
            buf = buf.write_psi(new_slots, jnp.stack(reps))
            back = buf.read_psi(new_slots)
            clusters.observe(new_ids, [back[i] for i in range(len(new_ids))])
        counts = {r: len(m) for r, m in clusters.clusters().items()}
        merges = clusters.merge_round()
        models = merge_cluster_models(state.models, merges, counts,
                                      ctx.init_params)

        # --- bi-level CFL (lines 14-19): one SPMD cohort step
        roots = np.fromiter((clusters.uf.find(int(c)) for c in client_ids),
                            np.int64, len(client_ids))
        if ctx.arena is not None:
            thetas = models.take(roots, ctx.init_params)
        else:
            thetas = jax.tree.map(lambda *xs: jnp.stack(xs),
                                  *[models.get(int(r), ctx.init_params)
                                    for r in roots])
        batches = _batches(ctx, client_ids)
        thetas = _place(ctx, thetas)
        batches = _place(ctx, batches)
        omega = _place(ctx, state.omega, replicated=True)
        thetas_i, omegas_i = self._cohort(ctx)(thetas, omega, batches)
        buf = buf.write(slots, thetas_i, omegas_i)
        return state.replace(models=models, clusters=clusters), buf

    def async_merge(self, ctx, state, batch, weights):
        """The sync round's aggregation half over one flush: global ω
        via ``AGGREGATORS[cfg.aggregator]``, per-cluster θ via the
        pow2-padded ``aggregate_segments`` — with each flushed delta
        re-rooted through the CURRENT partition (``find(cid)``), so
        merges that happened while it was in flight are honored."""
        cfg = ctx.cfg
        clusters = state.clusters
        omega = AGGREGATORS[cfg.aggregator](batch.aux, weights)
        roots = np.fromiter((clusters.uf.find(int(c)) for c in batch.cids),
                            np.int64, len(batch.cids))
        uroots, seg = np.unique(roots, return_inverse=True)
        agg = bilevel.aggregate_segments(batch.payload, weights, seg,
                                         bank_pow2(len(uroots)))
        models = state.models.put([int(r) for r in uroots], agg)
        if isinstance(clusters, devclust.DeviceClusters):
            objective = devclust.objective_closed(clusters.state)
        else:
            objective = clusters.objective()
        rec = {"n_clusters": clusters.n_clusters(), "objective": objective}
        return state.replace(omega=omega, models=models), rec

    def _cold_carry(self, ctx, state, clusters):
        """Build the scanned round's initial carry pieces from scratch:
        the grown partition state, the row-keyed model bank, the
        objective seed and an un-settled merge flag. The warm-resume
        path in ``scan_round`` skips all of this for back-to-back
        ``run_rounds`` calls on an untouched state."""
        if clusters.state is None:
            dim = int(np.shape(np.asarray(ctx.extractor(ctx.clients[0])))[0])
            dcs0 = devclust.init_state(
                max(clusters._capacity_hint, state.n_clients), dim)
        else:
            dcs0 = devclust.grow(clusters.state, state.n_clients)
        cap = int(dcs0.parent.shape[0])
        has0 = np.zeros(cap, bool)
        roots0 = state.models.roots
        # the row-keyed bank is capacity-sized (cap × |θ| — hundreds of
        # MB at thousands of clients), so building it with eager ops
        # costs two full-bank passes of dispatch per run_rounds CALL
        # (zeros, then a whole-bank copy for the root scatter) — at
        # 4000 clients that was ~0.3 s, a third of a 20-round span.
        # One jitted program fuses zeros + scatter into a single
        # write, cached on the context (bank capacity is pow2-
        # quantized, so the program set stays O(log K))
        if roots0:
            bcap = state.models.capacity
            idx_np = np.full(bcap, cap, np.int32)  # spare bank rows drop
            idx_np[:len(roots0)] = np.asarray(roots0, np.int32)

            def _build():
                def f(S, idx, init):
                    return jax.tree.map(
                        lambda i, s: jnp.zeros((cap,) + i.shape, i.dtype)
                        .at[idx].set(s.astype(i.dtype), mode="drop"),
                        init, S)
                return jax.jit(f)

            rows0 = ctx.jit(f"stocfl_rows0:{cap}:{bcap}", _build)(
                state.models.stacked, jnp.asarray(idx_np), ctx.init_params)
            has0[list(roots0)] = True
        else:
            rows0 = ctx.jit(
                f"stocfl_rows0:{cap}:0",
                lambda: jax.jit(lambda init: jax.tree.map(
                    lambda x: jnp.zeros((cap,) + x.shape, x.dtype),
                    init)))(ctx.init_params)
        # cached objective seed: the SAME standalone jit the eager
        # metric path calls (objective_closed), so a cache-carried value
        # is the exact float eager would have recorded for an unchanged
        # partition
        obj0 = devclust._jit_objective_closed()(dcs0).astype(jnp.float32)
        return (dcs0, cap, rows0, jnp.asarray(has0), obj0,
                jnp.asarray(False))

    def scan_round(self, ctx, state, pool, m):
        """StoCFL's whole round — Ψ-extraction, observe, fused merge,
        count-weighted bank merge, bi-level cohort step, per-cluster
        aggregation — as one traceable step (``cluster_backend="device"``
        required; checked by ``run_rounds``).

        The carry keeps the partition as a raw ``DeviceClusterState``
        and the cluster models as a row-keyed bank: ``rows[r]`` is the
        model of the cluster rooted at client id r, ``has[r]`` whether
        one exists (lazy θ_k = ω₀ otherwise) — the fixed-shape twin of
        ``ClusterBank``'s host-keyed rows, rebuilt into one by
        ``finalize``. Merge-group and per-cluster aggregations are
        segment-sums over ascending row order, matching
        ``ClusterBank.merge``'s and the eager round's summation order
        bitwise."""
        cfg = ctx.cfg
        tau = float(cfg.tau)
        ragged = ctx.arena.ragged
        clusters = state.clusters
        # warm resume: consecutive run_rounds calls on an untouched state
        # rebuild the cap-sized row bank, re-derive the objective seed
        # and re-arm the first merge pass from scratch — several full-
        # bank passes per CALL. finalize stashes the final carry pieces
        # keyed by the exact models/clusters OBJECTS it returned; every
        # state transition between spans (eager round, join, leave,
        # checkpoint load) replaces those objects, so identity is a
        # sound staleness key (bank/partition updates are copy-on-write
        # by construction — the one legacy in-place surface,
        # ClusterBank.__setitem__, has no engine callers). Bank rows
        # with has=False are never read (every consumer masks on has),
        # so resuming stale absorbed rows is bitwise-identical to the
        # zero rows a cold build would produce.
        resume = ctx.cache.get("stocfl_scan_resume")
        if (resume is not None
                and resume["models"] is state.models
                and resume["clusters"] is state.clusters
                and state.n_clients <= int(resume["dcs"].parent.shape[0])):
            dcs0 = resume["dcs"]
            cap = int(dcs0.parent.shape[0])
            rows0 = resume["rows"]
            has_arr0 = resume["has"]
            obj0 = resume["obj"]
            settled0 = resume["settled"]
        else:
            dcs0, cap, rows0, has_arr0, obj0, settled0 = \
                self._cold_carry(ctx, state, clusters)
        consts = _scan_consts(ctx, dict(_arena_consts(ctx),
                                        pool=jnp.asarray(pool),
                                        sizes=_sizes_f32(state),
                                        init=ctx.init_params))
        # carry: everything replicated — the partition/bank rows are
        # cluster-keyed (not client-sharded); the cohort-sharded work is
        # the per-round batches/thetas, whose segment-sums GSPMD lowers
        # to per-shard partials + a cross-shard reduce
        carry0 = _place(ctx, (state.rng_key, state.omega, dcs0, rows0,
                              has_arr0, obj0, settled0), replicated=True)
        cohort = self._cohort(ctx)
        psi = ctx.extractor
        aggname = cfg.aggregator
        mesh = ctx.mesh
        # static live-cluster bound for the merge pass: current clusters
        # plus every still-unseen live client (each could open a
        # singleton); can only shrink during the scan, so it stays
        # sufficient — and it keeps the pairwise candidate work K̃²-ish
        # instead of capacity² (the merge partition is k_max-invariant)
        n_live = state.n_clients - len(state.left)
        k_now = (state.clusters.n_clusters()
                 if state.clusters.state is not None else 0)
        unseen = max(n_live - len(state.clusters.seen), 0)
        k_bound = min(bank_pow2(max(k_now + unseen, 1)), cap)

        def step(carry, cs):
            key, omega, dcs, rows, has, obj, settled = carry
            ids_arr = jnp.arange(cap, dtype=jnp.int32)
            key, ids = cohort_sampler.draw(key, cs["pool"], m)
            batches = _gather_scan(cs, ids, ragged, mesh)
            new = ~jnp.take(dcs.live, ids)
            new_any = jnp.any(new)

            def observe(d):
                # Ψ per cohort member, one client at a time (lax.map
                # keeps the per-client extractor program identical to
                # the eager per-client calls — bitwise, not just
                # allclose); skipped entirely once everyone is observed
                reps = jax.lax.map(psi, batches)
                idx = jnp.where(new, ids, cap).astype(jnp.int32)
                return devclust.DeviceClusterState(
                    parent=d.parent.at[idx].set(
                        idx.astype(d.parent.dtype), mode="drop"),
                    live=d.live.at[idx].set(True, mode="drop"),
                    rep=d.rep.at[idx].set(reps.astype(d.rep.dtype),
                                          mode="drop"))

            dcs = jax.lax.cond(new_any, observe, lambda d: d, dcs)
            # settled-skip: once a merge pass runs with no merges, the
            # partition is at its fixed point — re-running the pass on
            # an unchanged state is a provable bitwise no-op (the parent
            # array is kept fully compressed and dead rows self-rooted
            # through every transition), so steady-state rounds skip the
            # whole means→candidates→components pipeline. Any new
            # observation re-arms the pass; a pass that merges leaves
            # ``settled`` False so cascades continue next round, exactly
            # like the eager per-round merge_round() calls.
            run_merge = new_any | ~settled

            def do_merge(d):
                return devclust.merge_round_impl(d, tau, k_bound)

            def skip_merge(d):
                pad = jnp.full((k_bound,), cap, jnp.int32)
                return d, pad, pad, jnp.zeros((k_bound,), jnp.float32)

            dcs, rows_live, new_roots, counts_c = jax.lax.cond(
                run_merge, do_merge, skip_merge, dcs)
            # --- count-weighted bank merge (ClusterBank.merge, row-keyed;
            # the heavy θ segment-sums are cond-skipped on merge-free
            # rounds, mirroring ClusterBank.merge's early return)
            mapped = ids_arr.at[rows_live].set(new_roots, mode="drop")
            w_full = jnp.zeros((cap,), jnp.float32).at[rows_live].set(
                counts_c.astype(jnp.float32), mode="drop")
            gsize = jax.ops.segment_sum((w_full > 0).astype(jnp.int32),
                                        mapped, num_segments=cap)
            merged = gsize > 1
            any_merged = jnp.any(merged)
            settled = jnp.where(run_merge, ~any_merged, settled)
            absorbed = (w_full > 0) & (mapped != ids_arr)

            def bank_merge(operand):
                rows, has = operand
                theta_full = jax.tree.map(
                    lambda R, I: jnp.where(
                        _row_mask(has, R), R,
                        jnp.asarray(I)[None].astype(R.dtype)),
                    rows, cs["init"])
                denom = jax.ops.segment_sum(w_full, mapped,
                                            num_segments=cap)
                wn = jnp.where(denom[mapped] > 0,
                               w_full / denom[mapped], 0.0)
                agg = jax.tree.map(
                    lambda x: jax.ops.segment_sum(
                        x * _row_mask(wn, x), mapped,
                        num_segments=cap).astype(x.dtype), theta_full)
                rows = jax.tree.map(
                    lambda R, A: jnp.where(_row_mask(merged, R),
                                           A.astype(R.dtype), R),
                    rows, agg)
                return rows, (has & ~absorbed) | merged

            rows, has = jax.lax.cond(any_merged, bank_merge,
                                     lambda o: o, (rows, has))
            # --- bi-level cohort step over post-merge cluster models
            r_ids = jnp.take(dcs.parent, ids)      # fully compressed roots
            has_r = jnp.take(has, r_ids)
            thetas = jax.tree.map(
                lambda R, I: jnp.where(_row_mask(has_r, R[:1]),
                                       jnp.take(R, r_ids, axis=0),
                                       jnp.asarray(I)[None].astype(R.dtype)),
                rows, cs["init"])
            thetas = specs.constrain_cohort(thetas, mesh)
            thetas_i, omegas_i = cohort(thetas, omega, batches)
            w = jnp.take(cs["sizes"], ids)
            omega = AGGREGATORS[aggname](omegas_i, w)
            # per-cluster FedAvg over COMPACT cohort slots (≤ m), then a
            # scatter of just the touched root rows: same segment sums
            # in the same cohort order as the eager unique-root path,
            # but the per-round bank traffic is O(m·|θ|), not
            # O(capacity·|θ|) — the scan's write-back stays cluster-
            # sized no matter how big the federation's row space is
            pos = jnp.arange(m, dtype=jnp.int32)
            firsts = jnp.argmax(r_ids[:, None] == r_ids[None, :],
                                axis=1).astype(jnp.int32)
            is_first = firsts == pos
            slot_of_pos = jnp.cumsum(is_first.astype(jnp.int32)) - 1
            slot = jnp.take(slot_of_pos, firsts)
            agg2 = bilevel.aggregate_segments(thetas_i, w, slot, m)
            target = jnp.where(is_first, r_ids, cap).astype(jnp.int32)
            rows = jax.tree.map(
                lambda R, A: R.at[target].set(
                    jnp.take(A, slot, axis=0).astype(R.dtype),
                    mode="drop"),
                rows, agg2)
            has = has.at[target].set(True, mode="drop")
            n_clusters = jnp.sum(dcs.live
                                 & (dcs.parent == ids_arr)).astype(jnp.int32)
            # Eq. 2 only moves when the partition does (observe or
            # merge); otherwise the carried value IS this round's exact
            # objective (same partition, deterministic reduction), so
            # the O(capacity·D) recompute is cond-skipped
            obj = jax.lax.cond(new_any | any_merged,
                               devclust.objective_closed_impl,
                               lambda _d: obj, dcs)
            rec = {"n_clusters": n_clusters,
                   "objective": obj,
                   "sampled": jnp.int32(m)}
            return (key, omega, dcs, rows, has, obj, settled), rec

        def finalize(state, carry, ys, rounds):
            key, omega, dcs, rows, has, obj, settled = carry
            clusters = devclust.DeviceClusters.from_arrays(
                tau, np.asarray(dcs.parent), np.asarray(dcs.live),
                np.asarray(dcs.rep))
            roots = [int(r) for r in np.nonzero(np.asarray(has))[0]]
            models = ClusterBank.from_dict(
                {r: jax.tree.map(lambda R, rr=r: R[rr], rows)
                 for r in roots})
            # stash the carry for the warm-resume path (see scan_round):
            # keyed by the exact objects returned below, so any state
            # transition between spans invalidates it. The carried obj
            # always equals objective_closed(dcs) (it is recomputed on
            # every partition change), and a True settled flag only
            # skips a merge pass that is a provable no-op on this
            # partition — both are bitwise-safe to resume.
            ctx.cache["stocfl_scan_resume"] = dict(
                models=models, clusters=clusters, dcs=dcs, rows=rows,
                has=has, obj=obj, settled=settled)
            return state.replace(
                omega=omega, rng_key=key, clusters=clusters, models=models,
                round=state.round + rounds,
                history=state.history + _scan_history(ys, rounds))

        return carry0, consts, step, finalize, (ragged, cap, k_bound)

    def evaluate(self, ctx, state, test_sets, true_cluster=None):
        """Each true cluster is evaluated with the model of the learned
        cluster holding most of its clients; ω is evaluated on everything."""
        assert ctx.eval_fn is not None
        assign = state.clusters.assignment()
        out, glob = {}, {}
        for tc, batch in test_sets.items():
            roots = [assign[c] for c in assign if true_cluster[c] == tc]
            if roots:
                root = max(set(roots), key=roots.count)
                model = state.cluster_model(root)
            else:
                model = state.omega
            out[tc] = float(ctx.eval_fn(model, batch))
            glob[tc] = float(ctx.eval_fn(state.omega, batch))
        return {"cluster": out, "cluster_avg": float(np.mean(list(out.values()))),
                "global": glob, "global_avg": float(np.mean(list(glob.values())))}

    def join(self, ctx, state, batch):
        """Dynamic join (§5): register the client, infer its cluster via Ψ
        against the PRE-EXISTING clusters, or open a fresh cluster seeded
        from the nearest one's model."""
        state, cid = super().join(ctx, state, batch)
        clusters = state.clusters.copy()
        models = state.models
        rep = ctx.extractor(batch)      # device array; backends convert
        root, near, _sim = clusters.nearest(rep)
        clusters.observe([cid], [rep])
        if root is not None:
            clusters.uf.union(min(root, cid), max(root, cid))
            # cid inherits the cluster model (no merge needed: cid had none)
        elif near is not None:
            models = models.set(clusters.uf.find(cid),
                                models.get(near, ctx.init_params))
        return state.replace(clusters=clusters, models=models), cid

    def leave(self, ctx, state, cid):
        """Dynamic leave: drop the client from reps AND the union-find so
        assignments stay consistent; the cluster keeps its model (knowledge
        persists, §5), re-keyed if the departure changed the root."""
        state = super().leave(ctx, state, cid)
        clusters = state.clusters.copy()
        remap = clusters.remove(cid)
        return state.replace(clusters=clusters,
                             models=state.models.rename(remap))

    def infer(self, ctx, state, batch):
        """Cluster inference for an unseen client (§4.4), without joining."""
        rep = ctx.extractor(batch)
        root, near, sim = state.clusters.nearest(rep)
        src = root if root is not None else near
        model = state.cluster_model(src) if src is not None else state.omega
        return {"cluster": root, "seed_from": src, "similarity": sim, "model": model}

    def infer_many(self, ctx, state, batches):
        """§4.4 for MANY unseen batches in one pass: stack the batches on
        a new leading axis, run the Ψ extractor once under ``vmap``, pull
        ONE cluster-means snapshot, and score every (rep, cluster) pair
        as a single (J, K̃) cosine matrix. Routing decisions (nearest
        root, τ clearance) match per-batch ``infer`` — this is the
        serving router's amortized path (``repro.serve.Router``)."""
        if not batches:
            return []
        stacked = jax.tree.map(
            lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *batches)
        reps = np.asarray(jax.vmap(ctx.extractor)(stacked), np.float32)
        if state.clusters is None or state.clusters.n_clusters() == 0:
            return [{"cluster": None, "seed_from": None, "similarity": 0.0,
                     "model": state.omega} for _ in batches]
        roots, means = state.clusters.cluster_means()
        mn = means / (np.linalg.norm(means, axis=1, keepdims=True) + 1e-12)
        rn = reps / (np.linalg.norm(reps, axis=1, keepdims=True) + 1e-12)
        sims = rn @ mn.T                                   # (J, K̃)
        tau = state.clusters.tau
        out = []
        for j in range(len(batches)):
            best = int(np.argmax(sims[j]))
            sim = float(sims[j][best])
            root = int(roots[best])
            out.append({"cluster": root if sim >= tau else None,
                        "seed_from": root, "similarity": sim,
                        "model": state.cluster_model(root)})
        return out


# ------------------------------------------------------------------ baselines
@register("fedavg")
class FedAvgStrategy(Strategy):
    """Single global model; λ=0 ∧ τ=−1 degeneration of StoCFL."""

    prox = False
    supports_async = True

    def _upd(self, ctx):
        cfg = ctx.cfg

        def build():
            fused = bool(cfg.fused_step)
            if self.prox:
                fn = lambda p, b: bilevel.local_sgd(ctx.loss_fn, p, b, cfg.lr,
                                                    cfg.local_steps, prox_to=p,
                                                    lam=cfg.mu, fused=fused)
            else:
                fn = lambda p, b: bilevel.local_sgd(ctx.loss_fn, p, b, cfg.lr,
                                                    cfg.local_steps, fused=fused)
            return bilevel.chunk_map(jax.jit(jax.vmap(fn, in_axes=(None, 0))),
                                     (None, 0), _chunk(ctx))

        return ctx.jit(f"{self.name}_upd:{bool(cfg.fused_step)}", build)

    def round(self, ctx, state, client_ids):
        ids = np.asarray(client_ids)
        batches = _place(ctx, _batches(ctx, ids))
        outs = self._upd(ctx)(_place(ctx, state.omega, replicated=True), batches)
        omega = bilevel.aggregate_stacked(outs, _weights(state, ids))
        return state.replace(omega=omega), {"sampled": len(ids)}

    # ------------------------------------------------------------ async
    def async_dispatch(self, ctx, state, client_ids, buf, slots):
        """Broadcast ω and run the cohort's local SGD (the sync round's
        training half, same compiled update), scattering the local
        params into the reserved buffer slots."""
        ids = np.asarray(client_ids)
        batches = _place(ctx, _batches(ctx, ids))
        outs = self._upd(ctx)(_place(ctx, state.omega, replicated=True),
                              batches)
        return state, buf.write(slots, outs)

    def async_merge(self, ctx, state, batch, weights):
        """Weighted mean of the flushed local params — the sync round's
        ``aggregate_stacked`` on the staleness-effective weights."""
        omega = bilevel.aggregate_stacked(batch.payload, weights)
        return state.replace(omega=omega), {}

    def scan_round(self, ctx, state, pool, m):
        """Scannable FedAvg/FedProx round: draw → gather → local SGD →
        weighted mean, carry ``(key, ω)`` — the same compiled cohort
        update as the eager round, on the same shapes."""
        ragged = ctx.arena.ragged
        upd = self._upd(ctx)
        mesh = ctx.mesh
        consts = _scan_consts(ctx, dict(_arena_consts(ctx),
                                        pool=jnp.asarray(pool),
                                        sizes=_sizes_f32(state)))
        carry0 = _place(ctx, (state.rng_key, state.omega), replicated=True)

        def step(carry, cs):
            key, omega = carry
            key, ids = cohort_sampler.draw(key, cs["pool"], m)
            batches = _gather_scan(cs, ids, ragged, mesh)
            outs = upd(omega, batches)
            omega = bilevel.aggregate_stacked(outs, jnp.take(cs["sizes"], ids))
            return (key, omega), {"sampled": jnp.int32(m)}

        def finalize(state, carry, ys, rounds):
            key, omega = carry
            return state.replace(omega=omega, rng_key=key,
                                 round=state.round + rounds,
                                 history=state.history + _scan_history(ys, rounds))

        return carry0, consts, step, finalize, (ragged,)


@register("fedprox")
class FedProxStrategy(FedAvgStrategy):
    """FedAvg + prox to the broadcast global (prox_to closes over the
    round's initial params, constant through the local scan)."""
    prox = True


@register("ditto")
class DittoStrategy(Strategy):
    """Global FedAvg + per-client personal models with prox to global
    (τ=1 degeneration: every client is its own cluster)."""

    def init_state(self, ctx):
        personal = {i: ctx.init_params for i in range(len(ctx.clients))}
        return super().init_state(ctx).replace(personal=personal)

    def _upds(self, ctx):
        cfg = ctx.cfg
        # gupd must NOT donate batches: the same cohort batch feeds pupd
        # right after (donation would free it on accelerators)
        fused = bool(cfg.fused_step)
        gupd = ctx.jit(f"ditto_g:{fused}", lambda: bilevel.chunk_map(
            jax.jit(jax.vmap(
                lambda p, b: bilevel.local_sgd(ctx.loss_fn, p, b, cfg.lr,
                                               cfg.local_steps, fused=fused),
                in_axes=(None, 0))), (None, 0), _chunk(ctx), donate=()))
        pupd = ctx.jit(f"ditto_p:{fused}", lambda: bilevel.chunk_map(
            jax.jit(jax.vmap(
                lambda v, g, b: bilevel.local_sgd(ctx.loss_fn, v, b, cfg.lr,
                                                  cfg.local_steps, prox_to=g,
                                                  lam=cfg.mu, fused=fused),
                in_axes=(0, None, 0))), (0, None, 0), _chunk(ctx)))
        return gupd, pupd

    def round(self, ctx, state, client_ids):
        ids = np.asarray(client_ids)
        gupd, pupd = self._upds(ctx)
        batches = _place(ctx, _batches(ctx, ids))
        omega = _place(ctx, state.omega, replicated=True)
        g_outs = gupd(omega, batches)
        v_stack = jax.tree.map(lambda *xs: jnp.stack(xs),
                               *[state.personal[int(c)] for c in ids])
        v_outs = pupd(_place(ctx, v_stack), omega, batches)
        omega = bilevel.aggregate_stacked(g_outs, _weights(state, ids))
        personal = dict(state.personal)
        for j, c in enumerate(ids):
            personal[int(c)] = jax.tree.map(lambda x: x[j], v_outs)
        return state.replace(omega=omega, personal=personal), {"sampled": len(ids)}

    def scan_round(self, ctx, state, pool, m):
        """Scannable Ditto round. The per-client personal models ride
        the carry as ONE stacked ``(n_clients, ...)`` pytree (cid ↔
        row); a round gathers the cohort's rows, proxes them to the
        broadcast ω, and scatters them back — ``finalize`` unstacks to
        the eager path's per-cid dict."""
        ragged = ctx.arena.ragged
        gupd, pupd = self._upds(ctx)
        n = state.n_clients
        # pow2 row capacity, like the pool/sizes consts: the stacked
        # personal carry must not re-shape (= recompile the scan) on
        # every join. Pad rows belong to unregistered cids — never
        # drawn, never gathered, never scattered — so their content is
        # irrelevant; duplicating row 0 keeps the stack a single eager
        # op whose compile is keyed by capn (pow2), not by n.
        capn = cohort_sampler.pool_capacity(n)
        personal0 = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[state.personal[i if i < n else 0] for i in range(capn)])
        mesh = ctx.mesh
        consts = _scan_consts(ctx, dict(_arena_consts(ctx),
                                        pool=jnp.asarray(pool),
                                        sizes=_sizes_f32(state)))
        # the stacked personal bank is the one client-indexed carry leaf:
        # shard its rows over the client axis (pow2 capn divides the pow2
        # mesh whenever capn ≥ devices) and re-pin the scatter output so
        # the carry's sharding is a scan fixed point — donation on
        # accelerators requires the in/out shardings to match
        carry0 = (_place(ctx, (state.rng_key, state.omega),
                         replicated=True)
                  + (_place(ctx, personal0),))

        def step(carry, cs):
            key, omega, personal = carry
            key, ids = cohort_sampler.draw(key, cs["pool"], m)
            batches = _gather_scan(cs, ids, ragged, mesh)
            g_outs = gupd(omega, batches)
            v = specs.constrain_cohort(
                jax.tree.map(lambda P: jnp.take(P, ids, axis=0), personal),
                mesh)
            v_outs = pupd(v, omega, batches)
            omega = bilevel.aggregate_stacked(g_outs,
                                              jnp.take(cs["sizes"], ids))
            personal = specs.constrain_cohort(
                jax.tree.map(lambda P, V: P.at[ids].set(V),
                             personal, v_outs),
                mesh)
            return (key, omega, personal), {"sampled": jnp.int32(m)}

        def finalize(state, carry, ys, rounds):
            key, omega, personal = carry
            # unstack every capn row (not just n): the per-index gather
            # compiles are then keyed by the pow2 bracket and fully warm
            # after the first churn cycle — later joins inside the same
            # bracket add zero compiles
            rows = [jax.tree.map(lambda P, ii=i: P[ii], personal)
                    for i in range(capn)]
            pd = {i: rows[i] for i in range(n)}
            return state.replace(omega=omega, rng_key=key, personal=pd,
                                 round=state.round + rounds,
                                 history=state.history + _scan_history(ys, rounds))

        return carry0, consts, step, finalize, (ragged,)

    def evaluate(self, ctx, state, test_sets, true_cluster=None):
        """Per true cluster: average of its clients' personal models' acc."""
        out = {}
        n = state.n_clients
        for tc, batch in test_sets.items():
            members = [i for i in range(n) if true_cluster[i] == tc]
            accs = [float(ctx.eval_fn(state.personal[i], batch)) for i in members[:8]]
            out[tc] = (float(np.mean(accs)) if accs
                       else float(ctx.eval_fn(state.omega, batch)))
        return {"cluster_avg": float(np.mean(list(out.values()))), "per": out}

    def join(self, ctx, state, batch):
        state, cid = super().join(ctx, state, batch)
        personal = dict(state.personal)
        personal[cid] = ctx.init_params
        return state.replace(personal=personal), cid


@register("ifca")
class IFCAStrategy(Strategy):
    """Ghosh et al. 2020: M̃ hypothesis models, clients pick argmin loss."""

    def init_state(self, ctx):
        cfg = ctx.cfg
        keys = jax.random.split(jax.random.PRNGKey(cfg.init_key), cfg.n_models)
        # perturb around init: IFCA needs distinct initializations
        models = {m: jax.tree.map(
            lambda x, k=k: x + 0.1 * jax.random.normal(
                jax.random.fold_in(k, 0), x.shape, x.dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, ctx.init_params)
            for m, k in enumerate(keys)}
        return super().init_state(ctx).replace(models=ClusterBank.from_dict(models))

    def _upd(self, ctx):
        cfg = ctx.cfg
        fused = bool(cfg.fused_step)
        return ctx.jit(f"ifca_upd:{fused}", lambda: bilevel.chunk_map(
            jax.jit(jax.vmap(
                lambda p, b: bilevel.local_sgd(ctx.loss_fn, p, b, cfg.lr,
                                               cfg.local_steps, fused=fused),
                in_axes=(0, 0))), (0, 0), _chunk(ctx)))

    def _choice(self, ctx):
        """(M, ...) models × (C, ...) batches -> (C, M) losses, one
        batched computation (the per-client Python loss loop was O(M·C)
        host dispatches). The cohort axis leads so the same chunking
        bounds the choice step's memory too — it would otherwise
        materialize M·C activations at once."""
        return ctx.jit("ifca_choice", lambda: bilevel.chunk_map(
            jax.jit(lambda ms, bs: jax.vmap(
                lambda b: jax.vmap(lambda m: ctx.loss_fn(m, b))(ms))(bs)),
            (None, 0), _chunk(ctx), donate=()))

    def round(self, ctx, state, client_ids):
        ids = np.asarray(client_ids)
        m_all = np.arange(ctx.cfg.n_models)
        batches = _batches(ctx, ids)
        hyps = state.models.take(m_all, ctx.init_params)
        losses = np.asarray(self._choice(ctx)(hyps, batches))
        choices = np.argmin(losses, axis=1)
        thetas = state.models.take(choices, ctx.init_params)
        outs = self._upd(ctx)(_place(ctx, thetas), _place(ctx, batches))
        w = _weights(state, ids)
        um, seg = np.unique(choices, return_inverse=True)
        agg = bilevel.aggregate_segments(outs, w, seg, bank_pow2(len(um)))
        models = state.models.put([int(m) for m in um], agg)
        return state.replace(models=models), {"sampled": len(ids)}

    def scan_round(self, ctx, state, pool, m):
        """Scannable IFCA round: the M̃ hypothesis models ride the carry
        stacked; choice = batched argmin loss, update = local SGD from
        the chosen hypothesis, write-back = a full-M̃ segment mean with
        untouched hypotheses kept (the fixed-shape equivalent of the
        eager path's unique-root scatter)."""
        ragged = ctx.arena.ragged
        M = int(ctx.cfg.n_models)
        choice, upd = self._choice(ctx), self._upd(ctx)
        rows0 = state.models.take(np.arange(M), ctx.init_params)
        mesh = ctx.mesh
        consts = _scan_consts(ctx, dict(_arena_consts(ctx),
                                        pool=jnp.asarray(pool),
                                        sizes=_sizes_f32(state)))
        carry0 = _place(ctx, (state.rng_key, rows0), replicated=True)

        def step(carry, cs):
            key, rows = carry
            key, ids = cohort_sampler.draw(key, cs["pool"], m)
            batches = _gather_scan(cs, ids, ragged, mesh)
            losses = choice(rows, batches)
            choices = jnp.argmin(losses, axis=1)
            thetas = specs.constrain_cohort(
                jax.tree.map(lambda R: jnp.take(R, choices, axis=0),
                             rows), mesh)
            outs = upd(thetas, batches)
            w = jnp.take(cs["sizes"], ids)
            agg = bilevel.aggregate_segments(outs, w, choices, M)
            present = jax.ops.segment_sum(jnp.ones_like(w), choices,
                                          num_segments=M) > 0
            rows = jax.tree.map(
                lambda R, A: jnp.where(_row_mask(present, R),
                                       A.astype(R.dtype), R), rows, agg)
            return (key, rows), {"sampled": jnp.int32(m)}

        def finalize(state, carry, ys, rounds):
            key, rows = carry
            models = ClusterBank.from_dict(
                {i: jax.tree.map(lambda R, ii=i: R[ii], rows)
                 for i in range(M)})
            return state.replace(models=models, rng_key=key,
                                 round=state.round + rounds,
                                 history=state.history + _scan_history(ys, rounds))

        return carry0, consts, step, finalize, (ragged, M)

    def evaluate(self, ctx, state, test_sets, true_cluster=None):
        out = {}
        for tc, batch in test_sets.items():
            accs = [float(ctx.eval_fn(state.models[m], batch))
                    for m in range(ctx.cfg.n_models)]
            out[tc] = float(np.max(accs))     # best-model (oracle assignment)
        return {"cluster_avg": float(np.mean(list(out.values()))), "per": out}


@register("cfl")
class CFLStrategy(Strategy):
    """Sattler et al. 2020a: full participation; recursively bi-partition a
    cluster near stationarity (relative-norm criterion); split seeds are
    the least-similar update pair, greedy assignment to the closer seed."""

    full_participation = True

    def init_state(self, ctx):
        state = super().init_state(ctx)
        return state.replace(members=(tuple(range(len(ctx.clients))),),
                             models=ClusterBank.from_dict({0: ctx.init_params}))

    def _core(self, ctx, L: int):
        """The WHOLE CFL round as one jitted program over a fixed
        ``L``-client layout: ``(assign (L,), k scalar, model rows
        (L, ...), batches, sizes) -> (assign', k', rows')``.

        Every client trains from its cluster's model (one gathered
        vmap), per-cluster FedAvg and the Sattler split statistics are
        masked reductions over the full client axis, and split emission
        renumbers clusters by cumulative-split offset (split cluster j →
        slots j+off and j+off+1, exactly the sequential emission order
        of the original per-cluster loop). Both the eager ``round`` and
        the ``run_rounds`` scan call THIS function — scan-vs-eager
        parity is by construction, and the split decisions (host floats
        before) are now device-deterministic."""
        cfg = ctx.cfg

        def build():
            upd = bilevel.chunk_map(
                jax.jit(jax.vmap(
                    lambda p, b: bilevel.local_sgd(ctx.loss_fn, p, b,
                                                   cfg.lr, cfg.local_steps,
                                                   fused=bool(cfg.fused_step)),
                    in_axes=(0, 0))), (0, 0), _chunk(ctx), donate=())

            def core(assign, k, rows, batches, sizes):
                # cohort-constrain the per-client operands HERE — eager
                # and scan both call this program, so the sharded
                # lowering (and its reduction order) is shared by
                # construction
                batches = specs.constrain_cohort(batches, ctx.mesh)
                thetas = specs.constrain_cohort(
                    jax.tree.map(lambda R: jnp.take(R, assign, axis=0),
                                 rows), ctx.mesh)
                outs = upd(thetas, batches)
                deltas = jax.tree.map(lambda o, t: o - t, outs, thetas)
                flat = jax.vmap(trees.tree_flatten_vector)(deltas)  # (L, d)
                norms = jnp.linalg.norm(flat, axis=1)
                ks = jnp.arange(L, dtype=jnp.int32)
                # per-cluster stats as O(L·d) segment reductions (every
                # client sits in exactly one cluster; within-segment
                # order is ascending cid, the member-tuple order)
                cnt = jax.ops.segment_sum(jnp.ones_like(assign), assign,
                                          num_segments=L)
                denom = jax.ops.segment_sum(sizes, assign, num_segments=L)
                wn = sizes / jnp.take(denom, assign)
                new_models = jax.tree.map(
                    lambda O: jax.ops.segment_sum(
                        O * wn.reshape((-1,) + (1,) * (O.ndim - 1)),
                        assign, num_segments=L).astype(O.dtype), outs)
                mean_g = jax.ops.segment_sum(flat, assign, num_segments=L
                                             ) / jnp.maximum(cnt, 1)[:, None]
                mean_norm = jnp.linalg.norm(mean_g, axis=1)
                max_norm = jax.ops.segment_max(norms, assign,
                                               num_segments=L)
                candidate = ((ks < k) & (cnt > 2)
                             & (max_norm > cfg.eps2)
                             & (mean_norm < cfg.eps_rel * max_norm))

                # split seeds: least-similar member pair, first-min in
                # row-major member order (the np.unravel_index rule).
                # The O(L²·d) similarity matrix and the per-cluster
                # masked argmins are cond-gated: rounds (and clusters)
                # with no split candidate skip them entirely — the
                # steady-state CFL round stays O(L·d)
                def seeds(_):
                    sims = flat / (norms[:, None] + 1e-12)
                    M = sims @ sims.T

                    def one(j):
                        def seed(j):
                            mask = assign == j
                            Mj = jnp.where(mask[:, None] & mask[None, :],
                                           M, jnp.inf)
                            amin = jnp.argmin(Mj)
                            gi, gj = amin // L, amin % L
                            c1 = mask & (M[:, gi] >= M[:, gj])
                            c2 = mask & ~c1
                            return c2, jnp.any(c1) & jnp.any(c2)

                        return jax.lax.cond(
                            candidate[j], seed,
                            lambda _: (jnp.zeros((L,), bool),
                                       jnp.bool_(False)), j)

                    return jax.lax.map(one, ks)

                c2, seed_ok = jax.lax.cond(
                    jnp.any(candidate), seeds,
                    lambda _: (jnp.zeros((L, L), bool),
                               jnp.zeros((L,), bool)), 0)
                split = candidate & seed_ok
                s = split.astype(jnp.int32)
                off = jnp.cumsum(s) - s
                new_pos = ks + off
                c2_p = c2[assign, jnp.arange(L)]
                base = jnp.take(new_pos, assign)
                assign2 = jnp.where(c2_p & jnp.take(split, assign),
                                    base + 1, base).astype(jnp.int32)
                idx1 = jnp.where(ks < k, new_pos, L)
                idx2 = jnp.where(split, new_pos + 1, L)
                rows2 = jax.tree.map(
                    lambda R, NM: R.at[idx1].set(NM.astype(R.dtype),
                                                 mode="drop")
                                   .at[idx2].set(NM.astype(R.dtype),
                                                 mode="drop"),
                    rows, new_models)
                k2 = (k + jnp.sum(jnp.where(ks < k, s, 0))).astype(jnp.int32)
                return assign2, k2, rows2

            return jax.jit(core)

        return ctx.jit(f"cfl_core:{L}", build)

    def _matrix(self, ctx, state):
        """Host matrix form of the CFL state: ``(live cids asc, assign
        per live position, k, (L, ...) model rows)`` — the fixed-shape
        layout ``_core`` runs on; member tuples keep clients ascending,
        so matrix ↔ tuples round-trips exactly."""
        live = np.array([i for i in range(state.n_clients)
                         if i not in state.left], np.int64)
        pos = {int(c): p for p, c in enumerate(live)}
        assign = np.zeros(len(live), np.int32)
        for j, grp in enumerate(state.members):
            for c in grp:
                assign[pos[int(c)]] = j
        k = len(state.members)
        rows = jax.tree.map(
            lambda x: jnp.zeros((len(live),) + tuple(jnp.shape(x)),
                                jnp.asarray(x).dtype), ctx.init_params)
        stacked = state.models.take(np.arange(k), ctx.init_params)
        rows = jax.tree.map(lambda Z, S: Z.at[:k].set(S.astype(Z.dtype)),
                            rows, stacked)
        return live, assign, k, rows

    @staticmethod
    def _untangle(live, assign, k, rows):
        """Matrix form back to the tuple partition + ``ClusterBank``."""
        members = tuple(tuple(int(c) for c in live[assign == j])
                        for j in range(k))
        models = ClusterBank.from_dict(
            {j: jax.tree.map(lambda R, jj=j: R[jj], rows)
             for j in range(k)})
        return members, models

    def round(self, ctx, state, client_ids):
        live, assign, k, rows = self._matrix(ctx, state)
        batches = _place(ctx, _batches(ctx, live))
        sizes = jnp.asarray(np.asarray(state.sizes, np.float32)[live])
        assign2, k2, rows2 = self._core(ctx, len(live))(
            jnp.asarray(assign), jnp.int32(k), rows, batches, sizes)
        members, models = self._untangle(live, np.asarray(assign2),
                                         int(k2), rows2)
        state = state.replace(members=members, models=models)
        return state, {"n_clusters": len(members),
                       "sampled": sum(len(m) for m in members)}

    def scan_round(self, ctx, state, pool, m):
        """Scannable CFL rounds: the carry is the matrix partition
        (``assign``, ``k``, model rows) and each step is one ``_core``
        call over the full live population (availability masks do not
        apply to full participation, mirroring the eager path)."""
        ragged = ctx.arena.ragged
        live, assign, k, rows = self._matrix(ctx, state)
        L = len(live)
        core = self._core(ctx, L)
        mesh = ctx.mesh
        consts = _scan_consts(ctx, dict(
            _arena_consts(ctx),
            live=jnp.asarray(live.astype(np.int32)),
            sizes=jnp.asarray(
                np.asarray(state.sizes, np.float32)[live])))
        carry0 = _place(ctx, (jnp.asarray(assign), jnp.int32(k), rows),
                        replicated=True)

        def step(carry, cs):
            assign, k, rows = carry
            batches = _gather_scan(cs, cs["live"], ragged, mesh)
            assign, k, rows = core(assign, k, rows, batches, cs["sizes"])
            return (assign, k, rows), {"n_clusters": k,
                                       "sampled": jnp.int32(L)}

        def finalize(state, carry, ys, rounds):
            assign, k, rows = carry
            members, models = self._untangle(live, np.asarray(assign),
                                             int(k), rows)
            return state.replace(members=members, models=models,
                                 round=state.round + rounds,
                                 history=state.history + _scan_history(ys, rounds))

        return carry0, consts, step, finalize, (ragged, L)

    def cluster_of(self, state, cid: int) -> int:
        for k, c in enumerate(state.members):
            if cid in c:
                return k
        return 0

    def join(self, ctx, state, batch):
        """CFL has no Ψ inference; assign the newcomer to the cluster whose
        model fits its data best (argmin loss, IFCA-style) so it trains
        and splits with that cluster from the next round on."""
        state, cid = super().join(ctx, state, batch)
        k = int(np.argmin([float(ctx.loss_fn(state.models[m], batch))
                           for m in range(len(state.members))]))
        members = list(state.members)
        members[k] = members[k] + (cid,)
        return state.replace(members=tuple(members)), cid

    def leave(self, ctx, state, cid):
        """Full participation trains on ``members``, so departure must
        rewrite the partition: drop the client everywhere, discard any
        cluster it leaves empty, and re-index the model table to match."""
        state = super().leave(ctx, state, cid)
        cid = int(cid)
        members, models = [], {}
        for k, group in enumerate(state.members):
            group = tuple(m for m in group if m != cid)
            if group:
                models[len(members)] = state.models[k]
                members.append(group)
        if not members:                       # last client left: keep the
            members = [()]                    # root cluster's model around
            models = {0: state.models.get(0, ctx.init_params)}
        return state.replace(members=tuple(members),
                             models=ClusterBank.from_dict(models))

    def evaluate(self, ctx, state, test_sets, true_cluster=None):
        out = {}
        for tc, batch in test_sets.items():
            ks = [self.cluster_of(state, i) for i in range(state.n_clients)
                  if true_cluster[i] == tc]
            k = max(set(ks), key=ks.count)
            out[tc] = float(ctx.eval_fn(state.models[k], batch))
        return {"cluster_avg": float(np.mean(list(out.values()))), "per": out,
                "n_clusters": len(state.members)}
