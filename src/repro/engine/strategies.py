"""The six federated strategies as thin definitions over shared machinery.

The paper frames StoCFL as a family that degenerates into the baselines
(§3.4: τ=1 → Ditto, τ=−1 → FedProx-family, λ=0 → CFL, λ=0 ∧ τ=−1 →
FedAvg); this module makes that literal: every method is a ``Strategy``
over the same vmapped cohort primitives (``bilevel.local_sgd`` /
``bilevel.make_cohort_update``), the same weighted aggregation, and the
same pure ``ServerState`` transitions — so benchmarks compare methods,
not orchestration code.

Scale substrate: when the context carries a ``ClientArena``, cohort data
is ONE device gather (``arena.gather``) and cluster models are batched
through the stacked ``ClusterBank`` (gather in, segment-sum aggregate
out) — per-round host work is O(1) in cohort size. Without an arena the
legacy per-round Python restack path runs instead (the pre-arena
behavior, kept as the fallback and as the benchmark baseline). Cohorts
larger than ``cfg.cohort_chunk`` execute in lax.map chunks with flat
memory (``bilevel.chunk_map``), which is what sustains 100%
participation at thousands of clients.

All transitions are pure: they copy the containers they change and return
a new ``ServerState``. Host-side control flow (partition bookkeeping,
model selection) stays in numpy; the per-round math is one jitted SPMD
computation with clients on the leading axis, optionally placed on the
mesh's client axis (``EngineContext.mesh``).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bilevel
from repro.core.aggregators import AGGREGATORS
from repro.core.device_clustering import make_cluster_state
from repro.engine.bank import ClusterBank, _pow2 as bank_pow2
from repro.engine.registry import register
from repro.engine.state import EngineContext, ServerState, fresh_rng_state
from repro.sharding import specs
from repro.utils import trees


# --------------------------------------------------------------------- shared
def client_sizes(clients) -> tuple:
    return tuple(int(np.shape(jax.tree.leaves(c)[0])[0]) for c in clients)


def _stack(ctx: EngineContext, ids) -> dict:
    """Legacy cohort data path: per-round Python restack of the host
    client list (the arena-less fallback)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs),
                        *[ctx.clients[int(c)] for c in ids])


def _batches(ctx: EngineContext, ids):
    """Cohort data: one arena gather, or the legacy per-round restack."""
    if ctx.arena is not None:
        return ctx.arena.gather(ids)
    return _stack(ctx, ids)


def _chunk(ctx: EngineContext) -> int:
    """Effective cohort chunk: the config knob, mesh-aligned so chunks
    shard evenly over the client axis."""
    return specs.align_cohort_chunk(int(ctx.cfg.cohort_chunk or 0), ctx.mesh)


def _append_to_arena(ctx: EngineContext, batch) -> None:
    if ctx.arena is not None:
        ctx.arena = ctx.arena.append(batch)


def _retire_from_arena(ctx: EngineContext, cid: int) -> None:
    """Tombstone a departed client's arena row (compacted in bulk once
    enough rows die — see ``ClientArena.tombstone``)."""
    if ctx.arena is not None:
        ctx.arena = ctx.arena.tombstone(int(cid))


def _weights(state: ServerState, ids) -> np.ndarray:
    return np.asarray(state.sizes, np.float32)[np.asarray(ids)]


def _place(ctx: EngineContext, tree, replicated: bool = False):
    """Place a cohort input on the client-axis mesh, when one is active."""
    if ctx.mesh is None:
        return tree
    if replicated:
        return specs.place_replicated(tree, ctx.mesh)
    return specs.place_cohort(tree, ctx.mesh)


def merge_cluster_models(models, merges, counts, init_params):
    """Merge θ along partition merges, each side weighted by its member
    count — a 10-client cluster absorbing a singleton moves by 1/11, not
    1/2. ``counts`` is the pre-merge {root: n_members} snapshot; cascaded
    merges within one round accumulate correctly.

    ``ClusterBank`` inputs take the batched gather/segment-sum path
    (``bank.merge``); plain dicts keep the original sequential pairwise
    means (same math — the cascade IS the flat count-weighted mean)."""
    if isinstance(models, ClusterBank):
        return models.merge(merges, counts, init_params)
    models = dict(models)
    counts = dict(counts)
    for keep, absorb in merges:
        m_keep = models.pop(keep, init_params)
        m_abs = models.pop(absorb, init_params)
        n_k = float(counts.get(keep, 1))
        n_a = float(counts.get(absorb, 1))
        models[keep] = trees.tree_weighted_mean([m_keep, m_abs], [n_k, n_a])
        counts[keep] = n_k + n_a
    return models


class Strategy:
    """Protocol every federated method implements.

    ``init_state(ctx)`` builds the initial ``ServerState``;
    ``round(ctx, state, client_ids)`` is one pure server round;
    ``evaluate`` / ``join`` / ``leave`` / ``infer`` are the serving-side
    transitions. Register implementations with ``@register("name")``.
    """

    name = "base"
    needs_extractor = False
    full_participation = False

    # ------------------------------------------------------------ lifecycle
    def init_state(self, ctx: EngineContext) -> ServerState:
        """Round-0 ``ServerState``: ω = ω₀, empty bank, fresh sampling rng."""
        return ServerState(ctx=ctx, strategy=self.name, round=0,
                           rng_state=fresh_rng_state(ctx.cfg.seed),
                           sizes=client_sizes(ctx.clients), left=frozenset(),
                           omega=ctx.init_params, models=ClusterBank.empty(),
                           personal={})

    def round(self, ctx: EngineContext, state: ServerState, client_ids):
        """One pure server round over the sampled cohort:
        ``(ctx, state, client_ids) -> (state', metrics dict)``."""
        raise NotImplementedError

    # ------------------------------------------------------------ serving
    def evaluate(self, ctx, state, test_sets, true_cluster=None) -> dict:
        """Held-out evaluation; the base serves every test set with ω."""
        accs = {k: float(ctx.eval_fn(state.omega, b)) for k, b in test_sets.items()}
        return {"cluster_avg": float(np.mean(list(accs.values()))), "per": accs}

    def join(self, ctx, state, batch):
        """Register a new client (§5): append its data to the world
        (client list + arena) and its size to the state; returns
        ``(state', cid)``. Subclasses add placement (Ψ-inference, model
        seeding)."""
        cid = len(ctx.clients)
        ctx.clients.append(batch)
        _append_to_arena(ctx, batch)
        sizes = state.sizes + (int(np.shape(jax.tree.leaves(batch)[0])[0]),)
        return state.replace(sizes=sizes), cid

    def leave(self, ctx, state, cid):
        """Departure (§5): stop sampling ``cid`` and tombstone its arena
        row. Subclasses additionally repair their partition."""
        _retire_from_arena(ctx, cid)
        return state.replace(left=state.left | {int(cid)})

    def infer(self, ctx, state, batch) -> dict:
        """Cluster inference for unseen data (§4.4) — clustered
        strategies only."""
        raise NotImplementedError(f"strategy {self.name!r} has no cluster inference")


# --------------------------------------------------------------------- stocfl
@register("stocfl")
class StoCFLStrategy(Strategy):
    """Algorithm 1: stochastic Ψ-clustering + bi-level cohort update."""

    needs_extractor = True

    def init_state(self, ctx):
        """Adds the Ψ-clustering bookkeeping: the host ``ClusterState``
        or, with ``cfg.cluster_backend="device"``, the jitted
        ``DeviceClusters`` union-find (same partition semantics, no
        per-round host round-trip — see ``core.device_clustering``)."""
        clusters = make_cluster_state(ctx.cfg.tau, ctx.cfg.cluster_backend,
                                      capacity=len(ctx.clients))
        return super().init_state(ctx).replace(clusters=clusters)

    def _cohort(self, ctx):
        cfg = ctx.cfg
        return ctx.jit("stocfl_cohort", lambda: bilevel.chunk_map(
            bilevel.make_cohort_update(ctx.loss_fn, cfg.lr, cfg.lam,
                                       cfg.local_steps, backend="jnp"),
            (0, None, 0), _chunk(ctx)))

    def round(self, ctx, state, client_ids):
        cfg = ctx.cfg
        client_ids = np.asarray(client_ids)
        clusters = state.clusters.copy()

        # --- stochastic client clustering (Algorithm 1 lines 5-13)
        new_ids = [int(c) for c in client_ids if c not in clusters.seen]
        if new_ids:
            # extractor outputs stay device arrays: the numpy backend
            # converts internally (the old host sync); the device backend
            # scatters them straight into its Ψ bank with no round-trip
            reps = [ctx.extractor(ctx.clients[c]) for c in new_ids]
            clusters.observe(new_ids, reps)
        counts = {r: len(m) for r, m in clusters.clusters().items()}
        merges = clusters.merge_round()
        models = merge_cluster_models(state.models, merges, counts, ctx.init_params)

        # --- bi-level CFL (lines 14-19): one SPMD cohort step
        roots = np.fromiter((clusters.uf.find(int(c)) for c in client_ids),
                            np.int64, len(client_ids))
        if ctx.arena is not None:
            thetas = models.take(roots, ctx.init_params)     # one gather
        else:                       # legacy per-client Python model stack
            thetas = jax.tree.map(lambda *xs: jnp.stack(xs),
                                  *[models.get(int(r), ctx.init_params)
                                    for r in roots])
        batches = _batches(ctx, client_ids)
        thetas = _place(ctx, thetas)
        batches = _place(ctx, batches)
        omega = _place(ctx, state.omega, replicated=True)
        thetas_i, omegas_i = self._cohort(ctx)(thetas, omega, batches)

        w = _weights(state, client_ids)
        omega = AGGREGATORS[cfg.aggregator](omegas_i, w)
        uroots, seg = np.unique(roots, return_inverse=True)
        # pow2-padded segment count: the per-round unique-cluster count
        # drifts under churn, and an exact count would recompile the
        # segment-sum + scatter every round (pad rows are zero, discarded
        # by put's scratch row)
        agg = bilevel.aggregate_segments(thetas_i, w, seg,
                                         bank_pow2(len(uroots)))
        models = models.put([int(r) for r in uroots], agg)

        rec = {"n_clusters": clusters.n_clusters(),
               "objective": clusters.objective(),
               "sampled": len(client_ids)}
        return state.replace(omega=omega, models=models, clusters=clusters), rec

    def evaluate(self, ctx, state, test_sets, true_cluster=None):
        """Each true cluster is evaluated with the model of the learned
        cluster holding most of its clients; ω is evaluated on everything."""
        assert ctx.eval_fn is not None
        assign = state.clusters.assignment()
        out, glob = {}, {}
        for tc, batch in test_sets.items():
            roots = [assign[c] for c in assign if true_cluster[c] == tc]
            if roots:
                root = max(set(roots), key=roots.count)
                model = state.cluster_model(root)
            else:
                model = state.omega
            out[tc] = float(ctx.eval_fn(model, batch))
            glob[tc] = float(ctx.eval_fn(state.omega, batch))
        return {"cluster": out, "cluster_avg": float(np.mean(list(out.values()))),
                "global": glob, "global_avg": float(np.mean(list(glob.values())))}

    def join(self, ctx, state, batch):
        """Dynamic join (§5): register the client, infer its cluster via Ψ
        against the PRE-EXISTING clusters, or open a fresh cluster seeded
        from the nearest one's model."""
        state, cid = super().join(ctx, state, batch)
        clusters = state.clusters.copy()
        models = state.models
        rep = ctx.extractor(batch)      # device array; backends convert
        root, near, _sim = clusters.nearest(rep)
        clusters.observe([cid], [rep])
        if root is not None:
            clusters.uf.union(min(root, cid), max(root, cid))
            # cid inherits the cluster model (no merge needed: cid had none)
        elif near is not None:
            models = models.set(clusters.uf.find(cid),
                                models.get(near, ctx.init_params))
        return state.replace(clusters=clusters, models=models), cid

    def leave(self, ctx, state, cid):
        """Dynamic leave: drop the client from reps AND the union-find so
        assignments stay consistent; the cluster keeps its model (knowledge
        persists, §5), re-keyed if the departure changed the root."""
        state = super().leave(ctx, state, cid)
        clusters = state.clusters.copy()
        remap = clusters.remove(cid)
        return state.replace(clusters=clusters,
                             models=state.models.rename(remap))

    def infer(self, ctx, state, batch):
        """Cluster inference for an unseen client (§4.4), without joining."""
        rep = ctx.extractor(batch)
        root, near, sim = state.clusters.nearest(rep)
        src = root if root is not None else near
        model = state.cluster_model(src) if src is not None else state.omega
        return {"cluster": root, "seed_from": src, "similarity": sim, "model": model}


# ------------------------------------------------------------------ baselines
@register("fedavg")
class FedAvgStrategy(Strategy):
    """Single global model; λ=0 ∧ τ=−1 degeneration of StoCFL."""

    prox = False

    def _upd(self, ctx):
        cfg = ctx.cfg

        def build():
            if self.prox:
                fn = lambda p, b: bilevel.local_sgd(ctx.loss_fn, p, b, cfg.lr,
                                                    cfg.local_steps, prox_to=p,
                                                    lam=cfg.mu)
            else:
                fn = lambda p, b: bilevel.local_sgd(ctx.loss_fn, p, b, cfg.lr,
                                                    cfg.local_steps)
            return bilevel.chunk_map(jax.jit(jax.vmap(fn, in_axes=(None, 0))),
                                     (None, 0), _chunk(ctx))

        return ctx.jit(f"{self.name}_upd", build)

    def round(self, ctx, state, client_ids):
        ids = np.asarray(client_ids)
        batches = _place(ctx, _batches(ctx, ids))
        outs = self._upd(ctx)(_place(ctx, state.omega, replicated=True), batches)
        omega = bilevel.aggregate_stacked(outs, _weights(state, ids))
        return state.replace(omega=omega), {"sampled": len(ids)}


@register("fedprox")
class FedProxStrategy(FedAvgStrategy):
    """FedAvg + prox to the broadcast global (prox_to closes over the
    round's initial params, constant through the local scan)."""
    prox = True


@register("ditto")
class DittoStrategy(Strategy):
    """Global FedAvg + per-client personal models with prox to global
    (τ=1 degeneration: every client is its own cluster)."""

    def init_state(self, ctx):
        personal = {i: ctx.init_params for i in range(len(ctx.clients))}
        return super().init_state(ctx).replace(personal=personal)

    def _upds(self, ctx):
        cfg = ctx.cfg
        # gupd must NOT donate batches: the same cohort batch feeds pupd
        # right after (donation would free it on accelerators)
        gupd = ctx.jit("ditto_g", lambda: bilevel.chunk_map(
            jax.jit(jax.vmap(
                lambda p, b: bilevel.local_sgd(ctx.loss_fn, p, b, cfg.lr,
                                               cfg.local_steps),
                in_axes=(None, 0))), (None, 0), _chunk(ctx), donate=()))
        pupd = ctx.jit("ditto_p", lambda: bilevel.chunk_map(
            jax.jit(jax.vmap(
                lambda v, g, b: bilevel.local_sgd(ctx.loss_fn, v, b, cfg.lr,
                                                  cfg.local_steps, prox_to=g,
                                                  lam=cfg.mu),
                in_axes=(0, None, 0))), (0, None, 0), _chunk(ctx)))
        return gupd, pupd

    def round(self, ctx, state, client_ids):
        ids = np.asarray(client_ids)
        gupd, pupd = self._upds(ctx)
        batches = _place(ctx, _batches(ctx, ids))
        omega = _place(ctx, state.omega, replicated=True)
        g_outs = gupd(omega, batches)
        v_stack = jax.tree.map(lambda *xs: jnp.stack(xs),
                               *[state.personal[int(c)] for c in ids])
        v_outs = pupd(_place(ctx, v_stack), omega, batches)
        omega = bilevel.aggregate_stacked(g_outs, _weights(state, ids))
        personal = dict(state.personal)
        for j, c in enumerate(ids):
            personal[int(c)] = jax.tree.map(lambda x: x[j], v_outs)
        return state.replace(omega=omega, personal=personal), {"sampled": len(ids)}

    def evaluate(self, ctx, state, test_sets, true_cluster=None):
        """Per true cluster: average of its clients' personal models' acc."""
        out = {}
        n = state.n_clients
        for tc, batch in test_sets.items():
            members = [i for i in range(n) if true_cluster[i] == tc]
            accs = [float(ctx.eval_fn(state.personal[i], batch)) for i in members[:8]]
            out[tc] = (float(np.mean(accs)) if accs
                       else float(ctx.eval_fn(state.omega, batch)))
        return {"cluster_avg": float(np.mean(list(out.values()))), "per": out}

    def join(self, ctx, state, batch):
        state, cid = super().join(ctx, state, batch)
        personal = dict(state.personal)
        personal[cid] = ctx.init_params
        return state.replace(personal=personal), cid


@register("ifca")
class IFCAStrategy(Strategy):
    """Ghosh et al. 2020: M̃ hypothesis models, clients pick argmin loss."""

    def init_state(self, ctx):
        cfg = ctx.cfg
        keys = jax.random.split(jax.random.PRNGKey(cfg.init_key), cfg.n_models)
        # perturb around init: IFCA needs distinct initializations
        models = {m: jax.tree.map(
            lambda x, k=k: x + 0.1 * jax.random.normal(
                jax.random.fold_in(k, 0), x.shape, x.dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, ctx.init_params)
            for m, k in enumerate(keys)}
        return super().init_state(ctx).replace(models=ClusterBank.from_dict(models))

    def _upd(self, ctx):
        cfg = ctx.cfg
        return ctx.jit("ifca_upd", lambda: bilevel.chunk_map(
            jax.jit(jax.vmap(
                lambda p, b: bilevel.local_sgd(ctx.loss_fn, p, b, cfg.lr,
                                               cfg.local_steps),
                in_axes=(0, 0))), (0, 0), _chunk(ctx)))

    def _choice(self, ctx):
        """(M, ...) models × (C, ...) batches -> (C, M) losses, one
        batched computation (the per-client Python loss loop was O(M·C)
        host dispatches). The cohort axis leads so the same chunking
        bounds the choice step's memory too — it would otherwise
        materialize M·C activations at once."""
        return ctx.jit("ifca_choice", lambda: bilevel.chunk_map(
            jax.jit(lambda ms, bs: jax.vmap(
                lambda b: jax.vmap(lambda m: ctx.loss_fn(m, b))(ms))(bs)),
            (None, 0), _chunk(ctx), donate=()))

    def round(self, ctx, state, client_ids):
        ids = np.asarray(client_ids)
        m_all = np.arange(ctx.cfg.n_models)
        batches = _batches(ctx, ids)
        hyps = state.models.take(m_all, ctx.init_params)
        losses = np.asarray(self._choice(ctx)(hyps, batches))
        choices = np.argmin(losses, axis=1)
        thetas = state.models.take(choices, ctx.init_params)
        outs = self._upd(ctx)(_place(ctx, thetas), _place(ctx, batches))
        w = _weights(state, ids)
        um, seg = np.unique(choices, return_inverse=True)
        agg = bilevel.aggregate_segments(outs, w, seg, bank_pow2(len(um)))
        models = state.models.put([int(m) for m in um], agg)
        return state.replace(models=models), {"sampled": len(ids)}

    def evaluate(self, ctx, state, test_sets, true_cluster=None):
        out = {}
        for tc, batch in test_sets.items():
            accs = [float(ctx.eval_fn(state.models[m], batch))
                    for m in range(ctx.cfg.n_models)]
            out[tc] = float(np.max(accs))     # best-model (oracle assignment)
        return {"cluster_avg": float(np.mean(list(out.values()))), "per": out}


@register("cfl")
class CFLStrategy(Strategy):
    """Sattler et al. 2020a: full participation; recursively bi-partition a
    cluster near stationarity (relative-norm criterion); split seeds are
    the least-similar update pair, greedy assignment to the closer seed."""

    full_participation = True

    def init_state(self, ctx):
        state = super().init_state(ctx)
        return state.replace(members=(tuple(range(len(ctx.clients))),),
                             models=ClusterBank.from_dict({0: ctx.init_params}))

    def _upd(self, ctx):
        cfg = ctx.cfg
        return ctx.jit("cfl_upd", lambda: bilevel.chunk_map(
            jax.jit(jax.vmap(
                lambda p, b: bilevel.local_sgd(ctx.loss_fn, p, b, cfg.lr,
                                               cfg.local_steps),
                in_axes=(None, 0))), (None, 0), _chunk(ctx)))

    def round(self, ctx, state, client_ids):
        cfg = ctx.cfg
        upd = self._upd(ctx)
        sizes = np.asarray(state.sizes, np.float32)
        new_members, new_models = [], []
        for k, members in enumerate(state.members):
            members = list(members)
            model = state.models[k]
            outs = upd(model, _place(ctx, _batches(ctx, members)))
            deltas = jax.tree.map(lambda o, m: o - m, outs, model)
            flat = np.asarray(jax.vmap(trees.tree_flatten_vector)(deltas))
            new_model = bilevel.aggregate_stacked(outs, sizes[np.array(members)])
            mean_norm = float(np.linalg.norm(flat.mean(axis=0)))
            max_norm = float(np.linalg.norm(flat, axis=1).max())
            if len(members) > 2 and max_norm > cfg.eps2 and mean_norm < cfg.eps_rel * max_norm:
                sims = flat / (np.linalg.norm(flat, axis=1, keepdims=True) + 1e-12)
                M = sims @ sims.T
                i, j = np.unravel_index(np.argmin(M), M.shape)
                c1 = [m for idx, m in enumerate(members) if M[idx, i] >= M[idx, j]]
                c2 = [m for m in members if m not in c1]
                if c1 and c2:
                    new_members += [tuple(c1), tuple(c2)]
                    new_models += [new_model, new_model]
                    continue
            new_members.append(tuple(members))
            new_models.append(new_model)
        state = state.replace(members=tuple(new_members),
                              models=ClusterBank.from_dict(dict(enumerate(new_models))))
        return state, {"n_clusters": len(new_members),
                       "sampled": sum(len(m) for m in new_members)}

    def cluster_of(self, state, cid: int) -> int:
        for k, c in enumerate(state.members):
            if cid in c:
                return k
        return 0

    def join(self, ctx, state, batch):
        """CFL has no Ψ inference; assign the newcomer to the cluster whose
        model fits its data best (argmin loss, IFCA-style) so it trains
        and splits with that cluster from the next round on."""
        state, cid = super().join(ctx, state, batch)
        k = int(np.argmin([float(ctx.loss_fn(state.models[m], batch))
                           for m in range(len(state.members))]))
        members = list(state.members)
        members[k] = members[k] + (cid,)
        return state.replace(members=tuple(members)), cid

    def leave(self, ctx, state, cid):
        """Full participation trains on ``members``, so departure must
        rewrite the partition: drop the client everywhere, discard any
        cluster it leaves empty, and re-index the model table to match."""
        state = super().leave(ctx, state, cid)
        cid = int(cid)
        members, models = [], {}
        for k, group in enumerate(state.members):
            group = tuple(m for m in group if m != cid)
            if group:
                models[len(members)] = state.models[k]
                members.append(group)
        if not members:                       # last client left: keep the
            members = [()]                    # root cluster's model around
            models = {0: state.models.get(0, ctx.init_params)}
        return state.replace(members=tuple(members),
                             models=ClusterBank.from_dict(models))

    def evaluate(self, ctx, state, test_sets, true_cluster=None):
        out = {}
        for tc, batch in test_sets.items():
            ks = [self.cluster_of(state, i) for i in range(state.n_clients)
                  if true_cluster[i] == tc]
            k = max(set(ks), key=ks.count)
            out[tc] = float(ctx.eval_fn(state.models[k], batch))
        return {"cluster_avg": float(np.mean(list(out.values()))), "per": out,
                "n_clusters": len(state.members)}
