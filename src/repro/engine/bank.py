"""Stacked cluster-model bank: ``{root: pytree}`` as ONE device pytree.

Per-cluster models used to live in a host dict keyed by union-find root;
every round then paid a Python loop to stack the sampled cohort's cluster
models and another loop to scatter per-cluster aggregates back — ~100 ms
of dispatch at 400 clients, a wall at thousands. ``ClusterBank`` keeps
all cluster models stacked on a leading K axis next to a host-side
root-index tuple, so the per-round model path is batched device ops:

    thetas = bank.take(roots, init)   # one jnp.take gather per leaf
    ...vmapped cohort update...
    bank   = bank.put(uroots, agg)    # one .at[idx].set scatter per leaf

and cluster merges (Algorithm 1 l.10-13) are a single count-weighted
segment-sum over rows (``bank.merge``) instead of sequential pairwise
pytree means.

The bank keeps the read-only ``Mapping`` surface of the dict it replaces
(``bank[root]``, ``.get``, ``.keys()``, ``== {}``) so strategy code,
checkpoints, and the legacy trainer shims keep working; all functional
updates return a NEW bank. It is registered as a pytree node (children:
the stacked model; aux: the root tuple), so it rides inside
``ServerState`` through ``jax.device_get`` and the mesh placement
helpers unchanged.

Shape stability under churn (§5): the stacked arrays carry power-of-two
row *capacity* (occupied rows first, zero rows after), and ``put`` pads
its scatter to a power-of-two update count through a scratch row. A
varying federation drifts the cluster count K every round; without the
quantization each new K (and each new per-round unique-cluster count)
would be a fresh XLA compile of every gather/scatter in the round —
the dominant cost of a churning round, not the math.
"""
from __future__ import annotations

from collections.abc import Mapping
from typing import Any, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _pow2(n: int) -> int:
    """Smallest power of two >= n (capacity / scatter-width quantum)."""
    return 1 if n <= 1 else 1 << (int(n) - 1).bit_length()


def _pad_rows(tree, n_new: int):
    """Append ``n_new`` zero rows to every leaf's leading axis."""
    return jax.tree.map(
        lambda x: jnp.concatenate(
            [x, jnp.zeros((n_new,) + x.shape[1:], x.dtype)]), tree)


class ClusterBank(Mapping):
    """K cluster/hypothesis models stacked on the leading axis.

    ``stacked``: pytree whose leaves are ``(capacity, ...)`` arrays with
    the K occupied rows first and zeroed spare rows after (``None`` when
    empty); ``roots``: tuple of int keys, position i ↔ row i.

    Under the client-axis mesh the bank REPLICATES (cluster-keyed, K ≪
    clients; every device needs every θ_k for the cohort gather) — its
    pow2 row capacity still matters there because replicated shapes key
    the same compiled-scan cache, see docs/SHARDING.md.
    """

    def __init__(self, stacked, roots: Sequence[int] = ()):
        self.roots: Tuple[int, ...] = tuple(int(r) for r in roots)
        self.stacked = stacked if self.roots else None
        self._index = {r: i for i, r in enumerate(self.roots)}
        assert len(self._index) == len(self.roots), "duplicate bank roots"

    # ------------------------------------------------------------ builders
    @classmethod
    def empty(cls) -> "ClusterBank":
        """The no-clusters bank (``stacked`` is None)."""
        return cls(None, ())

    @classmethod
    def from_dict(cls, models: Dict[int, Any]) -> "ClusterBank":
        """Stack a ``{root: pytree}`` dict into a bank (rows in sorted
        root order, capacity-padded)."""
        roots = sorted(int(k) for k in models)
        if not roots:
            return cls.empty()
        stacked = jax.tree.map(lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]),
                               *[models[r] for r in roots])
        cap = _pow2(len(roots))
        if cap > len(roots):
            stacked = _pad_rows(stacked, cap - len(roots))
        return cls(stacked, roots)

    @property
    def capacity(self) -> int:
        """Allocated rows (>= ``len(self)``, a power of two)."""
        if self.stacked is None:
            return 0
        return int(jax.tree.leaves(self.stacked)[0].shape[0])

    def to_dict(self) -> Dict[int, Any]:
        """Materialize back to a plain ``{root: pytree}`` dict."""
        return {r: self[r] for r in self.roots}

    # ------------------------------------------------------------ mapping
    def __getitem__(self, root):
        i = self._index[int(root)]
        return jax.tree.map(lambda x: x[i], self.stacked)

    def __iter__(self):
        return iter(self.roots)

    def __len__(self) -> int:
        return len(self.roots)

    def __contains__(self, root) -> bool:
        try:
            return int(root) in self._index
        except (TypeError, ValueError):
            return False

    def __eq__(self, other) -> bool:
        if not isinstance(other, Mapping):
            return NotImplemented
        if set(self.roots) != {int(k) for k in other.keys()}:
            return False
        for r in self.roots:
            mine = jax.tree.leaves(self[r])
            theirs = jax.tree.leaves(other[r])
            if len(mine) != len(theirs):
                return False
            if any(not np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(mine, theirs)):
                return False
        return True

    __hash__ = None

    def __repr__(self) -> str:
        return f"ClusterBank(roots={self.roots})"

    # ------------------------------------------------------------ gathers
    def take(self, roots, default):  # jaxlint: hot-path
        """Batched model gather: row per requested root, ``default`` for
        roots with no model yet (lazy θ_k = ω₀). One jnp.take per leaf;
        the default row (when needed) is appended once at index
        ``capacity``, so the gather shape depends only on (capacity,
        len(roots)) — both quantized."""
        # jaxlint: disable=R2 — roots are host ints by contract (union-find roots)
        roots = np.atleast_1d(np.asarray(roots)).astype(np.int64)
        cap = self.capacity
        # jaxlint: disable=R2 — host root→row index build, no device operand
        idx = np.fromiter((self._index.get(int(r), cap) for r in roots),
                          np.int32, len(roots))
        if self.stacked is None:
            ext = jax.tree.map(lambda d: jnp.asarray(d)[None], default)
            idx = np.zeros(len(roots), np.int32)
        elif (idx == cap).any():
            ext = jax.tree.map(
                lambda x, d: jnp.concatenate(
                    [x, jnp.asarray(d)[None].astype(x.dtype)]),
                self.stacked, default)
        else:
            ext = self.stacked
        j = jnp.asarray(idx)
        return jax.tree.map(lambda x: jnp.take(x, j, axis=0), ext)

    # ------------------------------------------------------------ scatters
    def put(self, roots, updates) -> "ClusterBank":  # jaxlint: hot-path
        """Scatter stacked ``updates`` (leading axis ↔ ``roots``) into the
        bank; unknown roots grow new rows (capacity doubles when full).
        Rows not named stay untouched.

        ``updates`` may carry MORE rows than ``len(roots)``: the first
        ``len(roots)`` rows are real, the rest are discarded through a
        scratch row. Callers quantize their update count that way (e.g.
        ``aggregate_segments`` padded to a power-of-two segment count),
        so the scatter compiles once per (capacity, row-count) pair
        instead of once per distinct per-round cluster count."""
        # jaxlint: disable=R2 — roots are host ints by contract (union-find roots)
        roots = [int(r) for r in np.atleast_1d(np.asarray(roots))]
        n = len(roots)
        assert len(set(roots)) == len(roots), "put() roots must be unique"
        n_rows = int(np.shape(jax.tree.leaves(updates)[0])[0])
        assert n_rows >= n, "updates carry fewer rows than roots"
        novel = [r for r in roots if r not in self._index]
        all_roots = self.roots + tuple(novel)
        index = {r: i for i, r in enumerate(all_roots)}
        if self.stacked is None:
            cap = _pow2(len(all_roots))
            base = jax.tree.map(
                lambda u: jnp.zeros((cap,) + u.shape[1:], u.dtype), updates)
        else:
            base, cap = self.stacked, self.capacity
            if len(all_roots) > cap:
                cap = _pow2(len(all_roots))
                base = _pad_rows(base, cap - self.capacity)
        # pad rows dump into a scratch row at index ``cap``, sliced off
        idx_np = np.full(n_rows, cap, np.int32)
        idx_np[:n] = [index[r] for r in roots]
        idx = jnp.asarray(idx_np)
        stacked = jax.tree.map(
            lambda b, u: jnp.concatenate(
                [b, jnp.zeros((1,) + b.shape[1:], b.dtype)]
            ).at[idx].set(u.astype(b.dtype))[:cap],
            base, updates)
        return ClusterBank(stacked, all_roots)

    def set(self, root: int, model) -> "ClusterBank":
        """Write one root's model (grows a row if the root is new)."""
        return self.put([root], jax.tree.map(lambda x: jnp.asarray(x)[None], model))

    def __setitem__(self, root, model):
        """In-place set — legacy checkpoint surface (``load_stocfl``)."""
        nb = self.set(int(root), model)
        self.stacked, self.roots, self._index = nb.stacked, nb.roots, nb._index

    def drop(self, roots) -> "ClusterBank":  # jaxlint: hot-path
        """Remove rows for ``roots`` (one keep-gather per leaf; the new
        bank is re-padded to a power-of-two capacity)."""
        # jaxlint: disable=R2 — host root keys, no device operand
        rm = {int(r) for r in roots} & set(self.roots)
        if not rm:
            return self
        keep = [r for r in self.roots if r not in rm]
        if not keep:
            return ClusterBank.empty()
        cap = _pow2(len(keep))
        idx_np = np.full(cap, self.capacity, np.int32)   # spare rows: zeros
        idx_np[: len(keep)] = [self._index[r] for r in keep]
        idx = jnp.asarray(idx_np)
        stacked = jax.tree.map(
            lambda x: jnp.take(
                jnp.concatenate([x, jnp.zeros((1,) + x.shape[1:], x.dtype)]),
                idx, axis=0),
            self.stacked)
        return ClusterBank(stacked, keep)

    def rename(self, remap: Dict[int, int]) -> "ClusterBank":
        """Re-key rows (e.g. after a departure re-roots a cluster) —
        host-only, no device op."""
        return ClusterBank(self.stacked,
                           [int(remap.get(r, r)) for r in self.roots])

    # ------------------------------------------------------------ merging
    def merge(self, merges, counts, init_params) -> "ClusterBank":  # jaxlint: hot-path
        """Batched Algorithm-1 model merge: θ of each merged group is the
        member-count-weighted mean of its pre-merge models — one gather +
        one weighted segment-sum per leaf, replacing the sequential
        pairwise ``tree_weighted_mean`` cascade (mathematically equal:
        cascading (n_a·a + n_b·b)/(n_a+n_b) with accumulated counts IS
        the flat Σ nᵢ·mᵢ / Σ nᵢ). ``merges`` is the (keep, absorb) list
        from ``ClusterState.merge_round``; ``counts`` the pre-merge
        {root: members} snapshot; missing models default to
        ``init_params`` (lazy θ_k = ω₀)."""
        if not merges:
            return self
        parent: Dict[int, int] = {}

        def find(r: int) -> int:
            while parent.get(r, r) != r:
                parent[r] = parent.get(parent[r], parent[r])
                r = parent[r]
            return r

        for keep, absorb in merges:
            # jaxlint: disable=R2 — host merge path by design (Alg.1 merge list)
            parent[find(int(absorb))] = find(int(keep))
        groups: Dict[int, list] = {}
        # jaxlint: disable=R2 — host merge path by design (Alg.1 merge list)
        for r in sorted({int(x) for pair in merges for x in pair}):
            groups.setdefault(find(r), []).append(r)

        from repro.core.bilevel import aggregate_segments

        finals = sorted(groups)
        members = [r for f in finals for r in groups[f]]
        seg = np.repeat(np.arange(len(finals), dtype=np.int32),
                        [len(groups[f]) for f in finals])
        # jaxlint: disable=R2 — weights come from the host member-count dict
        w = np.fromiter((counts.get(r, 1) for r in members),
                        np.float32, len(members))
        gathered = self.take(members, init_params)
        agg = aggregate_segments(gathered, w, seg, len(finals))
        absorbed = [r for r in members if r not in groups]
        return self.drop(absorbed).put(finals, agg)


def _flatten_bank(b: ClusterBank):
    return (b.stacked,), (b.roots,)


def _unflatten_bank(aux, children):
    return ClusterBank(children[0], aux[0])


jax.tree_util.register_pytree_node(ClusterBank, _flatten_bank, _unflatten_bank)
