"""repro.engine — functional federated-learning engine.

An explicit, pytree-serializable ``ServerState``, pure transitions
(``init`` / ``run_round`` / ``run_rounds`` / ``join`` / ``leave`` /
``evaluate`` / ``infer``), and a registry-based ``Strategy`` protocol
implemented by ``stocfl`` and the paper's baselines (``fedavg``,
``fedprox``, ``ditto``, ``ifca``, ``cfl``). ``run_rounds`` fuses a whole
multi-round span into one jitted ``lax.scan`` with on-device cohort
sampling (``repro.engine.sampler``), bit-faithful to the eager
``run_round`` loop. ``run_round_async`` removes the round barrier:
delayed client contributions land in a device-resident ``AsyncBuffer``
and flush as staleness-weighted merges, bitwise equal to ``run_round``
at zero delay (``repro.engine.async_agg``). See ``repro.engine.api``
for the full contract.
"""
from repro.engine.api import (advance_rng, evaluate, infer,  # noqa: F401
                              infer_batch, init,
                              join, leave, run, run_round, run_rounds,
                              sample_clients, scan_blockers, scan_history,
                              scan_program)
from repro.engine.async_agg import (AsyncBuffer, AsyncConfig,  # noqa: F401
                                    FlushBatch, run_round_async,
                                    staleness_weights)
from repro.engine.registry import (STRATEGIES, get_strategy,  # noqa: F401
                                   list_strategies, register)
from repro.engine.state import (EngineConfig, EngineContext,  # noqa: F401
                                ServerState)
from repro.engine.bank import ClusterBank  # noqa: F401
from repro.engine.sampler import (cohort_pool, cohort_size,  # noqa: F401
                                  draw_cohort, pool_capacity)
from repro.engine import strategies  # noqa: F401  (installs the registry)
from repro.engine.strategies import Strategy  # noqa: F401

__all__ = [
    "init", "run", "run_round", "run_rounds", "sample_clients",
    "advance_rng", "scan_blockers", "scan_history", "scan_program",
    "run_round_async", "staleness_weights",
    "cohort_pool", "cohort_size", "draw_cohort", "pool_capacity",
    "evaluate", "join", "leave", "infer", "infer_batch",
    "EngineConfig", "EngineContext", "ServerState",
    "AsyncConfig", "AsyncBuffer", "FlushBatch",
    "Strategy", "ClusterBank",
    "register", "get_strategy", "list_strategies", "STRATEGIES",
]
