"""repro.engine — functional federated-learning engine.

An explicit, pytree-serializable ``ServerState``, pure transitions
(``init`` / ``run_round`` / ``join`` / ``leave`` / ``evaluate`` /
``infer``), and a registry-based ``Strategy`` protocol implemented by
``stocfl`` and the paper's baselines (``fedavg``, ``fedprox``, ``ditto``,
``ifca``, ``cfl``). See ``repro.engine.api`` for the full contract.
"""
from repro.engine.api import (evaluate, infer, init, join, leave,  # noqa: F401
                              run, run_round, sample_clients)
from repro.engine.registry import (STRATEGIES, get_strategy,  # noqa: F401
                                   list_strategies, register)
from repro.engine.state import (EngineConfig, EngineContext,  # noqa: F401
                                ServerState)
from repro.engine.bank import ClusterBank  # noqa: F401
from repro.engine import strategies  # noqa: F401  (installs the registry)
from repro.engine.strategies import Strategy  # noqa: F401

__all__ = [
    "init", "run", "run_round", "sample_clients",
    "evaluate", "join", "leave", "infer",
    "EngineConfig", "EngineContext", "ServerState",
    "Strategy", "ClusterBank",
    "register", "get_strategy", "list_strategies", "STRATEGIES",
]
