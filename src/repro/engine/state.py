"""Engine state: an explicit, pytree-serializable federated server.

``ServerState`` is the ONLY thing a strategy transition may read and the
only thing it may produce — transitions are pure: they never mutate their
input, they return a new state (``dataclasses.replace`` + copied
containers). The model-bearing fields (``omega``, ``models``,
``personal``) are the pytree leaves, so the whole server checkpoint is
``jax.device_get(state)`` away and the cohort step can be placed on a
client-axis mesh; host-side bookkeeping (partition, rng, round counter)
rides along as aux data.

``EngineContext`` is the static world the state refers to: loss/eval
functions, the client datasets, compiled cohort updates, the Ψ extractor
and the optional mesh. It is built once by ``engine.init`` and is never
checkpointed — restoring a checkpoint reattaches the arrays to a freshly
built context.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.clustering import ClusterState


@dataclasses.dataclass
class EngineConfig:
    """Union of the knobs of every registered strategy.

    StoCFL uses (tau, lam, lr, local_steps, sample_rate, aggregator,
    project_dim); FedProx/Ditto read ``mu``; IFCA reads ``n_models`` and
    ``init_key``; CFL reads (eps_rel, eps2) and always runs full
    participation. ``cohort_chunk`` bounds how many clients execute in
    one vmapped step — larger cohorts run in lax.map chunks with flat
    memory (see ``bilevel.chunk_map``); 0 = unchunked.
    ``cluster_backend`` picks where StoCFL's partition lives: ``"device"``
    runs the jitted union-find + fused merge kernels of
    ``core.device_clustering`` (no per-round Ψ host sync, no Python pair
    scan); ``"numpy"`` is the host ``ClusterState`` fallback the parity
    battery checks the device path against.
    ``rng_backend`` picks where cohort sampling lives: ``"device"`` draws
    from a threefry key carried in ``ServerState.rng_key``
    (``engine.sampler`` — required by the fully-jitted ``run_rounds``
    scan, identical draws eager or scanned); ``"numpy"`` is the host
    bit-generator compatibility mode (bit-exact with pre-scan
    checkpoints and the legacy-trainer parity tests).
    ``fused_step`` routes every strategy's local update through the
    flatten-once ``kernels.prox_update_flat`` path (one fused elementwise
    pass on TPU; jnp oracle off-TPU — fp32 results stay bitwise).
    ``dtype`` is the compute precision of params/grads/batches
    ("float32" | "bfloat16"); Ψ-embeddings, cluster means, and the Eq. 2
    objective always stay fp32 (see ``engine.init``).
    ``async_cfg`` opts into async buffered aggregation: an
    ``engine.AsyncConfig`` consumed by ``run_round_async`` (staleness
    decay γ, staleness cap, buffer capacity, flush cadence); ``None``
    keeps the engine purely synchronous.
    """
    tau: float = 0.5
    lam: float = 0.05
    lr: float = 0.1
    local_steps: int = 5
    sample_rate: float = 0.1
    seed: int = 0
    aggregator: str = "mean"          # G(·): mean | median | trimmed_mean | krum
    project_dim: Optional[int] = None
    mu: float = 0.05                  # FedProx / Ditto prox weight
    n_models: int = 4                 # IFCA hypothesis count
    init_key: int = 0                 # IFCA perturbation key
    eps_rel: float = 0.35             # CFL split thresholds
    eps2: float = 0.01
    cohort_chunk: int = 0             # max clients per vmapped step (0=off)
    cluster_backend: str = "numpy"    # StoCFL partition: numpy | device
    rng_backend: str = "numpy"        # cohort sampling: numpy | device
    fused_step: bool = False          # flat fused bilevel/SGD local update
    dtype: str = "float32"            # param/grad compute precision
    async_cfg: Optional[Any] = None   # AsyncConfig: async buffered aggregation


@dataclasses.dataclass
class EngineContext:
    """Static (non-checkpointed) world: functions, data, compiled updates."""
    loss_fn: Callable
    init_params: Any
    clients: List[dict]
    cfg: EngineConfig
    eval_fn: Optional[Callable] = None
    leaf_filter: Optional[Callable] = None
    mesh: Optional[Any] = None        # jax Mesh: place cohort on client axis
    arena: Optional[Any] = None       # ClientArena: device-resident shards
    extractor: Optional[Callable] = None
    cache: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def jit(self, key: str, builder: Callable) -> Callable:
        """Memoize a compiled update under ``key`` (per-context cache)."""
        if key not in self.cache:
            self.cache[key] = builder()
        return self.cache[key]

    def mesh_devices(self) -> int:
        """Devices along the mesh's client axes (1 when no mesh is
        attached) — the shard count of every cohort-sharded leading
        axis; see ``sharding.mesh_client_count``."""
        if self.mesh is None:
            return 1
        from repro.sharding import specs
        return max(specs.mesh_client_count(self.mesh), 1)


@dataclasses.dataclass
class ServerState:
    """The federated server as a value.

    Pytree leaves: ``omega`` (global model), ``models`` (cluster /
    hypothesis models keyed by int), ``personal`` (per-client personal
    models, Ditto). Aux data: everything the host orchestration needs —
    strategy name, round counter, numpy bit-generator state (so client
    sampling is checkpoint-exact), per-client sample counts, the departed
    set, the Ψ clustering bookkeeping, CFL membership, and the metric
    history. Under ``cfg.rng_backend="device"`` the sampling state is
    instead the ``rng_key`` leaf — a device threefry key, so the whole
    multi-round loop (sampling included) can run as one ``lax.scan``
    (``engine.run_rounds``).
    """
    ctx: EngineContext
    strategy: str
    round: int
    rng_state: dict
    sizes: Tuple[int, ...]
    left: frozenset
    omega: Any
    models: Dict[int, Any]
    personal: Dict[int, Any]
    clusters: Optional[ClusterState] = None
    members: Optional[Tuple[Tuple[int, ...], ...]] = None   # CFL partition
    history: Tuple[dict, ...] = ()
    rng_key: Optional[Any] = None     # device sampling key (rng_backend="device")
    buffer: Optional[Any] = None      # AsyncBuffer: in-flight delayed deltas

    # ------------------------------------------------------------- helpers
    @property
    def n_clients(self) -> int:
        """Registered clients, departed included (ids are stable; the
        live count is ``n_clients - len(left)``)."""
        return len(self.ctx.clients)

    def cluster_model(self, root: int):
        """θ_k for a cluster root (lazy: ω₀ until first aggregate)."""
        return self.models.get(root, self.ctx.init_params)

    def client_root(self, cid: int) -> int:
        """Union-find root (= cluster id) of an observed client."""
        assert self.clusters is not None
        return self.clusters.uf.find(int(cid))

    def rng(self) -> np.random.Generator:
        """Materialize the generator at this state's position (pure: the
        state only stores the serializable bit-generator state)."""
        g = np.random.default_rng(0)
        g.bit_generator.state = self.rng_state
        return g

    def replace(self, **kw) -> "ServerState":
        """``dataclasses.replace`` shorthand — the one way transitions
        derive a new state from an old one."""
        return dataclasses.replace(self, **kw)


def fresh_rng_state(seed: int) -> dict:
    return np.random.default_rng(seed).bit_generator.state


def fresh_rng_key(seed: int):
    """Device sampling key for ``rng_backend="device"`` (threefry; lives
    in ``ServerState.rng_key``, advanced by splitting once per draw)."""
    import jax.random
    return jax.random.PRNGKey(int(seed))


def _flatten_state(s: ServerState):
    children = (s.omega, s.models, s.personal, s.rng_key, s.buffer)
    aux = (s.ctx, s.strategy, s.round, s.rng_state, s.sizes, s.left,
           s.clusters, s.members, s.history)
    return children, aux


def _unflatten_state(aux, children):
    omega, models, personal, rng_key, buffer = children
    ctx, strategy, rnd, rng_state, sizes, left, clusters, members, history = aux
    return ServerState(ctx=ctx, strategy=strategy, round=rnd,
                       rng_state=rng_state, sizes=sizes, left=left,
                       omega=omega, models=models, personal=personal,
                       clusters=clusters, members=members, history=history,
                       rng_key=rng_key, buffer=buffer)


jax.tree_util.register_pytree_node(ServerState, _flatten_state, _unflatten_state)
