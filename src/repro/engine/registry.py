"""Strategy registry — same mechanism as ``models/registry.py``: a flat
name -> implementation table so drivers select methods by string and new
methods plug in with a decorator, no orchestration rewiring."""
from __future__ import annotations

from typing import Dict, List

STRATEGIES: Dict[str, object] = {}


def register(name: str):
    """Class decorator: ``@register("fedavg")`` installs an instance."""
    def deco(cls):
        cls.name = name
        STRATEGIES[name] = cls()
        return cls
    return deco


def get_strategy(name: str):
    """Resolve a registered strategy instance by name (KeyError lists
    the registered names on a miss)."""
    if name not in STRATEGIES:
        raise KeyError(f"unknown strategy {name!r}; registered: {sorted(STRATEGIES)}")
    return STRATEGIES[name]


def list_strategies() -> List[str]:
    """Sorted names of every registered strategy."""
    return sorted(STRATEGIES)
