from repro.data.synthetic import (  # noqa: F401
    SETTING_FACTORIES,
    SETTINGS,
    drift_batch,
    femnist_like,
    hybrid,
    make_federation,
    pathological,
    rotated,
    rotated_factory,
    rotated_pathological,
    shifted,
)
from repro.data.arena import ClientArena  # noqa: F401
from repro.data.tokens import synthetic_lm_batch, token_stream  # noqa: F401
from repro.data.dirichlet import dirichlet_label_skew, quantity_skew  # noqa: F401
