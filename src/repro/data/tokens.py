"""Synthetic token streams for LLM-arch training paths.

Markov-chain token generator with per-cluster transition structure — gives
the LLM federated paths the same "clusterable distributions" property the
classification settings have (clients from the same latent domain share a
transition matrix), while staying fully offline.
"""
from __future__ import annotations

import numpy as np


def token_stream(vocab_size: int, seq_len: int, batch: int, seed: int = 0,
                 n_states: int = 64, domain: int = 0):
    """(batch, seq_len) int32 tokens from a domain-specific Markov chain.

    The chain STRUCTURE (bands, transitions) depends only on `domain` —
    all clients of a domain share one distribution; `seed` only drives the
    stochastic draws."""
    rng_dom = np.random.default_rng(7_777 + domain)
    rng = np.random.default_rng(seed * 1000 + domain)
    # low-rank transition structure: state -> preferred token band.
    # Domains are "topical": each draws its bands from a half-vocab window
    # offset by domain (50% overlap between adjacent domains), so domains
    # differ in token MARGINALS — the signal Ψ picks up via the vocab-
    # matrix gradients — not just in transition structure.
    lo = (domain * vocab_size // 4) % max(vocab_size // 2, 1)
    bands = lo + rng_dom.integers(0, max(vocab_size // 2, 1), size=n_states)
    width = max(vocab_size // n_states, 1)
    out = np.empty((batch, seq_len), np.int64)
    state = rng.integers(0, n_states, size=batch)
    trans = rng_dom.integers(0, n_states, size=(n_states, 4))
    for t in range(seq_len):
        tok = (bands[state] + rng.integers(0, width, size=batch)) % vocab_size
        out[:, t] = tok
        state = trans[state, rng.integers(0, 4, size=batch)]
    return out.astype(np.int32)


def synthetic_lm_batch(cfg, seq_len: int, batch: int, seed: int = 0, domain: int = 0):
    """Batch dict matching the registry's input_specs for any arch family."""
    toks = token_stream(cfg.vocab_size, seq_len, batch, seed, domain=domain)
    if cfg.arch_type == "audio":
        rng = np.random.default_rng(seed + 7)
        frames = rng.normal(size=(batch, cfg.enc_seq, cfg.d_model)).astype(np.float32) * 0.02
        return {"frames": frames, "tokens": toks}
    if cfg.arch_type == "vlm":
        rng = np.random.default_rng(seed + 7)
        patches = rng.normal(size=(batch, cfg.n_patches, cfg.d_model)).astype(np.float32) * 0.02
        n_text = max(seq_len - cfg.n_patches, 8)
        return {"patches": patches, "tokens": toks[:, :n_text]}
    return {"tokens": toks}
