"""Device-resident client arena: pack every shard once, gather per round.

The engine's legacy data path re-stacks the sampled cohort from the host
client list every round (``jnp.stack`` over C pytrees — hundreds of
dispatches plus H2D traffic at realistic populations). The arena packs
ALL client shards into a single stacked device pytree up front, so a
cohort is one ``jnp.take`` gather per leaf regardless of C — the
substrate for §3.3's "arbitrary proportion of client participation" at
thousands of clients.

Ragged client sizes are handled by pad-and-mask: every client's arrays
are zero-padded to the longest shard and the gathered batch carries a
``"mask"`` row-validity array; mask-aware losses (``models/simple``)
weight per-example terms by it, so pad rows contribute exactly nothing.
Equal-size federations pack without padding and gather batches that are
bitwise identical to the legacy restack — the arena/legacy parity tests
rely on this.

Dynamic membership (§5) is first-class: the packed arrays carry spare
row *capacity* that doubles on demand (``grow``), so ``append`` is one
O(row) device write instead of an O(N) full-buffer concat per join, and
departures ``tombstone`` their row in place — the data stays resident
(old forked states can still gather it) until enough rows die that
``compact`` reclaims them in one gather. Client ids stay stable through
all of it: gathers translate cid -> physical row through a host-side
index, so the engine's ``ServerState`` bookkeeping never learns about
row moves.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@functools.lru_cache(maxsize=None)
def _row_writer_for(backend: str):
    """Jitted single-row scatter ``x.at[i].set(v)`` for one backend; the
    stacked buffer is donated off-CPU so the write recycles it in place
    (true O(row) joins on accelerators — on CPU jax ignores donation and
    copies)."""
    donate = (0,) if backend != "cpu" else ()
    return jax.jit(lambda x, i, v: x.at[i].set(v), donate_argnums=donate)


def _row_writer():
    """The row writer for the backend active NOW. The backend is part of
    the (cached) writer, not frozen at first use — a process that selects
    its device after import (or a test that swaps platforms) gets the
    right donation behavior at every call."""
    return _row_writer_for(jax.default_backend())


class ClientArena:
    """All client shards as one stacked pytree with leading client axis.

    Layout: ``packed`` leaves are ``(capacity, n_max, ...)`` arrays of
    which rows ``[0, n_rows)`` are occupied and the rest are zeroed spare
    capacity; ``mask`` is the ``(capacity, n_max)`` float32 row-validity
    companion. Host-side bookkeeping maps stable client ids to physical
    rows: ``sizes[cid]`` is the true shard length, ``rows[cid]`` the
    physical row (−1 once ``compact`` reclaimed it), ``dead`` the set of
    tombstoned cids whose rows are still resident. ``ragged`` is true
    when any *live* shard is shorter than ``n_max`` (gathers then carry
    the ``"mask"`` leaf).
    """

    def __init__(self, packed, mask, sizes: np.ndarray, ragged: bool,
                 rows: Optional[np.ndarray] = None,
                 n_rows: Optional[int] = None,
                 dead: frozenset = frozenset()):
        self.packed = packed
        self.mask = mask
        self.sizes = np.asarray(sizes)
        self.ragged = bool(ragged)
        self.rows = (np.arange(len(self.sizes), dtype=np.int64)
                     if rows is None else np.asarray(rows, np.int64))
        self.n_rows = int(len(self.sizes) if n_rows is None else n_rows)
        self.dead = frozenset(int(c) for c in dead)
        self._device_rows = None

    @property
    def device_rows(self):
        """``rows`` (cid→physical row) as a device i32 vector, uploaded
        once per arena version and cached. Arenas are functional — every
        mutation builds a NEW ``ClientArena`` — so the cache can never
        serve a stale map; this is what keeps per-round scan-consts
        plumbing free of repeated host→device round-trips.

        The vector is padded to the next power of two (pad slots map to
        row 0 but belong to unregistered cids, which no cohort can ever
        draw) so that compiled programs taking the cid→row map recompile
        per population *bracket*, not per join — the same shape
        quantization as ``sampler.pool_capacity``."""
        if self._device_rows is None:
            n = len(self.rows)
            cap = 1 if n <= 1 else 1 << (n - 1).bit_length()
            padded = np.zeros(cap, np.int32)
            padded[:n] = self.rows.astype(np.int32)
            self._device_rows = jnp.asarray(padded)
        return self._device_rows

    # ------------------------------------------------------------- builders
    @classmethod
    def from_clients(cls, clients: Sequence[Any],
                     capacity: Optional[int] = None) -> "ClientArena":
        """Pack a client list into a fresh arena (one H2D upload).

        ``capacity`` pre-allocates spare rows for expected joins (default:
        exactly ``len(clients)`` rows — growth then starts on the first
        ``append``)."""
        if not clients:
            raise ValueError("ClientArena needs at least one client")
        sizes = np.array([int(np.shape(jax.tree.leaves(c)[0])[0])
                          for c in clients])
        for c, n in zip(clients, sizes):
            for leaf in jax.tree.leaves(c):
                assert np.shape(leaf)[0] == n, (
                    "every client leaf must share the leading example axis")
        n_max = int(sizes.max())
        ragged = bool((sizes != n_max).any())
        cap = max(int(capacity or 0), len(clients))

        def pack(*xs):
            xs = [np.asarray(x) for x in xs]
            if not ragged and cap == len(xs):
                return jnp.asarray(np.stack(xs))
            out = np.zeros((cap, n_max) + xs[0].shape[1:], xs[0].dtype)
            for i, x in enumerate(xs):
                out[i, : x.shape[0]] = x
            return jnp.asarray(out)

        packed = jax.tree.map(pack, *clients)
        if ragged and not isinstance(packed, dict):
            raise TypeError("ragged arenas need dict batches (for the "
                            "gathered 'mask' key); got "
                            f"{type(clients[0]).__name__}")
        mask = np.zeros((cap, n_max), np.float32)
        mask[: len(sizes)] = np.arange(n_max)[None, :] < sizes[:, None]
        return cls(packed, jnp.asarray(mask), sizes, ragged,
                   n_rows=len(clients))

    # --------------------------------------------------------------- views
    @property
    def n_max(self) -> int:
        """Example-axis length every shard is padded to."""
        return int(jax.tree.leaves(self.packed)[0].shape[1])

    @property
    def capacity(self) -> int:
        """Allocated rows (``n_rows`` occupied, the rest spare)."""
        return int(jax.tree.leaves(self.packed)[0].shape[0])

    def _live(self) -> np.ndarray:
        """Cids that are resident and not tombstoned."""
        alive = (self.rows >= 0)
        alive[list(self.dead & set(range(len(self.sizes))))] = False
        return np.nonzero(alive)[0]

    def _recompute_ragged(self, sizes: np.ndarray, rows: np.ndarray,
                          dead: frozenset) -> bool:
        alive = rows >= 0
        if dead:
            alive[list(dead)] = False
        live_sizes = sizes[alive]
        return bool(live_sizes.size and (live_sizes != self.n_max).any())

    # ------------------------------------------------------------- growth
    def grow(self, min_capacity: int) -> "ClientArena":
        """New arena with row capacity >= ``min_capacity``: capacity
        doubles (amortized-O(1) appends) and the new rows are zeroed spare
        space — one concat per leaf, paid O(log N) times over N joins
        instead of on every join."""
        cap = self.capacity
        if min_capacity <= cap:
            return self
        new_cap = cap
        while new_cap < min_capacity:
            new_cap *= 2

        def one(x):
            pad = jnp.zeros((new_cap - cap,) + x.shape[1:], x.dtype)
            return jnp.concatenate([x, pad])

        return ClientArena(jax.tree.map(one, self.packed), one(self.mask),
                           self.sizes, self.ragged, self.rows, self.n_rows,
                           self.dead)

    def _grow_example_axis(self, n: int) -> "ClientArena":
        """Re-pad every row to a longer example axis (a newcomer longer
        than every resident shard — rare, full copy)."""
        n_max = self.n_max
        if n <= n_max:
            return self

        def one(x):
            return jnp.pad(x, [(0, 0), (0, n - n_max)]
                           + [(0, 0)] * (x.ndim - 2))

        packed = jax.tree.map(one, self.packed)
        live = self.sizes[self._live()]
        ragged = bool(live.size and (live != n).any())
        return ClientArena(packed, one(self.mask), self.sizes, ragged,
                           self.rows, self.n_rows, self.dead)

    # ------------------------------------------------------------- append
    def append(self, batch) -> "ClientArena":
        """New arena with one more client: one O(row) device write into
        spare capacity (``grow`` doubles the row axis when full, so the
        per-join cost is amortized O(1) — §5 dynamic joins at thousands
        of resident clients stay flat). Only a newcomer LONGER than every
        resident shard forces re-padding the example axis. Off-CPU the
        write donates the packed buffers: the *input* arena's arrays are
        invalidated — always rebind (``arena = arena.append(b)``)."""
        n = int(np.shape(jax.tree.leaves(batch)[0])[0])
        ar = self._grow_example_axis(n)
        ar = ar.grow(ar.n_rows + 1)
        n_max = ar.n_max
        sizes = np.append(ar.sizes, n)
        rows = np.append(ar.rows, ar.n_rows)
        ragged = ar.ragged or n < n_max
        if ragged and not isinstance(ar.packed, dict):
            raise TypeError("ragged arenas need dict batches (for the "
                            "gathered 'mask' key)")
        write = _row_writer()
        i = jnp.asarray(ar.n_rows, jnp.int32)

        def one(x, b):
            row = np.zeros((n_max,) + x.shape[2:], x.dtype)
            row[:n] = np.asarray(b)
            return write(x, i, jnp.asarray(row))

        packed = jax.tree.map(one, ar.packed, batch)
        mask = write(ar.mask, i, jnp.asarray(
            (np.arange(n_max) < n).astype(np.float32)))
        return ClientArena(packed, mask, sizes, ragged, rows,
                           ar.n_rows + 1, ar.dead)

    def update(self, cid: int, batch) -> "ClientArena":
        """Rewrite one resident client's shard in place (distribution
        drift, §5): one O(row) device write. The new shard must fit the
        current example axis (``n <= n_max``); drift hooks preserve shard
        length so this never re-pads."""
        row = int(self.rows[cid])
        if row < 0:
            raise KeyError(f"client {cid} was compacted away")
        n = int(np.shape(jax.tree.leaves(batch)[0])[0])
        n_max = self.n_max
        if n > n_max:
            raise ValueError(f"update shard len {n} > arena n_max {n_max}")
        sizes = self.sizes.copy()
        sizes[cid] = n
        ragged = self._recompute_ragged(sizes, self.rows, self.dead)
        # validate BEFORE the donating writes: raising after them would
        # leave the caller holding an arena whose buffers were consumed
        if ragged and not isinstance(self.packed, dict):
            raise TypeError("ragged arenas need dict batches (for the "
                            "gathered 'mask' key)")
        write = _row_writer()
        i = jnp.asarray(row, jnp.int32)

        def one(x, b):
            r = np.zeros((n_max,) + x.shape[2:], x.dtype)
            r[:n] = np.asarray(b)
            return write(x, i, jnp.asarray(r))

        packed = jax.tree.map(one, self.packed, batch)
        mask = write(self.mask, i, jnp.asarray(
            (np.arange(n_max) < n).astype(np.float32)))
        return ClientArena(packed, mask, sizes, ragged, self.rows,
                           self.n_rows, self.dead)

    # ---------------------------------------------------------- departures
    def tombstone(self, cid: int, compact_frac: float = 0.5) -> "ClientArena":
        """Mark a departed client's row dead — O(1), no device op; the
        data stays gatherable (forked pre-departure states remain valid)
        until dead rows exceed ``compact_frac`` of the occupied rows, at
        which point the arena ``compact``s itself. ``compact_frac <= 0``
        disables auto-compaction."""
        cid = int(cid)
        if cid in self.dead or not 0 <= cid < len(self.sizes):
            return self
        dead = self.dead | {cid}
        ar = ClientArena(self.packed, self.mask, self.sizes,
                         self._recompute_ragged(self.sizes, self.rows, dead),
                         self.rows, self.n_rows, dead)
        n_dead_resident = sum(1 for c in dead if ar.rows[c] >= 0)
        if compact_frac > 0 and n_dead_resident > compact_frac * ar.n_rows:
            return ar.compact()
        return ar

    def compact(self) -> "ClientArena":
        """Reclaim tombstoned rows: one gather per leaf keeps only live
        rows (registered order preserved), dead cids' rows become −1, and
        capacity shrinks to the live count (the next ``append`` regrows).
        Gathering a compacted-away cid is an error — by then every state
        that could sample it has processed the departure."""
        live = self._live()
        if not live.size:
            raise ValueError("compact would empty the arena")
        src = jnp.asarray(self.rows[live].astype(np.int32))
        packed = jax.tree.map(lambda x: jnp.take(x, src, axis=0), self.packed)
        mask = jnp.take(self.mask, src, axis=0)
        rows = np.full(len(self.sizes), -1, np.int64)
        rows[live] = np.arange(live.size)
        ragged = self._recompute_ragged(self.sizes, rows, self.dead)
        return ClientArena(packed, mask, self.sizes, ragged, rows,
                           int(live.size), self.dead)

    # ------------------------------------------------------------ sharding
    def place(self, mesh) -> "ClientArena":
        """New arena with ``packed``/``mask`` device_put row-sharded over
        the mesh's client axes (``sharding.place_cohort`` on the leading
        capacity axis; divisibility-safe — a capacity that does not
        divide the device count stays replicated). ``engine.init`` calls
        this once when a mesh is attached, so every later gather reads
        from resident shards; arena mutations derive from the placed
        buffers and the scanned engine re-pins its consts per span
        (a no-op device_put when the sharding already matches)."""
        if mesh is None:
            return self
        from repro.sharding import specs
        return ClientArena(specs.place_cohort(self.packed, mesh),
                           specs.place_cohort(self.mask, mesh),
                           self.sizes, self.ragged, self.rows, self.n_rows,
                           self.dead)

    # ------------------------------------------------------------- gather
    def gather(self, client_ids) -> Any:
        """Stacked cohort batch for ``client_ids`` — one take per leaf,
        cids translated to physical rows. Ragged arenas add a ``"mask"``
        leaf for mask-aware losses."""
        cids = np.asarray(client_ids, np.int64)
        rows = self.rows[cids]
        if (rows < 0).any():
            bad = cids[rows < 0].tolist()
            raise KeyError(f"clients {bad} were compacted out of the arena")
        idx = jnp.asarray(rows.astype(np.int32))
        batch = jax.tree.map(lambda x: jnp.take(x, idx, axis=0), self.packed)
        if self.ragged:
            batch = dict(batch)
            batch["mask"] = jnp.take(self.mask, idx, axis=0)
        return batch

    def client(self, cid: int) -> Any:
        """One client's unpadded shard (host-loop uses: Ψ extraction)."""
        row = int(self.rows[cid])
        if row < 0:
            raise KeyError(f"client {cid} was compacted away")
        n = int(self.sizes[cid])
        return jax.tree.map(lambda x: x[row, :n], self.packed)

    # ------------------------------------------------------------- stats
    @property
    def n_clients(self) -> int:
        """Registered clients (tombstoned included — ids are stable)."""
        return len(self.sizes)

    @property
    def n_live(self) -> int:
        """Registered minus tombstoned."""
        return len(self.sizes) - len(self.dead)

    @property
    def nbytes(self) -> int:
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(self.packed))

    def __repr__(self) -> str:
        return (f"ClientArena(n={self.n_clients}, live={self.n_live}, "
                f"capacity={self.capacity}, n_max={self.n_max}, "
                f"ragged={self.ragged}, mb={self.nbytes / 2**20:.1f})")
