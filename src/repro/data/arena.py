"""Device-resident client arena: pack every shard once, gather per round.

The engine's legacy data path re-stacks the sampled cohort from the host
client list every round (``jnp.stack`` over C pytrees — hundreds of
dispatches plus H2D traffic at realistic populations). The arena packs
ALL client shards into a single stacked device pytree up front, so a
cohort is one ``jnp.take`` gather per leaf regardless of C — the
substrate for §3.3's "arbitrary proportion of client participation" at
thousands of clients.

Ragged client sizes are handled by pad-and-mask: every client's arrays
are zero-padded to the longest shard and the gathered batch carries a
``"mask"`` row-validity array; mask-aware losses (``models/simple``)
weight per-example terms by it, so pad rows contribute exactly nothing.
Equal-size federations pack without padding and gather batches that are
bitwise identical to the legacy restack — the arena/legacy parity tests
rely on this.
"""
from __future__ import annotations

from typing import Any, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


class ClientArena:
    """All client shards as one stacked pytree with leading client axis.

    ``packed``: pytree, leaves ``(N, n_max, ...)``; ``mask``:
    ``(N, n_max)`` float32 row validity; ``sizes``: host ``(N,)`` true
    shard lengths; ``ragged``: whether any padding exists.
    """

    def __init__(self, packed, mask, sizes: np.ndarray, ragged: bool):
        self.packed = packed
        self.mask = mask
        self.sizes = np.asarray(sizes)
        self.ragged = bool(ragged)

    @classmethod
    def from_clients(cls, clients: Sequence[Any]) -> "ClientArena":
        if not clients:
            raise ValueError("ClientArena needs at least one client")
        sizes = np.array([int(np.shape(jax.tree.leaves(c)[0])[0])
                          for c in clients])
        for c, n in zip(clients, sizes):
            for leaf in jax.tree.leaves(c):
                assert np.shape(leaf)[0] == n, (
                    "every client leaf must share the leading example axis")
        n_max = int(sizes.max())
        ragged = bool((sizes != n_max).any())

        def pack(*xs):
            xs = [np.asarray(x) for x in xs]
            if not ragged:
                return jnp.asarray(np.stack(xs))
            out = np.zeros((len(xs), n_max) + xs[0].shape[1:], xs[0].dtype)
            for i, x in enumerate(xs):
                out[i, : x.shape[0]] = x
            return jnp.asarray(out)

        packed = jax.tree.map(pack, *clients)
        if ragged and not isinstance(packed, dict):
            raise TypeError("ragged arenas need dict batches (for the "
                            "gathered 'mask' key); got "
                            f"{type(clients[0]).__name__}")
        mask = jnp.asarray(
            (np.arange(n_max)[None, :] < sizes[:, None]).astype(np.float32))
        return cls(packed, mask, sizes, ragged)

    # ------------------------------------------------------------- append
    def append(self, batch) -> "ClientArena":
        """New arena with one more client: one padded-row concat per leaf
        — a flat device copy with O(1) dispatches, instead of the O(N)
        host repack + per-client Python loop + full H2D re-upload of
        ``from_clients`` (§5 dynamic joins at thousands of resident
        clients). The concat still touches every resident byte on device;
        a growth-capacity buffer would amortize that if join bursts ever
        dominate. Only a newcomer LONGER than every resident shard forces
        re-padding the packed arrays to the new ``n_max``."""
        n = int(np.shape(jax.tree.leaves(batch)[0])[0])
        n_max = int(self.sizes.max())
        packed, ragged = self.packed, self.ragged
        if n > n_max:                         # grow the example axis
            packed = jax.tree.map(
                lambda x: jnp.pad(x, [(0, 0), (0, n - n_max)]
                                  + [(0, 0)] * (x.ndim - 2)), packed)
            mask_grown = jnp.pad(self.mask, [(0, 0), (0, n - n_max)])
            ragged = ragged or bool((self.sizes != n).any())
            n_max = n
        else:
            mask_grown = self.mask
            ragged = ragged or n < n_max
        if ragged and not isinstance(packed, dict):
            raise TypeError("ragged arenas need dict batches (for the "
                            "gathered 'mask' key)")

        def one(x, b):
            row = np.zeros((1, n_max) + x.shape[2:], x.dtype)
            row[0, :n] = np.asarray(b)
            return jnp.concatenate([x, jnp.asarray(row)])

        packed = jax.tree.map(one, packed, batch)
        row_mask = jnp.asarray(
            (np.arange(n_max)[None, :] < n).astype(np.float32))
        mask = jnp.concatenate([mask_grown, row_mask])
        return ClientArena(packed, mask, np.append(self.sizes, n), ragged)

    # ------------------------------------------------------------- gather
    def gather(self, client_ids) -> Any:
        """Stacked cohort batch for ``client_ids`` — one take per leaf.
        Ragged arenas add a ``"mask"`` leaf for mask-aware losses."""
        idx = jnp.asarray(np.asarray(client_ids, np.int32))
        batch = jax.tree.map(lambda x: jnp.take(x, idx, axis=0), self.packed)
        if self.ragged:
            batch = dict(batch)
            batch["mask"] = jnp.take(self.mask, idx, axis=0)
        return batch

    def client(self, cid: int) -> Any:
        """One client's unpadded shard (host-loop uses: Ψ extraction)."""
        n = int(self.sizes[cid])
        return jax.tree.map(lambda x: x[cid, :n], self.packed)

    # ------------------------------------------------------------- stats
    @property
    def n_clients(self) -> int:
        return len(self.sizes)

    @property
    def nbytes(self) -> int:
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(self.packed))

    def __repr__(self) -> str:
        return (f"ClientArena(n={self.n_clients}, n_max={int(self.sizes.max())}, "
                f"ragged={self.ragged}, mb={self.nbytes / 2**20:.1f})")
