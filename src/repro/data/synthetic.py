"""Synthetic Non-IID federations reproducing the paper's four skews (§4.1).

Real MNIST/FEMNIST are unavailable offline; we generate structured
Gaussian-prototype classification data that preserves the Non-IID
*mechanics* the paper manipulates:

  pathological — label-distribution skew: clients only hold the label
                 subset of their group ({0,1,2},{3,4},{5,6},{7,8,9});
  rotated      — feature-distribution skew: per-cluster fixed orthogonal
                 transform of the feature space (the vector-space analogue
                 of rotating every image by the cluster's angle);
  shifted      — label-concept skew: ȳ = (y + s) mod 10, s ∈ {0,3,6,9};
  hybrid       — feature-concept skew: same labels, disjoint generative
                 domains (MNIST-vs-FashionMNIST analogue);
  femnist      — hybrid mixture: clients drawn from latent "writer style"
                 clusters with per-client jitter, unequal sizes allowed.

Each builder returns (clients, true_cluster, test_sets):
  clients:      list of {"x": (n, dim) f32, "y": (n,) i32}
  true_cluster: list[int] per client
  test_sets:    dict true_cluster_id -> {"x","y"} held-out batch
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

DIM = 64
N_CLASSES = 10


def _protos(rng, n_classes=N_CLASSES, dim=DIM, sep=3.0):
    p = rng.normal(size=(n_classes, dim))
    return sep * p / np.linalg.norm(p, axis=1, keepdims=True)


def _sample(rng, protos, labels, noise=0.5):
    x = protos[labels] + rng.normal(size=(len(labels), protos.shape[1])) * noise
    return x.astype(np.float32)


def _orthogonal(rng, dim):
    q, _ = np.linalg.qr(rng.normal(size=(dim, dim)))
    return q.astype(np.float32)


def _batch(x, y):
    return {"x": np.asarray(x, np.float32), "y": np.asarray(y, np.int32)}


def _make_clients(rng, protos, transform_x, transform_y, n_clients, n_per,
                  labels_allowed=None, dim=DIM):
    clients = []
    for _ in range(n_clients):
        pool = labels_allowed if labels_allowed is not None else np.arange(N_CLASSES)
        y = rng.choice(pool, size=n_per)
        x = _sample(rng, protos, y)
        clients.append(_batch(transform_x(x), transform_y(y)))
    return clients


def pathological(n_clients=400, n_per=128, seed=0):
    """4 clusters by disjoint label groups (McMahan-style sort-and-split)."""
    rng = np.random.default_rng(seed)
    protos = _protos(rng)
    groups = [[0, 1, 2], [3, 4], [5, 6], [7, 8, 9]]
    per = n_clients // len(groups)
    clients, true_cluster = [], []
    for k, g in enumerate(groups):
        clients += _make_clients(rng, protos, lambda x: x, lambda y: y, per, n_per,
                                 labels_allowed=np.array(g))
        true_cluster += [k] * per
    test_sets = {}
    for k, g in enumerate(groups):
        y = rng.choice(np.array(g), size=512)
        test_sets[k] = _batch(_sample(rng, protos, y), y)
    return clients, true_cluster, test_sets


def rotated(n_clusters=4, n_clients=400, n_per=128, seed=0):
    """Per-cluster orthogonal feature transform (rotation analogue)."""
    rng = np.random.default_rng(seed)
    protos = _protos(rng)
    qs = [np.eye(DIM, dtype=np.float32)] + [_orthogonal(rng, DIM) for _ in range(n_clusters - 1)]
    per = n_clients // n_clusters
    clients, true_cluster = [], []
    for k in range(n_clusters):
        clients += _make_clients(rng, protos, lambda x, q=qs[k]: x @ q, lambda y: y, per, n_per)
        true_cluster += [k] * per
    test_sets = {}
    for k in range(n_clusters):
        y = rng.integers(0, N_CLASSES, size=512)
        test_sets[k] = _batch(_sample(rng, protos, y) @ qs[k], y)
    return clients, true_cluster, test_sets


def shifted(n_clusters=4, n_clients=400, n_per=128, seed=0, shifts=(0, 3, 6, 9)):
    """ȳ = (y + s) mod 10 per cluster (label-concept skew, Sattler-style)."""
    rng = np.random.default_rng(seed)
    protos = _protos(rng)
    per = n_clients // n_clusters
    clients, true_cluster = [], []
    for k in range(n_clusters):
        s = shifts[k % len(shifts)]
        clients += _make_clients(rng, protos, lambda x: x,
                                 lambda y, s=s: (y + s) % N_CLASSES, per, n_per)
        true_cluster += [k] * per
    test_sets = {}
    for k in range(n_clusters):
        s = shifts[k % len(shifts)]
        y = rng.integers(0, N_CLASSES, size=512)
        test_sets[k] = _batch(_sample(rng, protos, y), (y + s) % N_CLASSES)
    return clients, true_cluster, test_sets


def hybrid(n_clients=200, n_per=128, seed=0):
    """Two disjoint generative domains, same label space (MNIST vs F-MNIST)."""
    rng = np.random.default_rng(seed)
    protos_a = _protos(rng)
    protos_b = _protos(rng)                     # independent domain
    per = n_clients // 2
    clients, true_cluster = [], []
    for k, protos in enumerate([protos_a, protos_b]):
        clients += _make_clients(rng, protos, lambda x: x, lambda y: y, per, n_per)
        true_cluster += [k] * per
    test_sets = {}
    for k, protos in enumerate([protos_a, protos_b]):
        y = rng.integers(0, N_CLASSES, size=512)
        test_sets[k] = _batch(_sample(rng, protos, y), y)
    return clients, true_cluster, test_sets


def femnist_like(n_clients=300, n_per=128, seed=0, n_styles=2):
    """Latent writer-style mixture: n_styles generative styles, per-client
    jitter, the paper's 'no clear clusters but styles cluster' setting."""
    rng = np.random.default_rng(seed)
    protos = _protos(rng, n_classes=N_CLASSES)
    styles = [np.eye(DIM, dtype=np.float32)] + [_orthogonal(rng, DIM) for _ in range(n_styles - 1)]
    clients, true_cluster = [], []
    for i in range(n_clients):
        k = int(rng.integers(0, n_styles))
        y = rng.integers(0, N_CLASSES, size=n_per)
        jitter = rng.normal(size=(DIM, DIM)).astype(np.float32) * 0.02
        x = _sample(rng, protos, y) @ (styles[k] + jitter)
        clients.append(_batch(x, y))
        true_cluster.append(k)
    test_sets = {}
    for k in range(n_styles):
        y = rng.integers(0, N_CLASSES, size=512)
        test_sets[k] = _batch(_sample(rng, protos, y) @ styles[k], y)
    return clients, true_cluster, test_sets


def rotated_pathological(n_clients=400, n_per=128, seed=0):
    """§4.3 τ-study setting: 2 rotations × 4 label groups = 8 fine clusters."""
    rng = np.random.default_rng(seed)
    protos = _protos(rng)
    qs = [np.eye(DIM, dtype=np.float32), _orthogonal(rng, DIM)]
    groups = [[0, 1, 2], [3, 4], [5, 6], [7, 8, 9]]
    per = n_clients // (len(qs) * len(groups))
    clients, true_fine, true_rot, true_label = [], [], [], []
    for r, q in enumerate(qs):
        for gidx, g in enumerate(groups):
            clients += _make_clients(rng, protos, lambda x, q=q: x @ q, lambda y: y,
                                     per, n_per, labels_allowed=np.array(g))
            true_fine += [r * len(groups) + gidx] * per
            true_rot += [r] * per
            true_label += [gidx] * per
    return clients, {"fine": true_fine, "rotation": true_rot, "label": true_label}


SETTINGS = {
    "pathological": pathological,
    "rotated": rotated,
    "shifted": shifted,
    "hybrid": hybrid,
    "femnist": femnist_like,
}


def make_federation(setting: str, **kw):
    return SETTINGS[setting](**kw)


def rotated_partial(n_clusters=4, n_clients=40, n_per=12, seed=1, rot_dims=16):
    """Partially-shared structure: clusters differ only in a rotated
    ``rot_dims``-dim subspace (48/64 dims shared) with SCARCE per-client
    data — the regime where the paper's λ knowledge-transfer term matters
    (rotated digits share stroke features). See EXPERIMENTS.md Table-3 note."""
    rng = np.random.default_rng(seed)
    protos = _protos(rng)
    qs = []
    for _ in range(n_clusters):
        q = np.eye(DIM, dtype=np.float32)
        q[:rot_dims, :rot_dims] = _orthogonal(rng, rot_dims)
        qs.append(q)
    per = n_clients // n_clusters
    clients, true_cluster = [], []
    for k in range(n_clusters):
        clients += _make_clients(rng, protos, lambda x, q=qs[k]: x @ q,
                                 lambda y: y, per, n_per)
        true_cluster += [k] * per
    test_sets = {}
    for k in range(n_clusters):
        y = rng.integers(0, N_CLASSES, size=512)
        test_sets[k] = _batch(_sample(rng, protos, y) @ qs[k], y)
    return clients, true_cluster, test_sets


SETTINGS["rotated_partial"] = rotated_partial


# ----------------------------------------------------------- churn hooks
def rotated_factory(n_clusters=4, n_per=128, seed=0):
    """Client factory for §5 churn simulations over the ``rotated``
    setting: draws FRESH clients from the same latent distributions as
    ``rotated(n_clusters=..., seed=...)`` — the class prototypes and
    per-cluster orthogonal transforms are rebuilt with the identical rng
    consumption order, so a client made for ``cluster=k`` is a new i.i.d.
    draw from the distribution incumbent cluster k trained on (the
    paper's newly-joined-client experiment).

    Returns ``factory(cluster, rng, n=n_per) -> {"x", "y"}`` — the
    signature ``repro.sim.simulate`` expects for ``client_factory``.
    """
    rng = np.random.default_rng(seed)
    protos = _protos(rng)
    qs = [np.eye(DIM, dtype=np.float32)] + [_orthogonal(rng, DIM)
                                            for _ in range(n_clusters - 1)]

    def factory(cluster, rng2, n=n_per):
        k = int(cluster) % n_clusters if cluster is not None else \
            int(rng2.integers(n_clusters))
        y = rng2.integers(0, N_CLASSES, size=n)
        return _batch(_sample(rng2, protos, y) @ qs[k], y)

    return factory


SETTING_FACTORIES = {
    "rotated": rotated_factory,
}


def drift_batch(batch, rng, strength: float = 0.05):
    """Distribution-drift hook (``repro.sim`` ``Drift`` events): rotate a
    client's feature space by a small random orthogonal transform
    ``Q = qr(I + strength·G)`` — the continuous analogue of the
    ``rotated`` skew. Labels and shard length are preserved, so arena
    rows rewrite in place (``ClientArena.update``)."""
    x = np.asarray(batch["x"], np.float32)
    d = x.shape[1]
    g = rng.normal(size=(d, d)).astype(np.float32)
    q, _ = np.linalg.qr(np.eye(d, dtype=np.float32) + strength * g)
    out = {k: np.asarray(v) for k, v in batch.items() if k not in ("x",)}
    out["x"] = (x @ q.astype(np.float32)).astype(np.float32)
    return out
