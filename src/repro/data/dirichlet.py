"""Standard FL benchmark partitions beyond the paper's four settings:
Dirichlet label skew (Hsu et al.) and quantity skew — used to stress
StoCFL where NO crisp latent clustering exists (the femnist-like regime,
harder than the paper's block-structured settings)."""
from __future__ import annotations

import numpy as np

from repro.data.synthetic import DIM, N_CLASSES, _batch, _protos, _sample


def dirichlet_label_skew(n_clients=100, n_per=128, alpha=0.5, seed=0):
    """Each client's label marginal ~ Dir(α). Small α ⇒ extreme skew.

    Returns (clients, label_marginals, test_set) — no ground-truth cluster
    ids (there are none); callers inspect what StoCFL discovers."""
    rng = np.random.default_rng(seed)
    protos = _protos(rng)
    clients, marginals = [], []
    for _ in range(n_clients):
        p = rng.dirichlet(np.full(N_CLASSES, alpha))
        y = rng.choice(N_CLASSES, size=n_per, p=p)
        clients.append(_batch(_sample(rng, protos, y), y))
        marginals.append(p)
    y = rng.integers(0, N_CLASSES, size=1024)
    test = _batch(_sample(rng, protos, y), y)
    return clients, np.stack(marginals), test


def quantity_skew(n_clients=100, alpha=1.0, base=32, cap=512, seed=0):
    """Client dataset sizes ~ power law; same distribution otherwise.
    StoCFL's size-weighted aggregation should be invariant to this."""
    rng = np.random.default_rng(seed)
    protos = _protos(rng)
    sizes = np.clip((rng.pareto(alpha, n_clients) + 1) * base, base, cap).astype(int)
    clients = []
    for n in sizes:
        y = rng.integers(0, N_CLASSES, size=int(n))
        clients.append(_batch(_sample(rng, protos, y), y))
    y = rng.integers(0, N_CLASSES, size=1024)
    return clients, sizes, _batch(_sample(rng, protos, y), y)
