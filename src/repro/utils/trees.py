"""Pytree utilities used across the framework.

Parameters everywhere in repro are plain nested dicts of jnp arrays.
These helpers implement the linear-algebra-on-pytrees the StoCFL server
needs (weighted averages, axpy, norms, flattening for Ψ representations).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree, s):
    return jax.tree.map(lambda x: x * s, tree)


def tree_axpy(a, x, y):
    """a*x + y elementwise over two pytrees."""
    return jax.tree.map(lambda xi, yi: a * xi + yi, x, y)


def tree_dot(a, b):
    """Inner product of two pytrees (fp32 accumulate)."""
    leaves = jax.tree.map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b
    )
    return jax.tree.reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_norm(tree):
    return jnp.sqrt(tree_dot(tree, tree))


def tree_weighted_mean(trees, weights):
    """Weighted mean of a list of pytrees. weights: list/array of scalars."""
    w = jnp.asarray(weights, dtype=jnp.float32)
    w = w / jnp.sum(w)
    out = tree_scale(trees[0], w[0])
    for i in range(1, len(trees)):
        out = tree_axpy(w[i], trees[i], out)
    return out


def tree_flatten_vector(tree, dtype=jnp.float32):
    """Flatten a pytree into a single 1-D vector (Ψ representation space)."""
    leaves = jax.tree.leaves(tree)
    return jnp.concatenate([jnp.ravel(l).astype(dtype) for l in leaves])


def tree_unflatten_vector(vec, tree):
    """Inverse of tree_flatten_vector given a structure/shapes template."""
    leaves, treedef = jax.tree.flatten(tree)
    out, off = [], 0
    for l in leaves:
        n = int(np.prod(l.shape)) if l.shape else 1
        out.append(jnp.reshape(vec[off : off + n], l.shape).astype(l.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def tree_size(tree):
    """Total number of scalar parameters."""
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))


def tree_bytes(tree):
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree))


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def tree_has_nan(tree):
    leaves = [jnp.any(jnp.isnan(l)) for l in jax.tree.leaves(tree)]
    return jnp.any(jnp.stack(leaves))
