"""Persistent XLA compilation cache wiring.

One call makes every jit in the process write/read compiled executables
from a directory on disk, so a fresh process (or a ``jax.clear_caches()``
restart) pays deserialization milliseconds instead of the multi-second
XLA compile for every program it has seen before. The thresholds are
dropped to zero so SMALL programs cache too — this repo's compile tax is
many medium programs, not one giant one.

Used by ``launch.train`` (``--compile-cache``) and the benchmark harness
(``benchmarks.common``); CI shares one directory across bench steps and
asserts the warm-start drop (see ``scripts/check_warm_cache.py``).
"""
from __future__ import annotations

import os

import jax

_ENV_DIR = "JAX_COMPILATION_CACHE_DIR"


def default_cache_dir() -> str:
    return os.environ.get(_ENV_DIR) or os.path.join(
        os.path.expanduser("~"), ".cache", "repro-jax-cache")


def enable_compilation_cache(path: str | None = None) -> str:
    """Point jax's persistent compilation cache at ``path`` (default:
    ``$JAX_COMPILATION_CACHE_DIR`` or ``~/.cache/repro-jax-cache``) and
    drop the size/time thresholds so every program is cached. Returns
    the directory used. Safe to call more than once."""
    path = path or default_cache_dir()
    os.makedirs(path, exist_ok=True)
    try:
        from jax.experimental.compilation_cache import compilation_cache as cc
        cc.set_cache_dir(path)
        # jax latches a cache-used? decision at the FIRST compile of the
        # process; if anything compiled before this call, the latch says
        # "disabled" forever and the dir above is silently ignored.
        # reset_cache() clears the latch (and the in-memory handle) so
        # enabling mid-process actually takes effect.
        cc.reset_cache()
    except Exception:
        jax.config.update("jax_compilation_cache_dir", path)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    return path
