"""Minimal structured logger (no external deps, rank-0 aware)."""
from __future__ import annotations

import json
import sys
import time

import jax


class Logger:
    def __init__(self, name: str = "repro", stream=None):
        self.name = name
        self.stream = stream or sys.stderr
        self.t0 = time.time()

    def _emit(self, level: str, msg: str, **kv):
        if jax.process_index() != 0:
            return
        rec = {"t": round(time.time() - self.t0, 3), "lvl": level, "name": self.name, "msg": msg}
        rec.update(kv)
        print(json.dumps(rec, default=str), file=self.stream, flush=True)

    def info(self, msg, **kv):
        self._emit("info", msg, **kv)

    def warn(self, msg, **kv):
        self._emit("warn", msg, **kv)

    def metric(self, msg, **kv):
        self._emit("metric", msg, **kv)


def get_logger(name: str = "repro") -> Logger:
    return Logger(name)
