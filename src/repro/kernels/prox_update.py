"""Pallas TPU kernel: fused bi-level StoCFL client update.

Algorithm 1 lines 21-22, fused into one HBM pass:
    θ' = θ − η (g_θ + λ (θ − ω))
    ω' = ω − η g_ω
Unfused this reads/writes 4+2 arrays in ~7 passes; fused it streams each
operand exactly once (memory-bound, VPU elementwise). 1-D tiling over the
flattened parameter vector; block 64k floats (256 KiB fp32) per operand
keeps the 6-operand working set ≈1.5 MiB — comfortably inside VMEM.

Block-aligned vectors (the common case for the flatten-once adapter in
``core.bilevel``, which can pick its own block) pass straight through:
no padding copy, and θ/ω alias their outputs so the update happens in
the operands' own buffers. Misaligned sizes pay one ``jnp.pad`` per
operand (an append, not the old full-size zero-init + scatter-copy).
Inputs are donated off-CPU — callers must treat the four arrays as
consumed, which every call site of the fused path already does (grads
are per-step temporaries, θ/ω are immediately rebound).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _prox_kernel(theta_ref, omega_ref, gt_ref, go_ref, eta_ref, lam_ref,
                 theta_out_ref, omega_out_ref):
    eta = eta_ref[0]
    lam = lam_ref[0]
    th = theta_ref[...].astype(jnp.float32)
    om = omega_ref[...].astype(jnp.float32)
    theta_out_ref[...] = (th - eta * (gt_ref[...].astype(jnp.float32) + lam * (th - om))
                          ).astype(theta_out_ref.dtype)
    omega_out_ref[...] = (om - eta * go_ref[...].astype(jnp.float32)).astype(omega_out_ref.dtype)


def _prox_call(theta, omega, g_theta, g_omega, eta, lam, *,
               block: int, interpret: bool):
    """Traced body shared by the donating and non-donating entry jits."""
    n = theta.shape[0]
    n_pad = -(-n // block) * block
    if n_pad != n:
        # misaligned tail: one append-pad per operand (pad values are
        # computed but sliced off below — they never feed anything)
        theta, omega, g_theta, g_omega = (
            jnp.pad(a, (0, n_pad - n))
            for a in (theta, omega, g_theta, g_omega))
    eta_v = jnp.full((1,), eta, jnp.float32)
    lam_v = jnp.full((1,), lam, jnp.float32)

    outs = pl.pallas_call(
        _prox_kernel,
        grid=(n_pad // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), theta.dtype),
            jax.ShapeDtypeStruct((n_pad,), omega.dtype),
        ],
        # θ/ω update in place: with the jit-level donation below, the
        # aligned path writes back into the operands' own HBM buffers
        # (interpret mode runs the aliasing through the interpreter's
        # copy semantics — still correct, just not in-place)
        input_output_aliases={0: 0, 1: 1},
        interpret=interpret,
    )(theta, omega, g_theta, g_omega, eta_v, lam_v)
    if n_pad != n:
        return outs[0][:n], outs[1][:n]
    return outs[0], outs[1]


_prox_jit = functools.partial(jax.jit, static_argnames=("block", "interpret"))
_prox_plain = _prox_jit(_prox_call)
_prox_donating = _prox_jit(_prox_call, donate_argnums=(0, 1, 2, 3))


def prox_update_flat(theta, omega, g_theta, g_omega, eta, lam, *,
                     block: int = 65536, interpret: bool = False,
                     donate=None):
    """All four arrays 1-D of equal length; returns (theta', omega').

    ``donate=None`` resolves at CALL time: off-CPU the four operands are
    donated (their buffers are recycled into the outputs — the caller
    must not reuse them); on CPU jax ignores donation, so the plain jit
    is used to keep compiles warning-free. Pass an explicit bool to
    override."""
    if theta.shape[0] == 0:
        return theta, omega
    if donate is None:
        donate = jax.default_backend() != "cpu"
    fn = _prox_donating if donate else _prox_plain
    return fn(theta, omega, g_theta, g_omega, eta, lam,
              block=block, interpret=interpret)
