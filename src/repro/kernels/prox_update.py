"""Pallas TPU kernel: fused bi-level StoCFL client update.

Algorithm 1 lines 21-22, fused into one HBM pass:
    θ' = θ − η (g_θ + λ (θ − ω))
    ω' = ω − η g_ω
Unfused this reads/writes 4+2 arrays in ~7 passes; fused it streams each
operand exactly once (memory-bound, VPU elementwise). 1-D tiling over the
flattened parameter vector; block 64k floats (256 KiB fp32) per operand
keeps the 6-operand working set ≈1.5 MiB — comfortably inside VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _prox_kernel(theta_ref, omega_ref, gt_ref, go_ref, eta_ref, lam_ref,
                 theta_out_ref, omega_out_ref):
    eta = eta_ref[0]
    lam = lam_ref[0]
    th = theta_ref[...].astype(jnp.float32)
    om = omega_ref[...].astype(jnp.float32)
    theta_out_ref[...] = (th - eta * (gt_ref[...].astype(jnp.float32) + lam * (th - om))
                          ).astype(theta_out_ref.dtype)
    omega_out_ref[...] = (om - eta * go_ref[...].astype(jnp.float32)).astype(omega_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def prox_update_flat(theta, omega, g_theta, g_omega, eta, lam, *,
                     block: int = 65536, interpret: bool = False):
    """All four arrays 1-D of equal length; returns (theta', omega')."""
    n = theta.shape[0]
    n_pad = -(-n // block) * block
    pad = lambda a: jnp.zeros((n_pad,), a.dtype).at[:n].set(a)
    eta_v = jnp.full((1,), eta, jnp.float32)
    lam_v = jnp.full((1,), lam, jnp.float32)

    outs = pl.pallas_call(
        _prox_kernel,
        grid=(n_pad // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), theta.dtype),
            jax.ShapeDtypeStruct((n_pad,), omega.dtype),
        ],
        interpret=interpret,
    )(pad(theta), pad(omega), pad(g_theta), pad(g_omega), eta_v, lam_v)
    return outs[0][:n], outs[1][:n]
