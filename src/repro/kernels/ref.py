"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cosine_sim_ref(x):
    """x: (N, D) -> (N, N) fp32 cosine similarity."""
    x32 = x.astype(jnp.float32)
    norms = jnp.linalg.norm(x32, axis=1, keepdims=True)
    xn = jnp.where(norms > 0, x32 / norms, 0.0)
    return xn @ xn.T


def merge_candidates_ref(x, live, tau):
    """(K, D) means + (K,) live -> (K, K) fp32 0/1 merge-pair adjacency
    (cos ≥ τ, both rows live, diagonal off)."""
    M = cosine_sim_ref(x)
    lv = live.astype(bool)
    ids = jnp.arange(x.shape[0])
    ok = (M >= tau) & lv[:, None] & lv[None, :] & (ids[:, None] != ids[None, :])
    return ok.astype(jnp.float32)


def resolve_roots_ref(parent):
    """(N,) union-find parent pointers -> (N,) roots by iterated pointer
    halving ``p <- p[p]`` (⌈log2 N⌉+1 steps: each halves every path)."""
    steps = max(int(parent.shape[0]).bit_length(), 1)
    return jax.lax.fori_loop(0, steps, lambda _, p: jnp.take(p, p), parent)


def prox_update_ref(theta, omega, g_theta, g_omega, eta, lam):
    th = theta.astype(jnp.float32)
    om = omega.astype(jnp.float32)
    theta_new = th - eta * (g_theta.astype(jnp.float32) + lam * (th - om))
    omega_new = om - eta * g_omega.astype(jnp.float32)
    return theta_new.astype(theta.dtype), omega_new.astype(omega.dtype)


def ssm_scan_ref(dA, dBx, C):
    """Sequential-scan oracle. dA,dBx: (B,S,D,N); C: (B,S,N) -> (B,S,D)."""
    B, S, D, N = dA.shape

    def step(h, inp):
        a, b, c = inp
        h = a * h + b
        return h, jnp.einsum("bdn,bn->bd", h, c)

    h0 = jnp.zeros((B, D, N), jnp.float32)
    xs = (
        dA.astype(jnp.float32).transpose(1, 0, 2, 3),
        dBx.astype(jnp.float32).transpose(1, 0, 2, 3),
        C.astype(jnp.float32).transpose(1, 0, 2),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2)
