"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cosine_sim_ref(x):
    """x: (N, D) -> (N, N) fp32 cosine similarity."""
    x32 = x.astype(jnp.float32)
    norms = jnp.linalg.norm(x32, axis=1, keepdims=True)
    xn = jnp.where(norms > 0, x32 / norms, 0.0)
    return xn @ xn.T


def prox_update_ref(theta, omega, g_theta, g_omega, eta, lam):
    th = theta.astype(jnp.float32)
    om = omega.astype(jnp.float32)
    theta_new = th - eta * (g_theta.astype(jnp.float32) + lam * (th - om))
    omega_new = om - eta * g_omega.astype(jnp.float32)
    return theta_new.astype(theta.dtype), omega_new.astype(omega.dtype)


def ssm_scan_ref(dA, dBx, C):
    """Sequential-scan oracle. dA,dBx: (B,S,D,N); C: (B,S,N) -> (B,S,D)."""
    B, S, D, N = dA.shape

    def step(h, inp):
        a, b, c = inp
        h = a * h + b
        return h, jnp.einsum("bdn,bn->bd", h, c)

    h0 = jnp.zeros((B, D, N), jnp.float32)
    xs = (
        dA.astype(jnp.float32).transpose(1, 0, 2, 3),
        dBx.astype(jnp.float32).transpose(1, 0, 2, 3),
        C.astype(jnp.float32).transpose(1, 0, 2),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2)
