"""Jit'd public wrappers for the Pallas kernels, with backend selection.

On this container (CPU) the Pallas TPU kernels execute in interpret mode;
on a real TPU the same call sites compile to Mosaic. ``backend="jnp"``
routes to the pure-jnp oracle — the default inside big jitted graphs where
interpret-mode would be slow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref
from repro.kernels.cosine_sim import cosine_sim as _cosine_pallas
from repro.kernels.cosine_sim import merge_candidates as _candidates_pallas
from repro.kernels.prox_update import prox_update_flat as _prox_pallas
from repro.kernels.ssm_scan import ssm_scan as _ssm_pallas
from repro.utils import trees


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pairwise_cosine(x, backend: str = "auto"):
    """(N, D) representation matrix -> (N, N) cosine similarity."""
    if backend == "jnp" or (backend == "auto" and not _on_tpu()):
        return ref.cosine_sim_ref(x)
    return _cosine_pallas(x, interpret=not _on_tpu())


def merge_pairs(means, live, tau: float, backend: str = "auto"):
    """(K, D) cluster means + (K,) live mask -> (K, K) fp32 0/1 adjacency
    of mergeable pairs (cos ≥ τ, both live, diagonal off) — Algorithm 1
    line 10 as one fused device op (``cosine_sim.merge_candidates``)."""
    if backend == "jnp" or (backend == "auto" and not _on_tpu()):
        return ref.merge_candidates_ref(means, live, tau)
    return _candidates_pallas(means, live, tau=float(tau),
                              interpret=not _on_tpu())


# --------------------------------------------------------------- union-find
def _halving_kernel(steps, parent_ref, out_ref):
    out_ref[...] = jax.lax.fori_loop(
        0, steps, lambda _, p: jnp.take(p, p), parent_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def _resolve_pallas(parent, interpret: bool = False):
    n = parent.shape[0]
    steps = max(int(n).bit_length(), 1)
    return pl.pallas_call(
        functools.partial(_halving_kernel, steps),
        out_shape=jax.ShapeDtypeStruct((n,), parent.dtype),
        interpret=interpret,
    )(parent)


def resolve_roots(parent, backend: str = "auto"):
    """(N,) union-find parent array (``parent[i] == i`` at roots) ->
    (N,) fully-resolved roots.

    Iterated pointer halving ``p <- p[p]``: every find-path halves per
    step, so ⌈log2 N⌉+1 in-VMEM gathers resolve ANY forest — the device
    replacement for the numpy ``UnionFind.find`` Python loop. The whole
    array resolves as one vectorized op per step, and the step count
    depends only on the (static, pow2-padded) capacity, so the op jits
    into the clustering round with no data-dependent control flow."""
    if backend == "jnp" or (backend == "auto" and not _on_tpu()):
        return ref.resolve_roots_ref(parent)
    return _resolve_pallas(parent, interpret=not _on_tpu())


def prox_update_tree(theta, omega, g_theta, g_omega, eta, lam, backend: str = "auto"):
    """Fused bi-level update applied leaf-wise over parameter pytrees."""
    if backend == "jnp" or (backend == "auto" and not _on_tpu()):
        th = jax.tree.map(
            lambda t, o, g: (t.astype(jnp.float32)
                             - eta * (g.astype(jnp.float32) + lam * (t.astype(jnp.float32) - o.astype(jnp.float32)))
                             ).astype(t.dtype),
            theta, omega, g_theta)
        om = jax.tree.map(
            lambda o, g: (o.astype(jnp.float32) - eta * g.astype(jnp.float32)).astype(o.dtype),
            omega, g_omega)
        return th, om

    interp = not _on_tpu()
    th_leaves, treedef = jax.tree.flatten(theta)
    om_leaves = treedef.flatten_up_to(omega)
    gt_leaves = treedef.flatten_up_to(g_theta)
    go_leaves = treedef.flatten_up_to(g_omega)
    new_th, new_om = [], []
    for t, o, gt, go in zip(th_leaves, om_leaves, gt_leaves, go_leaves):
        tn, on = _prox_pallas(t.ravel(), o.ravel(), gt.ravel(), go.ravel(),
                              eta, lam, interpret=interp)
        new_th.append(tn.reshape(t.shape).astype(t.dtype))
        new_om.append(on.reshape(o.shape).astype(o.dtype))
    return jax.tree.unflatten(treedef, new_th), jax.tree.unflatten(treedef, new_om)


def prox_update_flat(theta, omega, g_theta, g_omega, eta, lam,
                     backend: str = "auto", **kw):
    """Fused bi-level update on flat 1-D vectors (Algorithm 1 l.21-22).

    The hot-path entry used by ``core.bilevel``'s flatten-once adapter:
    one fused elementwise pass over the concatenated parameter vector
    instead of per-leaf tree math. The jnp oracle mirrors the
    ``prox_update_tree`` leaf formula exactly (f32 accumulate, cast back
    to the operand dtype) so fused and tree paths agree bitwise off-TPU."""
    if backend == "jnp" or (backend == "auto" and not _on_tpu()):
        th32 = theta.astype(jnp.float32)
        om32 = omega.astype(jnp.float32)
        th = (th32 - eta * (g_theta.astype(jnp.float32) + lam * (th32 - om32))
              ).astype(theta.dtype)
        om = (om32 - eta * g_omega.astype(jnp.float32)).astype(omega.dtype)
        return th, om
    return _prox_pallas(theta, omega, g_theta, g_omega, eta, lam,
                        interpret=not _on_tpu(), **kw)


def ssm_scan(dA, dBx, C, backend: str = "auto", **kw):
    """Fused selective scan. See kernels/ssm_scan.py."""
    if backend == "jnp" or (backend == "auto" and not _on_tpu()):
        return ref.ssm_scan_ref(dA, dBx, C)
    return _ssm_pallas(dA, dBx, C, interpret=not _on_tpu(), **kw)
