"""Pallas TPU kernels: pairwise cosine-similarity and merge-candidate
matrices.

StoCFL's clustering hot-spot: the server recomputes the K̃×K̃ (up to N×N,
N=4800 cross-device) cosine matrix over distribution representations every
round (Algorithm 1, line 10). That is an X·Xᵀ on the MXU with fused
per-row inverse-norm scaling.

Tiling: grid (N/bn, N/bn, D/bk); operand tiles (bn, bk) live in VMEM, fp32
accumulation in the output tile across the contraction grid axis (TPU grid
iterates the trailing axis innermost, so out_ref accumulates correctly).
MXU-aligned defaults bn=128, bk=512.

``merge_candidates`` is the fused device-clustering variant: the same
X·Xᵀ tiling, but the final contraction step also applies the live-row
mask and the τ threshold in-register, emitting the 0/1 adjacency of
mergeable cluster pairs directly — the K̃² cosine matrix never leaves
VMEM, so the union-find merge pass (``core.device_clustering``) consumes
candidate pairs without materializing similarities in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cosine_kernel(x_ref, y_ref, inv_i_ref, inv_j_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    acc = jnp.dot(
        x_ref[...].astype(jnp.float32),
        y_ref[...].astype(jnp.float32).T,
        preferred_element_type=jnp.float32,
    )
    out_ref[...] += acc

    @pl.when(k == pl.num_programs(2) - 1)
    def _scale():
        out_ref[...] *= inv_i_ref[...][:, None] * inv_j_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def cosine_sim(x, *, bn: int = 128, bk: int = 512, interpret: bool = False):
    """x: (N, D) -> (N, N) cosine similarity, fp32.

    N is padded to bn and D to bk internally; zero rows get norm eps so
    padded entries are 0 and harmless.
    """
    n, d = x.shape
    n_pad = -(-n // bn) * bn
    d_pad = -(-d // bk) * bk
    xp = jnp.zeros((n_pad, d_pad), x.dtype).at[:n, :d].set(x)
    norms = jnp.sqrt(jnp.sum(xp.astype(jnp.float32) ** 2, axis=1))
    inv = jnp.where(norms > 0, jnp.float32(1.0) / norms, jnp.float32(0.0))

    out = pl.pallas_call(
        _cosine_kernel,
        grid=(n_pad // bn, n_pad // bn, d_pad // bk),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn,), lambda i, j, k: (i,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, n_pad), jnp.float32),
        interpret=interpret,
    )(xp, xp, inv, inv)
    return out[:n, :n]


def _candidates_kernel(tau, bn, x_ref, y_ref, inv_i_ref, inv_j_ref,
                       live_i_ref, live_j_ref, out_ref):
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        y_ref[...].astype(jnp.float32).T,
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _threshold():
        cos = out_ref[...] * inv_i_ref[...][:, None] * inv_j_ref[...][None, :]
        rows = jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 0) + i * bn
        cols = jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 1) + j * bn
        ok = ((cos >= tau)
              & (live_i_ref[...][:, None] > 0)
              & (live_j_ref[...][None, :] > 0)
              & (rows != cols))
        out_ref[...] = ok.astype(jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("tau", "bn", "bk", "interpret"))
def merge_candidates(x, live, *, tau: float, bn: int = 128, bk: int = 512,
                     interpret: bool = False):
    """(K, D) cluster means + (K,) live mask -> (K, K) fp32 0/1 adjacency.

    ``adj[i, j] = 1`` iff rows i ≠ j are both live and cos(x_i, x_j) ≥ τ
    — the candidate merge pairs of Algorithm 1 line 10, fused so the
    cosine tile is thresholded in VMEM instead of round-tripping a K̃²
    similarity matrix through HBM. Zero rows are norm-guarded to cosine
    0 (and are masked out by ``live`` anyway); the diagonal is always 0,
    so a τ ≤ cos(x, x) can never self-merge a cluster.
    """
    n, d = x.shape
    n_pad = -(-n // bn) * bn
    d_pad = -(-d // bk) * bk
    xp = jnp.zeros((n_pad, d_pad), x.dtype).at[:n, :d].set(x)
    lv = jnp.zeros((n_pad,), jnp.float32).at[:n].set(
        live.astype(jnp.float32))
    norms = jnp.sqrt(jnp.sum(xp.astype(jnp.float32) ** 2, axis=1))
    inv = jnp.where(norms > 0, jnp.float32(1.0) / norms, jnp.float32(0.0))

    out = pl.pallas_call(
        # jaxlint: disable=R2 — tau is static (static_argnames), baked into the kernel
        functools.partial(_candidates_kernel, float(tau), bn),
        grid=(n_pad // bn, n_pad // bn, d_pad // bk),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn,), lambda i, j, k: (i,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
            pl.BlockSpec((bn,), lambda i, j, k: (i,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, n_pad), jnp.float32),
        interpret=interpret,
    )(xp, xp, inv, inv, lv, lv)
    return out[:n, :n]
