"""Pallas TPU kernel: fused chunked selective-scan (Mamba recurrence).

y[t] = Σ_n h[t, d, n] · C[t, n]  with  h[t] = dA[t] ⊙ h[t-1] + dBx[t].

The recurrent state h (bd, N) lives in a VMEM scratch that persists across
the sequential chunk axis of the grid (TPU executes the trailing grid axis
innermost/sequentially), so the full h trajectory is NEVER materialized in
HBM — only the contracted output y streams out. This is the TPU-native
replacement for the GPU mamba kernel's shared-memory chunking.

Grid: (B, D/bd, S/chunk); scratch resets at chunk==0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(dA_ref, dBx_ref, c_ref, y_ref, h_scratch):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    dA = dA_ref[0]          # (chunk, bd, N)
    dBx = dBx_ref[0]
    C = c_ref[0]            # (chunk, N)

    def step(h, inp):
        a, b, c = inp
        h = a * h + b                               # (bd, N)
        return h, jnp.sum(h * c[None, :], axis=1)   # y_t: (bd,)

    h, ys = jax.lax.scan(step, h_scratch[...], (dA, dBx, C))
    y_ref[0] = ys
    h_scratch[...] = h


@functools.partial(jax.jit, static_argnames=("bd", "chunk", "interpret"))
def ssm_scan(dA, dBx, C, *, bd: int = 128, chunk: int = 128, interpret: bool = False):
    """dA, dBx: (B, S, D, N); C: (B, S, N) -> y: (B, S, D), fp32.

    D padded to bd, S to chunk (dA pads with 1s so padded steps keep h)."""
    B, S, D, N = dA.shape
    d_pad = -(-D // bd) * bd
    s_pad = -(-S // chunk) * chunk

    dA_p = jnp.ones((B, s_pad, d_pad, N), jnp.float32).at[:, :S, :D].set(dA)
    dBx_p = jnp.zeros((B, s_pad, d_pad, N), jnp.float32).at[:, :S, :D].set(dBx)
    C_p = jnp.zeros((B, s_pad, N), jnp.float32).at[:, :S].set(C)

    y = pl.pallas_call(
        _scan_kernel,
        grid=(B, d_pad // bd, s_pad // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, bd, N), lambda b, d, s: (b, s, d, 0)),
            pl.BlockSpec((1, chunk, bd, N), lambda b, d, s: (b, s, d, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, s: (b, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, bd), lambda b, d, s: (b, s, d)),
        out_shape=jax.ShapeDtypeStruct((B, s_pad, d_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(dA_p, dBx_p, C_p)
    return y[:, :S, :D]
