"""repro.kernels — Pallas TPU kernels with pure-jnp oracles.

OPTIONAL layer: custom kernels exist only for the paper's compute
hot-spots. Call sites go through the backend-selecting wrappers in
``repro.kernels.ops`` (Pallas/Mosaic on TPU, interpret mode or the jnp
oracle elsewhere); ``repro.kernels.ref`` holds the allclose ground
truths the kernel tests compare against.

Kernels: ``pairwise_cosine`` (Ψ similarity matrix, Algorithm 1 l.10),
``merge_pairs`` (fused masked cosine + τ threshold emitting merge
candidates — the device-clustering hot path), ``resolve_roots``
(union-find root resolution by iterated pointer halving),
``prox_update_tree`` (fused bi-level step, §3.3), ``ssm_scan``
(selective-scan for the SSM model family).
"""
from repro.kernels.ops import (merge_pairs, pairwise_cosine,  # noqa: F401
                               prox_update_tree, resolve_roots, ssm_scan)

__all__ = [
    "pairwise_cosine", "merge_pairs", "resolve_roots",
    "prox_update_tree", "ssm_scan",
]
