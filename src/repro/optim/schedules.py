"""Learning-rate schedules (callables of step count)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda count: jnp.asarray(value, jnp.float32)


def cosine_decay(init_value: float, decay_steps: int, alpha: float = 0.0):
    def fn(count):
        frac = jnp.clip(count / max(decay_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return init_value * ((1 - alpha) * cos + alpha)

    return fn


def warmup_cosine(peak: float, warmup_steps: int, decay_steps: int, floor: float = 0.0):
    cd = cosine_decay(peak, max(decay_steps - warmup_steps, 1), alpha=floor / max(peak, 1e-12))

    def fn(count):
        warm = peak * (count + 1) / max(warmup_steps, 1)
        return jnp.where(count < warmup_steps, warm, cd(count - warmup_steps))

    return fn
