from repro.optim.sgd import sgd, sgd_momentum  # noqa: F401
from repro.optim.adam import adam  # noqa: F401
from repro.optim.schedules import constant, cosine_decay, warmup_cosine  # noqa: F401
