"""SGD optimizers as (init, update) pure-function pairs.

The paper's clients run plain SGD (Algorithm 1, lines 21-22); momentum is
provided for the substrate's standalone training paths.

API (optax-like but dependency-free):
    opt = sgd(lr)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Any]


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def _lr_at(lr, count):
    return lr(count) if callable(lr) else lr


def sgd(lr) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros([], jnp.int32)}

    def update(grads, state, params=None):
        step_lr = _lr_at(lr, state["count"])
        updates = jax.tree.map(lambda g: -step_lr * g, grads)
        return updates, {"count": state["count"] + 1}

    return Optimizer(init, update)


def sgd_momentum(lr, momentum: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return {
            "count": jnp.zeros([], jnp.int32),
            "mu": jax.tree.map(jnp.zeros_like, params),
        }

    def update(grads, state, params=None):
        step_lr = _lr_at(lr, state["count"])
        mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: -step_lr * (momentum * m + g), mu, grads)
        else:
            upd = jax.tree.map(lambda m: -step_lr * m, mu)
        return upd, {"count": state["count"] + 1, "mu": mu}

    return Optimizer(init, update)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), grads))
    gnorm = jnp.sqrt(jnp.sum(jnp.stack(leaves)))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), gnorm
