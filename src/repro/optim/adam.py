"""Adam/AdamW for the substrate training paths (non-FL standalone runs)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.sgd import Optimizer, _lr_at


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {
            "count": jnp.zeros([], jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
        }

    def update(grads, state, params=None):
        count = state["count"] + 1
        step_lr = _lr_at(lr, state["count"])
        m = jax.tree.map(lambda mi, g: b1 * mi + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda vi, g: b2 * vi + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** count.astype(jnp.float32)
        bc2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(mi, vi, p):
            u = -step_lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)
            if weight_decay:
                u = u - step_lr * weight_decay * p.astype(jnp.float32)
            return u.astype(p.dtype) if p is not None else u

        if params is None:
            updates = jax.tree.map(lambda mi, vi: upd(mi, vi, mi), m, v)
        else:
            updates = jax.tree.map(upd, m, v, params)
        return updates, {"count": count, "m": m, "v": v}

    return Optimizer(init, update)
