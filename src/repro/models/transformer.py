"""Decoder-only transformer LM: dense (GQA), MoE, MLA variants.

Per-layer parameters are *stacked* along a leading L axis and consumed with
``jax.lax.scan`` so HLO size is depth-independent — critical for compiling
the 512-device dry-run of 60-layer models. ``cfg.remat`` wraps each layer
body in ``jax.checkpoint``.

Caches returned by prefill/decode are pytrees whose leaves carry the same
leading L axis (scanned alongside the layer stack).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.layers import cfg_scan, embed_init, dense_init, rmsnorm, rmsnorm_init, swiglu, swiglu_init
from repro.sharding import shard, unshard_fsdp


def _stack_init(layer_init, key, n, *args):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: layer_init(k, *args))(keys)


def _is_mla(cfg):
    return cfg.kv_lora_rank > 0


def _layer_init(key, cfg, moe: bool, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    attn_p = attn.mla_init(k1, cfg, dtype) if _is_mla(cfg) else attn.gqa_init(k1, cfg, dtype)
    mlp_p = moe_mod.moe_init(k2, cfg, dtype) if moe else swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype)
    return {
        "attn_norm": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_p,
        "mlp_norm": rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlp_p,
    }


def init(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kl, kd, kh = jax.random.split(key, 4)
    n_dense = cfg.moe_layer_start if cfg.n_experts else cfg.n_layers
    n_moe = cfg.n_layers - n_dense if cfg.n_experts else 0
    params = {
        "embed": embed_init(ke, cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
        "lm_head": dense_init(kh, cfg.d_model, cfg.vocab_size, dtype, scale=0.02),
    }
    if n_dense:
        params["layers"] = _stack_init(functools.partial(_layer_init, cfg=cfg, moe=False, dtype=dtype), kd, n_dense)
    if n_moe:
        params["moe_layers"] = _stack_init(functools.partial(_layer_init, cfg=cfg, moe=True, dtype=dtype), kl, n_moe)
    return params


# ------------------------------------------------------------- layer bodies
def _layer_train(cfg, moe, h, layer_p):
    layer_p = unshard_fsdp(layer_p)
    dt = h.dtype
    a_in = rmsnorm(layer_p["attn_norm"], h)
    if _is_mla(cfg):
        h = h + attn.mla_train(layer_p["attn"], a_in, cfg)
    else:
        h = h + attn.gqa_train(layer_p["attn"], a_in, cfg)
    m_in = rmsnorm(layer_p["mlp_norm"], h)
    if moe:
        m_out, aux = moe_mod.moe_ffn(layer_p["mlp"], m_in, cfg)
    else:
        m_out, aux = swiglu(layer_p["mlp"], m_in), jnp.float32(0.0)
    h = shard(h + m_out, "batch", None, None)
    return h.astype(dt), aux


def _scan_layers(body, h, stacked, cfg):
    fn = jax.checkpoint(body) if cfg.remat else body

    def step(carry, layer_p):
        h, aux = carry
        h, a = fn(h, layer_p)
        return (h, aux + a), None

    (h, aux), _ = cfg_scan(cfg, step, (h, jnp.float32(0.0)), stacked)
    return h, aux


def apply_stack_train(params, h, cfg):
    """Run the layer stack(s) on hidden states h. Returns (h, aux)."""
    aux = jnp.float32(0.0)
    if "layers" in params:
        h, a = _scan_layers(functools.partial(_layer_train, cfg, False), h, params["layers"], cfg)
        aux += a
    if "moe_layers" in params:
        h, a = _scan_layers(functools.partial(_layer_train, cfg, True), h, params["moe_layers"], cfg)
        aux += a
    return h, aux


def apply_stack_prefill(params, h, cfg):
    caches = {}
    if "layers" in params:
        h, caches["layers"] = _scan_prefill(functools.partial(_layer_prefill, cfg, False), h, params["layers"], cfg)
    if "moe_layers" in params:
        h, caches["moe_layers"] = _scan_prefill(functools.partial(_layer_prefill, cfg, True), h, params["moe_layers"], cfg)
    return h, caches


def forward_train(params, tokens, cfg):
    """tokens: (B,S) int32 -> logits (B,S,V), aux loss."""
    dt = jnp.dtype(cfg.dtype)
    h = params["embed"].astype(dt)[tokens]
    h = shard(h, "batch", None, None)
    h, aux = apply_stack_train(params, h, cfg)
    h = rmsnorm(params["final_norm"], h)
    logits = h @ params["lm_head"].astype(dt)
    return shard(logits, "batch", None, "tp"), aux


def lm_loss(params, batch, cfg, forward=forward_train):
    """Next-token cross-entropy (+ MoE aux). batch: {"tokens": (B,S)}."""
    tokens = batch["tokens"]
    logits, aux = forward(params, tokens, cfg)
    logits = logits[:, :-1].astype(jnp.float32)
    targets = tokens[:, 1:]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    loss = jnp.mean(logz - gold)
    return loss + 0.01 * aux


# ------------------------------------------------------------- prefill
def _layer_prefill(cfg, moe, h, layer_p):
    layer_p = unshard_fsdp(layer_p)
    a_in = rmsnorm(layer_p["attn_norm"], h)
    if _is_mla(cfg):
        a_out, cache = attn.mla_prefill(layer_p["attn"], a_in, cfg)
    else:
        a_out, cache = attn.gqa_prefill(layer_p["attn"], a_in, cfg)
    h = h + a_out
    m_in = rmsnorm(layer_p["mlp_norm"], h)
    if moe:
        m_out, _ = moe_mod.moe_ffn(layer_p["mlp"], m_in, cfg)
    else:
        m_out = swiglu(layer_p["mlp"], m_in)
    return shard(h + m_out, "batch", None, None), cache


def _scan_prefill(body, h, stacked, cfg):
    fn = jax.checkpoint(body) if cfg.remat else body

    def step(h, layer_p):
        h, cache = fn(h, layer_p)
        return h, cache

    return cfg_scan(cfg, step, h, stacked)


def prefill(params, tokens, cfg):
    """Returns (last-token logits (B,V), cache pytree)."""
    dt = jnp.dtype(cfg.dtype)
    h = params["embed"].astype(dt)[tokens]
    h = shard(h, "batch", None, None)
    h, caches = apply_stack_prefill(params, h, cfg)
    h = rmsnorm(params["final_norm"], h[:, -1:])
    logits = (h @ params["lm_head"].astype(dt))[:, 0]
    return logits, caches


# ------------------------------------------------------------- decode
def _layer_decode(cfg, moe, carry, inp):
    h, pos = carry
    layer_p, cache = inp
    layer_p = unshard_fsdp(layer_p)
    a_in = rmsnorm(layer_p["attn_norm"], h)
    if _is_mla(cfg):
        a_out, new_cache = attn.mla_decode(layer_p["attn"], a_in, cache, pos, cfg)
    else:
        a_out, new_cache = attn.gqa_decode(layer_p["attn"], a_in, cache, pos, cfg)
    h = h + a_out
    m_in = rmsnorm(layer_p["mlp_norm"], h)
    if moe:
        m_out, _ = moe_mod.moe_ffn(layer_p["mlp"], m_in, cfg)
    else:
        m_out = swiglu(layer_p["mlp"], m_in)
    return (h + m_out, pos), new_cache


def decode_step(params, token, caches, pos, cfg):
    """token: (B,) int32; pos: scalar int32 count of tokens already cached.

    Returns (logits (B,V), new caches)."""
    dt = jnp.dtype(cfg.dtype)
    h = params["embed"].astype(dt)[token][:, None, :]    # (B,1,d)
    new_caches = {}
    for name, moe in (("layers", False), ("moe_layers", True)):
        if name not in params:
            continue
        body = functools.partial(_layer_decode, cfg, moe)

        def step(carry, inp):
            return body(carry, inp)

        (h, _), new_caches[name] = cfg_scan(cfg, step, (h, pos), (params[name], caches[name]))
    h = rmsnorm(params["final_norm"], h)
    logits = (h @ params["lm_head"].astype(dt))[:, 0]
    return logits, new_caches


def make_cache(cfg, batch, seq_len, dtype=None):
    """Allocate (or spec) an empty decode cache for a decoder-only model."""
    dt = dtype or jnp.dtype(cfg.dtype)
    S = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    caches = {}
    n_dense = cfg.moe_layer_start if cfg.n_experts else cfg.n_layers
    n_moe = cfg.n_layers - n_dense if cfg.n_experts else 0
    if _is_mla(cfg):
        def one(L):
            return {
                "c_kv": jnp.zeros((L, batch, S, cfg.kv_lora_rank), dt),
                "k_rope": jnp.zeros((L, batch, S, cfg.qk_rope_dim), dt),
            }
    else:
        hd = cfg.resolved_head_dim

        def one(L):
            return {
                "k": jnp.zeros((L, batch, S, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((L, batch, S, cfg.n_kv_heads, hd), dt),
            }
    if n_dense:
        caches["layers"] = one(n_dense)
    if n_moe:
        caches["moe_layers"] = one(n_moe)
    return caches
