"""Shared building blocks: inits, norms, RoPE, gated MLPs.

All modules are (init, apply) pure-function pairs over plain dict pytrees.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import shard


def cfg_scan(cfg, f, init, xs, length=None):
    """lax.scan that fully unrolls when cfg.scan_unroll (cost-probe mode:
    XLA cost_analysis counts while-loop bodies once, so roofline probes
    lower unrolled)."""
    return jax.lax.scan(f, init, xs, length=length,
                        unroll=True if getattr(cfg, "scan_unroll", False) else 1)


# ----------------------------------------------------------------- inits
def dense_init(key, d_in, d_out, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab, d, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ----------------------------------------------------------------- norms
def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps=1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * params["scale"].astype(dt)


def layernorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * params["scale"].astype(dt) + params["bias"].astype(dt)


# ----------------------------------------------------------------- rope
def rope_freqs(head_dim, theta=10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta=10000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- mlp
def swiglu_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def swiglu(params, x, compute_dtype=None):
    dt = compute_dtype or x.dtype
    g = x @ params["w_gate"].astype(dt)
    u = x @ params["w_up"].astype(dt)
    h = jax.nn.silu(g) * u
    h = shard(h, "batch", None, "tp")
    return h @ params["w_down"].astype(dt)


def gelu_mlp_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "w_up": dense_init(k1, d_model, d_ff, dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": dense_init(k2, d_ff, d_model, dtype),
        "b_down": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(params, x, compute_dtype=None):
    dt = compute_dtype or x.dtype
    h = jax.nn.gelu(x @ params["w_up"].astype(dt) + params["b_up"].astype(dt))
    h = shard(h, "batch", None, "tp")
    return h @ params["w_down"].astype(dt) + params["b_down"].astype(dt)
