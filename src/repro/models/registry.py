"""Model registry: one uniform API over all six arch families.

Model(cfg).loss_fn / forward_train / prefill / decode / make_cache /
input_specs — everything StoCFL's trainer, the launcher and the dry-run
need, independent of family.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, ssm_lm, transformer, vlm
from repro.models.config import InputShape, ModelConfig


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable[[Any], Any]                       # key -> params
    loss_fn: Callable[[Any, Any], Any]               # (params, batch) -> loss
    forward_train: Callable[[Any, Any], Any]         # (params, batch) -> (logits, aux)
    prefill: Callable[[Any, Any], Any]               # (params, batch) -> (logits, cache)
    decode: Callable[[Any, Any, Any, Any], Any]      # (params, token, cache, pos)
    make_cache: Callable[[int, int], Any]            # (batch, seq_len) -> cache
    input_specs: Callable[[InputShape], dict]        # shape -> batch of ShapeDtypeStructs


def _ce_loss(logits, tokens, aux):
    """Sharding-friendly CE: the gold logit is a one-hot contraction (kept
    local to each vocab shard + tiny all-reduce) — NOT take_along_axis,
    which would all-gather the full fp32 logits across the model axis."""
    logits = logits[:, :-1].astype(jnp.float32)
    targets = tokens[:, 1:]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    return jnp.mean(logz - gold) + 0.01 * aux


def _token_specs(cfg, shape: InputShape):
    B = shape.global_batch
    return {"tokens": jax.ShapeDtypeStruct((B, shape.seq_len), jnp.int32)}


def build(cfg: ModelConfig) -> Model:
    dt = jnp.dtype(cfg.dtype)

    if cfg.arch_type in ("dense", "moe"):
        mod = transformer
    elif cfg.arch_type == "ssm":
        mod = ssm_lm
    elif cfg.arch_type == "hybrid":
        mod = hybrid
    elif cfg.arch_type == "audio":
        mod = encdec
    elif cfg.arch_type == "vlm":
        mod = vlm
    else:
        raise ValueError(f"unknown arch_type {cfg.arch_type}")

    # ---- family-specific batch plumbing -------------------------------
    if cfg.arch_type in ("dense", "moe", "ssm", "hybrid"):
        def forward_train(params, batch):
            return mod.forward_train(params, batch["tokens"], cfg)

        def loss_fn(params, batch):
            logits, aux = forward_train(params, batch)
            return _ce_loss(logits, batch["tokens"], aux)

        def prefill(params, batch):
            return mod.prefill(params, batch["tokens"], cfg)

        def input_specs(shape):
            return _token_specs(cfg, shape)

    elif cfg.arch_type == "audio":
        def forward_train(params, batch):
            return mod.forward_train(params, batch, cfg)

        def loss_fn(params, batch):
            logits, aux = forward_train(params, batch)
            return _ce_loss(logits, batch["tokens"], aux)

        def prefill(params, batch):
            return mod.prefill(params, batch, cfg)

        def input_specs(shape):
            B = shape.global_batch
            return {
                "frames": jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), dt),
                "tokens": jax.ShapeDtypeStruct((B, shape.seq_len), jnp.int32),
            }

    else:  # vlm
        def forward_train(params, batch):
            return mod.forward_train(params, batch, cfg)

        def loss_fn(params, batch):
            return mod.loss_fn(params, batch, cfg)

        def prefill(params, batch):
            return mod.prefill(params, batch, cfg)

        def input_specs(shape):
            B = shape.global_batch
            n_text = max(shape.seq_len - cfg.n_patches, 8)
            return {
                "patches": jax.ShapeDtypeStruct((B, cfg.n_patches, cfg.d_model), dt),
                "tokens": jax.ShapeDtypeStruct((B, n_text), jnp.int32),
            }

    def decode(params, token, cache, pos):
        return mod.decode_step(params, token, cache, pos, cfg)

    def make_cache(batch, seq_len):
        return mod.make_cache(cfg, batch, seq_len)

    return Model(
        cfg=cfg,
        init=lambda key: mod.init(key, cfg),
        loss_fn=loss_fn,
        forward_train=forward_train,
        prefill=prefill,
        decode=decode,
        make_cache=make_cache,
        input_specs=input_specs,
    )


def grow_cache(model: Model, cache, batch: int, seq_len: int):
    """Embed a prefill cache into a larger decode cache (prefix-preserving)."""
    full = jax.eval_shape(lambda: model.make_cache(batch, seq_len))
    full = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), full)
    return jax.tree.map(
        lambda f, g: f.at[tuple(slice(0, s) for s in g.shape)].set(g.astype(f.dtype))
        if f.shape != g.shape else g.astype(f.dtype),
        full, cache)


def decode_specs(model: Model, shape: InputShape):
    """ShapeDtypeStruct pytree for a decode step: (token, cache, pos)."""
    B = shape.global_batch
    cache = jax.eval_shape(lambda: model.make_cache(B, shape.seq_len))
    return {
        "token": jax.ShapeDtypeStruct((B,), jnp.int32),
        "cache": cache,
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def serve_cache_specs(model: Model, clusters: int, slots: int, max_len: int):
    """Decode-state cache spec for the serving engine (``repro.serve``):
    the per-arch ``make_cache(slots, max_len)`` pytree with a leading
    routed-cluster-group axis — every leaf is ``(clusters,) + leaf.shape``,
    so cluster k's slot s lives at ``leaf[k, :, s]`` (the slot axis is the
    cache's own batch axis, uniformly axis 1 across all six families).
    ``jax.eval_shape`` only — no allocation; ``serve.slots.alloc_slots``
    materializes the zeros."""
    base = jax.eval_shape(lambda: model.make_cache(slots, max_len))
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((clusters,) + tuple(s.shape), s.dtype),
        base)
