"""Whisper-style encoder-decoder backbone.

Per the brief's carve-out, the mel-spectrogram + conv feature frontend is
STUBBED: inputs are precomputed frame embeddings (B, enc_seq, d_model).
Deviation noted in DESIGN.md: we use RoPE in the decoder self-attention
(whisper uses learned absolute positions) — positional mechanics don't
change the systems behavior being studied.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.layers import (
    cfg_scan,
    dense_init,
    embed_init,
    gelu_mlp,
    gelu_mlp_init,
    layernorm,
    layernorm_init,
)
from repro.models.transformer import _stack_init
from repro.sharding import shard, unshard_fsdp


def _enc_layer_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": layernorm_init(cfg.d_model, dtype),
        "attn": attn.gqa_init(k1, cfg, dtype),
        "mlp_norm": layernorm_init(cfg.d_model, dtype),
        "mlp": gelu_mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _dec_layer_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "attn_norm": layernorm_init(cfg.d_model, dtype),
        "attn": attn.gqa_init(k1, cfg, dtype),
        "cross_norm": layernorm_init(cfg.d_model, dtype),
        "cross": attn.cross_attn_init(k2, cfg, dtype),
        "mlp_norm": layernorm_init(cfg.d_model, dtype),
        "mlp": gelu_mlp_init(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def init(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kenc, kdec, kh = jax.random.split(key, 4)
    return {
        "embed": embed_init(ke, cfg.vocab_size, cfg.d_model, dtype),
        "enc_layers": _stack_init(functools.partial(_enc_layer_init, cfg=cfg, dtype=dtype), kenc, cfg.n_enc_layers),
        "enc_norm": layernorm_init(cfg.d_model, dtype),
        "dec_layers": _stack_init(functools.partial(_dec_layer_init, cfg=cfg, dtype=dtype), kdec, cfg.n_layers),
        "dec_norm": layernorm_init(cfg.d_model, dtype),
        "lm_head": dense_init(kh, cfg.d_model, cfg.vocab_size, dtype, scale=0.02),
    }


def encode(params, frames, cfg):
    """frames: (B, enc_seq, d_model) stub embeddings -> encoder output."""
    dt = jnp.dtype(cfg.dtype)
    h = frames.astype(dt)
    h = shard(h, "batch", None, None)

    def body(h, p):
        p = unshard_fsdp(p)
        h = h + attn.bidir_attention(p["attn"], layernorm(p["attn_norm"], h), cfg)
        h = h + gelu_mlp(p["mlp"], layernorm(p["mlp_norm"], h))
        return h, None

    fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = cfg_scan(cfg, fn, h, params["enc_layers"])
    return layernorm(params["enc_norm"], h)


def _dec_body(cfg, mode, carry, inp):
    """mode train: inp=(p, cross_kv); prefill same; decode: (p, cross_kv, cache)."""
    if mode == "decode":
        h, pos = carry
        p, ckv, cache = inp
        p = unshard_fsdp(p)
        a_in = layernorm(p["attn_norm"], h)
        a_out, new_cache = attn.gqa_decode(p["attn"], a_in, cache, pos, cfg)
        h = h + a_out
        h = h + attn.cross_attend(p["cross"], layernorm(p["cross_norm"], h), ckv, cfg)
        h = h + gelu_mlp(p["mlp"], layernorm(p["mlp_norm"], h))
        return (h, pos), new_cache
    h = carry
    p, ckv = inp
    p = unshard_fsdp(p)
    a_in = layernorm(p["attn_norm"], h)
    if mode == "train":
        h = h + attn.gqa_train(p["attn"], a_in, cfg)
        new_cache = None
    else:
        a_out, new_cache = attn.gqa_prefill(p["attn"], a_in, cfg)
        h = h + a_out
    h = h + attn.cross_attend(p["cross"], layernorm(p["cross_norm"], h), ckv, cfg)
    h = h + gelu_mlp(p["mlp"], layernorm(p["mlp_norm"], h))
    return h, new_cache


def _cross_kvs(params, enc_out, cfg):
    """Precompute per-layer cross K/V: stacked (L, B, Se, Hkv, hd)."""
    def one(p):
        return attn.cross_kv(p["cross"], enc_out, cfg)
    return jax.vmap(one, in_axes=0)(params["dec_layers"])


def forward_train(params, batch, cfg):
    """batch: {"frames": (B,Se,d), "tokens": (B,S)} -> (logits, aux)."""
    dt = jnp.dtype(cfg.dtype)
    enc_out = encode(params, batch["frames"], cfg)
    ckvs = _cross_kvs(params, enc_out, cfg)
    h = params["embed"].astype(dt)[batch["tokens"]]
    body = functools.partial(_dec_body, cfg, "train")
    fn = jax.checkpoint(body) if cfg.remat else body

    def step(h, inp):
        return fn(h, inp)

    h, _ = cfg_scan(cfg, step, h, (params["dec_layers"], ckvs))
    h = layernorm(params["dec_norm"], h)
    logits = h @ params["lm_head"].astype(dt)
    return shard(logits, "batch", None, "tp"), jnp.float32(0.0)


def prefill(params, batch, cfg):
    dt = jnp.dtype(cfg.dtype)
    enc_out = encode(params, batch["frames"], cfg)
    ckvs = _cross_kvs(params, enc_out, cfg)
    h = params["embed"].astype(dt)[batch["tokens"]]
    body = functools.partial(_dec_body, cfg, "prefill")
    fn = jax.checkpoint(body) if cfg.remat else body

    def step(h, inp):
        return fn(h, inp)

    h, self_cache = cfg_scan(cfg, step, h, (params["dec_layers"], ckvs))
    h = layernorm(params["dec_norm"], h[:, -1:])
    logits = (h @ params["lm_head"].astype(dt))[:, 0]
    return logits, {"self": self_cache, "cross": ckvs}


def decode_step(params, token, caches, pos, cfg):
    dt = jnp.dtype(cfg.dtype)
    h = params["embed"].astype(dt)[token][:, None, :]
    body = functools.partial(_dec_body, cfg, "decode")

    def step(carry, inp):
        return body(carry, inp)

    (h, _), new_self = cfg_scan(cfg, step, (h, pos), (params["dec_layers"], caches["cross"], caches["self"]))
    h = layernorm(params["dec_norm"], h)
    logits = (h @ params["lm_head"].astype(dt))[:, 0]
    return logits, {"self": new_self, "cross": caches["cross"]}


def make_cache(cfg, batch, seq_len, dtype=None):
    dt = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    L = cfg.n_layers
    return {
        "self": {
            "k": jnp.zeros((L, batch, seq_len, cfg.n_kv_heads, hd), dt),
            "v": jnp.zeros((L, batch, seq_len, cfg.n_kv_heads, hd), dt),
        },
        "cross": {
            "k": jnp.zeros((L, batch, cfg.enc_seq, cfg.n_kv_heads, hd), dt),
            "v": jnp.zeros((L, batch, cfg.enc_seq, cfg.n_kv_heads, hd), dt),
        },
    }
