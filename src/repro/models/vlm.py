"""VLM backbone (InternVL2-style): vision prefix + decoder-only LM.

Per the brief's carve-out, the InternViT vision encoder is STUBBED:
inputs are precomputed patch embeddings (B, n_patches, d_model). The MLP
projector and the language backbone (InternLM2-class transformer) are real.
Loss is computed on text positions only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer as tf
from repro.models.layers import dense_init, rmsnorm
from repro.sharding import shard


def init(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    params = tf.init(k1, cfg)
    params["patch_proj"] = dense_init(k2, cfg.d_model, cfg.d_model, dtype)
    return params


def _assemble(params, batch, cfg):
    dt = jnp.dtype(cfg.dtype)
    patches = batch["patches"].astype(dt) @ params["patch_proj"].astype(dt)
    text = params["embed"].astype(dt)[batch["tokens"]]
    h = jnp.concatenate([patches, text], axis=1)
    return shard(h, "batch", None, None)


def forward_train(params, batch, cfg):
    """batch: {"patches": (B,P,d), "tokens": (B,S_text)} -> (text logits, aux)."""
    dt = jnp.dtype(cfg.dtype)
    P = batch["patches"].shape[1]
    h, aux = tf.apply_stack_train(params, _assemble(params, batch, cfg), cfg)
    h = rmsnorm(params["final_norm"], h[:, P:])           # text positions only
    logits = h @ params["lm_head"].astype(dt)
    return shard(logits, "batch", None, "tp"), aux


def loss_fn(params, batch, cfg):
    logits, aux = forward_train(params, batch, cfg)
    tokens = batch["tokens"]
    logits = logits[:, :-1].astype(jnp.float32)
    targets = tokens[:, 1:]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    return jnp.mean(logz - gold) + 0.01 * aux


def prefill(params, batch, cfg):
    dt = jnp.dtype(cfg.dtype)
    h, caches = tf.apply_stack_prefill(params, _assemble(params, batch, cfg), cfg)
    h = rmsnorm(params["final_norm"], h[:, -1:])
    logits = (h @ params["lm_head"].astype(dt))[:, 0]
    return logits, caches


decode_step = tf.decode_step
make_cache = tf.make_cache
