from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig  # noqa: F401
from repro.models.registry import Model, build, decode_specs  # noqa: F401
