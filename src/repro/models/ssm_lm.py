"""Attention-free Mamba1 LM (falcon-mamba-7b family)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import ssm
from repro.models.layers import cfg_scan, dense_init, embed_init, rmsnorm, rmsnorm_init
from repro.models.transformer import _stack_init
from repro.sharding import shard, unshard_fsdp


def _layer_init(key, cfg, dtype):
    return {
        "norm": rmsnorm_init(cfg.d_model, dtype),
        "mixer": ssm.mamba1_init(key, cfg, dtype),
    }


def init(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kl, kh = jax.random.split(key, 3)
    return {
        "embed": embed_init(ke, cfg.vocab_size, cfg.d_model, dtype),
        "layers": _stack_init(functools.partial(_layer_init, cfg=cfg, dtype=dtype), kl, cfg.n_layers),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
        "lm_head": dense_init(kh, cfg.d_model, cfg.vocab_size, dtype, scale=0.02),
    }


def forward_train(params, tokens, cfg):
    dt = jnp.dtype(cfg.dtype)
    h = params["embed"].astype(dt)[tokens]
    h = shard(h, "batch", None, None)

    def body(h, p):
        p = unshard_fsdp(p)
        return h + ssm.mamba1_train(p["mixer"], rmsnorm(p["norm"], h), cfg), None

    fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = cfg_scan(cfg, lambda c, p: fn(c, p), h, params["layers"])
    h = rmsnorm(params["final_norm"], h)
    logits = h @ params["lm_head"].astype(dt)
    return shard(logits, "batch", None, "tp"), jnp.float32(0.0)


def prefill(params, tokens, cfg):
    dt = jnp.dtype(cfg.dtype)
    h = params["embed"].astype(dt)[tokens]
    h = shard(h, "batch", None, None)

    def body(h, p):
        p = unshard_fsdp(p)
        out, cache = ssm.mamba1_prefill(p["mixer"], rmsnorm(p["norm"], h), cfg)
        return h + out, cache

    fn = jax.checkpoint(body) if cfg.remat else body
    h, caches = cfg_scan(cfg, lambda c, p: fn(c, p), h, params["layers"])
    h = rmsnorm(params["final_norm"], h[:, -1:])
    logits = (h @ params["lm_head"].astype(dt))[:, 0]
    return logits, caches


def decode_step(params, token, caches, pos, cfg):
    """pos is unused for SSMs (state is position-free) but kept for API parity."""
    dt = jnp.dtype(cfg.dtype)
    h = params["embed"].astype(dt)[token][:, None, :]

    def body(h, inp):
        p, cache = inp
        p = unshard_fsdp(p)
        out, new_cache = ssm.mamba1_decode(p["mixer"], rmsnorm(p["norm"], h), cache, cfg)
        return h + out, new_cache

    h, new_caches = cfg_scan(cfg, body, h, (params["layers"], caches))
    h = rmsnorm(params["final_norm"], h)
    logits = (h @ params["lm_head"].astype(dt))[:, 0]
    return logits, new_caches


def make_cache(cfg, batch, seq_len, dtype=None):
    """SSM cache is O(1) in seq_len — the long_500k story."""
    dt = dtype or jnp.dtype(cfg.dtype)
    L, di, ds, W = cfg.n_layers, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return {
        "h": jnp.zeros((L, batch, di, ds), jnp.float32),
        "conv": jnp.zeros((L, batch, W - 1, di), dt),
    }
