"""Mixture-of-Experts FFN — GShard-style einsum dispatch, expert-parallel.

Top-k routing with per-group capacity; dispatch/combine are one-hot einsums
so the layer is pure SPMD (XLA turns the expert-sharded einsums into
all-to-all / all-gather under pjit — visible in the dry-run HLO and counted
by the roofline's collective term).

Supports:
  - phi3.5-moe: 16 experts, top-2
  - deepseek-v2: 160 routed top-6 + 2 shared experts, expert d_ff 1536
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, swiglu, swiglu_init
from repro.sharding import shard


def moe_init(key, cfg, dtype=jnp.float32):
    E = cfg.n_experts
    d = cfg.d_model
    ff = cfg.moe_d_ff or cfg.d_ff
    k_r, k_g, k_u, k_d, k_s = jax.random.split(key, 5)
    params = {
        "router": {"w": dense_init(k_r, d, E, dtype, scale=0.02)},
        "experts": {
            "w_gate": (jax.random.normal(k_g, (E, d, ff)) / jnp.sqrt(d)).astype(dtype),
            "w_up": (jax.random.normal(k_u, (E, d, ff)) / jnp.sqrt(d)).astype(dtype),
            "w_down": (jax.random.normal(k_d, (E, ff, d)) / jnp.sqrt(ff)).astype(dtype),
        },
    }
    if cfg.n_shared_experts:
        params["shared"] = swiglu_init(k_s, d, ff * cfg.n_shared_experts, dtype)
    return params


def _group(x, group_size):
    """(B,S,d) -> (G,g,d) with g | B*S."""
    B, S, d = x.shape
    tokens = B * S
    g = min(group_size, tokens)
    while tokens % g:
        g -= 1
    return x.reshape(tokens // g, g, d), (B, S)


def moe_ffn(params, x, cfg, group_size: int = 0):
    """Returns (out, aux_loss). x: (B,S,d).

    group_size (default cfg.moe_group_size) sets the dispatch granularity:
    capacity c ∝ group tokens, and dispatch/combine einsum cost ∝ E·c·d per
    token — smaller groups cut dispatch flops AND the (G,g,E,c) one-hot
    footprint linearly (§Perf hillclimb #1)."""
    dt = x.dtype
    E, k = cfg.n_experts, cfg.moe_top_k
    xg, (B, S) = _group(x, group_size or cfg.moe_group_size)
    G, g, d = xg.shape
    cap = max(int(k * g / E * cfg.capacity_factor), 1)
    cap = -(-cap // 4) * 4 if cap >= 4 else cap            # pad to multiple of 4

    logits = (xg @ params["router"]["w"].astype(dt)).astype(jnp.float32)  # (G,g,E)
    gates = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(gates, k)            # (G,g,k)
    top_vals = top_vals / (jnp.sum(top_vals, axis=-1, keepdims=True) + 1e-9)

    # load-balance auxiliary loss (Switch/GShard form)
    me = jnp.mean(gates, axis=1)                                   # (G,E)
    onehot_all = jax.nn.one_hot(top_idx[..., 0], E, dtype=jnp.float32)
    ce = jnp.mean(onehot_all, axis=1)                              # (G,E)
    aux = jnp.mean(jnp.sum(me * ce, axis=-1)) * E

    # position of each (token, slot) within its expert's capacity buffer
    slot_onehot = jax.nn.one_hot(top_idx, E, dtype=jnp.int32)      # (G,g,k,E)
    flat = slot_onehot.transpose(0, 2, 1, 3).reshape(G, k * g, E)  # slot-major
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat                # (G,k*g,E)
    pos = jnp.sum(flat * pos_in_expert, axis=-1)                   # (G,k*g)
    pos = pos.reshape(G, k, g).transpose(0, 2, 1)                  # (G,g,k)
    keep = pos < cap

    # dispatch/combine tensors
    cap_onehot = jax.nn.one_hot(pos, cap, dtype=dt) * keep[..., None].astype(dt)  # (G,g,k,c)
    exp_onehot = jax.nn.one_hot(top_idx, E, dtype=dt)                             # (G,g,k,E)
    dispatch = jnp.einsum("gske,gskc->gsec", exp_onehot, cap_onehot)              # (G,g,E,c)
    combine = jnp.einsum("gsk,gske,gskc->gsec", top_vals.astype(dt), exp_onehot, cap_onehot)

    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch, xg)         # (E,G,c,d)
    expert_in = shard(expert_in, "expert", None, None, None)
    w_g = params["experts"]["w_gate"].astype(dt)
    w_u = params["experts"]["w_up"].astype(dt)
    w_d = params["experts"]["w_down"].astype(dt)
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", expert_in, w_g)) * jnp.einsum(
        "egcd,edf->egcf", expert_in, w_u
    )
    expert_out = jnp.einsum("egcf,efd->egcd", h, w_d)              # (E,G,c,d)
    expert_out = shard(expert_out, "expert", None, None, None)

    out = jnp.einsum("gsec,egcd->gsd", combine, expert_out)        # (G,g,d)
    out = out.reshape(B, S, d)
    if "shared" in params:
        out = out + swiglu(params["shared"], x)
    return out, aux
