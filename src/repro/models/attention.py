"""Attention: GQA (+ optional sliding window), MLA (DeepSeek-V2 style).

Three entry points per variant:
  *_train   — full-sequence causal attention (teacher forcing)
  *_prefill — full sequence, returns the KV cache for decoding
  *_decode  — one new token against an existing cache

Caches:
  GQA full:    {"k","v": (B, S_max, H_kv, hd)}   (k stored already-roped)
  GQA sliding: same shape with S_max = window, ring-buffer writes
  MLA:         {"c_kv": (B, S_max, r), "k_rope": (B, S_max, rope_dim)}
               — the compressed-KV cache that is MLA's raison d'être.

Long sequences use query-chunked attention (flash-style row blocking) so
the S×S logits matrix never materializes above ``_CHUNK`` rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import apply_rope, cfg_scan, dense_init
from repro.sharding import shard

_CHUNK = 1024          # query-chunk rows for long-sequence attention
_NEG = -1e30


# =========================================================== GQA weights
def gqa_init(key, cfg, dtype=jnp.float32):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dtype),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["b_k"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["b_v"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    return p


def _qkv(params, x, cfg, positions):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    dt = x.dtype
    q = x @ params["wq"].astype(dt)
    k = x @ params["wk"].astype(dt)
    v = x @ params["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + params["b_q"].astype(dt)
        k = k + params["b_k"].astype(dt)
        v = v + params["b_v"].astype(dt)
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", None, "tp", None)
    k = shard(k, "batch", None, "tp", None)
    v = shard(v, "batch", None, "tp", None)
    return q, k, v


def _repeat_kv(k, n_heads):
    """(B,S,H_kv,hd) -> (B,S,H,hd) by group broadcast."""
    B, S, Hkv, hd = k.shape
    rep = n_heads // Hkv
    if rep == 1:
        return k
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, Hkv, rep, hd)).reshape(B, S, n_heads, hd)


def _attend_rows(q_rows, k, v, mask_rows, scale):
    """q_rows: (B,R,H,hd); k,v: (B,S,H,hd); mask_rows: (R,S) or (B,R,S)."""
    logits = jnp.einsum("brhd,bshd->bhrs", q_rows, k).astype(jnp.float32) * scale
    logits = jnp.where(mask_rows[..., None, :, :] if mask_rows.ndim == 2 else mask_rows[:, None],
                       logits, _NEG)
    probs = jax.nn.softmax(logits, axis=-1).astype(q_rows.dtype)
    return jnp.einsum("bhrs,bshd->brhd", probs, v)


def causal_attention(q, k, v, cfg, q_offset=0):
    """Chunked causal (optionally sliding-window) attention.

    q: (B,Sq,H,hd); k,v: (B,Sk,H_kv,hd). q_offset = absolute position of
    q[0] relative to k[0] (prefill: 0; not used for decode path).
    """
    B, Sq, H, hd = q.shape
    hd_v = v.shape[-1]                 # MLA: v head dim ≠ qk head dim
    Sk = k.shape[1]
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    kpos = jnp.arange(Sk)

    def mask_for(qpos):
        m = kpos[None, :] <= qpos[:, None]
        if cfg.sliding_window:
            m &= kpos[None, :] > (qpos[:, None] - cfg.sliding_window)
        return m

    if Sq <= _CHUNK:
        qpos = jnp.arange(Sq) + q_offset
        return _attend_rows(q, k, v, mask_for(qpos), scale)

    n_chunks = Sq // _CHUNK
    qc = q.reshape(B, n_chunks, _CHUNK, H, hd).transpose(1, 0, 2, 3, 4)

    def body(carry, args):
        i, q_rows = args
        qpos = i * _CHUNK + jnp.arange(_CHUNK) + q_offset
        out = _attend_rows(q_rows, k, v, mask_for(qpos), scale)
        return carry, out

    _, outs = cfg_scan(cfg, body, None, (jnp.arange(n_chunks), qc))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd_v)


def gqa_train(params, x, cfg, positions=None):
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _qkv(params, x, cfg, positions)
    out = causal_attention(q, k, v, cfg)
    out = out.reshape(B, S, -1)
    return out @ params["wo"].astype(x.dtype)


def gqa_prefill(params, x, cfg, positions=None):
    """Returns (out, cache). Cache holds roped keys at absolute positions."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _qkv(params, x, cfg, positions)
    out = causal_attention(q, k, v, cfg)
    out = out.reshape(B, S, -1) @ params["wo"].astype(x.dtype)
    if cfg.sliding_window and S > cfg.sliding_window:
        k = k[:, -cfg.sliding_window:]
        v = v[:, -cfg.sliding_window:]
    return out, {"k": k, "v": v}


def gqa_decode(params, x, cache, pos, cfg):
    """x: (B,1,d); cache k/v: (B,S_max,H_kv,hd); pos: scalar int32 —
    number of tokens already in context (absolute position of new token)."""
    if cfg.flash_decode:
        from repro.sharding import current_ctx
        ctx = current_ctx()
        if (ctx is not None and ctx.mesh is not None
                and ctx.logical_map.get("tp")
                and cache["k"].shape[1] % ctx.mesh.shape[ctx.logical_map["tp"]] == 0):
            return _gqa_decode_flash(params, x, cache, pos, cfg)
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    dt = x.dtype
    positions = jnp.broadcast_to(pos, (B, 1))
    q, k_new, v_new = _qkv(params, x, cfg, positions)

    S_max = cache["k"].shape[1]
    if cfg.sliding_window:
        slot = pos % S_max
        valid_len = jnp.minimum(pos + 1, S_max)
    else:
        slot = pos
        valid_len = pos + 1
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))

    kk = _repeat_kv(k.astype(dt), cfg.n_heads)
    vv = _repeat_kv(v.astype(dt), cfg.n_heads)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bshd->bhqs", q, kk).astype(jnp.float32) * scale
    mask = jnp.arange(S_max)[None, None, None, :] < valid_len
    logits = jnp.where(mask, logits, _NEG)
    probs = jax.nn.softmax(logits, axis=-1).astype(dt)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, vv).reshape(B, 1, -1)
    out = out @ params["wo"].astype(dt)
    return out, {"k": k, "v": v}


# =========================================================== flash decode
def _flash_decode_core(axis, windowed, q, k, v, k_new, v_new, pos):
    """Per-shard decode attention over a seq-sharded KV cache (shard_map).

    q: (B,1,H,hd) replicated over `axis`; k/v: (B,S_loc,Hkv,hd) = this
    shard's contiguous cache slab. Two-pass-free online softmax: global max
    and normalizer via pmax/psum of (B,H) stats; context psum'd. Per-step
    collectives are O(B·H·hd) instead of all-gathering the cache."""
    B, S_loc = k.shape[0], k.shape[1]
    n_shards = jax.lax.psum(1, axis)
    S_max = S_loc * n_shards
    idx = jax.lax.axis_index(axis)
    start = idx * S_loc

    slot = pos % S_max if windowed else pos
    valid_len = jnp.minimum(pos + 1, S_max) if windowed else pos + 1
    slot_local = jnp.clip(slot - start, 0, S_loc - 1)
    in_range = (slot >= start) & (slot < start + S_loc)

    k_upd = jax.lax.dynamic_update_slice(k, k_new.astype(k.dtype), (0, slot_local, 0, 0))
    v_upd = jax.lax.dynamic_update_slice(v, v_new.astype(v.dtype), (0, slot_local, 0, 0))
    k = jnp.where(in_range, k_upd, k)
    v = jnp.where(in_range, v_upd, v)

    H = q.shape[2]
    kk = _repeat_kv(k.astype(q.dtype), H)
    vv = _repeat_kv(v.astype(q.dtype), H)
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bshd->bhqs", q, kk).astype(jnp.float32) * scale
    mask = (start + jnp.arange(S_loc))[None, None, None, :] < valid_len
    logits = jnp.where(mask, logits, _NEG)

    local_max = jnp.max(logits, axis=-1)                        # (B,H,1)
    gmax = jax.lax.pmax(local_max, axis)
    p = jnp.exp(logits - gmax[..., None]) * mask
    denom = jax.lax.psum(jnp.sum(p, axis=-1), axis)             # (B,H,1)
    ctx = jnp.einsum("bhqs,bshd->bqhd", p.astype(q.dtype), vv)
    ctx = jax.lax.psum(ctx, axis)
    out = ctx / denom.transpose(0, 2, 1)[..., None].astype(q.dtype)
    return out, k, v


def _gqa_decode_flash(params, x, cache, pos, cfg):
    """shard_map flash-decode path (requires an active mesh ctx with a tp
    axis and a cache whose seq dim divides it)."""
    try:
        from jax import shard_map
    except ImportError:  # jax < 0.5 keeps it under experimental
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.sharding import current_ctx

    ctx = current_ctx()
    mesh = ctx.mesh
    tp = ctx.logical_map.get("tp")
    batch_ax = ctx.logical_map.get("batch")
    B = x.shape[0]
    S_max = cache["k"].shape[1]
    n_tp = mesh.shape[tp]

    dt = x.dtype
    positions = jnp.broadcast_to(pos, (B, 1))
    q, k_new, v_new = _qkv(params, x, cfg, positions)

    b_ax = batch_ax if (batch_ax and B % (
        mesh.shape[batch_ax] if not isinstance(batch_ax, tuple)
        else int(np.prod([mesh.shape[a] for a in batch_ax]))) == 0) else None

    cache_spec = P(b_ax, tp, None, None)
    flat_spec = P(b_ax, None, None, None)
    core = functools.partial(_flash_decode_core, tp, bool(cfg.sliding_window))
    try:
        smap = functools.partial(shard_map, check_vma=False)
        smap(lambda: None, mesh=mesh, in_specs=(), out_specs=P())
    except TypeError:  # jax < 0.6 spells it check_rep
        smap = functools.partial(shard_map, check_rep=False)
    out, k2, v2 = smap(
        core, mesh=mesh,
        in_specs=(flat_spec, cache_spec, cache_spec, flat_spec, flat_spec, P()),
        out_specs=(flat_spec, cache_spec, cache_spec),
    )(q, cache["k"], cache["v"], k_new, v_new, pos)
    out = out.reshape(B, 1, -1) @ params["wo"].astype(dt)
    return out, {"k": k2, "v": v2}


# =========================================================== MLA (DeepSeek)
def mla_init(key, cfg, dtype=jnp.float32):
    """Multi-head Latent Attention: compressed KV (rank r) + decoupled RoPE."""
    H, r = cfg.n_heads, cfg.kv_lora_rank
    qk_n, qk_r, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 5)
    return {
        # query: full-rank (we omit q-lora; it is an orthogonal memory opt)
        "wq": dense_init(ks[0], cfg.d_model, H * (qk_n + qk_r), dtype),
        # kv down-projection to the latent + the shared rope key
        "wkv_a": dense_init(ks[1], cfg.d_model, r + qk_r, dtype),
        # latent up-projection to per-head k_nope and v
        "wkv_b": dense_init(ks[2], r, H * (qk_n + dv), dtype),
        "wo": dense_init(ks[3], H * dv, cfg.d_model, dtype),
    }


def _mla_qkv_full(params, x, cfg, positions):
    """Expanded (train/prefill) path: materialize per-head K,V."""
    B, S, _ = x.shape
    H, r = cfg.n_heads, cfg.kv_lora_rank
    qk_n, qk_r, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dt = x.dtype
    q = (x @ params["wq"].astype(dt)).reshape(B, S, H, qk_n + qk_r)
    q_nope, q_rope = q[..., :qk_n], q[..., qk_n:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = x @ params["wkv_a"].astype(dt)                 # (B,S,r+qk_r)
    c_kv, k_rope = kv_a[..., :r], kv_a[..., r:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # (B,S,1,qk_r)
    kv = (c_kv @ params["wkv_b"].astype(dt)).reshape(B, S, H, qk_n + dv)
    k_nope, v = kv[..., :qk_n], kv[..., qk_n:]

    k_rope_b = jnp.broadcast_to(k_rope, (B, S, H, qk_r))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    q_full = shard(q_full, "batch", None, "tp", None)
    k_full = shard(k_full, "batch", None, "tp", None)
    v = shard(v, "batch", None, "tp", None)
    return q_full, k_full, v, c_kv, k_rope[:, :, 0, :]


class _MLACfg:
    """Adapter so causal_attention sees head_dim/window of the MLA variant."""
    def __init__(self, cfg):
        self.sliding_window = cfg.sliding_window
        self.scan_unroll = cfg.scan_unroll


def mla_train(params, x, cfg, positions=None):
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v, _, _ = _mla_qkv_full(params, x, cfg, positions)
    out = causal_attention(q, k, v, _MLACfg(cfg))
    return out.reshape(B, S, -1) @ params["wo"].astype(x.dtype)


def mla_prefill(params, x, cfg, positions=None):
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v, c_kv, k_rope = _mla_qkv_full(params, x, cfg, positions)
    out = causal_attention(q, k, v, _MLACfg(cfg))
    out = out.reshape(B, S, -1) @ params["wo"].astype(x.dtype)
    if cfg.sliding_window and S > cfg.sliding_window:
        c_kv = c_kv[:, -cfg.sliding_window:]
        k_rope = k_rope[:, -cfg.sliding_window:]
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def mla_decode(params, x, cache, pos, cfg):
    """Weight-absorbed MLA decode: attention runs in the r-dim latent space.

    cache: c_kv (B,S_max,r), k_rope (B,S_max,qk_r). Scores =
    (q_nope·W_uk)·c_kv + q_rope·k_rope; output = (probs·c_kv)·W_uv.
    """
    B = x.shape[0]
    H, r = cfg.n_heads, cfg.kv_lora_rank
    qk_n, qk_r, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dt = x.dtype
    S_max = cache["c_kv"].shape[1]
    positions = jnp.broadcast_to(pos, (B, 1))

    q = (x @ params["wq"].astype(dt)).reshape(B, 1, H, qk_n + qk_r)
    q_nope, q_rope = q[..., :qk_n], q[..., qk_n:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)[:, 0]   # (B,H,qk_r)
    q_nope = q_nope[:, 0]                                          # (B,H,qk_n)

    kv_a = (x @ params["wkv_a"].astype(dt))[:, 0]                  # (B,r+qk_r)
    c_new, kr_new = kv_a[..., :r], kv_a[..., r:]
    kr_new = apply_rope(kr_new[:, None, None, :], positions, cfg.rope_theta)[:, 0, 0]

    if cfg.sliding_window:
        slot = pos % S_max
        valid_len = jnp.minimum(pos + 1, S_max)
    else:
        slot = pos
        valid_len = pos + 1
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_new[:, None].astype(cache["c_kv"].dtype), (0, slot, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], kr_new[:, None].astype(cache["k_rope"].dtype), (0, slot, 0))

    wkv_b = params["wkv_b"].astype(dt).reshape(r, H, qk_n + dv)
    w_uk, w_uv = wkv_b[..., :qk_n], wkv_b[..., qk_n:]              # (r,H,qk_n), (r,H,dv)

    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope, w_uk)               # absorbed query
    scores = jnp.einsum("bhr,bsr->bhs", q_lat, c_kv.astype(dt))
    scores = scores + jnp.einsum("bhp,bsp->bhs", q_rope, k_rope.astype(dt))
    scores = scores.astype(jnp.float32) / jnp.sqrt(float(qk_n + qk_r))
    mask = jnp.arange(S_max)[None, None, :] < valid_len
    scores = jnp.where(mask, scores, _NEG)
    probs = jax.nn.softmax(scores, axis=-1).astype(dt)
    ctx_lat = jnp.einsum("bhs,bsr->bhr", probs, c_kv.astype(dt))   # latent context
    out = jnp.einsum("bhr,rhv->bhv", ctx_lat, w_uv).reshape(B, 1, H * dv)
    out = out @ params["wo"].astype(dt)
    return out, {"c_kv": c_kv, "k_rope": k_rope}


# =========================================================== cross-attn
def cross_attn_init(key, cfg, dtype=jnp.float32):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, dtype),
        "w_cross_k": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "w_cross_v": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dtype),
    }


def cross_kv(params, enc_out, cfg):
    B, Se, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    dt = enc_out.dtype
    k = (enc_out @ params["w_cross_k"].astype(dt)).reshape(B, Se, cfg.n_kv_heads, hd)
    v = (enc_out @ params["w_cross_v"].astype(dt)).reshape(B, Se, cfg.n_kv_heads, hd)
    return {"k": k, "v": v}

def cross_attend(params, x, kv, cfg):
    """x: (B,Sq,d) queries over precomputed encoder k/v (no mask)."""
    B, Sq, _ = x.shape
    hd = cfg.resolved_head_dim
    dt = x.dtype
    q = (x @ params["wq"].astype(dt)).reshape(B, Sq, cfg.n_heads, hd)
    k = _repeat_kv(kv["k"].astype(dt), cfg.n_heads)
    v = _repeat_kv(kv["v"].astype(dt), cfg.n_heads)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32) * scale
    probs = jax.nn.softmax(logits, axis=-1).astype(dt)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v).reshape(B, Sq, -1)
    return out @ params["wo"].astype(dt)


def bidir_attention(params, x, cfg):
    """Encoder self-attention (no causal mask), GQA weights."""
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _qkv(params, x, cfg, positions)
    kk = _repeat_kv(k, cfg.n_heads)
    vv = _repeat_kv(v, cfg.n_heads)
    scale = 1.0 / jnp.sqrt(cfg.resolved_head_dim).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bshd->bhqs", q, kk).astype(jnp.float32) * scale
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, vv).reshape(B, S, -1)
    return out @ params["wo"].astype(x.dtype)
