"""State-space blocks: Mamba1 (falcon-mamba) and Mamba2 (zamba2 core).

Train/prefill run a *chunked selective scan*: `lax.scan` over sequence
chunks carrying the recurrent state, `associative_scan` inside each chunk.
This bounds the (B, chunk, d_inner_shard, d_state) working set to VMEM-scale
— the same blocking the Pallas `ssm_scan` kernel implements natively on TPU.

Decode is the O(1) single-step recurrence on a cached state
  {"h": (B, d_inner, d_state) [or (B, nh, hd, d_state) for v2],
   "conv": (B, conv_width-1, d_inner)}
which is why SSM archs run long_500k natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.sharding import shard


def _scan_y(dA, dBx, C, h0, cfg, need_state: bool):
    """y[t] = Σ_n h[t]·C[t] with h[t] = dA[t]h[t-1] + dBx[t].

    When the caller does not need the final state (training) and the config
    opts in, route through the fused Pallas kernel (kernels/ssm_scan.py) —
    h never hits HBM. Otherwise run the jnp chunked scan and contract."""
    if cfg.use_pallas and not need_state and h0 is None:
        from repro.kernels import ops
        y = ops.ssm_scan(dA, dBx, C,
                         backend="auto" if jax.default_backend() == "tpu" else "jnp")
        return y, None
    B, S = dA.shape[0], dA.shape[1]
    if h0 is None:
        h0 = jnp.zeros(dA.shape[:1] + dA.shape[2:], jnp.float32)
    h_all, h_last = _chunked_scan(dA, dBx, h0, cfg.ssm_chunk, cfg.scan_unroll)
    y = jnp.einsum("bsdn,bsn->bsd", h_all, C.astype(jnp.float32))
    return y, h_last


# ----------------------------------------------------------------- common
def _causal_conv(x, conv_w, conv_b, tail=None):
    """Depthwise causal conv. x: (B,S,ch), conv_w: (ch,W), tail: (B,W-1,ch)."""
    W = conv_w.shape[1]
    if tail is None:
        tail = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)               # (B,S+W-1,ch)
    out = sum(xp[:, i : i + x.shape[1]] * conv_w[:, i] for i in range(W))
    return out + conv_b, xp[:, -(W - 1):]                  # (B,S,ch), new tail


def _chunked_scan(dA, dBx, h0, chunk, unroll=False):
    """h_t = dA_t * h_{t-1} + dBx_t over axis 1 (seq), chunked.

    dA, dBx: (B, S, ...state dims...); h0: (B, ...state dims...).
    Returns (h_all: (B,S,...), h_last).
    """
    B, S = dA.shape[0], dA.shape[1]
    n = max(S // chunk, 1)
    chunk = S // n
    state_shape = dA.shape[2:]
    dA_c = dA.reshape(B, n, chunk, *state_shape).transpose(1, 0, 2, *range(3, 3 + len(state_shape)))
    dBx_c = dBx.reshape(B, n, chunk, *state_shape).transpose(1, 0, 2, *range(3, 3 + len(state_shape)))

    def combine(a, b):
        return a[0] * b[0], b[0] * a[1] + b[1]

    def body(h, args):
        a, bx = args                                       # (B,chunk,...)
        A_cum, B_cum = jax.lax.associative_scan(combine, (a, bx), axis=1)
        h_all = A_cum * h[:, None] + B_cum                 # (B,chunk,...)
        return h_all[:, -1], h_all

    h_last, outs = jax.lax.scan(body, h0, (dA_c, dBx_c), unroll=True if unroll else 1)
    outs = outs.transpose(1, 0, 2, *range(3, 3 + len(state_shape))).reshape(B, S, *state_shape)
    return outs, h_last


# ----------------------------------------------------------------- mamba1
def mamba1_init(key, cfg, dtype=jnp.float32):
    d, di, ds = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dtr, W = cfg.resolved_dt_rank, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    A = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (di, W)) / jnp.sqrt(W)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dtr + 2 * ds, dtype),
        "dt_proj": dense_init(ks[3], dtr, di, dtype),
        "dt_bias": (jax.random.uniform(ks[4], (di,), minval=-4.6, maxval=-2.3)).astype(dtype),
        "a_log2": jnp.log(A).astype(dtype),                # (d_inner, d_state)
        "d_skip": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[5], di, d, dtype),
    }


def _mamba1_core(params, x, cfg, h0=None, conv_tail=None, need_state=True):
    B, S, _ = x.shape
    di, ds, dtr = cfg.d_inner, cfg.ssm_state, cfg.resolved_dt_rank
    dt_ = x.dtype
    xz = x @ params["in_proj"].astype(dt_)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = shard(x_in, "batch", None, "tp")
    x_c, new_tail = _causal_conv(x_in, params["conv_w"].astype(dt_), params["conv_b"].astype(dt_), conv_tail)
    x_c = jax.nn.silu(x_c)

    dbc = x_c @ params["x_proj"].astype(dt_)               # (B,S,dtr+2ds)
    dt_raw, Bc, Cc = jnp.split(dbc, [dtr, dtr + ds], axis=-1)
    delta = jax.nn.softplus(dt_raw @ params["dt_proj"].astype(dt_) + params["dt_bias"].astype(dt_))
    delta = delta.astype(jnp.float32)                      # (B,S,di)
    A = -jnp.exp(params["a_log2"].astype(jnp.float32))     # (di,ds)
    dA = jnp.exp(delta[..., None] * A)                     # (B,S,di,ds)
    dBx = (delta * x_c.astype(jnp.float32))[..., None] * Bc.astype(jnp.float32)[:, :, None, :]

    y, h_last = _scan_y(dA, dBx, Cc.astype(jnp.float32), h0, cfg,
                        need_state=need_state)
    y = y + params["d_skip"].astype(jnp.float32) * x_c.astype(jnp.float32)
    y = (y.astype(dt_) * jax.nn.silu(z))
    out = y @ params["out_proj"].astype(dt_)
    return out, h_last, new_tail


def mamba1_train(params, x, cfg):
    out, _, _ = _mamba1_core(params, x, cfg, need_state=False)
    return out


def mamba1_prefill(params, x, cfg):
    out, h, tail = _mamba1_core(params, x, cfg)
    return out, {"h": h, "conv": tail}


def mamba1_decode(params, x, cache, cfg):
    """x: (B,1,d). O(1) recurrence against cached (h, conv tail)."""
    out, h, tail = _mamba1_core(params, x, cfg, h0=cache["h"], conv_tail=cache["conv"].astype(x.dtype))
    return out, {"h": h, "conv": tail}


# ----------------------------------------------------------------- mamba2
def mamba2_init(key, cfg, dtype=jnp.float32):
    d, di, ds = cfg.d_model, cfg.d_inner, cfg.ssm_state
    hd = cfg.ssm_head_dim
    nh = di // hd
    W = cfg.ssm_conv
    ks = jax.random.split(key, 4)
    # in_proj emits [x (di), z (di), B (ds), C (ds), dt (nh)]
    return {
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * ds + nh, dtype),
        "conv_w": (jax.random.normal(ks[1], (di + 2 * ds, W)) / jnp.sqrt(W)).astype(dtype),
        "conv_b": jnp.zeros((di + 2 * ds,), dtype),
        "dt_bias": jnp.zeros((nh,), dtype),
        "a_log": jnp.zeros((nh,), dtype),                  # scalar decay per head
        "d_skip": jnp.ones((nh,), dtype),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], di, d, dtype),
    }


def _mamba2_core(params, x, cfg, h0=None, conv_tail=None):
    B, S, _ = x.shape
    di, ds = cfg.d_inner, cfg.ssm_state
    hd = cfg.ssm_head_dim
    nh = di // hd
    dt_ = x.dtype
    proj = x @ params["in_proj"].astype(dt_)
    xBC, z, dt_raw = jnp.split(proj, [di + 2 * ds, 2 * di + 2 * ds], axis=-1)
    xBC, new_tail = _causal_conv(xBC, params["conv_w"].astype(dt_), params["conv_b"].astype(dt_), conv_tail)
    xBC = jax.nn.silu(xBC)
    x_in, Bc, Cc = jnp.split(xBC, [di, di + ds], axis=-1)

    delta = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))  # (B,S,nh)
    A = -jnp.exp(params["a_log"].astype(jnp.float32))      # (nh,)
    dA = jnp.exp(delta * A)[..., None, None]               # (B,S,nh,1,1)
    xh = x_in.reshape(B, S, nh, hd).astype(jnp.float32)
    dBx = (delta[..., None] * xh)[..., None] * Bc.astype(jnp.float32)[:, :, None, None, :]  # (B,S,nh,hd,ds)

    if h0 is None:
        h0 = jnp.zeros((B, nh, hd, ds), jnp.float32)
    dA_b = jnp.broadcast_to(dA, dBx.shape)
    h_all, h_last = _chunked_scan(dA_b, dBx, h0, cfg.ssm_chunk, cfg.scan_unroll)
    y = jnp.einsum("bsnhd,bsd->bsnh", h_all.reshape(B, S, nh, hd, ds), Cc.astype(jnp.float32))
    y = y + params["d_skip"].astype(jnp.float32)[:, None] * xh
    y = y.reshape(B, S, di)
    # gated RMSNorm (mamba2)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * params["norm_scale"].astype(jnp.float32)
    return (y.astype(dt_) @ params["out_proj"].astype(dt_)), h_last, new_tail


def mamba2_train(params, x, cfg):
    out, _, _ = _mamba2_core(params, x, cfg)
    return out


def mamba2_prefill(params, x, cfg):
    out, h, tail = _mamba2_core(params, x, cfg)
    return out, {"h": h, "conv": tail}


def mamba2_decode(params, x, cache, cfg):
    out, h, tail = _mamba2_core(params, x, cfg, h0=cache["h"], conv_tail=cache["conv"].astype(x.dtype))
    return out, {"h": h, "conv": tail}
