"""Model/architecture configuration.

One frozen dataclass covers all six assigned arch families; family-specific
fields default to 0/None and are validated by the registry.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    vocab_size: int
    # attention (0 heads => attention-free)
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0                 # 0 => d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None   # None => full causal attention
    flash_decode: bool = False        # shard_map partial-softmax decode over
                                      # the seq-sharded KV cache (§Perf #2)
    # mlp
    d_ff: int = 0
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0                 # expert hidden (deepseek-style); 0 => d_ff
    capacity_factor: float = 1.25
    moe_group_size: int = 4096        # dispatch group tokens (perf knob)
    moe_layer_start: int = 0          # first MoE layer index (deepseek: layer 0 dense)
    # MLA (deepseek)
    kv_lora_rank: int = 0             # 0 => regular GQA
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128
    # SSM (mamba)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_dt_rank: int = 0              # 0 => ceil(d_model/16)
    ssm_head_dim: int = 64            # mamba2 only
    ssm_version: int = 1              # 1 | 2
    ssm_chunk: int = 128              # chunked-scan chunk length
    use_pallas: bool = False          # route hot loops through kernels/ (TPU)
    # hybrid (zamba2)
    attn_every: int = 0               # shared attn block applied every k core layers
    # enc-dec (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 1500               # post-conv audio frames (frontend stubbed)
    # vlm
    n_patches: int = 0                # vision prefix length (encoder stubbed)
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    scan_unroll: bool = False         # fully unroll layer/seq scans (cost probes)
    # metadata
    source: str = ""                  # citation

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.n_heads:
            return self.d_model // self.n_heads
        return 0

    @property
    def resolved_dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}
