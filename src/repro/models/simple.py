"""The paper's own task models: MLP (MNIST), CNN (CIFAR10), CNN (FEMNIST).

These are what StoCFL's experiments actually train (§4.2 "a linear
classification model with a hidden layer of 2048 units", "a CNN with two
convolutional layers followed by two fully connected layers"). They share
the classification Model API: apply(params, x) -> logits.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


@dataclasses.dataclass(frozen=True)
class TaskConfig:
    name: str
    kind: str            # mlp | cnn
    input_shape: tuple   # e.g. (784,) or (32,32,3)
    n_classes: int = 10
    hidden: int = 2048
    conv_channels: tuple = (32, 64)
    fc_hidden: int = 128


MNIST_MLP = TaskConfig("mnist_mlp", "mlp", (784,), 10, hidden=2048)
CIFAR_CNN = TaskConfig("cifar_cnn", "cnn", (32, 32, 3), 10)
FEMNIST_CNN = TaskConfig("femnist_cnn", "cnn", (28, 28, 1), 62)
SYNTH_MLP = TaskConfig("synth_mlp", "mlp", (64,), 10, hidden=256)


def init(key, cfg: TaskConfig):
    if cfg.kind == "mlp":
        k1, k2 = jax.random.split(key)
        d_in = int(jnp.prod(jnp.array(cfg.input_shape)))
        return {
            "w1": dense_init(k1, d_in, cfg.hidden),
            "b1": jnp.zeros((cfg.hidden,)),
            "w2": dense_init(k2, cfg.hidden, cfg.n_classes),
            "b2": jnp.zeros((cfg.n_classes,)),
        }
    k1, k2, k3, k4 = jax.random.split(key, 4)
    c1, c2 = cfg.conv_channels
    in_ch = cfg.input_shape[-1]
    h, w = cfg.input_shape[0] // 4, cfg.input_shape[1] // 4   # two 2x2 maxpools
    flat = h * w * c2
    # Xavier init (paper §4.2)
    return {
        "conv1_w": jax.random.normal(k1, (3, 3, in_ch, c1)) * jnp.sqrt(2.0 / (9 * in_ch)),
        "conv1_b": jnp.zeros((c1,)),
        "conv2_w": jax.random.normal(k2, (3, 3, c1, c2)) * jnp.sqrt(2.0 / (9 * c1)),
        "conv2_b": jnp.zeros((c2,)),
        "fc1_w": dense_init(k3, flat, cfg.fc_hidden),
        "fc1_b": jnp.zeros((cfg.fc_hidden,)),
        "fc2_w": dense_init(k4, cfg.fc_hidden, cfg.n_classes),
        "fc2_b": jnp.zeros((cfg.n_classes,)),
    }


def _maxpool2(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def apply(params, x, cfg: TaskConfig):
    """x: (B, *input_shape) -> logits (B, n_classes)."""
    if cfg.kind == "mlp":
        x = x.reshape(x.shape[0], -1)
        h = jax.nn.relu(x @ params["w1"] + params["b1"])
        return h @ params["w2"] + params["b2"]
    h = jax.lax.conv_general_dilated(
        x, params["conv1_w"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    ) + params["conv1_b"]
    h = _maxpool2(jax.nn.relu(h))
    h = jax.lax.conv_general_dilated(
        h, params["conv2_w"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    ) + params["conv2_b"]
    h = _maxpool2(jax.nn.relu(h))
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["fc1_w"] + params["fc1_b"])
    return h @ params["fc2_w"] + params["fc2_b"]


def loss_fn(params, batch, cfg: TaskConfig):
    """batch: {"x": (B,...), "y": (B,) int32} -> mean CE loss.

    An optional ``"mask"`` leaf ((B,) validity weights — the arena's
    pad-and-mask representation of ragged client shards) turns the mean
    into a masked mean: pad rows contribute exactly nothing."""
    logits = apply(params, batch["x"], cfg).astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
    per = logz - gold
    mask = batch.get("mask")
    if mask is None:
        return jnp.mean(per)
    m = mask.astype(jnp.float32)
    return jnp.sum(per * m) / jnp.maximum(jnp.sum(m), 1.0)


def accuracy(params, batch, cfg: TaskConfig):
    logits = apply(params, batch["x"], cfg)
    hit = (jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32)
    mask = batch.get("mask")
    if mask is None:
        return jnp.mean(hit)
    m = mask.astype(jnp.float32)
    return jnp.sum(hit * m) / jnp.maximum(jnp.sum(m), 1.0)
