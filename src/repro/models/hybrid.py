"""Zamba2-style hybrid: Mamba2 core stack + one *shared* attention block.

The shared block (single parameter set, applied every ``cfg.attn_every``
core layers — Zamba's parameter-sharing trick) takes concat(embedding,
hidden) at 2*d_model, projects in, runs GQA + SwiGLU, and adds back to the
residual stream. Its KV caches are per-application (stacked axis A).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ssm
from repro.models.layers import cfg_scan, dense_init, embed_init, rmsnorm, rmsnorm_init, swiglu, swiglu_init
from repro.models.transformer import _stack_init
from repro.sharding import shard, unshard_fsdp


def _n_groups(cfg):
    return cfg.n_layers // cfg.attn_every   # shared attn applied after each full group


def init(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    ke, km, ka, kh, kp, kmlp = jax.random.split(key, 6)
    params = {
        "embed": embed_init(ke, cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
        "lm_head": dense_init(kh, cfg.d_model, cfg.vocab_size, dtype, scale=0.02),
        "mamba_layers": _stack_init(lambda k: ssm.mamba2_init(k, cfg, dtype), km, cfg.n_layers),
        "shared": {
            "in_proj": dense_init(kp, 2 * cfg.d_model, cfg.d_model, dtype),
            "attn_norm": rmsnorm_init(2 * cfg.d_model, dtype),
            "attn": attn.gqa_init(ka, cfg, dtype),
            "mlp_norm": rmsnorm_init(cfg.d_model, dtype),
            "mlp": swiglu_init(kmlp, cfg.d_model, cfg.d_ff, dtype),
        },
    }
    return params


def _group_slices(cfg, stacked):
    """Split the stacked mamba params into per-group slices + remainder."""
    g, e = cfg.attn_every, _n_groups(cfg)
    groups = [jax.tree.map(lambda x: x[i * g : (i + 1) * g], stacked) for i in range(e)]
    rem = jax.tree.map(lambda x: x[e * g :], stacked)
    n_rem = cfg.n_layers - e * g
    return groups, rem, n_rem


def _mamba_group(cfg, mode, h, group_params, caches=None):
    """Run a slice of mamba2 layers via scan. mode: train|prefill|decode."""
    if mode == "train":
        fn = (lambda h, p: (h + ssm.mamba2_train(unshard_fsdp(p), h, cfg), None))
        if cfg.remat:
            fn = jax.checkpoint(fn)
        h, _ = cfg_scan(cfg, fn, h, group_params)
        return h, None
    if mode == "prefill":
        def fn(h, p):
            out, cache = ssm.mamba2_prefill(unshard_fsdp(p), h, cfg)
            return h + out, cache
        if cfg.remat:
            fn = jax.checkpoint(fn)
        return cfg_scan(cfg, fn, h, group_params)
    # decode
    def fn(h, inp):
        p, cache = inp
        out, new_cache = ssm.mamba2_decode(unshard_fsdp(p), h, cache, cfg)
        return h + out, new_cache
    return cfg_scan(cfg, fn, h, (group_params, caches))


def _shared_block(cfg, params, h, h_embed, mode, cache=None, pos=None):
    """Shared attention + MLP block. Returns (h, new_kv_cache_or_None)."""
    sp = unshard_fsdp(params["shared"])
    dt = h.dtype
    x2 = jnp.concatenate([h_embed, h], axis=-1)
    x2 = rmsnorm(sp["attn_norm"], x2)
    x = x2 @ sp["in_proj"].astype(dt)
    x = shard(x, "batch", None, None)
    if mode == "train":
        a = attn.gqa_train(sp["attn"], x, cfg)
        new_cache = None
    elif mode == "prefill":
        a, new_cache = attn.gqa_prefill(sp["attn"], x, cfg)
    else:
        a, new_cache = attn.gqa_decode(sp["attn"], x, cache, pos, cfg)
    h = h + a
    h = h + swiglu(sp["mlp"], rmsnorm(sp["mlp_norm"], h))
    return h, new_cache


def forward_train(params, tokens, cfg):
    dt = jnp.dtype(cfg.dtype)
    h = params["embed"].astype(dt)[tokens]
    h = shard(h, "batch", None, None)
    h_embed = h
    groups, rem, n_rem = _group_slices(cfg, params["mamba_layers"])
    for gp in groups:
        h, _ = _mamba_group(cfg, "train", h, gp)
        h, _ = _shared_block(cfg, params, h, h_embed, "train")
    if n_rem:
        h, _ = _mamba_group(cfg, "train", h, rem)
    h = rmsnorm(params["final_norm"], h)
    logits = h @ params["lm_head"].astype(dt)
    return shard(logits, "batch", None, "tp"), jnp.float32(0.0)


def prefill(params, tokens, cfg):
    dt = jnp.dtype(cfg.dtype)
    h = params["embed"].astype(dt)[tokens]
    h_embed = h
    groups, rem, n_rem = _group_slices(cfg, params["mamba_layers"])
    m_caches, a_caches = [], []
    for gp in groups:
        h, c = _mamba_group(cfg, "prefill", h, gp)
        m_caches.append(c)
        h, ac = _shared_block(cfg, params, h, h_embed, "prefill")
        a_caches.append(ac)
    if n_rem:
        h, c = _mamba_group(cfg, "prefill", h, rem)
        m_caches.append(c)
    mamba_cache = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *m_caches)
    if a_caches:
        attn_cache = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *a_caches)
    else:                      # no shared-attn applications (probe configs)
        attn_cache = make_cache(cfg, h.shape[0], tokens.shape[1])["attn"]
    h = rmsnorm(params["final_norm"], h[:, -1:])
    logits = (h @ params["lm_head"].astype(dt))[:, 0]
    return logits, {"mamba": mamba_cache, "attn": attn_cache}


def decode_step(params, token, caches, pos, cfg):
    dt = jnp.dtype(cfg.dtype)
    h = params["embed"].astype(dt)[token][:, None, :]
    h_embed = h
    groups, rem, n_rem = _group_slices(cfg, params["mamba_layers"])
    g = cfg.attn_every
    e = _n_groups(cfg)
    new_m, new_a = [], []
    for i, gp in enumerate(groups):
        mc = jax.tree.map(lambda x: x[i * g : (i + 1) * g], caches["mamba"])
        h, c = _mamba_group(cfg, "decode", h, gp, mc)
        new_m.append(c)
        ac = jax.tree.map(lambda x: x[i], caches["attn"])
        h, nac = _shared_block(cfg, params, h, h_embed, "decode", ac, pos)
        new_a.append(nac)
    if n_rem:
        mc = jax.tree.map(lambda x: x[e * g :], caches["mamba"])
        h, c = _mamba_group(cfg, "decode", h, rem, mc)
        new_m.append(c)
    mamba_cache = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *new_m)
    if new_a:
        attn_cache = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *new_a)
    else:
        attn_cache = caches["attn"]
    h = rmsnorm(params["final_norm"], h)
    logits = (h @ params["lm_head"].astype(dt))[:, 0]
    return logits, {"mamba": mamba_cache, "attn": attn_cache}


def make_cache(cfg, batch, seq_len, dtype=None):
    dt = dtype or jnp.dtype(cfg.dtype)
    di, ds = cfg.d_inner, cfg.ssm_state
    hd_ssm = cfg.ssm_head_dim
    nh = di // hd_ssm
    W = cfg.ssm_conv
    L, A = cfg.n_layers, _n_groups(cfg)
    S = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    hd = cfg.resolved_head_dim
    return {
        "mamba": {
            "h": jnp.zeros((L, batch, nh, hd_ssm, ds), jnp.float32),
            "conv": jnp.zeros((L, batch, W - 1, di + 2 * ds), dt),
        },
        "attn": {
            "k": jnp.zeros((A, batch, S, cfg.n_kv_heads, hd), dt),
            "v": jnp.zeros((A, batch, S, cfg.n_kv_heads, hd), dt),
        },
    }
