"""Mesh-sharding rules and cohort placement (see docs/SHARDING.md).

Two sharding surfaces live here:

* the MaxText-style logical-axis rule table for model parameters and
  activations (``ShardCtx`` / ``shard`` / ``param_shardings`` /
  ``unshard_fsdp``), used by the LLM substrate path; and
* the client-axis cohort placement the federated engine's scanned round
  loop runs on (``cohort_spec`` / ``place_cohort`` /
  ``constrain_cohort`` / ``psum_segments``): stacked cohort pytrees
  carry clients on the leading axis, placed over the mesh's client axes
  (``client_axes``), with every placement divisibility-safe — a
  non-dividing axis silently relaxes to replicated, so correctness
  never depends on mesh size.
"""
from repro.sharding.specs import (  # noqa: F401
    ShardCtx,
    align_cohort_chunk,
    client_axes,
    cohort_spec,
    constrain_cohort,
    current_ctx,
    mesh_client_count,
    mesh_fingerprint,
    param_shardings,
    place_buffer_rows,
    place_cohort,
    place_decode_state,
    place_replicated,
    psum_segments,
    replicated,
    shard,
    spec_for_path,
    unshard_fsdp,
)

__all__ = [
    "ShardCtx",
    "align_cohort_chunk",
    "client_axes",
    "cohort_spec",
    "constrain_cohort",
    "current_ctx",
    "mesh_client_count",
    "mesh_fingerprint",
    "param_shardings",
    "place_buffer_rows",
    "place_cohort",
    "place_decode_state",
    "place_replicated",
    "psum_segments",
    "replicated",
    "shard",
    "spec_for_path",
    "unshard_fsdp",
]
