from repro.sharding.specs import (  # noqa: F401
    ShardCtx,
    current_ctx,
    param_shardings,
    replicated,
    shard,
    spec_for_path,
    unshard_fsdp,
)
