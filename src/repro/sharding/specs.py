"""Sharding rule table + activation-sharding context.

Models call ``shard(x, "batch", None, "tp")`` with *logical* axis names;
the active ``ShardCtx`` maps logical names to mesh axes (or is a no-op when
running single-device smoke tests). Parameter shardings are produced by a
regex rule table over pytree paths — the same mechanism MaxText/T5X use.

Logical axes:
  batch   -> ("pod","data") on the production mesh (client/batch axis)
  tp      -> "model"        (tensor-parallel: heads, d_ff, experts, vocab)
  fsdp    -> "data"         (parameter row sharding, ZeRO-style)
  none    -> replicated
"""
from __future__ import annotations

import re
import threading
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ctx = threading.local()


class ShardCtx:
    """Maps logical axis names to physical mesh axes for one mesh."""

    def __init__(self, mesh: Optional[Mesh], logical_map: Optional[dict] = None):
        self.mesh = mesh
        if logical_map is None and mesh is not None:
            axes = mesh.axis_names
            logical_map = {
                "batch": tuple(a for a in ("pod", "data") if a in axes) or None,
                "fsdp": "data" if "data" in axes else None,
                "tp": "model" if "model" in axes else None,
                "expert": "model" if "model" in axes else None,
            }
        self.logical_map = logical_map or {}

    def resolve(self, logical: Sequence) -> P:
        """Logical per-dim axis names -> physical ``PartitionSpec``
        (unmapped logical names and ``None`` dims replicate)."""
        phys = []
        for ax in logical:
            if ax is None:
                phys.append(None)
            else:
                m = self.logical_map.get(ax, None)
                phys.append(m)
        return P(*phys)

    def __enter__(self):
        prev = getattr(_ctx, "stack", [])
        _ctx.stack = prev + [self]
        return self

    def __exit__(self, *exc):
        _ctx.stack = _ctx.stack[:-1]
        return False


def current_ctx() -> Optional[ShardCtx]:
    """The innermost active ``ShardCtx`` (``with ShardCtx(mesh): ...``),
    or None when no sharding context is entered — ``shard`` and
    ``unshard_fsdp`` are then no-ops."""
    stack = getattr(_ctx, "stack", [])
    return stack[-1] if stack else None


def _divisible(x, spec: P, mesh: Mesh) -> bool:
    """True if every sharded dim of x divides by its mesh-axis product."""
    for dim, ax in zip(x.shape, spec):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if dim % n != 0:
            return False
    return True


def shard(x, *logical):
    """Constrain activation x to the logical sharding, if a ctx is active.

    Silently relaxes any axis that doesn't divide (e.g. 8 kv-heads over a
    16-way model axis) to replicated — divisibility-safe by construction.
    """
    ctx = current_ctx()
    if ctx is None or ctx.mesh is None:
        return x
    spec = ctx.resolve(logical)
    # relax non-divisible axes
    fixed = []
    for dim, ax in zip(x.shape, spec):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= ctx.mesh.shape[a]
        fixed.append(ax if dim % n == 0 else None)
    spec = P(*fixed)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter sharding rules: (path regex, logical spec per dim)
# Applied to pytree paths like "layers/attn/wq" with array rank awareness.
# Stacked-layer params have a leading L axis -> rule specs are for the
# *trailing* dims; leading unmatched dims are replicated.
# ---------------------------------------------------------------------------
PARAM_RULES = [
    # embeddings (vocab, d) / head (d, vocab): vocab on tp, d replicated —
    # sharding d over fsdp makes the lm_head contraction partial-sum and
    # forces a full-logits all-reduce (measured 4×39.8 GB/step on qwen2).
    (r".*(embed)$", ("tp", None)),
    (r".*(lm_head|output_proj)$", (None, "tp")),
    # attention projections (d_model, heads*hd): rows fsdp, cols tp
    (r".*(wq|wk|wv|wkv_a|wkv_b|wq_a|wq_b|w_cross_k|w_cross_v)$", ("fsdp", "tp")),
    (r".*(wo)$", ("tp", "fsdp")),
    # MoE experts: (E, d, ff) -> experts on tp (expert parallel), rows fsdp
    # (must precede the generic mlp rules: same leaf names, extra E dim)
    (r".*experts/(w_gate|w_up)$", ("expert", "fsdp", None)),
    (r".*experts/(w_down)$", ("expert", None, "fsdp")),
    (r".*router/w$", ("fsdp", None)),
    # mlp
    (r".*(w_gate|w_up)$", ("fsdp", "tp")),
    (r".*(w_down)$", ("tp", "fsdp")),
    # mamba
    (r".*(in_proj)$", ("fsdp", "tp")),
    (r".*(x_proj)$", ("tp", None)),
    (r".*(dt_proj)$", (None, "tp")),
    (r".*(out_proj)$", ("tp", "fsdp")),
    (r".*(a_log2|conv_w)$", ("tp", None)),
    (r".*(a_log|d_skip|conv_b|dt_bias)$", ("tp",)),
    # biases / norms / small vectors: replicate
    (r".*(scale|bias|b_q|b_k|b_v)$", ()),
]


def spec_for_path(path: str, ndim: int, ctx: ShardCtx) -> P:
    """Resolve a parameter pytree path against ``PARAM_RULES``: first
    matching rule wins, rule specs bind to the TRAILING dims (stacked-
    layer leading axes replicate), no match replicates everything."""
    for pat, logical in PARAM_RULES:
        if re.match(pat, path):
            spec = ctx.resolve(logical)
            pads = ndim - len(logical)
            if pads < 0:    # rule longer than rank (e.g. stacked scalar)
                spec = P(*spec[-ndim:]) if ndim else P()
                return spec
            return P(*([None] * pads + list(spec)))
    return P(*([None] * ndim))


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_shardings(params, mesh: Mesh, ctx: Optional[ShardCtx] = None):
    """NamedSharding pytree for a parameter pytree (divisibility-safe)."""
    ctx = ctx or ShardCtx(mesh)

    def one(kp, x):
        spec = spec_for_path(_path_str(kp), len(x.shape), ctx)
        fixed = []
        for dim, ax in zip(x.shape, spec):
            if ax is None:
                fixed.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            fixed.append(ax if dim % n == 0 else None)
        return NamedSharding(mesh, P(*fixed))

    return jax.tree_util.tree_map_with_path(one, params)


def replicated(mesh: Mesh):
    """Fully-replicated ``NamedSharding`` over ``mesh``."""
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Cohort placement: the engine's vmapped cohort step carries clients on the
# leading axis of every stacked pytree (thetas, batches). These helpers
# place that axis on the mesh's client/data axes and replicate the shared
# inputs (ω), so the whole round runs as one SPMD computation.
# ---------------------------------------------------------------------------
def client_axes(mesh: Mesh):
    """Physical mesh axes that carry the client/cohort dimension."""
    return tuple(a for a in ("pod", "data", "clients") if a in mesh.axis_names)


def mesh_client_count(mesh: Mesh) -> int:
    """Total devices along the client/cohort axes."""
    n = 1
    for a in client_axes(mesh):
        n *= mesh.shape[a]
    return n


def align_cohort_chunk(chunk: int, mesh: Optional[Mesh]) -> int:
    """Round ``cohort_chunk`` up to a multiple of the mesh's client-axis
    size so every lax.map chunk shards evenly over the devices (a chunk
    that doesn't divide falls back to replicated placement — wasteful)."""
    if mesh is None or chunk <= 0:
        return chunk
    n = mesh_client_count(mesh)
    return chunk if n <= 1 else -(-chunk // n) * n


def cohort_spec(mesh: Mesh, ndim: int) -> P:
    """PartitionSpec sharding the leading (client) axis over client_axes."""
    axes = client_axes(mesh)
    if ndim == 0 or not axes:
        return P()
    lead = axes if len(axes) > 1 else axes[0]
    return P(lead, *([None] * (ndim - 1)))


def place_cohort(tree, mesh: Mesh):
    """device_put a stacked cohort pytree with the leading client axis on
    the mesh (divisibility-safe: a non-dividing cohort stays replicated)."""
    def one(x):
        spec = cohort_spec(mesh, getattr(x, "ndim", 0))
        if not _divisible(x, spec, mesh):
            spec = P()
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(one, tree)


def place_replicated(tree, mesh: Mesh):
    """device_put a pytree fully replicated over the mesh (ω, shared refs)."""
    sh = NamedSharding(mesh, P())
    return jax.tree.map(lambda x: jax.device_put(x, sh), tree)


def place_buffer_rows(tree, mesh: Mesh):
    """Pin an async delta-buffer row bank (or a flushed row stack) to the
    mesh's client axes — the same rule as arena rows: the leading axis is
    the per-client row axis, so it rides the client axes whenever it
    divides them (pow2 buffer capacities always divide a pow2 mesh) and
    relaxes to replicated otherwise. Alias of ``place_cohort``, named for
    the engine's async surface (``AsyncBuffer.place``)."""
    return place_cohort(tree, mesh)


def place_decode_state(tree, mesh: Mesh):
    """Pin the serving engine's fixed-slot decode state
    (``repro.serve.DecodeSlots``) to the mesh: every leaf's LEADING axis
    is the routed-cluster-group axis, so cluster groups — each a
    personalized model's slot block — spread across the mesh's
    client/data axes while the per-group decode math stays local.
    Divisibility-safe like ``place_cohort`` (a group count that does not
    divide the client-axis device count stays replicated); alias of
    ``place_cohort``, named for the serving surface
    (``serve.ServeEngine(mesh=...)``)."""
    return place_cohort(tree, mesh)


def constrain_cohort(tree, mesh: Optional[Mesh]):
    """Trace-time twin of ``place_cohort``: ``with_sharding_constraint``
    every stacked leaf's LEADING (client) axis onto the mesh's client
    axes, inside a jitted computation.

    This is the constraint the scanned round body places on gathered
    cohort batches and per-cohort model stacks — XLA then partitions the
    vmapped per-client math over the devices and lowers the cross-client
    reductions (weighted means, segment-sums) to per-shard partials plus
    an all-reduce. Divisibility-safe like ``place_cohort``: a leading
    axis that does not divide the client-axis device count keeps the
    leaf replicated (correctness never depends on cohort divisibility);
    ``mesh=None`` is the single-device no-op."""
    if mesh is None or not client_axes(mesh):
        return tree

    def one(x):
        spec = cohort_spec(mesh, getattr(x, "ndim", 0))
        if not _divisible(x, spec, mesh):
            spec = P()
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return jax.tree.map(one, tree)


def mesh_fingerprint(mesh: Optional[Mesh]):
    """Hashable identity of a mesh for compile-cache keys: axis names,
    axis sizes, and the device ids in mesh order — two meshes with the
    same fingerprint lower a ``with_sharding_constraint`` identically,
    two different ones must not share a cached scan."""
    if mesh is None:
        return None
    return (tuple(mesh.axis_names),
            tuple(int(mesh.shape[a]) for a in mesh.axis_names),
            tuple(int(d.id) for d in mesh.devices.flat))


def psum_segments(stacked, weights, segment_ids, num_segments: int,
                  mesh: Mesh):
    """Weighted segment-sum over a client-sharded leading axis, written
    as an EXPLICIT ``shard_map``: each shard reduces its local rows into
    ``num_segments`` partial sums, then one ``psum`` over the client
    axes combines them — the collective form of
    ``bilevel.aggregate_segments``'s dense reduction.

    The GSPMD-constrained engine path lowers to this same shape
    (per-shard ``segment_sum`` + cross-shard all-reduce); this function
    exists so the mesh battery can check the compiled engine against an
    independent hand-written collective (docs/SHARDING.md). Falls back
    to the dense reduction when the leading axis does not divide the
    mesh's client-axis device count."""
    from jax.experimental.shard_map import shard_map

    axes = client_axes(mesh)
    lead = int(np.shape(jax.tree.leaves(stacked)[0])[0])
    n = mesh_client_count(mesh)
    dense = lambda: jax.tree.map(
        lambda x: jax.ops.segment_sum(
            x * weights.reshape((-1,) + (1,) * (x.ndim - 1)),
            segment_ids, num_segments=num_segments), stacked)
    if not axes or n <= 1 or lead % n != 0:
        return dense()
    axis_tag = axes if len(axes) > 1 else axes[0]

    def local(xs, w, seg):
        part = jax.tree.map(
            lambda x: jax.ops.segment_sum(
                x * w.reshape((-1,) + (1,) * (x.ndim - 1)),
                seg, num_segments=num_segments), xs)
        return jax.lax.psum(part, axes)

    spec = P(axis_tag)
    return shard_map(local, mesh=mesh,
                     in_specs=(spec, spec, spec), out_specs=P())(
        stacked, weights, segment_ids)


def unshard_fsdp(tree):
    """ZeRO-3 compute layout: re-constrain a layer's weights with the fsdp
    axis gathered (tp kept). Placed at the top of each layer body, this
    makes XLA emit per-layer weight all-gathers (fwd/bwd) and weight-grad
    reduce-scatters instead of activation-sized partial-sum all-reduces
    (measured 8 GB/layer -> weight-sized on qwen2 train_4k)."""
    ctx = current_ctx()
    if ctx is None or ctx.mesh is None:
        return tree
    ctx2 = ShardCtx(ctx.mesh, {**ctx.logical_map, "fsdp": None})

    def one(kp, x):
        spec = spec_for_path(_path_str(kp), len(x.shape), ctx2)
        fixed = []
        for dim, ax in zip(x.shape, spec):
            if ax is None:
                fixed.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= ctx.mesh.shape[a]
            fixed.append(ax if dim % n == 0 else None)
        return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, P(*fixed)))

    return jax.tree_util.tree_map_with_path(one, tree)
