from repro.checkpoint.ckpt import load_pytree, save_pytree, save_stocfl, load_stocfl  # noqa: F401
