from repro.checkpoint.ckpt import (load_pytree, load_server_state,  # noqa: F401
                                   load_stocfl, save_pytree,
                                   save_server_state, save_stocfl,
                                   wait_pending)
