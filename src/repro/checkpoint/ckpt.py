"""Dependency-free pytree checkpointing (npz + json manifest).

Path-flattened keys ("layers/attn/wq") so checkpoints are stable across
dict-ordering and easy to inspect with np.load. Shard-aware: arrays are
pulled to host with jax.device_get (works for sharded global arrays on a
real mesh — each process writes its addressable shards; single-process
here, so full arrays).

Async writes: every save accepts ``block=False``, which snapshots the
arrays to host SYNCHRONOUSLY (so the checkpoint is a consistent cut no
matter what the caller mutates next) and hands the file I/O to a
single background writer thread — training rounds overlap the disk
stall instead of serializing behind it. ``wait_pending()`` is the
barrier; it re-raises the first writer error. Writes to the same
directory are ordered (one writer thread), so an async manifest never
lands before its arrays.
"""
from __future__ import annotations

import json
import os
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional

import jax
import numpy as np

_WRITER: Optional[ThreadPoolExecutor] = None
_WRITER_LOCK = threading.Lock()
_PENDING: List[Future] = []


def _writer() -> ThreadPoolExecutor:
    global _WRITER
    with _WRITER_LOCK:
        if _WRITER is None:
            _WRITER = ThreadPoolExecutor(max_workers=1,
                                         thread_name_prefix="ckpt-writer")
        return _WRITER


def _submit(fn, *args) -> Future:
    fut = _writer().submit(fn, *args)
    _PENDING.append(fut)
    return fut


def wait_pending() -> None:
    """Block until every async checkpoint write has landed; re-raises the
    first writer failure. Call before reading a checkpoint back, and at
    the end of a run."""
    pending, _PENDING[:] = _PENDING[:], []
    for fut in pending:
        fut.result()


def _np_safe(x):
    """Host array in an npz-portable dtype (npy headers can't describe
    ml_dtypes' bfloat16 — store as lossless f32; ``load_pytree`` casts
    back to the template's dtype)."""
    x = np.asarray(x)
    if str(x.dtype) == "bfloat16":
        return x.astype(np.float32)
    return x


def _flatten(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def save_pytree(path: str, tree, block: bool = True) -> Optional[Future]:
    """``block=False`` snapshots to host now, writes the npz in the
    background; returns the Future (``wait_pending()`` is the barrier)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = {k: _np_safe(v) for k, v in _flatten(jax.device_get(tree)).items()}
    if block:
        np.savez(path, **flat)
        return None
    return _submit(lambda: np.savez(path, **flat))


def load_pytree(path: str, template=None):
    """Without a template, returns the flat {path: array} dict; with one,
    reassembles arrays into the template's structure."""
    data = dict(np.load(path if path.endswith(".npz") else path + ".npz"))
    if template is None:
        return data

    def rebuild(tmpl, prefix=""):
        if isinstance(tmpl, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in tmpl.items()}
        if isinstance(tmpl, (list, tuple)):
            vals = [rebuild(v, f"{prefix}{i}/") for i, v in enumerate(tmpl)]
            return type(tmpl)(vals)
        arr = data[prefix[:-1]]
        # bf16 leaves were stored as lossless f32 (_np_safe): restore
        # the template's dtype so round-trips are bit-exact
        dt = getattr(tmpl, "dtype", None)
        if dt is not None and arr.dtype != dt:
            arr = arr.astype(dt)
        return arr

    return rebuild(template)


# ---------------------------------------------------------------------------
# Engine ServerState checkpoints: the state IS a pytree, so the arrays go
# through save_pytree wholesale; host bookkeeping (partition, rng position,
# history) rides in a json manifest. Restoring reattaches onto a freshly
# engine.init'ed state (which supplies the context + parameter templates)
# and resumes bit-exactly — including the client-sampling rng.
# ---------------------------------------------------------------------------
def save_server_state(dirpath: str, state,
                      block: bool = True) -> Optional[Future]:
    """Checkpoint an ``engine.ServerState`` (any strategy) to a directory.

    Both clustering backends round-trip: the numpy ``ClusterState`` as a
    parent dict + per-client reps npz, the ``DeviceClusters`` pytree as
    its three stacked arrays (``clusters_device.npz``) — bit-exact
    either way. ``block=False`` snapshots everything to host now and
    writes the three files from the background writer thread (returns
    the Future; ``wait_pending()`` to barrier)."""
    from repro.core.device_clustering import DeviceClusters

    os.makedirs(dirpath, exist_ok=True)
    arrays = {"omega": state.omega,
              "models": {str(k): v for k, v in state.models.items()},
              "personal": {str(k): v for k, v in state.personal.items()}}
    flat_arrays = {k: _np_safe(v)
                   for k, v in _flatten(jax.device_get(arrays)).items()}
    device_clusters = isinstance(state.clusters, DeviceClusters)
    manifest = {
        "strategy": state.strategy,
        "round": state.round,
        "rng_state": state.rng_state,
        # device sampling key (rng_backend="device"): raw uint32 words,
        # restored bit-exactly so a resumed run_rounds scan draws the
        # same cohorts as the uninterrupted one
        "rng_key": (None if state.rng_key is None else
                    [int(x) for x in
                     np.asarray(state.rng_key).ravel().tolist()]),
        "sizes": [int(s) for s in state.sizes],
        "left": sorted(int(c) for c in state.left),
        "members": ([list(map(int, m)) for m in state.members]
                    if state.members is not None else None),
        "history": list(state.history),
        "model_keys": sorted(int(k) for k in state.models),
        "personal_keys": sorted(int(k) for k in state.personal),
        "clusters": None if state.clusters is None else {
            "tau": state.clusters.tau,
            "backend": "device" if device_clusters else "numpy",
            "parent": (None if device_clusters else
                       {str(k): int(v)
                        for k, v in state.clusters.uf.parent.items()}),
            "seen": sorted(int(c) for c in state.clusters.seen),
        },
    }
    if device_clusters:
        cluster_file, cluster_arrays = "clusters_device.npz", {
            k: np.asarray(v) for k, v in state.clusters.arrays().items()}
    elif state.clusters is not None:
        cluster_file, cluster_arrays = "reps.npz", {
            str(k): np.asarray(v) for k, v in state.clusters.reps.items()}
    else:
        cluster_file, cluster_arrays = None, None

    # async delta buffer: device row banks to async_buffer.npz, host
    # entry bookkeeping (slots, arrival rounds, seq order, f32 weights)
    # to the manifest — a mid-buffer resume replays bit-exactly
    buf = getattr(state, "buffer", None)
    buffer_arrays = None
    if buf is None:
        manifest["async_buffer"] = None
    else:
        comps = [k for k, v in (("payload", buf.payload), ("aux", buf.aux),
                                ("psi", buf.psi)) if v is not None]
        manifest["async_buffer"] = {
            "capacity": int(buf.capacity),
            "next_seq": int(buf.next_seq),
            "entries": [[int(e.slot), int(e.cid), int(e.dispatch),
                         int(e.arrival), int(e.seq), float(e.weight)]
                        for e in buf.entries],
            "components": comps,
        }
        if comps:
            buffer_arrays = {
                k: _np_safe(v) for k, v in _flatten(jax.device_get(
                    {c: getattr(buf, c) for c in comps})).items()}

    def write():
        np.savez(os.path.join(dirpath, "arrays.npz"), **flat_arrays)
        with open(os.path.join(dirpath, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if cluster_file is not None:
            np.savez(os.path.join(dirpath, cluster_file), **cluster_arrays)
        if buffer_arrays is not None:
            np.savez(os.path.join(dirpath, "async_buffer.npz"),
                     **buffer_arrays)

    if block:
        write()
        return None
    return _submit(write)


def load_server_state(dirpath: str, state):
    """Restore a checkpoint onto a freshly-initialized ``ServerState``.

    ``state`` supplies the context (loss/eval fns, clients, compiled
    updates) and the parameter-shape templates; the returned state carries
    the checkpointed arrays, partition, history, and rng position.

    Mesh-transparent: restored arrays land unplaced and re-place on the
    next scanned span (``engine.run_rounds`` re-pins carries/consts per
    span — a no-op device_put once placed), so a checkpoint saved under
    one mesh resumes under another, or under none. Mid-scan resume
    parity is pinned by ``tests/test_mesh_engine.py``."""
    from repro.core.clustering import ClusterState
    from repro.core.device_clustering import DeviceClusters

    from repro.engine.bank import ClusterBank

    with open(os.path.join(dirpath, "manifest.json")) as f:
        man = json.load(f)
    tmpl = state.ctx.init_params
    template = {"omega": tmpl,
                "models": {str(k): tmpl for k in man["model_keys"]},
                "personal": {str(k): tmpl for k in man["personal_keys"]}}
    arrays = load_pytree(os.path.join(dirpath, "arrays.npz"), template)
    clusters = None
    if man["clusters"] is not None:
        if man["clusters"].get("backend", "numpy") == "device":
            dev = np.load(os.path.join(dirpath, "clusters_device.npz"))
            clusters = DeviceClusters.from_arrays(
                man["clusters"]["tau"], dev["parent"], dev["live"],
                dev["rep"])
        else:
            clusters = ClusterState(man["clusters"]["tau"])
            clusters.uf.parent = {int(k): int(v)
                                  for k, v in man["clusters"]["parent"].items()}
            clusters.seen = set(man["clusters"]["seen"])
            reps_path = os.path.join(dirpath, "reps.npz")
            if os.path.exists(reps_path):
                reps = np.load(reps_path)
                clusters.reps = {int(k): reps[k] for k in reps.files}
    import jax.numpy as jnp

    rng_key = state.rng_key
    if man.get("rng_key") is not None:
        rng_key = jnp.asarray(np.asarray(man["rng_key"], np.uint32))
    # async delta buffer: row templates come from init_params with the
    # checkpointed pow2 capacity as the leading axis (bf16 banks were
    # stored as lossless f32 and cast back); Ψ rows reload raw (always
    # fp32). Pre-async checkpoints carry no "async_buffer" key → None.
    buffer = None
    abm = man.get("async_buffer")
    if abm is not None:
        from repro.engine.async_agg import AsyncBuffer, _Entry
        cap = int(abm["capacity"])
        comps = list(abm["components"])
        rows_tmpl = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(
                (cap,) + tuple(np.shape(x)), np.asarray(x).dtype), tmpl)
        template = {c: (rows_tmpl if c in ("payload", "aux") else None)
                    for c in comps}
        parts = (load_pytree(os.path.join(dirpath, "async_buffer.npz"),
                             template) if comps else {})
        asdev = lambda t: (None if t is None
                           else jax.tree.map(jnp.asarray, t))
        buffer = AsyncBuffer(
            capacity=cap,
            payload=asdev(parts.get("payload")),
            aux=asdev(parts.get("aux")),
            psi=asdev(parts.get("psi")),
            entries=tuple(_Entry(int(s), int(c), int(d), int(a), int(q),
                                 float(w))
                          for s, c, d, a, q, w in abm["entries"]),
            next_seq=int(abm["next_seq"]))
    return state.replace(
        buffer=buffer,
        strategy=man["strategy"], round=man["round"],
        rng_state=man["rng_state"], rng_key=rng_key,
        sizes=tuple(man["sizes"]), left=frozenset(man["left"]),
        omega=arrays["omega"],
        models=ClusterBank.from_dict(
            {int(k): v for k, v in arrays["models"].items()}),
        personal={int(k): v for k, v in arrays["personal"].items()},
        clusters=clusters,
        members=(tuple(tuple(m) for m in man["members"])
                 if man["members"] is not None else None),
        history=tuple(man["history"]))


def save_stocfl(dirpath: str, trainer) -> None:
    """Full StoCFL server state: ω, cluster models, partition, reps."""
    os.makedirs(dirpath, exist_ok=True)
    save_pytree(os.path.join(dirpath, "omega.npz"), trainer.omega)
    for root, model in trainer.models.items():
        save_pytree(os.path.join(dirpath, f"cluster_{root}.npz"), model)
    state = {
        "tau": trainer.state.tau,
        "parent": {str(k): v for k, v in trainer.state.uf.parent.items()},
        "seen": sorted(trainer.state.seen),
        "history": trainer.history,
    }
    with open(os.path.join(dirpath, "state.json"), "w") as f:
        json.dump(state, f)
    np.savez(os.path.join(dirpath, "reps.npz"),
             **{str(k): v for k, v in trainer.state.reps.items()})


def load_stocfl(dirpath: str, trainer) -> None:
    """Restore server state in place (clients/loss_fn stay caller-provided)."""
    trainer.omega = load_pytree(os.path.join(dirpath, "omega.npz"), trainer.init_params)
    with open(os.path.join(dirpath, "state.json")) as f:
        state = json.load(f)
    trainer.state.tau = state["tau"]
    trainer.state.uf.parent = {int(k): int(v) for k, v in state["parent"].items()}
    trainer.state.seen = set(state["seen"])
    trainer.history = state["history"]
    reps = np.load(os.path.join(dirpath, "reps.npz"))
    trainer.state.reps = {int(k): reps[k] for k in reps.files}
    for fn in os.listdir(dirpath):
        if fn.startswith("cluster_") and fn.endswith(".npz"):
            root = int(fn[len("cluster_"):-len(".npz")])
            trainer.models[root] = load_pytree(os.path.join(dirpath, fn), trainer.init_params)
