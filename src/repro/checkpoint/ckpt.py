"""Dependency-free pytree checkpointing (npz + json manifest).

Path-flattened keys ("layers/attn/wq") so checkpoints are stable across
dict-ordering and easy to inspect with np.load. Shard-aware: arrays are
pulled to host with jax.device_get (works for sharded global arrays on a
real mesh — each process writes its addressable shards; single-process
here, so full arrays).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict

import jax
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def save_pytree(path: str, tree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(jax.device_get(tree))
    np.savez(path, **flat)


def load_pytree(path: str, template=None):
    """Without a template, returns the flat {path: array} dict; with one,
    reassembles arrays into the template's structure."""
    data = dict(np.load(path if path.endswith(".npz") else path + ".npz"))
    if template is None:
        return data

    def rebuild(tmpl, prefix=""):
        if isinstance(tmpl, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in tmpl.items()}
        if isinstance(tmpl, (list, tuple)):
            vals = [rebuild(v, f"{prefix}{i}/") for i, v in enumerate(tmpl)]
            return type(tmpl)(vals)
        return data[prefix[:-1]]

    return rebuild(template)


def save_stocfl(dirpath: str, trainer) -> None:
    """Full StoCFL server state: ω, cluster models, partition, reps."""
    os.makedirs(dirpath, exist_ok=True)
    save_pytree(os.path.join(dirpath, "omega.npz"), trainer.omega)
    for root, model in trainer.models.items():
        save_pytree(os.path.join(dirpath, f"cluster_{root}.npz"), model)
    state = {
        "tau": trainer.state.tau,
        "parent": {str(k): v for k, v in trainer.state.uf.parent.items()},
        "seen": sorted(trainer.state.seen),
        "history": trainer.history,
    }
    with open(os.path.join(dirpath, "state.json"), "w") as f:
        json.dump(state, f)
    np.savez(os.path.join(dirpath, "reps.npz"),
             **{str(k): v for k, v in trainer.state.reps.items()})


def load_stocfl(dirpath: str, trainer) -> None:
    """Restore server state in place (clients/loss_fn stay caller-provided)."""
    trainer.omega = load_pytree(os.path.join(dirpath, "omega.npz"), trainer.init_params)
    with open(os.path.join(dirpath, "state.json")) as f:
        state = json.load(f)
    trainer.state.tau = state["tau"]
    trainer.state.uf.parent = {int(k): int(v) for k, v in state["parent"].items()}
    trainer.state.seen = set(state["seen"])
    trainer.history = state["history"]
    reps = np.load(os.path.join(dirpath, "reps.npz"))
    trainer.state.reps = {int(k): reps[k] for k in reps.files}
    for fn in os.listdir(dirpath):
        if fn.startswith("cluster_") and fn.endswith(".npz"):
            root = int(fn[len("cluster_"):-len(".npz")])
            trainer.models[root] = load_pytree(os.path.join(dirpath, fn), trainer.init_params)
