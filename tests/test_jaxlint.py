"""Self-test battery for the ``repro.analysis.jaxlint`` hazard linter.

Per rule (R1–R5): a true positive the rule must flag, a true negative
it must not flag, and a waived positive that stays visible but
annotated.  Plus the waiver/hot-path comment machinery and the
``scripts/lint_jax.py`` CLI contract: a seeded violation fails
``--strict`` (exit 1), a reason-less waiver fails ``--strict``, and the
real tree under ``src/repro`` passes it — the CI gate this repo ships.
"""
import json
import os
import subprocess
import sys
import textwrap

from repro.analysis import jaxlint

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _lint(src, path="src/repro/engine/fake.py"):
    findings, waivers = jaxlint.lint_source(textwrap.dedent(src), path)
    return findings, waivers


def _rules(src, **kw):
    """Rule ids of UNWAIVED findings."""
    findings, _ = _lint(src, **kw)
    return {f.rule for f in findings if not f.waived}


# ------------------------------------------------------------ R1: key reuse
R1_TP = """
    import jax

    def resample(key, shape):
        a = jax.random.normal(key, shape)
        b = jax.random.uniform(key, shape)
        return a + b
"""

R1_TN = """
    import jax

    def resample(key, shape):
        key, sub = jax.random.split(key)
        a = jax.random.normal(sub, shape)
        key, sub = jax.random.split(key)
        b = jax.random.uniform(sub, shape)
        return a + b
"""


def test_r1_flags_key_reuse():
    assert "R1" in _rules(R1_TP)


def test_r1_accepts_split_discipline():
    assert "R1" not in _rules(R1_TN)


def test_r1_waiver_annotates_not_silences():
    src = R1_TP.replace(
        "b = jax.random.uniform(key, shape)",
        "b = jax.random.uniform(key, shape)  "
        "# jaxlint: disable=R1 — correlated draw is intentional here")
    findings, waivers = _lint(src)
    r1 = [f for f in findings if f.rule == "R1"]
    assert r1 and all(f.waived for f in r1)
    assert "intentional" in r1[0].waiver_reason
    assert all(w.used for w in waivers)


# ------------------------------------- R2: host sync reachable from a trace
R2_TP = """
    import jax.numpy as jnp

    def step(carry, xs):
        total = jnp.sum(carry)
        return carry, float(total)
"""

R2_TN = """
    import jax.numpy as jnp

    def summarize(history):
        total = jnp.sum(history)
        return float(total)
"""


def test_r2_flags_sync_in_entry_point():
    assert "R2" in _rules(R2_TP)


def test_r2_ignores_cold_host_helpers():
    assert "R2" not in _rules(R2_TN)


def test_r2_hot_path_marker_opts_in():
    src = """
        import numpy as np

        def assemble(rows):  # jaxlint: hot-path
            return np.asarray(rows)
    """
    assert "R2" in _rules(src)


def test_r2_transitive_reach_through_calls():
    """A helper called from an entry point inherits its traced scope."""
    src = """
        import jax.numpy as jnp

        def _peek(x):
            return float(jnp.max(x))

        def scan_fn(carry, xs):
            return carry, _peek(carry)
    """
    findings, _ = _lint(src)
    assert any(f.rule == "R2" and not f.waived for f in findings)


# ------------------------------------------ R3: Python control flow on trace
R3_TP = """
    def step(carry, xs):
        if carry > 0:
            return carry, None
        return -carry, None
"""

R3_TN = """
    def step(carry, xs, *, debug: bool = False):
        if debug:
            return carry, None
        if xs is None:
            return carry, None
        return -carry, None
"""


def test_r3_flags_branch_on_traced_value():
    assert "R3" in _rules(R3_TP)


def test_r3_accepts_static_predicates():
    assert "R3" not in _rules(R3_TN)


# --------------------------------------------- R4: module-scope jnp compute
def test_r4_flags_module_scope_compute():
    src = """
        import jax.numpy as jnp

        TABLE = jnp.arange(8) * 2
    """
    assert "R4" in _rules(src)


def test_r4_ignores_main_guard_and_functions():
    src = """
        import jax.numpy as jnp

        def table():
            return jnp.arange(8) * 2

        if __name__ == "__main__":
            print(jnp.arange(8))
    """
    assert "R4" not in _rules(src)


# --------------------------------- R5: dtype-widening literals in kernel code
R5_TP = """
    import jax.numpy as jnp

    def scale_kernel(x_ref):
        return x_ref[...] * 1.5
"""

R5_TN = """
    import jax.numpy as jnp

    def scale_kernel(x_ref):
        return x_ref[...] * jnp.float32(1.5)
"""


def test_r5_flags_bare_float_in_kernel_file():
    assert "R5" in _rules(R5_TP, path="src/repro/kernels/fake.py")


def test_r5_accepts_typed_constants():
    assert "R5" not in _rules(R5_TN, path="src/repro/kernels/fake.py")


def test_r5_scoped_to_kernel_files():
    """The same widening literal outside kernel code is not R5's
    business (engine math is float32-dominated but not Pallas-lowered)."""
    assert "R5" not in _rules(R5_TP, path="src/repro/engine/fake.py")


# ------------------------------------------------------- waiver machinery
def test_def_line_waiver_covers_whole_function():
    src = """
        import jax.numpy as jnp

        def step(carry, xs):  # jaxlint: disable=R2 — sync here is test-only
            return carry, float(jnp.sum(carry))
    """
    findings, _ = _lint(src)
    r2 = [f for f in findings if f.rule == "R2"]
    assert r2 and all(f.waived for f in r2)


def test_unused_waivers_are_reported():
    src = """
        def plain():  # jaxlint: disable=R2 — nothing to waive
            return 1
    """
    _, waivers = _lint(src)
    assert len(waivers) == 1 and not waivers[0].used


def test_reasonless_waiver_detected_by_report():
    src = """
        import jax.numpy as jnp

        def step(carry, xs):  # jaxlint: disable=R2
            return carry, float(jnp.sum(carry))
    """
    report = jaxlint.LintReport()
    findings, waivers = _lint(src)
    report.findings += findings
    report.waivers += waivers
    assert not report.errors                     # waived...
    assert report.reasonless_waivers()           # ...but unjustified


def test_report_json_summary():
    findings, waivers = _lint(R2_TP)
    report = jaxlint.LintReport(findings=findings, waivers=waivers)
    doc = report.to_json()
    assert doc["summary"]["errors"] == len(report.errors) > 0
    assert {"findings", "waivers", "summary"} <= set(doc)


def test_rules_registry_documents_all_emitted_rules():
    assert set(jaxlint.RULES) == {"R1", "R2", "R3", "R4", "R5"}


# ------------------------------------------------------------- CLI contract
def _cli(*args, cwd=REPO):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "lint_jax.py"), *args],
        capture_output=True, text=True, cwd=cwd, env=env)


def test_cli_strict_fails_on_seeded_violation(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent(R2_TP))
    proc = _cli(str(bad), "--strict")
    assert proc.returncode == 1
    assert "R2" in proc.stdout


def test_cli_strict_fails_on_reasonless_waiver(tmp_path):
    bad = tmp_path / "waived.py"
    bad.write_text(textwrap.dedent("""
        import jax.numpy as jnp

        def step(carry, xs):  # jaxlint: disable=R2
            return carry, float(jnp.sum(carry))
    """))
    proc = _cli(str(bad), "--strict")
    assert proc.returncode == 1
    assert "justification" in (proc.stdout + proc.stderr).lower()


def test_cli_clean_file_passes_strict(tmp_path):
    ok = tmp_path / "ok.py"
    ok.write_text(textwrap.dedent(R1_TN))
    proc = _cli(str(ok), "--strict")
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_waiver_artifact(tmp_path):
    src = tmp_path / "waived.py"
    src.write_text(textwrap.dedent("""
        import jax.numpy as jnp

        def step(carry, xs):  # jaxlint: disable=R2 — test fixture
            return carry, float(jnp.sum(carry))
    """))
    out = tmp_path / "waivers.json"
    proc = _cli(str(src), "--strict", "--waivers", str(out))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    doc = json.loads(out.read_text())
    assert doc["summary"]["waived"] == 1 and doc["summary"]["errors"] == 0


def test_repo_tree_passes_strict_lint():
    """The shipped gate: ``src/repro`` is lint-clean under --strict,
    every waiver justified."""
    proc = _cli("--strict")
    assert proc.returncode == 0, proc.stdout + proc.stderr
