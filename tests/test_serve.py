"""Serving engine battery: continuous batching must be invisible.

The contract under test — a request served through the fixed-slot
continuous-batching engine gets EXACTLY the tokens the debugged
sequential loop would give it (same route, same greedy decode), for
every token arch family, through slot reuse, staggered finishes and
eviction; routing is computed once per client and cached; and the
decode inner loop never touches the host (``sanitize.no_transfer``).
Run in float32 — greedy argmax ties flip under bfloat16.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine, serve
from repro.analysis import sanitize
from repro.configs import get_config
from repro.data import synthetic_lm_batch
from repro.launch.serve import build_parser, build_server_state
from repro.models import build
from repro.models.registry import serve_cache_specs

P, G, HIST_S, HIST_B = 8, 5, 128, 4
FAMILIES = ["qwen2_1_5b", "falcon_mamba_7b", "zamba2_1_2b"]


@functools.lru_cache(maxsize=None)
def _setup(arch, clusters=2):
    cfg = get_config(arch, smoke=True).with_(dtype="float32")
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    st = engine.init("stocfl", model.loss_fn, model.init(key), [],
                     engine.EngineConfig(tau=0.3, seed=0, project_dim=4096))
    cm = {}
    for k in range(clusters):
        ref = jax.tree.map(jnp.asarray, synthetic_lm_batch(
            cfg, HIST_S, HIST_B, seed=100 + k, domain=k))
        st, cid = engine.join(st, ref)
        cm[st.client_root(cid)] = model.init(jax.random.fold_in(key, k))
    return cfg, model, st.replace(models=cm)


def _hist(cfg, i):
    return jax.tree.map(jnp.asarray, synthetic_lm_batch(
        cfg, HIST_S, HIST_B, seed=1000 + i, domain=i % 2))


def _req(cfg, i, gen=G, plen=P):
    prompt = np.asarray(synthetic_lm_batch(
        cfg, plen, 1, seed=i, domain=i % 2)["tokens"][0], np.int32)
    return serve.Request(rid=i, client_id=f"c{i}", prompt=prompt,
                         gen=gen, history=_hist(cfg, i))


# ===================================================== routing
def test_route_matches_engine_infer():
    cfg, model, st = _setup("qwen2_1_5b")
    router = serve.Router(st)
    for i in range(3):
        h = _hist(cfg, i)
        inf = engine.infer(st, h)
        rt = router.route(f"c{i}", h)
        want = inf["cluster"] if inf["cluster"] is not None else inf["seed_from"]
        assert rt.root == want
        assert rt.accepted == (inf["cluster"] is not None)
        assert rt.similarity == pytest.approx(inf["similarity"], abs=1e-5)


def test_infer_batch_matches_infer():
    cfg, model, st = _setup("qwen2_1_5b")
    hists = [_hist(cfg, i) for i in range(4)]
    batched = engine.infer_batch(st, hists)
    for h, b in zip(hists, batched):
        one = engine.infer(st, h)
        assert b["cluster"] == one["cluster"]
        assert b["seed_from"] == one["seed_from"]
        assert b["similarity"] == pytest.approx(one["similarity"], abs=1e-4)


def test_router_cache_hits():
    cfg, model, st = _setup("qwen2_1_5b")
    router = serve.Router(st)
    first = router.route("c0", _hist(cfg, 0))
    assert (router.hits, router.misses) == (0, 1)
    again = router.route("c0")                    # reconnect: no history
    assert (router.hits, router.misses) == (1, 1)
    assert again == first
    with pytest.raises(ValueError, match="no cached route"):
        router.route("never-seen")


# ===================================================== token parity
@pytest.mark.parametrize("arch", FAMILIES)
def test_batched_matches_sequential(arch):
    """More requests than lanes → admission waves + slot reuse, and
    every request's tokens must equal the sequential loop's."""
    cfg, model, st = _setup(arch)
    eng = serve.ServeEngine(model, st, serve.ServeConfig(
        slots=2, max_len=P + G, max_gen=G))
    reqs = [_req(cfg, i) for i in range(6)]       # 6 reqs, 4 lanes total
    eng.submit_many(reqs)
    res = eng.run()
    assert sorted(res) == [r.rid for r in reqs]

    loop = serve.SequentialLoop(model, st, max_len=P + G, max_gen=G)
    for r in reqs:
        sr = loop.serve(r)
        er = res[r.rid]
        assert er.cluster == sr.cluster
        assert list(er.tokens) == list(sr.tokens), f"rid={r.rid}"
    assert eng.stats()["harvested"] == 6


def test_staggered_gens_and_slot_reuse():
    """Heterogeneous gen budgets finish at different steps; freed lanes
    are re-admitted mid-flight and the late arrivals still match the
    sequential reference."""
    cfg, model, st = _setup("qwen2_1_5b")
    gens = [2, 5, 3, 4, 5, 1]
    eng = serve.ServeEngine(model, st, serve.ServeConfig(
        slots=1, max_len=P + G, max_gen=G))       # 2 lanes total → reuse
    reqs = [serve.Request(rid=i, client_id=f"c{i}",
                          prompt=_req(cfg, i).prompt, gen=g,
                          history=_hist(cfg, i))
            for i, g in enumerate(gens)]
    eng.submit_many(reqs)
    res = eng.run()
    loop = serve.SequentialLoop(model, st, max_len=P + G, max_gen=G)
    for r in reqs:
        sr = loop.serve(r)
        assert len(res[r.rid].tokens) == r.gen
        assert list(res[r.rid].tokens) == list(sr.tokens), f"rid={r.rid}"


# ===================================================== eviction
def test_eviction_partial_output_and_lane_reuse():
    cfg, model, st = _setup("qwen2_1_5b")
    eng = serve.ServeEngine(model, st, serve.ServeConfig(
        slots=1, max_len=P + G, max_gen=G))
    reqs = [_req(cfg, i) for i in range(3)]
    eng.submit_many(reqs)
    eng._admit_all()                               # 2 lanes busy, 1 queued
    eng._decode_burst(2)
    eng.sched.tick(2)
    ev = eng.evict(reqs[0].rid)
    assert ev.evicted and len(ev.tokens) == 3      # prefill tok + 2 steps
    loop = serve.SequentialLoop(model, st, max_len=P + G, max_gen=G)
    ref = loop.serve(reqs[0])
    assert list(ev.tokens) == list(ref.tokens[:3])  # partial = true prefix
    rest = eng.run()                               # freed lane serves rid 2
    assert list(rest[reqs[2].rid].tokens) == list(
        loop.serve(reqs[2]).tokens)

    # evicting a queued request drops it with zero tokens
    eng.reset()
    eng.submit_many([_req(cfg, 10), _req(cfg, 11), _req(cfg, 12)])
    gone = eng.evict(12)
    assert gone.evicted and len(gone.tokens) == 0
    assert sorted(eng.run()) == [10, 11]
    assert eng.evict("unknown") is None


# ===================================================== data plane hygiene
def test_decode_burst_is_transfer_free():
    """The serve inner loop under ``transfer_guard('disallow')`` — no
    implicit host syncs anywhere in the decode data plane."""
    cfg, model, st = _setup("qwen2_1_5b")
    eng = serve.ServeEngine(model, st, serve.ServeConfig(
        slots=2, max_len=P + G, max_gen=G))
    eng.submit_many([_req(cfg, i) for i in range(4)])
    eng._admit_all()
    eng._decode_burst(1)                           # compile outside guard
    with sanitize.no_transfer():
        eng._decode_burst(3)
    assert eng.stats()["decode_steps"] == 4


def test_reset_keeps_compiled_programs():
    cfg, model, st = _setup("qwen2_1_5b")
    eng = serve.ServeEngine(model, st, serve.ServeConfig(
        slots=2, max_len=P + G, max_gen=G))
    warm = [_req(cfg, i) for i in range(4)]
    eng.submit_many(warm)
    eng.run()                                      # pays every compile
    eng.reset()
    again = [serve.Request(rid=100 + r.rid, client_id=r.client_id,
                           prompt=r.prompt, gen=r.gen) for r in warm]
    with sanitize.compile_budget(0):               # identical shapes: none
        eng.submit_many(again)                     # routes from cache
        res = eng.run()
    assert sorted(res) == [100, 101, 102, 103]


def test_gen_one_finishes_at_admission():
    cfg, model, st = _setup("qwen2_1_5b")
    eng = serve.ServeEngine(model, st, serve.ServeConfig(
        slots=2, max_len=P + G, max_gen=G))
    eng.submit_many([_req(cfg, 0, gen=1), _req(cfg, 1, gen=1)])
    res = eng.run()
    assert all(len(r.tokens) == 1 for r in res.values())
    assert eng.stats()["decode_steps"] == 0


# ===================================================== guards & specs
def test_submit_validation():
    cfg, model, st = _setup("qwen2_1_5b")
    eng = serve.ServeEngine(model, st, serve.ServeConfig(
        slots=1, max_len=P + G, max_gen=G))
    with pytest.raises(ValueError, match="gen"):
        eng.submit(_req(cfg, 0, gen=G + 1))
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(_req(cfg, 0, plen=P + G))


def test_sliding_window_guard():
    cfg, model, st = _setup("zamba2_1_2b")
    with pytest.raises(ValueError, match="sliding"):
        serve.ServeEngine(model, st, serve.ServeConfig(
            slots=1, max_len=cfg.sliding_window + 1, max_gen=G))


def test_non_token_arch_rejected():
    cfg = get_config("whisper_medium", smoke=True)
    model = build(cfg)
    _, _, st = _setup("qwen2_1_5b")                # any state will do
    with pytest.raises(ValueError, match="token-LM"):
        serve.ServeEngine(model, st)


@pytest.mark.parametrize("arch", FAMILIES)
def test_serve_cache_specs_shapes(arch):
    """Every leaf gains a leading cluster axis over make_cache(slots,
    max_len); the slot axis stays the cache's own batch axis (axis 1)."""
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    K, Bs, S = 3, 4, 16
    specs = serve_cache_specs(model, K, Bs, S)
    base = jax.eval_shape(lambda: model.make_cache(Bs, S))
    for spec, b in zip(jax.tree.leaves(specs), jax.tree.leaves(base)):
        assert spec.shape == (K,) + tuple(b.shape)
        assert b.shape[1] == Bs


# ===================================================== driver CLI
def test_smoke_flag_is_a_real_pair():
    """--smoke/--full are mutually exclusive with smoke as default —
    the old parser made --smoke a no-op (store_true over default=True
    with no way to detect it was passed)."""
    ap = build_parser()
    assert ap.parse_args([]).smoke is True
    assert ap.parse_args(["--smoke"]).smoke is True
    assert ap.parse_args(["--full"]).smoke is False
    with pytest.raises(SystemExit):
        ap.parse_args(["--smoke", "--full"])


def test_build_server_state_round_trips():
    cfg, model, _ = _setup("qwen2_1_5b")
    st = build_server_state(cfg, model, clusters=2, tau=0.3, seed=0)
    assert len(st.models) == 2
