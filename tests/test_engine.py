"""Engine API tests: strategy parity, pure transitions, checkpoint
round-trips with bitwise-identical trajectories, dynamic membership."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.checkpoint import load_server_state, save_server_state
from repro.core import StoCFL, StoCFLConfig
from repro.core.clustering import ClusterState
from repro.data import rotated
from repro.engine.strategies import merge_cluster_models
from repro.models import simple

TASK = simple.SYNTH_MLP
LOSS = lambda p, b: simple.loss_fn(p, b, TASK)
EVAL = jax.jit(lambda p, b: simple.accuracy(p, b, TASK))


def _fed(n_clients=12, n_per=32, seed=3):
    clients, tc, tests = rotated(n_clusters=2, n_clients=n_clients,
                                 n_per=n_per, seed=seed)
    clients = [jax.tree.map(jnp.asarray, c) for c in clients]
    tests = {k: jax.tree.map(jnp.asarray, v) for k, v in tests.items()}
    return clients, tc, tests


def _params(seed=0):
    return simple.init(jax.random.PRNGKey(seed), TASK)


def _cfg(**kw):
    kw.setdefault("local_steps", 2)
    kw.setdefault("sample_rate", 0.5)
    kw.setdefault("seed", 0)
    return engine.EngineConfig(**kw)


def _leaves_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        if not np.array_equal(np.asarray(x), np.asarray(y)):
            return False
    return True


def test_registry_has_all_methods():
    assert set(engine.list_strategies()) >= {
        "stocfl", "fedavg", "fedprox", "ditto", "ifca", "cfl"}


def test_stocfl_engine_matches_legacy_trainer():
    """Acceptance: engine.run_round ≡ legacy StoCFL.round, same seed."""
    clients, tc, tests = _fed()
    st = engine.init("stocfl", LOSS, _params(), clients, _cfg(), eval_fn=EVAL)
    tr = StoCFL(LOSS, _params(), clients,
                StoCFLConfig(local_steps=2, sample_rate=0.5, seed=0),
                eval_fn=EVAL)
    for _ in range(4):
        ids = tr.sample_clients()             # legacy-surface sampling...
        rec_legacy = tr.round(ids)
        st, rec_engine = engine.run_round(st)  # ...must equal engine sampling
        assert rec_engine == rec_legacy
    assert _leaves_equal(st.omega, tr.omega)
    assert st.models.keys() == tr.models.keys()
    for k in st.models:
        assert _leaves_equal(st.models[k], tr.models[k])
    assert engine.evaluate(st, tests, tc) == tr.evaluate(tests, tc)


@pytest.mark.parametrize("name", ["stocfl", "fedavg", "fedprox", "ditto",
                                  "ifca", "cfl"])
def test_checkpoint_roundtrip_identical_trajectory(tmp_path, name):
    """Run N rounds, checkpoint, restore into a FRESH context, continue —
    the continued trajectory must be bitwise identical to the
    uninterrupted one (sampling rng included)."""
    clients, tc, tests = _fed()
    st = engine.init(name, LOSS, _params(), clients, _cfg(), eval_fn=EVAL)
    for _ in range(2):
        st, _ = engine.run_round(st)
    save_server_state(str(tmp_path / name), st)

    # branch A: continue in-process
    a, recs_a = st, []
    for _ in range(3):
        a, r = engine.run_round(a)
        recs_a.append(r)

    # branch B: fresh context + restore, then continue
    b = engine.init(name, LOSS, _params(), clients, _cfg(), eval_fn=EVAL)
    b = load_server_state(str(tmp_path / name), b)
    assert b.round == st.round and b.history == st.history
    recs_b = []
    for _ in range(3):
        b, r = engine.run_round(b)
        recs_b.append(r)

    assert recs_a == recs_b
    assert _leaves_equal(a.omega, b.omega)
    assert a.models.keys() == b.models.keys()
    for k in a.models:
        assert _leaves_equal(a.models[k], b.models[k])
    assert engine.evaluate(a, tests, tc) == engine.evaluate(b, tests, tc)


def test_run_round_is_pure():
    """Transitions return new state; the input state is untouched."""
    clients, _, _ = _fed()
    st0 = engine.init("stocfl", LOSS, _params(), clients, _cfg())
    before_omega = jax.tree.map(lambda x: np.asarray(x).copy(), st0.omega)
    before_seen = set(st0.clusters.seen)
    before_rng = dict(st0.rng_state)
    st1, _ = engine.run_round(st0)
    assert st1 is not st0
    assert _leaves_equal(st0.omega, before_omega)
    assert st0.clusters.seen == before_seen
    assert st0.models == {}
    assert st0.rng_state == before_rng and st1.rng_state != before_rng
    assert st0.round == 0 and st1.round == 1


def test_leave_keeps_partition_consistent():
    """Regression: a departed client must vanish from the union-find too —
    roots, assignment() and cluster_means() stay mutually consistent, and
    cluster models follow a root change."""
    clients, _, _ = _fed(n_clients=8)
    st = engine.init("stocfl", LOSS, _params(), clients,
                     _cfg(sample_rate=1.0))
    st, _ = engine.run_round(st)
    roots = sorted(st.clusters.clusters())
    victim = roots[0]                      # a cluster ROOT departs
    members = st.clusters.clusters()[victim]
    st = engine.leave(st, victim)

    assert victim not in st.clusters.reps
    assert victim not in st.clusters.uf.parent
    assign = st.clusters.assignment()
    assert victim not in assign
    # every assigned root is a live, observed client
    mean_roots, _ = st.clusters.cluster_means()
    assert set(assign.values()) == set(mean_roots)
    # the cluster survived under its new root, model re-keyed along
    if len(members) > 1:
        new_root = min(m for m in members if m != victim)
        assert new_root in mean_roots
        assert new_root in st.models and victim not in st.models
    # departed clients are never sampled again
    for _ in range(5):
        _, ids = engine.sample_clients(st)
        assert victim not in ids
    st, _ = engine.run_round(st)           # and rounds still run fine


def test_join_then_leave_roundtrip():
    clients, tc, _ = _fed(n_clients=8)
    extra, _, _ = _fed(n_clients=2, seed=11)
    st = engine.init("stocfl", LOSS, _params(), clients, _cfg(sample_rate=1.0))
    st, _ = engine.run_round(st)
    k0 = st.clusters.n_clusters()
    st, cid = engine.join(st, extra[0])
    assert cid == 8 and cid in st.clusters.assignment()
    st = engine.leave(st, cid)
    assert cid not in st.clusters.assignment()
    assert st.clusters.n_clusters() == k0


def test_cfl_join_and_leave_rewrite_partition():
    """Regression: cfl trains on ``members``, so join/leave must rewrite
    the partition — not just the sampling pool."""
    clients, _, _ = _fed(n_clients=6)
    extra, _, _ = _fed(n_clients=2, seed=11)
    st = engine.init("cfl", LOSS, _params(), clients, _cfg())
    st, _ = engine.run_round(st)

    st = engine.leave(st, 2)
    assert all(2 not in g for g in st.members)
    assert sorted(st.models) == list(range(len(st.members)))
    st, rec = engine.run_round(st)
    assert rec["sampled"] == 5                # departed client not trained on

    st, cid = engine.join(st, extra[0])
    assert any(cid in g for g in st.members)  # newcomer actually trains
    st, rec = engine.run_round(st)
    assert rec["sampled"] == 6


def test_nearest_consistent_with_infer():
    rng = np.random.default_rng(0)
    cs = ClusterState(tau=0.9)
    reps = [np.eye(4)[i % 2] + 0.01 * rng.normal(size=4) for i in range(4)]
    cs.observe(range(4), reps)
    cs.merge_round()
    probe = np.eye(4)[0]
    root, near, sim = cs.nearest(probe)
    assert (root, sim) == cs.infer(probe)
    assert near is not None and sim > 0.9 and root == near
    ortho = np.eye(4)[3]
    root2, near2, _ = cs.nearest(ortho)
    assert root2 is None and near2 in cs.clusters()


def test_merge_weights_by_cardinality():
    """Regression: cluster-model merges weight by member count, not 1:1."""
    ones = {"w": jnp.ones((2,))}
    fives = {"w": 5.0 * jnp.ones((2,))}
    merged = merge_cluster_models({0: ones, 7: fives}, [(0, 7)],
                                  {0: 3, 7: 1}, ones)
    np.testing.assert_allclose(np.asarray(merged[0]["w"]),
                               2.0 * np.ones(2), rtol=1e-6)   # (3·1+1·5)/4
    # cascaded merge: counts accumulate
    merged = merge_cluster_models({0: ones, 1: fives, 2: fives},
                                  [(0, 1), (0, 2)], {0: 1, 1: 1, 2: 2}, ones)
    np.testing.assert_allclose(np.asarray(merged[0]["w"]),
                               4.0 * np.ones(2), rtol=1e-6)   # ((1+5)/2·2+2·5)/4


def test_server_state_is_pytree():
    clients, _, _ = _fed(n_clients=4)
    st = engine.init("stocfl", LOSS, _params(), clients, _cfg(sample_rate=1.0))
    st, _ = engine.run_round(st)
    host = jax.device_get(st)              # pulls every model leaf to host
    assert isinstance(host, engine.ServerState)
    assert _leaves_equal(host.omega, st.omega)
    # cluster models are ONE stacked pytree (leading K axis), not K copies:
    # the state's leaf count is omega + one stacked model, regardless of K
    n_leaves = len(jax.tree.leaves(st))
    assert n_leaves == (len(jax.tree.leaves(st.omega))
                        + len(jax.tree.leaves(st.models.stacked)))
    k = len(st.models)
    assert k >= 1
    # rows are pow2-capacity padded (shape-stable under §5 churn): the
    # leading axis is the capacity, with the K occupied rows first
    assert st.models.capacity >= k
    for leaf in jax.tree.leaves(st.models.stacked):
        assert leaf.shape[0] == st.models.capacity
    assert isinstance(host.models, engine.ClusterBank)
    assert host.models.keys() == st.models.keys()


def test_cohort_mesh_placement_matches_host():
    """The mesh-placed cohort step computes the same round as the host path."""
    from repro.launch.mesh import make_cohort_mesh
    clients, _, _ = _fed(n_clients=6)
    mesh = make_cohort_mesh()
    a = engine.init("stocfl", LOSS, _params(), clients, _cfg(sample_rate=1.0))
    b = engine.init("stocfl", LOSS, _params(), clients, _cfg(sample_rate=1.0),
                    mesh=mesh)
    for _ in range(2):
        a, ra = engine.run_round(a)
        b, rb = engine.run_round(b)
        assert ra == rb
    for la, lb in zip(jax.tree.leaves(a.omega), jax.tree.leaves(b.omega)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=1e-6, atol=1e-6)
