"""Distribution extractor Ψ + synthetic federation properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.extractor import make_extractor
from repro.data import (femnist_like, hybrid, make_federation, pathological,
                        rotated, shifted)
from repro.kernels import ref
from repro.models import simple

TASK = simple.SYNTH_MLP
LOSS = lambda p, b: simple.loss_fn(p, b, TASK)


def _psi_matrix(setting, n_clients=24, seed=1, **kw):
    maker = {"rotated": rotated, "shifted": shifted, "pathological": pathological,
             "hybrid": hybrid, "femnist": femnist_like}[setting]
    clients, tc, _ = maker(n_clients=n_clients, seed=seed, **kw)
    params = simple.init(jax.random.PRNGKey(0), TASK)
    ext = make_extractor(LOSS, params)
    reps = np.stack([np.asarray(ext(jax.tree.map(jnp.asarray, c))) for c in clients])
    M = np.asarray(ref.cosine_sim_ref(jnp.asarray(reps)))
    return M, np.array(tc)


def test_psi_unit_norm():
    clients, _, _ = rotated(n_clusters=2, n_clients=4, seed=0)
    params = simple.init(jax.random.PRNGKey(0), TASK)
    ext = make_extractor(LOSS, params)
    rep = np.asarray(ext(jax.tree.map(jnp.asarray, clients[0])))
    assert rep.ndim == 1
    np.testing.assert_allclose(np.linalg.norm(rep), 1.0, atol=1e-5)


def test_psi_projection_preserves_similarity():
    clients, tc, _ = rotated(n_clusters=2, n_clients=12, seed=2)
    params = simple.init(jax.random.PRNGKey(0), TASK)
    full = make_extractor(LOSS, params)
    proj = make_extractor(LOSS, params, project_dim=1024)
    rf = np.stack([np.asarray(full(jax.tree.map(jnp.asarray, c))) for c in clients])
    rp = np.stack([np.asarray(proj(jax.tree.map(jnp.asarray, c))) for c in clients])
    Mf = np.asarray(ref.cosine_sim_ref(jnp.asarray(rf)))
    Mp = np.asarray(ref.cosine_sim_ref(jnp.asarray(rp)))
    iu = np.triu_indices(12, 1)
    corr = np.corrcoef(Mf[iu], Mp[iu])[0, 1]
    assert corr > 0.9                        # JL sketch preserves structure


@pytest.mark.parametrize("setting", ["pathological", "rotated", "shifted", "hybrid"])
def test_within_exceeds_between(setting):
    """Fig. 2's premise: same-distribution clients have higher Ψ cosine."""
    M, tc = _psi_matrix(setting)
    same = M[(tc[:, None] == tc[None, :]) & ~np.eye(len(tc), dtype=bool)]
    diff = M[tc[:, None] != tc[None, :]]
    assert same.mean() > diff.mean() + 0.3
    assert same.min() > diff.max() - 0.2     # near-separable at τ≈0.5


def test_federation_shapes():
    for setting in ["pathological", "rotated", "shifted", "hybrid", "femnist"]:
        clients, tc, tests = make_federation(setting, n_clients=16, seed=0)
        assert len(clients) == len(tc) == 16
        for c in clients:
            assert c["x"].shape[0] == c["y"].shape[0]
            assert c["x"].dtype == np.float32 and c["y"].dtype == np.int32
        for k, b in tests.items():
            assert b["x"].shape[0] == b["y"].shape[0] == 512


def test_shifted_labels_actually_shift():
    clients, tc, _ = shifted(n_clusters=4, n_clients=8, seed=0)
    # same features domain, different label maps: cluster 0 has shift 0
    ys = [set(np.unique(c["y"])) for c in clients]
    assert all(len(y) > 1 for y in ys)


def test_pathological_label_partition():
    clients, tc, _ = pathological(n_clients=8, seed=0)
    groups = [[0, 1, 2], [3, 4], [5, 6], [7, 8, 9]]
    for c, k in zip(clients, tc):
        assert set(np.unique(c["y"])) <= set(groups[k])
