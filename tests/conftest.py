import os

# Smoke tests and benches must see the single real CPU device — the 512
# placeholder devices are ONLY for launch/dryrun.py (which sets XLA_FLAGS
# itself before any import). Keep any inherited flag from leaking in.
os.environ.pop("XLA_FLAGS", None)

# Opt-in multi-device lane: REPRO_FORCE_HOST_DEVICES=N splits the host
# CPU into N real XLA devices (the mesh parity battery in
# test_mesh_engine.py runs under N=8 in CI). Must be translated into
# XLA_FLAGS before jax is first imported — it is ignored afterwards.
_force = os.environ.pop("REPRO_FORCE_HOST_DEVICES", "")
if _force:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={int(_force)}")

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")
