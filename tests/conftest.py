import os

# Smoke tests and benches must see the single real CPU device — the 512
# placeholder devices are ONLY for launch/dryrun.py (which sets XLA_FLAGS
# itself before any import). Keep any inherited flag from leaking in.
os.environ.pop("XLA_FLAGS", None)

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")
