"""Prefill→decode consistency: decoding token t against the prefix cache
must reproduce the teacher-forced logits at position t (per arch family).
Run in float32 for tight tolerances."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.data import synthetic_lm_batch
from repro.models import build
from repro.models.registry import grow_cache

S, B = 24, 2


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_teacher_forcing(arch):
    cfg = get_config(arch, smoke=True).with_(dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = jax.tree.map(jnp.asarray, synthetic_lm_batch(cfg, S, B, seed=3))
    tokens = batch["tokens"]

    # teacher-forced logits at every position
    logits_all, _ = jax.jit(model.forward_train)(params, batch)

    # prefill on the first S-1 tokens, then decode token S-1
    pre_batch = dict(batch)
    pre_batch["tokens"] = tokens[:, : S - 1]
    logits_pre, cache = jax.jit(model.prefill)(params, pre_batch)
    np.testing.assert_allclose(
        np.asarray(logits_pre), np.asarray(logits_all[:, S - 2]), atol=2e-3, rtol=2e-3)

    cache = grow_cache(model, cache, B, S)
    logits_dec, _ = jax.jit(model.decode)(params, tokens[:, S - 1], cache, jnp.int32(S - 1))
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_all[:, S - 1]), atol=2e-3, rtol=2e-3)
