"""Scan-vs-eager parity battery for the fully-jitted round loop.

``engine.run_rounds`` collapses N rounds into one ``lax.scan`` with
on-device cohort sampling; the eager ``run_round`` loop is the
reference. These tests pin the strong claim: for every registered
strategy the scanned loop is BITWISE equal to the eager one — final ω,
every cluster-bank row, the partition, the per-round metric history and
the advanced PRNG key — over multi-round runs, across churn boundaries
(join/leave between scans), and through a checkpoint save/resume in the
middle of a scanned run. Plus seeded sampler checks and the
skipped-round semantics of an all-unavailable pool (the randomized
hypothesis sweep of the sampler lives in
``tests/test_sampler_properties.py``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.checkpoint import load_server_state, save_server_state
from repro.data import rotated
from repro.engine import sampler
from repro.models import simple

TASK = simple.SYNTH_MLP
LOSS = lambda p, b: simple.loss_fn(p, b, TASK)
EVAL = jax.jit(lambda p, b: simple.accuracy(p, b, TASK))

ALL = ["stocfl", "fedavg", "fedprox", "ditto", "ifca", "cfl"]


def _fed(n_clients=12, n_per=32, seed=3):
    clients, tc, tests = rotated(n_clusters=2, n_clients=n_clients,
                                 n_per=n_per, seed=seed)
    clients = [jax.tree.map(jnp.asarray, c) for c in clients]
    tests = {k: jax.tree.map(jnp.asarray, v) for k, v in tests.items()}
    return clients, tc, tests


def _params(seed=0):
    return simple.init(jax.random.PRNGKey(seed), TASK)


def _cfg(name, **kw):
    kw.setdefault("local_steps", 2)
    kw.setdefault("sample_rate", 0.5)
    kw.setdefault("seed", 0)
    kw.setdefault("rng_backend", "device")
    if name == "stocfl":
        kw.setdefault("cluster_backend", "device")
    if name == "cfl":
        kw["sample_rate"] = 1.0
        # thresholds that actually exercise splits on the fixture
        kw.setdefault("eps_rel", 0.9)
        kw.setdefault("eps2", 1e-4)
    return engine.EngineConfig(**kw)


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(la) != len(lb):
        return False
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


def _assert_states_bitwise(a, b):
    """The battery's definition of 'equal': params, bank rows,
    partition, personal models, history (metrics incl.), round counter
    and PRNG key — all exactly equal."""
    assert _leaves_equal(a.omega, b.omega), "omega diverged"
    assert set(a.models.keys()) == set(b.models.keys()), "bank keys diverged"
    for k in a.models:
        assert _leaves_equal(a.models[k], b.models[k]), f"bank row {k} diverged"
    assert set(a.personal) == set(b.personal)
    for k in a.personal:
        assert _leaves_equal(a.personal[k], b.personal[k])
    if a.clusters is not None:
        assert a.clusters.assignment() == b.clusters.assignment(), \
            "partition diverged"
        assert sorted(a.clusters.seen) == sorted(b.clusters.seen)
        for c in a.clusters.seen:
            assert np.array_equal(np.asarray(a.clusters.reps[c]),
                                  np.asarray(b.clusters.reps[c])), \
                f"Ψ rep of client {c} diverged"
    assert a.members == b.members
    assert a.round == b.round
    assert a.history == b.history, "metric history diverged"
    assert a.left == b.left
    if a.rng_key is not None or b.rng_key is not None:
        assert np.array_equal(np.asarray(a.rng_key), np.asarray(b.rng_key)), \
            "PRNG key diverged (draw sequences would fork)"


def _init(name, clients, **kw):
    return engine.init(name, LOSS, _params(), clients, _cfg(name, **kw),
                       eval_fn=EVAL, arena=True)


# ================================================== core parity battery
@pytest.mark.parametrize("name", ALL)
def test_scan_equals_eager_five_rounds(name):
    """run_rounds(state, 5) ≡ 5 × run_round, bitwise, per strategy."""
    clients, tc, tests = _fed()
    a = _init(name, clients)
    b = _init(name, clients)
    for _ in range(5):
        a, _ = engine.run_round(a)
    b = engine.run_rounds(b, 5)
    _assert_states_bitwise(a, b)
    # and the evaluation protocol sees the same server
    assert engine.evaluate(a, tests, tc) == engine.evaluate(b, tests, tc)


def test_scan_equals_eager_ragged_arena_stocfl():
    """RAGGED arena (one shard shorter than n_max): the eager StoCFL
    round extracts Ψ from the same padded+masked arena row the scan
    uses, so the rep bank, the partition, and everything downstream
    stay bitwise equal between the two loops."""
    clients, _, _ = _fed()
    clients = list(clients)
    clients[0] = jax.tree.map(lambda x: x[:17], clients[0])
    a = _init("stocfl", clients)
    assert a.ctx.arena.ragged
    b = _init("stocfl", clients)
    for _ in range(5):
        a, _ = engine.run_round(a)
    b = engine.run_rounds(b, 5)
    _assert_states_bitwise(a, b)


@pytest.mark.parametrize("name", ALL)
def test_scan_splits_compose(name):
    """run_rounds(2) then run_rounds(3) ≡ run_rounds(5): the carry
    round-trips through ServerState without loss."""
    clients, _, _ = _fed()
    a = _init(name, clients)
    b = _init(name, clients)
    a = engine.run_rounds(a, 5)
    b = engine.run_rounds(engine.run_rounds(b, 2), 3)
    _assert_states_bitwise(a, b)


@pytest.mark.parametrize("name", ALL)
def test_scan_parity_across_churn_boundary(name):
    """Scan 2 rounds, join one client + retire one, scan 3 more — vs the
    same sequence run eagerly. Churn happens BETWEEN scans (the
    simulator's event-free-span contract) and the trajectories must
    stay bitwise equal through it."""
    clients, _, _ = _fed()
    extra, _, _ = _fed(n_clients=2, seed=11)

    def drive(runner):
        st = _init(name, clients)
        st = runner(st, 2)
        st, _cid = engine.join(st, extra[0])
        st = engine.leave(st, 3)
        return runner(st, 3)

    def eager(st, n):
        for _ in range(n):
            st, _ = engine.run_round(st)
        return st

    def scanned(st, n):
        return engine.run_rounds(st, n)

    _assert_states_bitwise(drive(eager), drive(scanned))


@pytest.mark.parametrize("name", ["stocfl", "ditto"])
def test_scan_checkpoint_resume_mid_run(tmp_path, name):
    """Scan 2 rounds, checkpoint, restore into a FRESH context, scan 3
    more — bitwise equal to the uninterrupted 5-round scan AND to the
    eager 5-round loop (device PRNG key round-trips through the
    manifest)."""
    clients, _, _ = _fed()
    a = _init(name, clients)
    a = engine.run_rounds(a, 2)
    save_server_state(str(tmp_path / name), a)

    b = _init(name, clients)
    b = load_server_state(str(tmp_path / name), b)
    assert np.array_equal(np.asarray(b.rng_key), np.asarray(a.rng_key))
    b = engine.run_rounds(b, 3)

    c = engine.run_rounds(_init(name, clients), 5)
    d = _init(name, clients)
    for _ in range(5):
        d, _ = engine.run_round(d)
    _assert_states_bitwise(b, c)
    _assert_states_bitwise(b, d)


def test_scan_spans_in_simulator_match_eager():
    """simulate(scan_spans=True) ≡ simulate(scan_spans=False) bitwise:
    event-free spans compile to scanned segments, churn rounds stay
    eager, the trajectory (incl. history) is unchanged."""
    from repro.sim import Timeline, simulate
    from repro.sim.events import Join, Leave

    clients, _, _ = _fed()
    extra, _, _ = _fed(n_clients=2, seed=11)

    def run(scan_spans):
        tl = Timeline([Join(t=3, batch=extra[0], cluster=0),
                       Leave(t=6, cid=2)])
        st = _init("stocfl", clients)
        st, log = simulate(st, tl, rounds=10, seed=0,
                           scan_spans=scan_spans)
        return st, log

    a, log_a = run(False)
    b, log_b = run(True)
    _assert_states_bitwise(a, b)
    assert any(r.get("scanned") for r in log_b.records), \
        "scan_spans=True never actually scanned a span"
    assert not any(r.get("scanned") for r in log_a.records)
    # the per-round log agrees on everything but wall times / markers
    for ra, rb in zip(log_a.records, log_b.records):
        for key in ("t", "n_registered", "n_live", "cohort", "skipped"):
            assert ra[key] == rb[key], (key, ra, rb)


# ============================================== skipped-round semantics
def test_all_unavailable_rounds_are_skipped_noops():
    """Empty pool: eager run_round raises a readable ValueError; the
    scanned loop (which cannot raise mid-trace) records skipped no-op
    rounds instead — params untouched, history advanced."""
    clients, _, _ = _fed()
    st = _init("fedavg", clients)
    everyone = frozenset(range(st.n_clients))
    with pytest.raises(ValueError, match="non-empty cohort"):
        ids = np.zeros(0, np.int64)
        engine.run_round(st, ids)
    out = engine.run_rounds(st, 3, unavailable=everyone)
    assert out.round == st.round + 3
    assert out.history[-3:] == ({"skipped": True, "sampled": 0},) * 3
    assert _leaves_equal(out.omega, st.omega)


def test_full_participation_ignores_unavailable():
    """Availability does not apply to full participation (CFL trains
    its whole partition — the simulator's rule): an 'everyone
    unavailable' scan still trains every live client, bitwise equal to
    the eager loop, instead of no-op'ing."""
    clients, _, _ = _fed()
    a = _init("cfl", clients)
    b = _init("cfl", clients)
    for _ in range(3):
        a, _ = engine.run_round(a)
    b = engine.run_rounds(b, 3, unavailable=frozenset(range(len(clients))))
    _assert_states_bitwise(a, b)


def test_scan_cache_respects_ragged_flip():
    """An arena that turns ragged WITHOUT changing buffer shapes
    (``arena.update`` with a shorter shard) must not reuse the maskless
    compiled scan — the cache is keyed on trace-baked statics, so the
    post-flip scan stays bitwise equal to the eager loop."""
    clients, _, _ = _fed()
    a = _init("fedavg", clients)
    b = _init("fedavg", clients)
    a = engine.run_rounds(a, 2)                     # compiles maskless
    for _ in range(2):
        b, _ = engine.run_round(b)
    shorter = jax.tree.map(lambda x: x[:16], clients[0])
    a.ctx.arena = a.ctx.arena.update(0, shorter)
    b.ctx.arena = b.ctx.arena.update(0, shorter)
    a.ctx.clients[0] = shorter
    b.ctx.clients[0] = shorter
    sizes = tuple(16 if i == 0 else s for i, s in enumerate(a.sizes))
    a, b = a.replace(sizes=sizes), b.replace(sizes=sizes)
    assert a.ctx.arena.ragged
    a = engine.run_rounds(a, 3)
    for _ in range(3):
        b, _ = engine.run_round(b)
    _assert_states_bitwise(a, b)


def test_scan_preconditions_raise_eagerly():
    """Missing arena / host rng / host partition fail with a host-side
    ValueError naming the fix, not an opaque trace error."""
    clients, _, _ = _fed()
    no_arena = engine.init("fedavg", LOSS, _params(), clients,
                           _cfg("fedavg"))
    with pytest.raises(ValueError, match="arena"):
        engine.run_rounds(no_arena, 2)
    host_rng = engine.init("fedavg", LOSS, _params(), clients,
                           _cfg("fedavg", rng_backend="numpy"), arena=True)
    with pytest.raises(ValueError, match="rng_backend"):
        engine.run_rounds(host_rng, 2)
    host_part = engine.init(
        "stocfl", LOSS, _params(), clients,
        _cfg("stocfl", cluster_backend="numpy"), arena=True)
    with pytest.raises(ValueError, match="cluster_backend"):
        engine.run_rounds(host_part, 2)


# =============================================== device sampler (seeded)
@pytest.mark.parametrize("seed", [0, 7, 1234])
def test_sampler_seeded_sweep(seed):
    """Seeded sweep of the on-device draw: no duplicates, size =
    ⌈rate·live⌉ (clipped to the pool), departed/unavailable never
    drawn. (The randomized version hypothesis-sweeps the same claims in
    test_sampler_properties.py.)"""
    rng = np.random.default_rng(seed)
    for _ in range(20):
        n = int(rng.integers(2, 64))
        rate = float(rng.uniform(0.05, 1.0))
        left = set(rng.choice(n, rng.integers(0, n), replace=False).tolist())
        avail = sorted(set(range(n)) - left)
        busy = set(rng.choice(avail, rng.integers(0, len(avail)),
                              replace=False).tolist()) if len(avail) > 1 else set()
        pool = sampler.cohort_pool(n, left, busy)
        live = n - len(left)
        m = sampler.cohort_size(rate, live, int(pool.sum()))
        assert m == min(int(np.ceil(rate * live)), int(pool.sum())) \
            or (int(pool.sum()) == 0 and m == 0)
        if m == 0:
            continue
        key = jax.random.PRNGKey(int(rng.integers(2**31)))
        _, ids = sampler.draw_cohort(key, pool, m)
        ids = set(np.asarray(ids).tolist())
        assert len(ids) == m, "duplicate draw"
        assert not (ids & left), "drew a departed client"
        assert not (ids & busy), "drew an unavailable client"


def test_sampler_deterministic_from_key():
    """Identical key -> identical draw sequence (and the advanced keys
    chain identically), on every call."""
    pool = sampler.cohort_pool(16, {1, 5}, {2})
    for seed in (0, 3, 99):
        k1 = k2 = jax.random.PRNGKey(seed)
        for _ in range(3):
            k1, a = sampler.draw_cohort(k1, pool, 4)
            k2, b = sampler.draw_cohort(k2, pool, 4)
            assert np.array_equal(np.asarray(a), np.asarray(b))
            assert np.array_equal(np.asarray(k1), np.asarray(k2))


def test_numpy_backend_unchanged_by_default():
    """rng_backend defaults to the numpy compatibility mode: states
    carry no device key and sampling still advances the bit-generator
    (pre-scan checkpoints and the legacy parity tests depend on it)."""
    clients, _, _ = _fed()
    st = engine.init("fedavg", LOSS, _params(), clients,
                     engine.EngineConfig(sample_rate=0.5, local_steps=1))
    assert st.rng_key is None
    before = dict(st.rng_state)
    st2, _ = engine.run_round(st)
    assert st2.rng_state != before and st2.rng_key is None
