"""Hypothesis properties for the device clustering core (CI runs these
with the ``[test]`` extra; ``tests/test_device_clustering.py`` carries
deterministic seeded slices of the same invariants for extra-less
environments).

  * device union-find root resolution ≡ numpy ``UnionFind`` under ANY
    union sequence (the satellite's random-union property);
  * observe → merge_round partition ≡ the numpy scan for any group
    layout, in any observation order.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the test extra
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.clustering import ClusterState, UnionFind
from repro.core import device_clustering as dc
from repro.core.device_clustering import DeviceClusters


def _unit_reps(labels, seed=0, d=12, noise=0.02):
    rng = np.random.default_rng(seed)
    anchors = rng.normal(size=(max(labels) + 1, d))
    anchors /= np.linalg.norm(anchors, axis=1, keepdims=True)
    out = []
    for g in labels:
        v = anchors[g] + rng.normal(size=d) * noise
        out.append((v / np.linalg.norm(v)).astype(np.float32))
    return out


@settings(max_examples=40, deadline=None, derandomize=True)
@given(st.lists(st.tuples(st.integers(0, 15), st.integers(0, 15)),
                max_size=40))
def test_device_unionfind_matches_numpy(edges):
    """After ANY union sequence, pointer-halving resolution of the
    device parent array equals ``UnionFind.find`` for every id."""
    uf = UnionFind()
    for i in range(16):
        uf.add(i)
    state = dc.init_state(16, 2)
    state = dc.observe(state, jnp.arange(16, dtype=jnp.int32),
                       jnp.zeros((16, 2), jnp.float32))
    for a, b in edges:
        uf.union(a, b)
        state = dc._jit_union()(state, jnp.int32(a), jnp.int32(b))
    from repro.kernels import ops
    roots = np.asarray(ops.resolve_roots(state.parent))
    for i in range(16):
        assert int(roots[i]) == uf.find(i)


@settings(max_examples=20, deadline=None, derandomize=True)
@given(st.lists(st.integers(0, 3), min_size=2, max_size=24),
       st.integers(0, 50), st.integers(0, 10_000))
def test_merge_partition_matches_numpy_any_order(labels, seed,
                                                 shuffle_seed):
    """Observing the same clients in any order: the device partition
    equals the numpy partition (both are the τ-graph's transitive
    closure, so only the rep SET matters)."""
    reps = _unit_reps(labels, seed)
    perm = list(range(len(labels)))
    np.random.default_rng(shuffle_seed).shuffle(perm)
    a = ClusterState(tau=0.8)
    b = DeviceClusters(tau=0.8, capacity=len(labels))
    a.observe(range(len(labels)), reps)
    b.observe(perm, [reps[i] for i in perm])
    a.merge_round()
    b.merge_round()
    assert frozenset(frozenset(m) for m in a.clusters().values()) == \
        frozenset(frozenset(m) for m in b.clusters().values())
