"""Unit + property tests for stochastic federated client clustering."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the test extra
from hypothesis import given, settings, strategies as st

from repro.core.clustering import ClusterState, UnionFind, adjusted_rand_index


def _reps(groups, d=16, noise=0.01, seed=0):
    """Synthetic Ψ vectors: unit vectors near per-group anchors."""
    rng = np.random.default_rng(seed)
    anchors = rng.normal(size=(max(groups) + 1, d))
    anchors /= np.linalg.norm(anchors, axis=1, keepdims=True)
    out = []
    for g in groups:
        v = anchors[g] + rng.normal(size=d) * noise
        out.append(v / np.linalg.norm(v))
    return out


def test_union_find_transitive():
    uf = UnionFind()
    for i in range(5):
        uf.add(i)
    uf.union(0, 1)
    uf.union(1, 2)
    assert uf.find(2) == uf.find(0) == 0      # smallest id wins
    assert uf.find(3) == 3


def test_merge_recovers_groups():
    groups = [0, 0, 1, 1, 2, 2, 0]
    st_ = ClusterState(tau=0.8)
    st_.observe(range(len(groups)), _reps(groups))
    st_.merge_round()
    assign = st_.assignment()
    ari = adjusted_rand_index([assign[i] for i in range(len(groups))], groups)
    assert ari == 1.0
    assert st_.n_clusters() == 3


def test_tau_one_never_merges():
    """τ=1 ⇒ personalized regime (paper §3.4: Ditto)."""
    groups = [0, 0, 0, 0]
    st_ = ClusterState(tau=1.0000001)
    st_.observe(range(4), _reps(groups))
    st_.merge_round()
    assert st_.n_clusters() == 4


def test_tau_minus_one_merges_all():
    """τ=−1 ⇒ global regime (paper §3.4: FedProx/FedAvg)."""
    groups = [0, 1, 2, 3]
    st_ = ClusterState(tau=-1.0)
    st_.observe(range(4), _reps(groups, noise=0.5))
    st_.merge_round()
    assert st_.n_clusters() == 1


def test_objective_decreases_with_merges():
    """Eq. 2 objective shrinks as similar clusters merge."""
    groups = [0, 0, 1, 1]
    st_ = ClusterState(tau=0.9)
    st_.observe(range(4), _reps(groups))
    before = st_.objective()
    st_.merge_round()
    after = st_.objective()
    assert after <= before


def test_streaming_observation_partial_participation():
    """Clients arriving over rounds end in the same partition as all-at-once."""
    groups = [0, 1, 0, 1, 0, 1, 0, 1]
    reps = _reps(groups, seed=3)
    st_all = ClusterState(tau=0.8)
    st_all.observe(range(8), reps)
    st_all.merge_round()

    st_stream = ClusterState(tau=0.8)
    for start in range(0, 8, 2):          # 25% participation per round
        st_stream.observe(range(start, start + 2), reps[start:start + 2])
        st_stream.merge_round()
    a1, a2 = st_all.assignment(), st_stream.assignment()
    ari = adjusted_rand_index([a1[i] for i in range(8)], [a2[i] for i in range(8)])
    assert ari == 1.0


def test_infer_new_client():
    groups = [0, 0, 1, 1]
    reps = _reps(groups + [0, 1], seed=5)
    st_ = ClusterState(tau=0.8)
    st_.observe(range(4), reps[:4])
    st_.merge_round()
    root0, sim0 = st_.infer(reps[4])      # near group 0
    assert root0 is not None and sim0 >= 0.8
    assert st_.uf.find(0) == root0
    far = np.ones(16) / 4.0               # unrelated direction
    root_new, _ = st_.infer(far / np.linalg.norm(far))
    assert root_new is None               # opens a new cluster


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 3), min_size=2, max_size=30))
def test_merge_idempotent(groups):
    """Running merge_round twice with no new observations is a no-op."""
    st_ = ClusterState(tau=0.8)
    st_.observe(range(len(groups)), _reps(groups, seed=7))
    st_.merge_round()
    k1 = st_.n_clusters()
    merges = st_.merge_round()
    assert merges == [] or st_.n_clusters() <= k1
    st_.merge_round()
    assert st_.n_clusters() == st_.n_clusters()


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 20), st.integers(0, 1000))
def test_ari_identity_and_permutation(n, seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 3, size=n)
    assert adjusted_rand_index(labels, labels) == pytest.approx(1.0)
    perm = (labels + 1) % 3               # relabeled partition, same structure
    assert adjusted_rand_index(labels, perm) == pytest.approx(1.0)
