"""Fused flat-step battery: the prox kernel's padding boundaries, the
flatten-once adapter, and the ``fused_step`` engine knob.

Three layers, matching the dispatch chain:

  kernel    ``kernels.prox_update.prox_update_flat`` (Pallas, interpret
            mode off-TPU) against the pure-jnp oracle at every padding
            boundary n ∈ {0, 1, block−1, block, block+1} — the aligned
            sizes take the no-copy fast path, the misaligned ones the
            append-pad path, and both must match the oracle exactly.
  adapter   ``bilevel.make_client_update(fused=True)`` /
            ``bilevel.local_sgd(fused=True)`` are BITWISE equal to the
            per-leaf tree path in fp32 (same f32-accumulate expression
            tree, flatten/unflatten is a pure permutation).
  engine    a federation run with ``EngineConfig(fused_step=True)``
            reproduces the unfused trajectory bitwise (fp32) for every
            strategy, eager and scanned.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core import bilevel
from repro.kernels import ops
from repro.kernels.prox_update import prox_update_flat as prox_pallas
from repro.models import simple

TASK = simple.SYNTH_MLP
LOSS = lambda p, b: simple.loss_fn(p, b, TASK)

ALL = ["stocfl", "fedavg", "fedprox", "ditto", "ifca", "cfl"]
BLOCK = 8


def _vecs(n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return tuple(jax.random.normal(k, (n,), jnp.float32) for k in ks)


@pytest.mark.parametrize("n", [0, 1, BLOCK - 1, BLOCK, BLOCK + 1,
                               3 * BLOCK, 3 * BLOCK + 2])
def test_prox_kernel_matches_oracle_at_padding_boundaries(n):
    th, om, gt, go = _vecs(n)
    eta, lam = 0.1, 0.05
    want = ops.prox_update_flat(th, om, gt, go, eta, lam, backend="jnp")
    got = prox_pallas(th, om, gt, go, eta, lam, block=BLOCK,
                      interpret=True, donate=False)
    for w, g in zip(want, got):
        assert g.shape == (n,)
        # kernel and oracle are separate XLA programs — FMA contraction
        # may differ by an ulp; bitwise identity is only claimed for the
        # jnp-oracle hot path (adapter tests below)
        np.testing.assert_allclose(np.asarray(w), np.asarray(g),
                                   rtol=1e-6, atol=1e-7)


def test_prox_kernel_empty_is_identity():
    th, om, gt, go = _vecs(0)
    t2, o2 = prox_pallas(th, om, gt, go, 0.1, 0.05, block=BLOCK,
                         interpret=True, donate=False)
    assert t2.shape == (0,) and o2.shape == (0,)


def test_prox_oracle_matches_tree_leafwise():
    # the flat oracle is the tree formula on the concatenated vector
    params = simple.init(jax.random.PRNGKey(1), TASK)
    ref = simple.init(jax.random.PRNGKey(2), TASK)
    gt = jax.tree.map(lambda x: x + 0.3, params)
    go = jax.tree.map(lambda x: x - 0.1, ref)
    spec = bilevel.flat_spec(params)
    th_t, om_t = ops.prox_update_tree(params, ref, gt, go, 0.1, 0.05,
                                      backend="jnp")
    th_f, om_f = ops.prox_update_flat(
        bilevel.flatten_tree(params), bilevel.flatten_tree(ref),
        bilevel.flatten_tree(gt), bilevel.flatten_tree(go), 0.1, 0.05,
        backend="jnp")
    for a, b in zip(jax.tree.leaves(th_t),
                    jax.tree.leaves(bilevel.unflatten_tree(th_f, spec))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(om_t),
                    jax.tree.leaves(bilevel.unflatten_tree(om_f, spec))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_flatten_roundtrip_mixed_dtypes():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": jnp.ones((4,), jnp.bfloat16),
            "c": jnp.float32(2.5).reshape(())}
    spec = bilevel.flat_spec(tree)
    back = bilevel.unflatten_tree(bilevel.flatten_tree(tree), spec)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def _batch(seed=0, n=16):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {"x": jax.random.normal(k1, (n, 64)),
            "y": jax.random.randint(k2, (n,), 0, 10)}


def _tree_eq(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_fused_client_update_bitwise_fp32():
    theta = simple.init(jax.random.PRNGKey(3), TASK)
    omega = simple.init(jax.random.PRNGKey(4), TASK)
    batch = _batch()
    plain = bilevel.make_client_update(LOSS, 0.1, 0.05, local_steps=3,
                                       backend="jnp")
    fused = bilevel.make_client_update(LOSS, 0.1, 0.05, local_steps=3,
                                       backend="jnp", fused=True)
    th_p, om_p = jax.jit(plain)(theta, omega, batch)
    th_f, om_f = jax.jit(fused)(theta, omega, batch)
    _tree_eq(th_p, th_f)
    _tree_eq(om_p, om_f)


def test_fused_client_update_bitwise_under_vmap():
    # the adapter captures per-client (unbatched) shapes at trace time
    keys = jax.random.split(jax.random.PRNGKey(5), 4)
    thetas = jax.vmap(lambda k: simple.init(k, TASK))(keys)
    omega = simple.init(jax.random.PRNGKey(6), TASK)
    batches = jax.tree.map(
        lambda *xs: jnp.stack(xs), *[_batch(seed=i) for i in range(4)])
    plain = bilevel.make_cohort_update(LOSS, 0.1, 0.05, local_steps=2,
                                       backend="jnp")(thetas, omega, batches)
    fused = bilevel.make_cohort_update(LOSS, 0.1, 0.05, local_steps=2,
                                       backend="jnp",
                                       fused=True)(thetas, omega, batches)
    _tree_eq(plain[0], fused[0])
    _tree_eq(plain[1], fused[1])


@pytest.mark.parametrize("prox", [False, True])
def test_fused_local_sgd_bitwise_fp32(prox):
    params = simple.init(jax.random.PRNGKey(7), TASK)
    anchor = simple.init(jax.random.PRNGKey(8), TASK) if prox else None
    batch = _batch(seed=1)
    kw = dict(lr=0.1, steps=3, prox_to=anchor, lam=0.05 if prox else 0.0)
    plain = jax.jit(lambda p: bilevel.local_sgd(LOSS, p, batch, **kw))
    fused = jax.jit(lambda p: bilevel.local_sgd(LOSS, p, batch,
                                                backend="jnp", fused=True,
                                                **kw))
    _tree_eq(plain(params), fused(params))


# --------------------------------------------------------------- engine level
def _fed(n_clients=12, n_per=32, seed=3):
    from repro.data import rotated
    clients, tc, tests = rotated(n_clusters=2, n_clients=n_clients,
                                 n_per=n_per, seed=seed)
    return [jax.tree.map(jnp.asarray, c) for c in clients], tc, tests


def _cfg(name, **kw):
    kw.setdefault("local_steps", 2)
    kw.setdefault("sample_rate", 0.5)
    kw.setdefault("seed", 0)
    kw.setdefault("rng_backend", "device")
    if name == "stocfl":
        kw.setdefault("cluster_backend", "device")
    if name == "cfl":
        kw["sample_rate"] = 1.0
        kw.setdefault("eps_rel", 0.9)
        kw.setdefault("eps2", 1e-4)
    return engine.EngineConfig(**kw)


def _run(name, fused, rounds=4, scan=False):
    clients, _, _ = _fed()
    st = engine.init(name, LOSS, simple.init(jax.random.PRNGKey(0), TASK),
                     clients, _cfg(name, fused_step=fused), arena=True)
    if scan:
        return engine.run_rounds(st, rounds)
    for _ in range(rounds):
        st, _ = engine.run_round(st)
    return st


@pytest.mark.parametrize("name", ALL)
def test_engine_fused_step_bitwise_fp32(name):
    a = _run(name, fused=False)
    b = _run(name, fused=True)
    _tree_eq(a.omega, b.omega)
    assert set(a.models.keys()) == set(b.models.keys())
    for k in a.models:
        _tree_eq(a.models[k], b.models[k])
    for k in a.personal:
        _tree_eq(a.personal[k], b.personal[k])
    assert a.history == b.history


def test_scan_fused_matches_eager_fused():
    a = _run("stocfl", fused=True, scan=False)
    b = _run("stocfl", fused=True, scan=True)
    _tree_eq(a.omega, b.omega)
    assert a.history == b.history
