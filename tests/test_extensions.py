"""Beyond-paper extensions: robust aggregators, dynamic join/leave,
Dirichlet partitions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import StoCFL, StoCFLConfig
from repro.core.aggregators import (krum_select, mean_aggregate,
                                    median_aggregate, trimmed_mean_aggregate)
from repro.data import rotated
from repro.data.dirichlet import dirichlet_label_skew, quantity_skew
from repro.models import simple

TASK = simple.SYNTH_MLP
LOSS = lambda p, b: simple.loss_fn(p, b, TASK)
EVAL = jax.jit(lambda p, b: simple.accuracy(p, b, TASK))


def _stack(trees_list):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees_list)


def test_aggregators_agree_on_identical_updates():
    t = {"w": jnp.ones((4,)) * 3.0}
    stacked = _stack([t, t, t])
    for agg in (mean_aggregate, median_aggregate, trimmed_mean_aggregate, krum_select):
        out = agg(stacked, [1.0, 1.0, 1.0])
        np.testing.assert_allclose(np.asarray(out["w"]), 3.0)


def test_median_krum_resist_byzantine():
    """One poisoned update (×1000) must not move robust aggregates much."""
    good = [{"w": jnp.ones((8,)) + 0.01 * i} for i in range(4)]
    bad = {"w": jnp.ones((8,)) * 1000.0}
    stacked = _stack(good + [bad])
    w = [1.0] * 5
    mean = mean_aggregate(stacked, w)
    med = median_aggregate(stacked, w)
    krum = krum_select(stacked, w, f=1)
    assert float(jnp.max(mean["w"])) > 100.0          # mean is poisoned
    assert float(jnp.max(med["w"])) < 2.0             # median is not
    assert float(jnp.max(krum["w"])) < 2.0            # krum picks a good one


def test_stocfl_with_median_aggregator_survives_poison():
    clients, tc, tests = rotated(n_clusters=2, n_clients=16, n_per=64, seed=0)
    clients = [jax.tree.map(jnp.asarray, c) for c in clients]
    # poison one client's labels
    clients[3] = {"x": clients[3]["x"], "y": (clients[3]["y"] + 5) % 10}
    params = simple.init(jax.random.PRNGKey(0), TASK)
    tr = StoCFL(LOSS, params, clients,
                StoCFLConfig(tau=0.5, lam=0.05, lr=0.1, local_steps=3,
                             sample_rate=1.0, seed=0, aggregator="median"),
                eval_fn=EVAL)
    tr.fit(8)
    tests = {k: jax.tree.map(jnp.asarray, v) for k, v in tests.items()}
    res = tr.evaluate(tests, tc)
    assert res["cluster_avg"] > 0.8


def test_dynamic_join_leave():
    all_clients, tc, _ = rotated(n_clusters=2, n_clients=18, n_per=64, seed=2)
    all_clients = [jax.tree.map(jnp.asarray, c) for c in all_clients]
    params = simple.init(jax.random.PRNGKey(0), TASK)
    tr = StoCFL(LOSS, params, all_clients[:16],
                StoCFLConfig(tau=0.5, lam=0.05, lr=0.1, local_steps=3,
                             sample_rate=0.5, seed=0))
    tr.fit(10)
    k_before = tr.state.n_clusters()
    # join: same-distribution client lands in an existing cluster
    cid = tr.join_client(all_clients[16])
    assert tr.n == 17
    assert tr.state.n_clusters() == k_before
    joined_root = tr.state.uf.find(cid)
    majority = [tc[m] for m in tr.state.clusters()[joined_root] if m < 16]
    assert max(set(majority), key=majority.count) == tc[16]
    # leave: client excluded from sampling, cluster model persists
    tr.leave_client(cid)
    for _ in range(3):
        assert cid not in tr.sample_clients()
    assert joined_root in tr.models or joined_root in [tr.state.uf.find(i) for i in range(16)]


def test_dirichlet_partition_shapes():
    clients, marg, test = dirichlet_label_skew(n_clients=12, alpha=0.3, seed=0)
    assert len(clients) == 12 and marg.shape == (12, 10)
    np.testing.assert_allclose(marg.sum(axis=1), 1.0, atol=1e-6)
    # extreme skew: most clients concentrate on few labels
    assert (marg.max(axis=1) > 0.3).mean() > 0.5


def test_quantity_skew_weighting():
    clients, sizes, _ = quantity_skew(n_clients=10, seed=0)
    assert all(len(c["y"]) == s for c, s in zip(clients, sizes))
    assert sizes.min() >= 32
