"""Sync-limit parity battery for async buffered aggregation
(``engine.run_round_async`` / ``engine.AsyncBuffer`` — docs/ASYNC.md).

The centerpiece contract: at zero delay with flush-every-round, the
async round is BITWISE equal to the synchronous ``engine.run_round`` for
every async-capable strategy (stocfl / fedavg / fedprox), with no mesh
and on client-axis meshes of size 1 and 4 (run under
``REPRO_FORCE_HOST_DEVICES=8`` for the multi-device lane — conftest
translates it before jax imports; CI does). Around that centerpiece:

- bounded staleness: no buffered delta older than ``staleness_cap`` is
  ever merged (the recorded ``max_staleness`` proves it round by round);
- arrival-order / memory-layout independence: a flush merges entries in
  dispatch (seq) order at whatever slots the buffer assigned them, so
  out-of-order arrivals and different buffer capacities (hence slot
  layouts) cannot change a single bit of the result;
- checkpoint mid-buffer: save with deltas in flight, restore into a
  fresh engine, finish — bitwise vs the uninterrupted run;
- churn boundaries: joins and leaves land while deltas are in flight;
  a departed client's delta is dropped, never merged.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import engine
from repro.checkpoint import load_server_state, save_server_state
from repro.data import rotated
from repro.engine.async_agg import AsyncBuffer
from repro.launch.mesh import make_client_mesh
from repro.models import simple

TASK = simple.SYNTH_MLP
LOSS = lambda p, b: simple.loss_fn(p, b, TASK)

ASYNC = ["stocfl", "fedavg", "fedprox"]
# None = no mesh; 1 and 4 = ("clients",) meshes (4 needs the forced-host
# multi-device lane; sizes above the device count are skipped)
MESHES = [None] + [s for s in (1, 4) if s <= len(jax.devices())]


def _fed(n_clients=12, n_per=32, seed=3):
    clients, tc, tests = rotated(n_clusters=2, n_clients=n_clients,
                                 n_per=n_per, seed=seed)
    return [jax.tree.map(jnp.asarray, c) for c in clients]


def _params(seed=0):
    return simple.init(jax.random.PRNGKey(seed), TASK)


def _cfg(name, **kw):
    kw.setdefault("local_steps", 2)
    kw.setdefault("sample_rate", 0.5)
    kw.setdefault("seed", 0)
    kw.setdefault("rng_backend", "device")
    if name == "stocfl":
        kw.setdefault("cluster_backend", "device")
    return engine.EngineConfig(**kw)


def _init(name, clients, mesh_n=None, **kw):
    mesh = None if mesh_n is None else make_client_mesh(mesh_n)
    return engine.init(name, LOSS, _params(), clients, _cfg(name, **kw),
                       arena=True, mesh=mesh)


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(la) != len(lb):
        return False
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


def _assert_bitwise(sync, asy, history_subset=True):
    """Async state ≡ sync state, bitwise: params, bank rows, partition,
    Ψ reps, round counter, PRNG key. History: every key the sync round
    recorded must appear in the async record with the identical value
    (async records carry extra flush bookkeeping on top)."""
    assert _leaves_equal(sync.omega, asy.omega), "omega diverged"
    assert set(sync.models.keys()) == set(asy.models.keys()), \
        "bank keys diverged"
    for k in sync.models.keys():
        assert _leaves_equal(sync.models[k], asy.models[k]), \
            f"bank row {k} diverged"
    if sync.clusters is not None:
        assert sync.clusters.assignment() == asy.clusters.assignment(), \
            "partition diverged"
        assert sorted(sync.clusters.seen) == sorted(asy.clusters.seen)
        for c in sync.clusters.seen:
            assert np.array_equal(np.asarray(sync.clusters.reps[c]),
                                  np.asarray(asy.clusters.reps[c])), \
                f"Ψ rep of client {c} diverged"
    assert sync.round == asy.round
    assert sync.left == asy.left
    assert np.array_equal(np.asarray(sync.rng_key), np.asarray(asy.rng_key)), \
        "PRNG key diverged (draw sequences would fork)"
    if history_subset:
        assert len(sync.history) == len(asy.history)
        for hs, ha in zip(sync.history, asy.history):
            for k, v in hs.items():
                assert k in ha and ha[k] == v, f"history[{k}] diverged"


def _bitwise_states(a, b):
    """Full async-vs-async equality (incl. buffer bookkeeping)."""
    assert _leaves_equal(a.omega, b.omega)
    assert set(a.models.keys()) == set(b.models.keys())
    for k in a.models.keys():
        assert _leaves_equal(a.models[k], b.models[k])
    if a.clusters is not None:
        assert a.clusters.assignment() == b.clusters.assignment()
    assert a.round == b.round and a.left == b.left
    assert np.array_equal(np.asarray(a.rng_key), np.asarray(b.rng_key))
    assert a.history == b.history
    assert (a.buffer is None) == (b.buffer is None)
    if a.buffer is not None:
        assert a.buffer.entries == b.buffer.entries


# ================================================= sync-limit centerpiece
@pytest.mark.parametrize("mesh_n", MESHES)
@pytest.mark.parametrize("name", ASYNC)
def test_zero_delay_parity(name, mesh_n):
    """Zero delay + flush-every-round ≡ run_round, BITWISE, for five
    rounds — per strategy, per mesh {none, 1, 4}."""
    clients = _fed()
    sync = _init(name, clients, mesh_n)
    asy = _init(name, clients, mesh_n, async_cfg=engine.AsyncConfig())
    for _ in range(5):
        sync, _ = engine.run_round(sync)
        asy, _ = engine.run_round_async(asy)
    _assert_bitwise(sync, asy)


@pytest.mark.parametrize("name", ASYNC)
def test_zero_delay_parity_decay_irrelevant(name):
    """γ < 1 cannot perturb the sync limit: γ^0 is exactly 1.0, so the
    effective weights are bit-identical to the sync counts."""
    clients = _fed()
    sync = _init(name, clients)
    asy = _init(name, clients,
                async_cfg=engine.AsyncConfig(staleness_decay=0.5))
    for _ in range(3):
        sync, _ = engine.run_round(sync)
        asy, _ = engine.run_round_async(asy)
    _assert_bitwise(sync, asy)


def test_unsupported_strategy_raises():
    """Strategies without async hooks fail loudly, not silently-sync."""
    clients = _fed()
    st = _init("ditto", clients, async_cfg=engine.AsyncConfig())
    with pytest.raises(NotImplementedError, match="async"):
        engine.run_round_async(st)


def test_empty_cohort_raises():
    """Same empty-cohort contract as run_round."""
    clients = _fed()
    st = _init("fedavg", clients, async_cfg=engine.AsyncConfig())
    with pytest.raises(ValueError, match="non-empty"):
        engine.run_round_async(st, client_ids=np.asarray([], np.int64))


# ==================================================== bounded staleness
def test_bounded_staleness_invariant():
    """No merged delta is ever older than the cap, and hopeless entries
    (delay alone over the cap) are dropped — occupancy stays bounded."""
    cap = 2
    clients = _fed()
    st = _init("stocfl", clients,
               async_cfg=engine.AsyncConfig(staleness_cap=cap,
                                            staleness_decay=0.8))
    rng = np.random.default_rng(7)
    for _ in range(10):
        st, rec = engine.run_round_async(st, delays=rng.integers(0, 6, 6))
        assert rec["max_staleness"] <= cap
        assert rec["in_flight"] <= rec["sampled"] * (cap + 1)
    assert any(r["dropped_stale"] > 0 for r in st.history), \
        "fixture never exercised the cap"
    assert all(r["max_staleness"] <= cap for r in st.history)


# ============================== arrival-order / layout independence
def test_flush_merges_in_dispatch_order():
    """Out-of-order arrivals within a flush are canonicalized: the
    flush presents entries in dispatch (seq) order whatever their slots
    or arrival pattern, and the gathered rows are bit-identical to the
    dispatched ones."""
    rows = lambda v: {"w": jnp.full((1, 2, 3), float(v), jnp.float32)}
    buf = AsyncBuffer.fresh(4)
    # dispatch A at round 0 arriving at 2 (slow), B at round 1 arriving
    # at 2 (fast) — B "overtakes" A in real time
    buf, sa = buf.reserve([10], dispatch=0, arrivals=[2], weights=[3.0])
    buf = buf.write(sa, rows(1.0))
    buf, sb = buf.reserve([11], dispatch=1, arrivals=[2], weights=[5.0])
    buf = buf.write(sb, rows(2.0))
    buf, batch, drops = buf.flush(t=2, staleness_cap=4)
    assert batch is not None and drops == {"stale": 0, "left": 0}
    assert batch.cids.tolist() == [10, 11], "not dispatch order"
    assert batch.staleness.tolist() == [2, 1]
    assert batch.weight.tolist() == [3.0, 5.0]
    got = np.asarray(batch.payload["w"])
    assert np.array_equal(got[0], np.full((2, 3), 1.0, np.float32))
    assert np.array_equal(got[1], np.full((2, 3), 2.0, np.float32))
    assert buf.in_flight == 0


@pytest.mark.parametrize("capacity", [0, 16, 128])
def test_buffer_capacity_layout_independence(capacity):
    """The buffer's row capacity (hence slot layout and pow2 padding)
    is pure memory policy: every capacity yields the bitwise-identical
    trajectory under the same delays."""
    clients = _fed()
    delays = [np.array([0, 1, 2, 0, 1, 2]), np.array([2, 2, 0, 0, 1, 1]),
              np.zeros(6, np.int64), np.array([1, 0, 1, 0, 1, 0])]
    ref = _init("stocfl", clients,
                async_cfg=engine.AsyncConfig(staleness_decay=0.9))
    got = _init("stocfl", clients,
                async_cfg=engine.AsyncConfig(staleness_decay=0.9,
                                             buffer_capacity=capacity))
    for d in delays:
        ref, _ = engine.run_round_async(ref, delays=d)
        got, _ = engine.run_round_async(got, delays=d)
    _bitwise_states(ref, got)


def test_buffer_grows_on_overflow():
    """A capacity smaller than the cohort doubles pow2-amortized instead
    of corrupting rows — and the trajectory stays bitwise."""
    clients = _fed()
    ref = _init("fedavg", clients, async_cfg=engine.AsyncConfig())
    tiny = _init("fedavg", clients,
                 async_cfg=engine.AsyncConfig(buffer_capacity=2))
    for d in ([3, 3, 3, 3, 3, 3], [0, 0, 0, 0, 0, 0]):
        ref, _ = engine.run_round_async(ref, delays=np.asarray(d))
        tiny, _ = engine.run_round_async(tiny, delays=np.asarray(d))
    assert tiny.buffer.capacity >= 8
    _bitwise_states(ref, tiny)


# ================================================== checkpoint mid-buffer
@pytest.mark.parametrize("name", ["stocfl", "fedavg"])
def test_checkpoint_mid_buffer_resume(name, tmp_path):
    """Save with deltas in flight, restore into a FRESH engine, finish:
    bitwise vs the uninterrupted run — buffer rows, entry bookkeeping,
    seq order and f32 weights all round-trip."""
    clients = _fed()
    acfg = engine.AsyncConfig(staleness_decay=0.8, staleness_cap=3)
    st = _init(name, clients, async_cfg=acfg)
    rng = np.random.default_rng(5)
    head = [rng.integers(0, 3, 6) for _ in range(3)]
    tail = [rng.integers(0, 3, 6) for _ in range(3)]
    for d in head:
        st, _ = engine.run_round_async(st, delays=d)
    assert st.buffer.in_flight > 0, "fixture never left deltas in flight"
    save_server_state(str(tmp_path / "ck"), st)
    resumed = load_server_state(str(tmp_path / "ck"),
                                _init(name, clients, async_cfg=acfg))
    assert resumed.buffer.entries == st.buffer.entries
    for d in tail:
        st, _ = engine.run_round_async(st, delays=d)
        resumed, _ = engine.run_round_async(resumed, delays=d)
    _bitwise_states(st, resumed)


def test_pre_async_checkpoint_loads_without_buffer(tmp_path):
    """A checkpoint saved by a synchronous run carries no buffer and
    restores with ``buffer=None`` (forward compatibility)."""
    clients = _fed()
    st = _init("fedavg", clients)
    st, _ = engine.run_round(st)
    save_server_state(str(tmp_path / "ck"), st)
    back = load_server_state(str(tmp_path / "ck"), _init("fedavg", clients))
    assert back.buffer is None
    assert _leaves_equal(st.omega, back.omega)


# ======================================================= churn in flight
def test_leave_drops_in_flight_delta():
    """A client that departs while its delta is buffered is dropped at
    the flush, never merged — and the run keeps going."""
    clients = _fed()
    st = _init("stocfl", clients, async_cfg=engine.AsyncConfig())
    # round 0: everyone reports 2 rounds late
    st, rec = engine.run_round_async(st, delays=np.full(6, 2, np.int64))
    assert rec["in_flight"] == 6
    victim = int(st.buffer.entries[0].cid)
    st = engine.leave(st, victim)
    dropped = merged_victim = 0
    for _ in range(3):
        st, rec = engine.run_round_async(st)
        dropped += rec["dropped_left"]
    assert dropped >= 1, "departed client's delta was not dropped"
    assert victim in st.left
    # the victim's contribution must not have reached any merge: no
    # flushed batch may contain it (checked via the entry bookkeeping —
    # nothing in flight carries the departed cid anymore)
    assert all(int(e.cid) != victim for e in st.buffer.entries)
    assert merged_victim == 0


def test_join_while_deltas_in_flight():
    """A client joining mid-flight gets observed, clustered, and merged
    through the same buffer path on its first sampled round."""
    clients = _fed()
    extra = _fed(n_clients=14, seed=9)[12:]
    st = _init("stocfl", clients,
               async_cfg=engine.AsyncConfig(staleness_cap=3))
    st, _ = engine.run_round_async(st, delays=np.full(6, 1, np.int64))
    assert st.buffer.in_flight > 0
    st, cid = engine.join(st, extra[0])
    for _ in range(6):
        st, _ = engine.run_round_async(st, delays=np.full(7, 1, np.int64)[
            : max(1, int(np.ceil(0.5 * (st.n_clients - len(st.left)))))])
    assert cid in st.clusters.seen, "joined client never observed"
    assert sum(r["merged"] for r in st.history) > 0
