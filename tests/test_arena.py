"""Arena + chunked-cohort battery: the device-resident data path and the
chunked executor must be invisible to every strategy — identical (bitwise
where dtypes allow) or tightly-allclose trajectories vs the legacy
per-round restack and the unchunked vmapped step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core import bilevel
from repro.data import rotated
from repro.data.arena import ClientArena
from repro.models import simple

TASK = simple.SYNTH_MLP
LOSS = lambda p, b: simple.loss_fn(p, b, TASK)
EVAL = jax.jit(lambda p, b: simple.accuracy(p, b, TASK))
ALL = ["stocfl", "fedavg", "fedprox", "ditto", "ifca", "cfl"]


def _fed(n_clients=8, n_per=24, seed=3):
    clients, tc, tests = rotated(n_clusters=2, n_clients=n_clients,
                                 n_per=n_per, seed=seed)
    clients = [jax.tree.map(jnp.asarray, c) for c in clients]
    tests = {k: jax.tree.map(jnp.asarray, v) for k, v in tests.items()}
    return clients, tc, tests


def _params(seed=0):
    return simple.init(jax.random.PRNGKey(seed), TASK)


def _cfg(**kw):
    kw.setdefault("local_steps", 2)
    kw.setdefault("sample_rate", 0.5)
    kw.setdefault("seed", 0)
    return engine.EngineConfig(**kw)


def _assert_state_close(a, b, exact=True):
    assert a.round == b.round
    assert a.history == b.history if exact else True
    for la, lb in zip(jax.tree.leaves(a.omega), jax.tree.leaves(b.omega)):
        if exact:
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        else:
            np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                       rtol=2e-6, atol=1e-6)
    assert a.models.keys() == b.models.keys()
    for k in a.models:
        for la, lb in zip(jax.tree.leaves(a.models[k]),
                          jax.tree.leaves(b.models[k])):
            if exact:
                np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
            else:
                np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                           rtol=2e-6, atol=1e-6)
    assert a.personal.keys() == b.personal.keys()
    for k in a.personal:
        for la, lb in zip(jax.tree.leaves(a.personal[k]),
                          jax.tree.leaves(b.personal[k])):
            if exact:
                np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
            else:
                np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                           rtol=2e-6, atol=1e-6)


# ------------------------------------------------------------ arena basics
def test_arena_pack_equal_sizes_is_exact():
    clients, _, _ = _fed(n_clients=6)
    ar = ClientArena.from_clients(clients)
    assert not ar.ragged and ar.n_clients == 6
    ids = [4, 1, 3]
    got = ar.gather(ids)
    want = jax.tree.map(lambda *xs: jnp.stack(xs), *[clients[i] for i in ids])
    assert "mask" not in got                      # no pad -> legacy shapes
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_arena_ragged_pad_and_mask():
    rng = np.random.default_rng(0)
    sizes = [5, 9, 3]
    clients = [{"x": rng.normal(size=(n, 4)).astype(np.float32),
                "y": rng.integers(0, 3, size=n).astype(np.int32)}
               for n in sizes]
    ar = ClientArena.from_clients(clients)
    assert ar.ragged
    got = ar.gather([0, 1, 2])
    assert got["x"].shape == (3, 9, 4) and got["mask"].shape == (3, 9)
    np.testing.assert_array_equal(np.asarray(got["mask"]).sum(axis=1), sizes)
    # pad rows are zero AND masked out
    np.testing.assert_array_equal(np.asarray(got["x"][0, 5:]), 0.0)
    # unpadded single-client view round-trips
    for i, n in enumerate(sizes):
        c = ar.client(i)
        np.testing.assert_array_equal(np.asarray(c["x"]), clients[i]["x"])


def test_arena_masked_loss_matches_unpadded():
    """Masked loss over a padded shard == plain loss over the raw shard —
    pad rows contribute exactly nothing."""
    rng = np.random.default_rng(1)
    sizes = [7, 12]
    clients = [{"x": rng.normal(size=(n, 64)).astype(np.float32),
                "y": rng.integers(0, 10, size=n).astype(np.int32)}
               for n in sizes]
    ar = ClientArena.from_clients(clients)
    params = _params()
    got = ar.gather([0, 1])
    for i in range(2):
        padded = jax.tree.map(lambda x: x[i], got)
        want = float(LOSS(params, jax.tree.map(jnp.asarray, clients[i])))
        assert float(LOSS(params, padded)) == pytest.approx(want, rel=1e-6)
        want_acc = float(EVAL(params, jax.tree.map(jnp.asarray, clients[i])))
        assert float(EVAL(params, padded)) == pytest.approx(want_acc, rel=1e-6)


def test_ragged_federation_trains_only_with_arena():
    """Ragged shards can't jnp.stack (legacy path); the arena's
    pad-and-mask makes the same federation trainable."""
    rng = np.random.default_rng(2)
    clients = [{"x": rng.normal(size=(n, 64)).astype(np.float32),
                "y": rng.integers(0, 10, size=n).astype(np.int32)}
               for n in [16, 24, 8, 16, 24, 8]]
    st = engine.init("stocfl", LOSS, _params(), clients,
                     _cfg(sample_rate=1.0), arena=True)
    assert tuple(st.ctx.arena.sizes) == (16, 24, 8, 16, 24, 8)
    for _ in range(2):
        st, rec = engine.run_round(st)
    assert rec["sampled"] == 6
    assert all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree.leaves(st.omega))


def test_arena_append_matches_repack():
    """O(1) append ≡ full from_clients repack, through every case: same
    size, shorter (goes ragged), and longer (re-pads the arena)."""
    rng = np.random.default_rng(3)
    mk = lambda n: {"x": rng.normal(size=(n, 4)).astype(np.float32),
                    "y": rng.integers(0, 3, size=n).astype(np.int32)}
    clients = [mk(6), mk(6)]
    ar = ClientArena.from_clients(clients)
    for n_new in [6, 3, 9]:                   # equal, shorter, longer
        clients.append(mk(n_new))
        ar = ar.append(clients[-1])
        want = ClientArena.from_clients(clients)
        assert ar.ragged == want.ragged
        np.testing.assert_array_equal(ar.sizes, want.sizes)
        ga, gw = (a.gather(range(len(clients))) for a in (ar, want))
        for la, lw in zip(jax.tree.leaves(ga), jax.tree.leaves(gw)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lw))


# ------------------------------------------------------ arena/legacy parity
@pytest.mark.parametrize("name", ALL)
def test_arena_matches_legacy_restack(name):
    """Equal-size federations: the arena gather feeds bit-identical
    batches, so the whole ServerState trajectory is bitwise equal to the
    legacy per-round restack path — for every registered strategy."""
    clients, tc, tests = _fed()
    a = engine.init(name, LOSS, _params(), clients, _cfg(), eval_fn=EVAL)
    b = engine.init(name, LOSS, _params(), clients, _cfg(), eval_fn=EVAL,
                    arena=True)
    assert a.ctx.arena is None and b.ctx.arena is not None
    for _ in range(3):
        a, ra = engine.run_round(a)
        b, rb = engine.run_round(b)
        assert ra == rb
    _assert_state_close(a, b, exact=True)
    assert engine.evaluate(a, tests, tc) == engine.evaluate(b, tests, tc)


# -------------------------------------------------- chunked cohort execution
def test_chunk_map_matches_unchunked_fn():
    cohort = bilevel.make_cohort_update(LOSS, lr=0.1, lam=0.05, local_steps=2)
    chunked = bilevel.chunk_map(cohort, (0, None, 0), chunk=3)
    clients, _, _ = _fed(n_clients=8)
    params = _params()
    thetas = jax.tree.map(lambda x: jnp.stack([x] * 8), params)
    batches = jax.tree.map(lambda *xs: jnp.stack(xs), *clients)
    t0, o0 = cohort(thetas, params, batches)          # 8 = one vmap
    t1, o1 = chunked(thetas, params, batches)         # 8 = 3+3+2(padded)
    for a, b in zip(jax.tree.leaves((t0, o0)), jax.tree.leaves((t1, o1))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-6, atol=1e-6)


def test_chunk_map_noop_below_chunk():
    cohort = bilevel.make_cohort_update(LOSS, lr=0.1, lam=0.05, local_steps=1)
    chunked = bilevel.chunk_map(cohort, (0, None, 0), chunk=16)
    clients, _, _ = _fed(n_clients=4)
    params = _params()
    thetas = jax.tree.map(lambda x: jnp.stack([x] * 4), params)
    batches = jax.tree.map(lambda *xs: jnp.stack(xs), *clients)
    t0, _ = cohort(thetas, params, batches)
    t1, _ = chunked(thetas, params, batches)
    for a, b in zip(jax.tree.leaves(t0), jax.tree.leaves(t1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name", ALL)
def test_chunked_matches_unchunked_rounds(name):
    """cohort_chunk must not change any strategy's trajectory (clients are
    independent under vmap; chunking only re-tiles the batch axis)."""
    clients, _, _ = _fed()
    a = engine.init(name, LOSS, _params(), clients,
                    _cfg(sample_rate=1.0), eval_fn=EVAL, arena=True)
    b = engine.init(name, LOSS, _params(), clients,
                    _cfg(sample_rate=1.0, cohort_chunk=3), eval_fn=EVAL,
                    arena=True)
    for _ in range(2):
        a, ra = engine.run_round(a)
        b, rb = engine.run_round(b)
        assert ra.get("sampled") == rb.get("sampled")
    _assert_state_close(b, a, exact=False)


def test_chunked_arena_join_leave_still_work():
    clients, _, _ = _fed(n_clients=8)
    extra, _, _ = _fed(n_clients=2, seed=11)
    st = engine.init("stocfl", LOSS, _params(), clients,
                     _cfg(sample_rate=1.0, cohort_chunk=4), arena=True)
    st, _ = engine.run_round(st)
    st, cid = engine.join(st, extra[0])
    assert st.ctx.arena.n_clients == 9        # arena repacked on join
    st, rec = engine.run_round(st)
    assert rec["sampled"] == 9
    st = engine.leave(st, cid)
    st, rec = engine.run_round(st)
    assert rec["sampled"] == 8
