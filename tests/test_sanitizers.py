"""Runtime-sanitizer battery: the ``repro.analysis.sanitize`` context
managers themselves, plus the zero-host-transfer proof for ALL six
strategies' scanned round loop.

The transfer proof generalizes the one-off ``transfer_guard`` test in
``tests/test_device_clustering.py`` from the clustering step to the
whole per-strategy scan: ``engine.scan_program`` exposes the compiled
span as (fn, carry0, consts, finalize); after a warm-up call, re-running
``fn`` under ``sanitize.no_transfer()`` proves the scanned rounds —
cohort draw, arena gather, local SGD, clustering, aggregation — never
fall back to host (arena + device cluster backend + device rng).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.analysis import sanitize
from repro.data import rotated
from repro.models import simple

TASK = simple.SYNTH_MLP
LOSS = lambda p, b: simple.loss_fn(p, b, TASK)
EVAL = jax.jit(lambda p, b: simple.accuracy(p, b, TASK))

ALL = ["stocfl", "fedavg", "fedprox", "ditto", "ifca", "cfl"]


def _fed(n_clients=12, n_per=32, seed=3):
    clients, tc, tests = rotated(n_clusters=2, n_clients=n_clients,
                                 n_per=n_per, seed=seed)
    clients = [jax.tree.map(jnp.asarray, c) for c in clients]
    return clients, tc, tests


def _cfg(name, **kw):
    kw.setdefault("local_steps", 2)
    kw.setdefault("sample_rate", 0.5)
    kw.setdefault("seed", 0)
    kw.setdefault("rng_backend", "device")
    if name == "stocfl":
        kw.setdefault("cluster_backend", "device")
    if name == "cfl":
        kw["sample_rate"] = 1.0
        kw.setdefault("eps_rel", 0.9)
        kw.setdefault("eps2", 1e-4)
    return engine.EngineConfig(**kw)


def _init(name, clients, **kw):
    return engine.init(name, LOSS, simple.init(jax.random.PRNGKey(0), TASK),
                       clients, _cfg(name, **kw), eval_fn=EVAL, arena=True)


# ================================================= compile_budget unit tests
def test_compile_budget_counts_fresh_compiles():
    """A never-seen jit program compiles inside the block and is
    counted; an immediate identical re-call hits the cache and adds
    nothing."""
    f = jax.jit(lambda x: x * 3 + 1)
    x = jnp.ones((17, 3))     # shape unique to this test
    with sanitize.compile_budget() as log:
        f(x).block_until_ready()
        first = log.count
        f(x).block_until_ready()
    assert first >= 1, "fresh jit compile was not observed"
    assert log.count == first, "cache hit was miscounted as a compile"


def test_compile_budget_overrun_raises():
    with pytest.raises(sanitize.CompileBudgetExceeded):
        with sanitize.compile_budget(0):
            jax.jit(lambda x: x - 7)(jnp.ones((19, 2))).block_until_ready()


def test_compile_budget_names_when_logging():
    """``log_names=True`` captures jit(<name>) labels for diagnostics
    (and restores the jax_log_compiles flag afterwards)."""
    prev = jax.config.jax_log_compiles

    def tagged_fn(x):
        return x + 11

    with sanitize.compile_budget(log_names=True) as log:
        jax.jit(tagged_fn)(jnp.ones((23, 2))).block_until_ready()
    assert jax.config.jax_log_compiles == prev
    assert any("tagged_fn" in n for n in log.names), log.names


def test_compile_budget_nests_without_double_counting():
    """Stacked budgets each see the inner compile exactly once (the
    listener unregisters cleanly)."""
    with sanitize.compile_budget() as outer:
        with sanitize.compile_budget() as inner:
            jax.jit(lambda x: x / 5)(jnp.ones((29, 2))).block_until_ready()
        n_in, n_out = inner.count, outer.count
    assert n_in >= 1 and n_in == n_out
    # after exit the listener is gone: new compiles don't mutate the log
    jax.jit(lambda x: x / 6)(jnp.ones((31, 2))).block_until_ready()
    assert outer.count == n_out


# ==================================================== no_transfer unit tests
def test_no_transfer_blocks_implicit_scalar_upload():
    """An eager op with a bare Python scalar operand needs a
    host→device upload every call — the exact hazard lint rule R5/R2
    police — and the guard rejects it.  (On the CPU backend zero-copy
    d2h views are not guarded; actual copies are.)"""
    x = jnp.arange(8.0)
    x.block_until_ready()
    (x * 9876.5).block_until_ready()      # warmed: the compile is cached,
    with pytest.raises(Exception, match="[Dd]isallowed.*transfer"):
        with sanitize.no_transfer():      # ...the scalar upload is not
            (x * 9876.5).block_until_ready()


def test_no_transfer_blocks_numpy_args_to_jit():
    f = jax.jit(lambda a: a * 2)
    host = np.ones((13, 2), np.float32)
    f(host).block_until_ready()           # warm compile
    with pytest.raises(Exception, match="[Dd]isallowed.*transfer"):
        with sanitize.no_transfer():
            f(host)


def test_no_transfer_allows_pure_device_compute():
    f = jax.jit(lambda x: jnp.sum(x * 2))
    x = jnp.arange(37.0)
    f(x).block_until_ready()      # warm-up commits operands + program
    with sanitize.no_transfer():
        f(x).block_until_ready()


# ====================================================== nan_guard unit tests
def test_nan_guard_raises_on_nan_and_restores_flag():
    prev = jax.config.jax_debug_nans
    f = jax.jit(lambda x: jnp.log(x))
    with pytest.raises(FloatingPointError):
        with sanitize.nan_guard():
            f(jnp.float32(-1.0)).block_until_ready()
    assert jax.config.jax_debug_nans == prev
    # outside the guard the same computation quietly produces nan again
    assert np.isnan(np.asarray(f(jnp.float32(-1.0))))


def test_nan_guard_clean_stocfl_round():
    """A healthy StoCFL round under nan_guard: no false positives from
    the engine's own math (the CI smoke runs this same guard)."""
    clients, _, _ = _fed()
    st = _init("stocfl", clients)
    with sanitize.nan_guard():
        st = engine.run_rounds(st, 1)
    assert st.round == 1


# ============================== zero-transfer battery over all strategies
@pytest.mark.parametrize("name", ALL)
def test_scanned_rounds_zero_host_transfers(name):
    """The scanned round loop of EVERY strategy runs entirely on
    device: after a warm-up call of the compiled span, re-invoking it
    under ``no_transfer()`` (transfer_guard 'disallow') completes — no
    implicit host→device upload, no device→host sync anywhere in draw /
    gather / train / cluster / aggregate. ``finalize`` (history
    records, bank rebuild) is the explicit host hand-off and stays
    outside the guard by construction."""
    clients, _, _ = _fed()
    st = _init(name, clients)
    rounds = 3
    prog = engine.scan_program(st, rounds)
    assert prog is not None
    fn, carry0, consts, finalize = prog
    fn(carry0, consts)                      # compile + commit operands
    with sanitize.no_transfer():
        carry, ys = fn(carry0, consts)
        jax.block_until_ready((carry, ys))
    st2 = finalize(st, carry, ys, rounds)
    assert st2.round == st.round + rounds
    assert len(st2.history) == len(st.history) + rounds


@pytest.mark.parametrize("name", ALL)
def test_sharded_scanned_rounds_zero_host_transfers(name):
    """Zero-transfer holds on a multi-device client mesh too: the
    cross-shard gathers and all-reduces the sharded scan adds are
    device-to-device collectives, not host round-trips. Runs on a
    4-device ("clients",) mesh under REPRO_FORCE_HOST_DEVICES (CI);
    degenerates to the 1-device mesh otherwise — still a real check of
    the mesh code path."""
    from repro.launch.mesh import make_client_mesh
    nd = min(4, len(jax.devices()))
    clients, _, _ = _fed()
    st = engine.init(name, LOSS, simple.init(jax.random.PRNGKey(0), TASK),
                     clients, _cfg(name), eval_fn=EVAL, arena=True,
                     mesh=make_client_mesh(nd))
    rounds = 3
    prog = engine.scan_program(st, rounds)
    assert prog is not None
    fn, carry0, consts, finalize = prog
    fn(carry0, consts)                      # compile + commit operands
    with sanitize.no_transfer():
        carry, ys = fn(carry0, consts)
        jax.block_until_ready((carry, ys))
    st2 = finalize(st, carry, ys, rounds)
    assert st2.round == st.round + rounds


def test_async_buffer_data_plane_zero_host_transfers():
    """The async buffer's data plane — slot scatter at dispatch, row
    gather at flush, weighted aggregation of the flushed stack — runs
    entirely on device. After one warm async round compiles every
    program, re-invoking the jitted row movement + merge on committed
    device operands under ``no_transfer()`` completes. (The control
    plane — entry bookkeeping, staleness weights — is host-side BY
    DESIGN: it's O(cohort) Python scalars per round, see docs/ASYNC.md.)"""
    from repro.core import bilevel
    from repro.engine.async_agg import _gather_rows, _scatter_rows
    clients, _, _ = _fed()
    st = _init("fedavg", clients, async_cfg=engine.AsyncConfig())
    st, _ = engine.run_round_async(st)      # warm: rows + programs exist
    rows = st.buffer.payload
    slots = jnp.arange(4)
    upd = jax.tree.map(lambda r: r[:4], rows)
    agg = jax.jit(bilevel.aggregate_stacked)
    w = jnp.ones(4, jnp.float32)
    # warm the exact calls, then prove them transfer-free
    jax.block_until_ready((_scatter_rows(rows, slots, upd),
                           agg(_gather_rows(rows, slots), w)))
    with sanitize.no_transfer():
        rows2 = _scatter_rows(rows, slots, upd)
        merged = agg(_gather_rows(rows2, slots), w)
        jax.block_until_ready((rows2, merged))


def test_scan_program_skipped_pool_returns_none():
    """An empty pool (everyone unavailable) has no program — run_rounds
    records skipped rounds instead."""
    clients, _, _ = _fed()
    st = _init("fedavg", clients)
    assert engine.scan_program(st, 2, unavailable=set(range(12))) is None
    st2 = engine.run_rounds(st, 2, unavailable=set(range(12)))
    assert [r.get("skipped") for r in st2.history[-2:]] == [True, True]
