"""§3.4 degeneracy claims as executable trajectory tests: StoCFL's knobs
collapse it onto each baseline, and the engine reproduces the baseline's
trajectory round-for-round.

  τ=1          → Ditto  (no merges: every client is its own cluster, the
                 θ-prox to ω is Ditto's personal prox to the broadcast
                 global; exact at local_steps=1, where the fused bi-level
                 step proxes to the same pre-step ω Ditto broadcasts)
  λ=0          → CFL    (no knowledge transfer: with the PARTITION frozen
                 to the same clusters, per-cluster θ updates are plain
                 local SGD + per-cluster FedAvg — exactly CFL's step)
  λ=0 ∧ τ=−1   → FedAvg (single cluster + no prox: both θ_k and ω follow
                 the FedAvg recursion)

Each pair runs 3 rounds and must match allclose at every round.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.data import rotated
from repro.models import simple

TASK = simple.SYNTH_MLP
LOSS = lambda p, b: simple.loss_fn(p, b, TASK)

RTOL, ATOL = 2e-6, 1e-6


def _fed(n_clients=8, n_per=24, seed=5):
    clients, tc, tests = rotated(n_clusters=2, n_clients=n_clients,
                                 n_per=n_per, seed=seed)
    return [jax.tree.map(jnp.asarray, c) for c in clients], tc


def _params(seed=0):
    return simple.init(jax.random.PRNGKey(seed), TASK)


def _close(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("arena", [False, True], ids=["legacy", "arena"])
def test_tau_one_equals_ditto(arena):
    """τ=1, E=1: per-client cluster models ≡ Ditto personal models and
    both ω trajectories coincide, round by round."""
    clients, _ = _fed()
    n = len(clients)
    cfg_s = engine.EngineConfig(tau=1.0, lam=0.05, lr=0.1, local_steps=1,
                                sample_rate=0.5, seed=0)
    cfg_d = engine.EngineConfig(lr=0.1, local_steps=1, sample_rate=0.5,
                                seed=0, mu=0.05)
    sto = engine.init("stocfl", LOSS, _params(), clients, cfg_s, arena=arena)
    dit = engine.init("ditto", LOSS, _params(), clients, cfg_d, arena=arena)
    for _ in range(3):
        sto, rs = engine.run_round(sto)
        dit, rd = engine.run_round(dit)
        assert rs["sampled"] == rd["sampled"]      # same rng -> same cohort
        assert rs["n_clusters"] == len(sto.clusters.seen)   # never merges
        _close(sto.omega, dit.omega)
        for cid in range(n):                       # singleton root == cid
            _close(sto.cluster_model(cid), dit.personal[cid])


@pytest.mark.parametrize("arena", [False, True], ids=["legacy", "arena"])
def test_lam_zero_equals_cfl(arena):
    """λ=0 with the partition frozen to the same clusters: StoCFL's
    per-cluster θ transition ≡ CFL's per-cluster FedAvg of local SGD.

    StoCFL discovers the partition in round 1 (Ψ-merging); CFL is then
    started FROM that partition (members pre-set, split criterion
    disabled via a huge eps2 so the partition stays frozen) with the same
    per-cluster models, and both must stay in lockstep for 3 rounds."""
    clients, _ = _fed()
    cfg_s = engine.EngineConfig(tau=0.5, lam=0.0, lr=0.1, local_steps=2,
                                sample_rate=1.0, seed=0)
    sto = engine.init("stocfl", LOSS, _params(), clients, cfg_s, arena=arena)
    sto, _ = engine.run_round(sto)                 # round 1: partition forms

    part = {}
    for cid, root in sto.clusters.assignment().items():
        part.setdefault(root, []).append(cid)
    roots = sorted(part)
    assert len(roots) >= 2                         # a real multi-cluster case

    cfg_c = engine.EngineConfig(lr=0.1, local_steps=2, sample_rate=1.0,
                                seed=0, eps2=1e9)  # never split
    cfl = engine.init("cfl", LOSS, _params(), clients, cfg_c, arena=arena)
    cfl = cfl.replace(
        members=tuple(tuple(sorted(part[r])) for r in roots),
        models=engine.ClusterBank.from_dict(
            {k: sto.models[r] for k, r in enumerate(roots)}))

    for _ in range(3):
        sto, _ = engine.run_round(sto)
        cfl, rc = engine.run_round(cfl)
        assert rc["n_clusters"] == len(roots)      # CFL partition frozen
        assert sorted(part) == roots               # Ψ partition frozen too
        part = {}
        for cid, root in sto.clusters.assignment().items():
            part.setdefault(root, []).append(cid)
        for k, r in enumerate(roots):
            _close(sto.models[r], cfl.models[k])


@pytest.mark.parametrize("arena", [False, True], ids=["legacy", "arena"])
def test_lam_zero_tau_minus_one_equals_fedavg(arena):
    """λ=0 ∧ τ=−1: everything merges into one cluster, the prox vanishes —
    StoCFL's single θ AND its ω both follow the FedAvg recursion.

    Full participation makes the equivalence total. Under partial
    participation only ω stays on FedAvg's trajectory: each round's
    newly-OBSERVED clients enter the merge as lazy θ=ω₀ singletons
    (knowledge-preserving seeding, §3.2), which nudges θ off the pure
    recursion — asserted separately below."""
    clients, _ = _fed()
    cfg_s = engine.EngineConfig(tau=-1.0, lam=0.0, lr=0.1, local_steps=2,
                                sample_rate=1.0, seed=0)
    cfg_f = engine.EngineConfig(lr=0.1, local_steps=2, sample_rate=1.0, seed=0)
    sto = engine.init("stocfl", LOSS, _params(), clients, cfg_s, arena=arena)
    fed = engine.init("fedavg", LOSS, _params(), clients, cfg_f, arena=arena)
    for _ in range(3):
        sto, rs = engine.run_round(sto)
        fed, rf = engine.run_round(fed)
        assert rs["sampled"] == rf["sampled"]
        assert rs["n_clusters"] == 1
        _close(sto.omega, fed.omega)
        root = min(sto.clusters.seen)
        _close(sto.models[root], fed.omega)


def test_lam_zero_tau_minus_one_omega_tracks_fedavg_partial():
    """Partial participation (0.5): ω still follows FedAvg exactly — the
    lazy-θ seeding above only perturbs the cluster model."""
    clients, _ = _fed()
    cfg_s = engine.EngineConfig(tau=-1.0, lam=0.0, lr=0.1, local_steps=2,
                                sample_rate=0.5, seed=0)
    cfg_f = engine.EngineConfig(lr=0.1, local_steps=2, sample_rate=0.5, seed=0)
    sto = engine.init("stocfl", LOSS, _params(), clients, cfg_s)
    fed = engine.init("fedavg", LOSS, _params(), clients, cfg_f)
    for _ in range(3):
        sto, rs = engine.run_round(sto)
        fed, rf = engine.run_round(fed)
        assert rs["sampled"] == rf["sampled"] and rs["n_clusters"] == 1
        _close(sto.omega, fed.omega)
