"""End-to-end behaviour tests: StoCFL recovers clusters and beats the
global model; new-client inference works; checkpoints round-trip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_stocfl, save_stocfl
from repro.core import StoCFL, StoCFLConfig, adjusted_rand_index
from repro.core.baselines import FLConfig, FedAvg, IFCA
from repro.data import rotated, shifted
from repro.models import simple

TASK = simple.SYNTH_MLP
LOSS = lambda p, b: simple.loss_fn(p, b, TASK)
EVAL = jax.jit(lambda p, b: simple.accuracy(p, b, TASK))


def _fed(maker=rotated, n_clients=40, seed=1, **kw):
    clients, tc, tests = maker(n_clusters=4, n_clients=n_clients, seed=seed, **kw)
    clients = [jax.tree.map(jnp.asarray, c) for c in clients]
    tests = {k: jax.tree.map(jnp.asarray, v) for k, v in tests.items()}
    return clients, tc, tests


@pytest.fixture(scope="module")
def trained():
    all_clients, all_tc, tests = _fed(n_clients=48)
    held_idx = [i for i in range(48) if i % 6 == 5]      # 8 held-out clients
    train_idx = [i for i in range(48) if i % 6 != 5]     # 40 participants
    clients = [all_clients[i] for i in train_idx]
    tc = [all_tc[i] for i in train_idx]
    held = [(all_clients[i], all_tc[i]) for i in held_idx]
    params = simple.init(jax.random.PRNGKey(0), TASK)
    tr = StoCFL(LOSS, params, clients,
                StoCFLConfig(tau=0.5, lam=0.05, lr=0.1, local_steps=5,
                             sample_rate=0.25, seed=0), eval_fn=EVAL)
    tr.fit(20)
    return tr, tc, tests, clients, held


def test_cluster_recovery(trained):
    tr, tc, _, _, _ = trained
    assign = tr.state.assignment()
    ids = sorted(assign)
    ari = adjusted_rand_index([assign[c] for c in ids], [tc[c] for c in ids])
    assert ari == 1.0
    assert tr.state.n_clusters() == 4       # K discovered, not given


def test_cluster_models_beat_global(trained):
    tr, tc, tests, _, _ = trained
    res = tr.evaluate(tests, tc)
    assert res["cluster_avg"] > res["global_avg"]
    assert res["cluster_avg"] > 0.9


def test_stocfl_beats_fedavg(trained):
    tr, tc, tests, clients, _ = trained
    params = simple.init(jax.random.PRNGKey(0), TASK)
    fed = FedAvg(LOSS, params, clients,
                 FLConfig(lr=0.1, local_steps=5, sample_rate=0.25, seed=0),
                 eval_fn=EVAL)
    fed.fit(20)
    res_f = fed.evaluate(tests)
    res_s = tr.evaluate(tests, tc)
    assert res_s["cluster_avg"] > res_f["cluster_avg"]


def test_new_client_inference(trained):
    """§4.4: an unseen client from a known distribution joins its cluster."""
    tr, tc, _, _, held = trained
    hit = 0
    for c, k in held:
        out = tr.infer_new_client(c)
        if out["cluster"] is not None:
            members = tr.state.clusters()[out["cluster"]]
            majority = max(set(tc[m] for m in members),
                           key=lambda g: sum(tc[m] == g for m in members))
            hit += int(majority == k)
    assert hit >= 6


def test_checkpoint_roundtrip(tmp_path, trained):
    tr, tc, tests, clients, _ = trained
    d = str(tmp_path / "ckpt")
    save_stocfl(d, tr)
    params = simple.init(jax.random.PRNGKey(0), TASK)
    tr2 = StoCFL(LOSS, params, clients,
                 StoCFLConfig(tau=0.5, lam=0.05, lr=0.1, local_steps=5,
                              sample_rate=0.25, seed=0), eval_fn=EVAL)
    load_stocfl(d, tr2)
    assert tr2.state.n_clusters() == tr.state.n_clusters()
    assert tr2.state.assignment() == tr.state.assignment()
    r1 = tr.evaluate(tests, tc)
    r2 = tr2.evaluate(tests, tc)
    assert r1["cluster_avg"] == pytest.approx(r2["cluster_avg"], abs=1e-6)


def test_ifca_runs_and_learns():
    clients, tc, tests = _fed(n_clients=16)
    params = simple.init(jax.random.PRNGKey(0), TASK)
    tr = IFCA(LOSS, params, clients,
              FLConfig(lr=0.1, local_steps=5, sample_rate=0.5, seed=0),
              eval_fn=EVAL, n_models=4)
    tr.fit(10)
    res = tr.evaluate(tests)
    assert res["cluster_avg"] > 0.5
