"""The Pallas kernels as first-class model paths (cfg.use_pallas)."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.kernels.ops as ops
from repro.configs import get_config
from repro.models import build


def test_mamba_train_kernel_path_matches_jnp():
    cfg0 = get_config("falcon-mamba-7b", smoke=True).with_(dtype="float32")
    m_jnp = build(cfg0)
    m_pal = build(cfg0.with_(use_pallas=True))
    params = m_jnp.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 40), 0,
                                          cfg0.vocab_size)}
    l1, _ = m_jnp.forward_train(params, batch)
    l2, _ = m_pal.forward_train(params, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-3, rtol=2e-3)


def test_mamba_train_through_interpret_kernel(monkeypatch):
    """Force the actual pl.pallas_call (interpret mode) inside the model."""
    from repro.kernels.ssm_scan import ssm_scan as kernel

    def forced(dA, dBx, C, backend="auto", **kw):
        return kernel(dA, dBx, C, bd=16, chunk=16, interpret=True)

    cfg0 = get_config("falcon-mamba-7b", smoke=True).with_(dtype="float32")
    m_jnp = build(cfg0)
    m_pal = build(cfg0.with_(use_pallas=True))
    params = m_jnp.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, 24), 0,
                                          cfg0.vocab_size)}
    l1, _ = m_jnp.forward_train(params, batch)
    monkeypatch.setattr(ops, "ssm_scan", forced)
    l2, _ = m_pal.forward_train(params, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-3, rtol=2e-3)


def test_gradients_flow_through_kernel_path():
    cfg = get_config("falcon-mamba-7b", smoke=True).with_(dtype="float32",
                                                          use_pallas=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, 24), 0,
                                          cfg.vocab_size)}
    loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss))
    assert all(np.isfinite(np.asarray(g)).all() for g in jax.tree.leaves(grads))
