"""Model building-block unit tests: rope, norms, attention masks, MoE
routing invariants, mamba scan equivalence, sliding window."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the test extra
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import ssm
from repro.models.attention import causal_attention, gqa_decode, gqa_init, gqa_prefill
from repro.models.layers import apply_rope, rmsnorm, rmsnorm_init
from repro.models.moe import moe_ffn, moe_init


def test_rope_preserves_norm_and_relative_phase():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 6, 2, 16))
    pos = jnp.broadcast_to(jnp.arange(6), (1, 6))
    y = apply_rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-4)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(key, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 16))
    def dot_at(i, j):
        qi = apply_rope(q, jnp.full((1, 1), i))
        kj = apply_rope(k, jnp.full((1, 1), j))
        return float(jnp.sum(qi * kj))
    assert dot_at(3, 1) == pytest.approx(dot_at(7, 5), rel=1e-4)


def test_rmsnorm_scale_invariance():
    p = rmsnorm_init(8)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8))
    y1 = rmsnorm(p, x)
    y2 = rmsnorm(p, x * 100.0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4)


def test_causal_attention_is_causal():
    """Changing future tokens must not change past outputs."""
    cfg = get_config("internlm2-1.8b", smoke=True).with_(dtype="float32")
    key = jax.random.PRNGKey(0)
    B, S, H, hd = 1, 12, cfg.n_heads, cfg.resolved_head_dim
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, cfg.n_kv_heads, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, cfg.n_kv_heads, hd))
    out1 = causal_attention(q, k, v, cfg)
    k2 = k.at[:, -1].set(99.0)
    v2 = v.at[:, -1].set(99.0)
    out2 = causal_attention(q, k2, v2, cfg)
    np.testing.assert_allclose(np.asarray(out1[:, :-1]), np.asarray(out2[:, :-1]),
                               atol=1e-5)
    assert not np.allclose(np.asarray(out1[:, -1]), np.asarray(out2[:, -1]))


def test_sliding_window_masks_old_tokens():
    cfg = get_config("internlm2-1.8b", smoke=True).with_(dtype="float32", sliding_window=4)
    key = jax.random.PRNGKey(0)
    B, S, H, hd = 1, 16, cfg.n_heads, cfg.resolved_head_dim
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, cfg.n_kv_heads, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, cfg.n_kv_heads, hd))
    out1 = causal_attention(q, k, v, cfg)
    # tokens more than `window` in the past must not affect the output
    k2 = k.at[:, 0].set(99.0)
    v2 = v.at[:, 0].set(99.0)
    out2 = causal_attention(q, k2, v2, cfg)
    np.testing.assert_allclose(np.asarray(out1[:, 8:]), np.asarray(out2[:, 8:]), atol=1e-5)


def test_sliding_decode_ring_buffer():
    """Decode past the window: slot wraps, oldest entry evicted."""
    cfg = get_config("internlm2-1.8b", smoke=True).with_(dtype="float32", sliding_window=8)
    key = jax.random.PRNGKey(0)
    p = gqa_init(key, cfg)
    x = jax.random.normal(key, (1, 1, cfg.d_model))
    cache = {"k": jnp.zeros((1, 8, cfg.n_kv_heads, cfg.resolved_head_dim)),
             "v": jnp.zeros((1, 8, cfg.n_kv_heads, cfg.resolved_head_dim))}
    out, cache = gqa_decode(p, x, cache, jnp.int32(9), cfg)   # pos 9 -> slot 1
    assert np.isfinite(np.asarray(out)).all()
    assert not np.allclose(np.asarray(cache["k"][:, 1]), 0.0)
    assert np.allclose(np.asarray(cache["k"][:, 2]), 0.0)


@settings(max_examples=8, deadline=None)
@given(tokens=st.integers(8, 64), e=st.sampled_from([2, 4]), k=st.integers(1, 2))
def test_moe_combine_weights_sum(tokens, e, k):
    """Per-token combine weights sum to ≤1 (1 when nothing dropped)."""
    cfg = get_config("phi3.5-moe-42b-a6.6b", smoke=True).with_(
        n_experts=e, moe_top_k=k, capacity_factor=8.0, dtype="float32")
    key = jax.random.PRNGKey(tokens)
    params = moe_init(key, cfg)
    x = jax.random.normal(key, (2, tokens, cfg.d_model), jnp.float32) * 0.1
    out, aux = moe_ffn(params, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) > 0.0


def test_moe_zero_capacity_drops_gracefully():
    cfg = get_config("phi3.5-moe-42b-a6.6b", smoke=True).with_(
        capacity_factor=0.01, dtype="float32")   # almost everything dropped
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model), jnp.float32)
    out, _ = moe_ffn(params, x, cfg)
    assert np.isfinite(np.asarray(out)).all()


def test_mamba_chunked_scan_matches_sequential():
    """Chunked associative scan == naive sequential recurrence."""
    B, S, D, N = 2, 40, 6, 4
    key = jax.random.PRNGKey(0)
    dA = jax.nn.sigmoid(jax.random.normal(key, (B, S, D, N)))
    dBx = jax.random.normal(jax.random.fold_in(key, 1), (B, S, D, N)) * 0.2
    h0 = jnp.zeros((B, D, N))
    out_c, last_c = ssm._chunked_scan(dA, dBx, h0, chunk=8)
    h = h0
    outs = []
    for t in range(S):
        h = dA[:, t] * h + dBx[:, t]
        outs.append(h)
    out_s = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_s), atol=1e-5)
    np.testing.assert_allclose(np.asarray(last_c), np.asarray(out_s[:, -1]), atol=1e-5)


def test_mamba1_decode_steps_match_prefill():
    """Running decode token-by-token == one prefill pass (state equality)."""
    cfg = get_config("falcon-mamba-7b", smoke=True).with_(dtype="float32")
    key = jax.random.PRNGKey(0)
    p = ssm.mamba1_init(key, cfg)
    x = jax.random.normal(key, (1, 6, cfg.d_model)) * 0.5
    out_pre, cache_pre = ssm.mamba1_prefill(p, x, cfg)
    cache = {"h": jnp.zeros_like(cache_pre["h"]), "conv": jnp.zeros_like(cache_pre["conv"])}
    outs = []
    for t in range(6):
        o, cache = ssm.mamba1_decode(p, x[:, t : t + 1], cache, cfg)
        outs.append(o)
    out_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_pre), np.asarray(out_step), atol=1e-4)
    np.testing.assert_allclose(np.asarray(cache_pre["h"]), np.asarray(cache["h"]),
                               atol=1e-4)
