"""Hypothesis properties for async buffered aggregation
(``repro.engine.async_agg``) — the randomized counterpart of the seeded
sync-limit battery in ``tests/test_async_agg.py``.

Three invariants, over hypothesis-chosen weights, delays, and buffer
shapes:

- staleness weights are monotone non-increasing in delay (γ ≤ 1) and
  exactly the raw counts at zero staleness (γ^0 ≡ 1.0);
- at γ = 1 the total merge weight of any flush partition equals the
  synchronous round's total — no weight is created or destroyed by
  buffering, only by the explicit stale/left drops;
- the buffer's pow2 capacity quantization never forks the sampler draw
  sequence: reserve/grow touch no PRNG, so any capacity yields the
  identical cohort stream (the ``pool_capacity`` invariant style of
  ``test_sampler_properties.py``, applied to the delta buffer).
"""
import numpy as np
import pytest

import jax

from repro.engine import sampler
from repro.engine.async_agg import AsyncBuffer, staleness_weights

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as hst  # noqa: E402


@settings(deadline=None, max_examples=60)
@given(w=hst.floats(0.5, 1e4), decay=hst.floats(0.0, 1.0),
       s=hst.integers(0, 12))
def test_staleness_weights_monotone(w, decay, s):
    """w·γ^s is non-increasing in s for γ ∈ [0, 1], stays f32, and is
    bit-identical to the raw weight at s = 0."""
    ws = staleness_weights(np.full(s + 1, w), np.arange(s + 1), decay)
    assert ws.dtype == np.float32
    assert np.all(np.diff(ws) <= 0), "weight grew with staleness"
    assert ws[0] == np.float32(w), "γ^0 perturbed the zero-staleness weight"


@settings(deadline=None, max_examples=60)
@given(ws=hst.lists(hst.floats(0.5, 1e4), min_size=1, max_size=32),
       ss=hst.data())
def test_gamma_one_conserves_total_weight(ws, ss):
    """γ = 1: the flushed effective weights sum to exactly the sync
    total, whatever the per-entry staleness (1.0^s ≡ 1.0 bitwise)."""
    w = np.asarray(ws, np.float32)
    s = np.asarray(ss.draw(hst.lists(hst.integers(0, 10), min_size=len(w),
                                     max_size=len(w))))
    eff = staleness_weights(w, s, 1.0)
    assert np.array_equal(eff, w), "γ=1 changed a weight bit"
    assert np.float32(eff.sum()) == np.float32(w.sum())


@settings(deadline=None, max_examples=40)
@given(cap=hst.integers(1, 200), m=hst.integers(1, 32),
       rounds=hst.integers(1, 4))
def test_reserve_slots_deterministic_and_pow2(cap, m, rounds):
    """Reserve never consults randomness: slot assignment is the lowest
    free rows in draw order, capacity stays pow2 through growth, and
    entries keep consecutive seq numbers across rounds."""
    buf = AsyncBuffer.fresh(cap)
    assert buf.capacity & (buf.capacity - 1) == 0
    seq = 0
    for t in range(rounds):
        buf, slots = buf.reserve(list(range(t * m, t * m + m)), t,
                                 [t + 5] * m, [1.0] * m)
        assert buf.capacity & (buf.capacity - 1) == 0, "capacity not pow2"
        assert len(set(slots.tolist())) == m, "slot collision"
        for e in buf.entries[-m:]:
            assert e.seq == seq
            seq += 1
    occupied = [e.slot for e in buf.entries]
    assert len(set(occupied)) == len(occupied), "two entries share a row"
    assert max(occupied) < buf.capacity


@settings(deadline=None, max_examples=20)
@given(seed=hst.integers(0, 2**31 - 1), cap_exp=hst.integers(0, 7))
def test_buffer_capacity_never_forks_draw_sequence(seed, cap_exp):
    """The sampler key stream is independent of the delta buffer: a
    pow2-padded buffer of ANY capacity leaves every cohort draw
    identical (buffer ops consume no PRNG — the async engine threads
    the same ``draw_cohort`` chain as the sync one)."""
    pool = sampler.cohort_pool(16, {1, 5}, set())
    k_ref = k_buf = jax.random.PRNGKey(seed)
    buf = AsyncBuffer.fresh(1 << cap_exp)
    for t in range(3):
        k_ref, a = sampler.draw_cohort(k_ref, pool, 4)
        k_buf, b = sampler.draw_cohort(k_buf, pool, 4)
        # interleave buffer traffic between draws — must be a no-op for
        # the key chain
        buf, slots = buf.reserve([int(x) for x in np.asarray(b)], t,
                                 [t] * 4, [1.0] * 4)
        buf, _, _ = buf.flush(t, staleness_cap=4)
        assert np.array_equal(np.asarray(a), np.asarray(b))
        assert np.array_equal(np.asarray(k_ref), np.asarray(k_buf)), \
            "buffer traffic forked the PRNG chain"
