"""Parity battery for the device-resident clustering core.

``core.device_clustering`` must be indistinguishable from the numpy
``ClusterState`` everywhere the engine can observe:

  * union-find root resolution matches ``UnionFind`` under random union
    sequences (hypothesis property);
  * observe → merge_round produces the same partition, the same merge
    set, the same remaps under departures;
  * all six strategies produce bitwise-identical trajectories with
    ``cluster_backend`` flipped (clustered + unclustered, static + under
    churn), and device checkpoints round-trip bit-exactly;
  * ARI(device partition, host partition) == 1.0 on all four Non-IID
    settings;
  * the clustering step itself runs with ZERO per-round host transfers
    (enforced with ``jax.transfer_guard``) — the tentpole's reason to
    exist.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.checkpoint import load_server_state, save_server_state
from repro.core.clustering import ClusterState, UnionFind, adjusted_rand_index
from repro.core import device_clustering as dc
from repro.core.device_clustering import DeviceClusters
from repro.data import make_federation
from repro.models import simple

TASK = simple.SYNTH_MLP
LOSS = lambda p, b: simple.loss_fn(p, b, TASK)
EVAL = jax.jit(lambda p, b: simple.accuracy(p, b, TASK))


def _unit_reps(labels, seed=0, d=16, noise=0.02):
    rng = np.random.default_rng(seed)
    anchors = rng.normal(size=(max(labels) + 1, d))
    anchors /= np.linalg.norm(anchors, axis=1, keepdims=True)
    out = []
    for g in labels:
        v = anchors[g] + rng.normal(size=d) * noise
        out.append((v / np.linalg.norm(v)).astype(np.float32))
    return out


def _pair(tau=0.8, n=0):
    return ClusterState(tau=tau), DeviceClusters(tau=tau, capacity=n)


def _leaves_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        if not np.array_equal(np.asarray(x), np.asarray(y)):
            return False
    return True


# ------------------------------------------------------------ union-find
def _check_union_sequence(edges, n=16):
    """Device parent array (pointer-halving resolution) must equal
    numpy ``UnionFind.find`` for every id after this union sequence."""
    uf = UnionFind()
    for i in range(n):
        uf.add(i)
    state = dc.init_state(n, 2)
    state = dc.observe(state, jnp.arange(n, dtype=jnp.int32),
                       jnp.zeros((n, 2), jnp.float32))
    for a, b in edges:
        uf.union(a, b)
        state = dc._jit_union()(state, jnp.int32(a), jnp.int32(b))
    from repro.kernels import ops
    roots = np.asarray(ops.resolve_roots(state.parent))
    for i in range(n):
        assert int(roots[i]) == uf.find(i)


def test_device_unionfind_matches_numpy_seeded_sweep():
    """Deterministic slice of the hypothesis property (see
    ``tests/test_device_properties.py``), runnable without the test
    extra: 30 seeded random union sequences."""
    for seed in range(30):
        rng = np.random.default_rng(seed)
        edges = [tuple(rng.integers(0, 16, 2)) for _ in range(rng.integers(0, 40))]
        _check_union_sequence(edges)


def test_component_labels_worst_case_path():
    """A path graph is the deepest component per node count: the
    fixed-point min-label propagation must still close it."""
    for n in (2, 3, 17, 64, 129):
        adj = np.zeros((n, n), np.float32)
        for i in range(n - 1):
            adj[i, i + 1] = adj[i + 1, i] = 1.0
        labels = np.asarray(dc.component_labels(jnp.asarray(adj)))
        assert (labels == 0).all()
    # two components + an isolated node
    adj = np.zeros((5, 5), np.float32)
    adj[0, 1] = adj[1, 0] = adj[2, 3] = adj[3, 2] = 1.0
    assert np.asarray(dc.component_labels(jnp.asarray(adj))).tolist() == \
        [0, 0, 2, 2, 4]


def test_component_labels_permuted_paths():
    """Regression: chains whose node ids are a RANDOM permutation of
    path order defeated the old fixed ⌈log2 N⌉+1 step count (the
    pointer-jumping 'radius doubles' argument fails off sorted order —
    200/200 wrong at n=64); the fixed-point loop must close them all."""
    for trial in range(25):
        rng = np.random.default_rng(trial)
        n = int(rng.integers(4, 80))
        order = rng.permutation(n)
        adj = np.zeros((n, n), np.float32)
        for x, y in zip(order[:-1], order[1:]):
            adj[x, y] = adj[y, x] = 1.0
        labels = np.asarray(dc.component_labels(jnp.asarray(adj)))
        assert (labels == 0).all(), (trial, n)


def test_arc_chain_partition_parity_permuted_ids():
    """Regression (end-to-end form of the above): 16 clusters on a 10°
    arc with τ=cos(15°) — only arc-adjacent pairs qualify, so the
    τ-graph is a chain through a random id permutation. Both backends
    must collapse it to ONE cluster."""
    tau = float(np.cos(np.deg2rad(15.0)))
    for seed in range(8):
        rng = np.random.default_rng(seed)
        perm = rng.permutation(16)
        ang = {int(cid): 10.0 * pos for pos, cid in enumerate(perm)}
        reps = np.stack(
            [[np.cos(np.deg2rad(ang[i])), np.sin(np.deg2rad(ang[i]))]
             for i in range(16)]).astype(np.float32)
        a, b = _pair(tau=tau, n=16)
        a.observe(range(16), list(reps))
        b.observe(range(16), list(reps))
        a.merge_round()
        b.merge_round()
        assert a.assignment() == b.assignment()
        assert b.n_clusters() == 1


# --------------------------------------------------------------- merging
def test_merge_round_parity_random_groups():
    """Same observations → same merge set and same partition as the
    numpy scan, over a seeded sweep of random group layouts."""
    for seed in range(12):
        rng = np.random.default_rng(seed + 100)
        labels = rng.integers(0, 4, size=int(rng.integers(2, 24))).tolist()
        reps = _unit_reps(labels, seed)
        a, b = _pair(n=len(labels))
        a.observe(range(len(labels)), reps)
        b.observe(range(len(labels)), reps)
        ma, mb = a.merge_round(), b.merge_round()
        assert sorted(ma) == mb
        assert a.assignment() == b.assignment()
        assert a.clusters() == b.clusters()


def test_streaming_and_departures_parity():
    """Clients arriving over rounds + departures (root and non-root):
    partitions, remaps, and uf.parent stay equal throughout."""
    labels = [0, 1, 2, 0, 1, 2, 0, 1, 2, 0, 1, 2]
    reps = _unit_reps(labels, seed=7)
    a, b = _pair(n=4)                        # force device grow() path
    for lo in range(0, 12, 3):
        ids = list(range(lo, lo + 3))
        a.observe(ids, reps[lo:lo + 3])
        b.observe(ids, reps[lo:lo + 3])
        assert sorted(a.merge_round()) == b.merge_round()
        assert a.assignment() == b.assignment()
    for cid in (0, 5, 1, 11):                # roots and members
        ra, rb = a.remove(cid), b.remove(cid)
        assert ra == rb
        assert a.assignment() == b.assignment()
        assert a.uf.parent == b.uf.parent
        # the host mirror must equal the device parent array EXACTLY,
        # tombstoned rows included (regression: removing a cluster's
        # root used to leave the dead row pointing at the new root)
        assert np.array_equal(b._parent,
                              np.asarray(b.state.parent).astype(np.int64))
    # rejoin after departure reuses the tombstoned row
    a.observe([0], [reps[0]])
    b.observe([0], [reps[0]])
    assert sorted(a.merge_round()) == b.merge_round()
    assert a.assignment() == b.assignment()


def test_chain_topology_same_partition_and_bank_merge():
    """Chain τ-graphs where a scan's intermediate keep is not the
    component min: the two backends emit DIFFERENT merge lists (the
    device normalizes to (component_min, member)), but the partition is
    identical and — because ``ClusterBank.merge`` reconstructs groups
    from the list's transitive closure — the merged bank is bitwise
    identical either way."""
    from repro.engine.bank import ClusterBank

    # unit vectors on an arc; τ = cos(45°) admits exactly the 40°-apart
    # pairs: edges {(0,3), (2,3), (1,2)} — a chain 0-3-2-1
    angles = np.deg2rad([0.0, 120.0, 80.0, 40.0])
    reps = np.stack([np.cos(angles), np.sin(angles)], 1).astype(np.float32)
    tau = float(np.cos(np.deg2rad(45.0)))
    a, b = _pair(tau=tau)
    a.observe(range(4), list(reps))
    b.observe(range(4), list(reps))
    counts = {r: len(m) for r, m in a.clusters().items()}
    ma, mb = a.merge_round(), b.merge_round()
    assert sorted(ma) != mb          # the lists DO diverge on a chain...
    assert a.assignment() == b.assignment() == {i: 0 for i in range(4)}
    models = ClusterBank.from_dict(
        {i: {"w": jnp.full((3,), float(i + 1))} for i in range(4)})
    init = {"w": jnp.zeros(3)}
    bank_a = models.merge(ma, counts, init)
    bank_b = models.merge(mb, counts, init)
    assert set(bank_a.keys()) == set(bank_b.keys())   # ...and the banks
    for k in bank_a:                                  # stay bitwise equal
        assert _leaves_equal(bank_a[k], bank_b[k])


def test_pallas_kernels_match_oracles_interpret_mode():
    """Interpret-mode smoke for the two new Pallas kernels (the
    hypothesis sweeps in test_kernels.py need the test extra; this
    always runs): fused masked-cosine+τ candidates and pointer-halving
    root resolution against their jnp oracles."""
    from repro.kernels import ops, ref
    from repro.kernels.cosine_sim import merge_candidates

    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(13, 24)).astype(np.float32))
    live = jnp.asarray(rng.random(13) > 0.3)
    for tau in (-1.0, 0.2, 0.95):
        got = merge_candidates(x, live, tau=tau, bn=8, bk=16,
                               interpret=True)
        want = ref.merge_candidates_ref(x, live, tau)
        assert np.array_equal(np.asarray(got), np.asarray(want))
    parent = np.arange(37, dtype=np.int32)
    for i in rng.permutation(37)[:20]:
        parent[i] = rng.integers(0, i + 1)
    got = ops._resolve_pallas(jnp.asarray(parent), interpret=True)
    want = ref.resolve_roots_ref(jnp.asarray(parent))
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_nearest_and_infer_parity():
    labels = [0, 0, 1, 1, 2, 2]
    reps = _unit_reps(labels, seed=5)
    a, b = _pair()
    a.observe(range(6), reps)
    b.observe(range(6), reps)
    a.merge_round(), b.merge_round()
    for q in _unit_reps([0, 1, 2], seed=11) + [np.ones(16, np.float32) / 4]:
        root_a, near_a, sim_a = a.nearest(q)
        root_b, near_b, sim_b = b.nearest(q)
        assert (root_a, near_a) == (root_b, near_b)
        assert sim_a == pytest.approx(sim_b, abs=1e-6)
        assert a.infer(q)[0] == b.infer(q)[0]
    assert a.objective() == pytest.approx(b.objective(), abs=1e-5)


def test_empty_and_singleton_edge_cases():
    a, b = _pair()
    assert b.merge_round() == [] == a.merge_round()
    assert a.nearest(np.ones(4)) == b.nearest(np.ones(4)) == (None, None, 0.0)
    assert a.remove(3) == b.remove(3) == {}
    assert a.objective() == b.objective() == 0.0
    a.observe([0], _unit_reps([0]))
    b.observe([0], _unit_reps([0]))
    assert a.merge_round() == b.merge_round() == []
    assert a.n_clusters() == b.n_clusters() == 1


# --------------------------------------------------- pad norm-guard (fix)
def test_similarity_matrix_pad_rows_stay_zero():
    """K̃ not a multiple of the 64-row pad quantum: the padded ghost
    rows/cols (their diagonal included) must reach merge_round as exact 0 —
    a τ ≤ 0 run must merge only REAL clusters."""
    labels = [0, 1, 2]                       # K̃ = 3, far from 64
    cs = ClusterState(tau=-1.0)
    cs.observe(range(3), _unit_reps(labels, noise=0.3))
    roots, M = cs.similarity_matrix()
    assert M.shape == (3, 3)
    merges = cs.merge_round()
    touched = {r for pair in merges for r in pair}
    assert touched <= set(range(3))          # no ghost roots ever
    assert cs.n_clusters() == 1


# ------------------------------------------------------- engine trajectories
def _fed(setting="rotated", n_clients=12, seed=3):
    clients, tc, tests = make_federation(setting, n_clients=n_clients,
                                         seed=seed)
    clients = [jax.tree.map(jnp.asarray, c) for c in clients]
    tests = {k: jax.tree.map(jnp.asarray, v) for k, v in tests.items()}
    return clients, tc, tests


def _cfg(**kw):
    kw.setdefault("local_steps", 2)
    kw.setdefault("sample_rate", 0.5)
    kw.setdefault("seed", 0)
    return engine.EngineConfig(**kw)


def _run(backend, name="stocfl", rounds=4, arena=False, setting="rotated"):
    clients, tc, tests = _fed(setting=setting)
    stt = engine.init(name, LOSS, _params(), clients,
                      _cfg(cluster_backend=backend), eval_fn=EVAL,
                      arena=arena)
    for _ in range(rounds):
        stt, _ = engine.run_round(stt)
    return stt, tc, tests


def _params(seed=0):
    return simple.init(jax.random.PRNGKey(seed), TASK)


@pytest.mark.parametrize("name", ["stocfl", "fedavg", "fedprox", "ditto",
                                  "ifca", "cfl"])
def test_backend_parity_all_strategies(name):
    """Bitwise parity with ``cluster_backend`` flipped, for every
    registered strategy (clustered ones exercise the device path; the
    rest prove the flag is inert for them)."""
    a, tc, tests = _run("numpy", name)
    b, _, _ = _run("device", name)
    assert _leaves_equal(a.omega, b.omega)
    assert set(a.models.keys()) == set(b.models.keys())
    for k in a.models:
        assert _leaves_equal(a.models[k], b.models[k])
    if a.clusters is not None:
        assert a.clusters.assignment() == b.clusters.assignment()
        ids = sorted(a.clusters.assignment())
        assert adjusted_rand_index(
            [a.clusters.assignment()[i] for i in ids],
            [b.clusters.assignment()[i] for i in ids]) == 1.0
    assert engine.evaluate(a, tests, tc) == engine.evaluate(b, tests, tc)


def test_backend_parity_with_arena():
    """Arena + device clustering vs arena + numpy: still bitwise."""
    a, _, _ = _run("numpy", arena=True)
    b, _, _ = _run("device", arena=True)
    assert _leaves_equal(a.omega, b.omega)
    assert a.clusters.assignment() == b.clusters.assignment()


@pytest.mark.parametrize("setting", ["pathological", "rotated", "shifted",
                                     "hybrid"])
def test_partition_ari_across_noniid_settings(setting):
    """ARI(device partition, host partition) == 1.0 on every Non-IID
    data skew the paper evaluates (§4.1)."""
    a, _, _ = _run("numpy", rounds=5, setting=setting)
    b, _, _ = _run("device", rounds=5, setting=setting)
    ids = sorted(a.clusters.assignment())
    assert ids == sorted(b.clusters.assignment())
    ari = adjusted_rand_index([a.clusters.assignment()[i] for i in ids],
                              [b.clusters.assignment()[i] for i in ids])
    assert ari == 1.0


def test_backend_parity_under_churn():
    """§5 joins/leaves through the simulator: both backends walk the
    identical trajectory (partition, ω, routed accuracy)."""
    from repro.sim import Join, Leave, Timeline
    from repro.sim.simulate import simulate

    from repro.data.synthetic import rotated_factory
    factory = rotated_factory(n_clusters=4, n_per=128, seed=0)
    events = [Join(t=2, cluster=1), Leave(t=3, cid=0),
              Join(t=4, cluster=2), Leave(t=5, cid=None)]
    outs = {}
    for backend in ("numpy", "device"):
        clients, tc, tests = _fed()
        stt = engine.init("stocfl", LOSS, _params(), clients,
                          _cfg(cluster_backend=backend), eval_fn=EVAL)
        tl = Timeline(events=list(events))
        stt, log = simulate(stt, tl, rounds=7, client_factory=factory,
                            seed=0, eval_every=3, test_sets=tests,
                            true_cluster=tc)
        outs[backend] = (stt, log)
    a, la = outs["numpy"]
    b, lb = outs["device"]
    assert _leaves_equal(a.omega, b.omega)
    assert a.clusters.assignment() == b.clusters.assignment()
    assert a.left == b.left
    assert la.records == lb.records or all(
        {k: v for k, v in ra.items() if not k.startswith("sec")} ==
        {k: v for k, v in rb.items() if not k.startswith("sec")}
        for ra, rb in zip(la.records, lb.records))


def test_checkpoint_roundtrip_device(tmp_path):
    """Device-backend checkpoint: save mid-run, restore into a fresh
    context, continue — bitwise identical to the uninterrupted run
    (partition arrays included)."""
    clients, tc, tests = _fed()
    cfg = _cfg(cluster_backend="device")
    stt = engine.init("stocfl", LOSS, _params(), clients, cfg, eval_fn=EVAL)
    for _ in range(2):
        stt, _ = engine.run_round(stt)
    save_server_state(str(tmp_path / "dev"), stt)

    a = stt
    for _ in range(3):
        a, _ = engine.run_round(a)

    b = engine.init("stocfl", LOSS, _params(), clients, cfg, eval_fn=EVAL)
    b = load_server_state(str(tmp_path / "dev"), b)
    assert isinstance(b.clusters, DeviceClusters)
    assert b.clusters.assignment() == stt.clusters.assignment()
    assert np.array_equal(np.asarray(b.clusters.state.parent),
                          np.asarray(stt.clusters.state.parent))
    assert np.array_equal(np.asarray(b.clusters.state.rep),
                          np.asarray(stt.clusters.state.rep))
    for _ in range(3):
        b, _ = engine.run_round(b)
    assert _leaves_equal(a.omega, b.omega)
    assert a.clusters.assignment() == b.clusters.assignment()
    assert engine.evaluate(a, tests, tc) == engine.evaluate(b, tests, tc)


# --------------------------------------------------------- transfer guard
def test_clustering_step_zero_host_transfers():
    """The acceptance bar: once warm, the jitted clustering transitions
    (observe + merge_round) execute with NO device↔host transfer —
    ``jax.transfer_guard("disallow")`` would raise on any."""
    labels = [0, 1, 2, 0, 1, 2, 0, 1]
    reps = jnp.asarray(np.stack(_unit_reps(labels, seed=1)))
    state = dc.init_state(len(labels), reps.shape[1])
    idx = jnp.arange(len(labels), dtype=jnp.int32)
    # warm-up: compile every shape
    state_w = dc.observe(state, idx, reps)
    dc.merge_round(state_w, 0.8)
    jax.block_until_ready(state_w.parent)

    with jax.transfer_guard("disallow"):
        s2 = dc.observe(state, idx, reps)
        s3, rows, new_roots, counts = dc.merge_round(s2, 0.8)
        jax.block_until_ready((s3.parent, rows, new_roots, counts))
    # sanity: the guarded computation produced the real partition
    assert np.unique(np.asarray(s3.parent)[:len(labels)]).size == 3


def test_observe_shapes_are_quantized():
    """Different per-round new-client counts reuse pow2-padded scatter
    shapes (the compile-set bound under churn)."""
    b = DeviceClusters(tau=0.8, capacity=16)
    reps = _unit_reps([0] * 9, seed=2)
    b.observe([0], reps[:1])
    b.observe([1, 2, 3], reps[1:4])          # pads 3 -> 4
    b.observe([4, 5, 6, 7, 8], reps[4:9])    # pads 5 -> 8
    assert sorted(b.seen) == list(range(9))
    assert b.capacity == 16
    b.observe([16], _unit_reps([0], seed=3))  # beyond capacity: grow
    assert b.capacity == 32
    assert 16 in b.seen and b.uf.find(16) == 16
