"""Per-kernel allclose vs the ref.py oracles, with hypothesis shape/dtype
sweeps, executed in Pallas interpret mode on CPU (TPU is the target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the test extra
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.cosine_sim import cosine_sim
from repro.kernels.prox_update import prox_update_flat
from repro.kernels.ssm_scan import ssm_scan
from repro.kernels import ops

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------ cosine_sim
@settings(max_examples=12, deadline=None)
@given(n=st.integers(3, 70), d=st.integers(2, 160),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_cosine_sim_sweep(n, d, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(n * 1000 + d), (n, d)) * 2).astype(dtype)
    got = cosine_sim(x, bn=16, bk=64, interpret=True)
    want = ref.cosine_sim_ref(x)
    atol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=atol)


def test_cosine_sim_diagonal_ones():
    x = jax.random.normal(KEY, (33, 50))
    got = cosine_sim(x, bn=16, bk=64, interpret=True)
    np.testing.assert_allclose(np.diag(np.asarray(got)), 1.0, atol=1e-5)


def test_cosine_sim_zero_row_safe():
    x = jnp.zeros((8, 16)).at[1].set(1.0)
    got = cosine_sim(x, bn=8, bk=16, interpret=True)
    assert np.isfinite(np.asarray(got)).all()
    assert np.asarray(got)[0, 0] == 0.0       # zero vector -> zero sim


# ------------------------------------------------------------ prox_update
@settings(max_examples=12, deadline=None)
@given(n=st.integers(1, 5000), eta=st.floats(0.0, 1.0), lam=st.floats(0.0, 10.0))
def test_prox_update_sweep(n, eta, lam):
    ks = jax.random.split(jax.random.PRNGKey(n), 4)
    t, o, gt, go = (jax.random.normal(k, (n,)) for k in ks)
    got_t, got_o = prox_update_flat(t, o, gt, go, eta, lam, block=256, interpret=True)
    want_t, want_o = ref.prox_update_ref(t, o, gt, go, eta, lam)
    np.testing.assert_allclose(np.asarray(got_t), np.asarray(want_t), atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_o), np.asarray(want_o), atol=1e-5)


def test_prox_update_lambda_zero_is_sgd():
    """λ=0 degenerates to two independent SGD steps (paper §3.4)."""
    ks = jax.random.split(KEY, 4)
    t, o, gt, go = (jax.random.normal(k, (300,)) for k in ks)
    got_t, got_o = prox_update_flat(t, o, gt, go, 0.1, 0.0, block=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got_t), np.asarray(t - 0.1 * gt), atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_o), np.asarray(o - 0.1 * go), atol=1e-6)


def test_prox_update_pull_toward_omega():
    """Large λ pulls θ toward ω."""
    t = jnp.ones((100,)) * 5.0
    o = jnp.zeros((100,))
    z = jnp.zeros((100,))
    got_t, _ = prox_update_flat(t, o, z, z, 0.1, 1.0, block=64, interpret=True)
    assert float(jnp.max(jnp.abs(got_t))) < 5.0


# ------------------------------------------------------------ ssm_scan
@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 3), s=st.integers(1, 70), d=st.integers(1, 40),
       n=st.integers(1, 16))
def test_ssm_scan_sweep(b, s, d, n):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(b * s + d), 3)
    dA = jax.nn.sigmoid(jax.random.normal(k1, (b, s, d, n)))
    dBx = jax.random.normal(k2, (b, s, d, n)) * 0.1
    C = jax.random.normal(k3, (b, s, n))
    got = ssm_scan(dA, dBx, C, bd=16, chunk=16, interpret=True)
    want = ref.ssm_scan_ref(dA, dBx, C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_ssm_scan_decay_zero_is_pointwise():
    """dA=0 ⇒ h_t = dBx_t: scan degenerates to a pointwise contraction."""
    b, s, d, n = 2, 10, 8, 4
    dBx = jax.random.normal(KEY, (b, s, d, n))
    C = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, n))
    got = ssm_scan(jnp.zeros((b, s, d, n)), dBx, C, bd=8, chunk=8, interpret=True)
    want = jnp.einsum("bsdn,bsn->bsd", dBx, C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ---------------------------------------------- arena pad-and-mask shapes
# The ClientArena pads ragged populations with zero rows; the kernels see
# rep matrices whose tail rows are pad and flat params whose lengths don't
# hit block multiples. Pad rows must be inert: exact zeros in the output,
# zero influence on the real block.

def test_cosine_sim_pad_rows_are_inert():
    """Arena-style (N_real + pad) rep matrix: pallas == ref everywhere,
    pad rows/cols come out exactly 0, and the real block is unchanged
    vs computing on the unpadded matrix alone."""
    n_real, n_pad, d = 11, 21, 40            # pad to a ragged non-multiple
    x = jax.random.normal(KEY, (n_real, d))
    xp = jnp.zeros((n_pad, d)).at[:n_real].set(x)
    got = cosine_sim(xp, bn=16, bk=64, interpret=True)
    want = ref.cosine_sim_ref(xp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    g = np.asarray(got)
    np.testing.assert_array_equal(g[n_real:, :], 0.0)      # mask rows
    np.testing.assert_array_equal(g[:, n_real:], 0.0)      # mask cols
    alone = cosine_sim(x, bn=16, bk=64, interpret=True)
    np.testing.assert_allclose(g[:n_real, :n_real], np.asarray(alone),
                               atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(n_real=st.integers(1, 30), n_pad_extra=st.integers(0, 20))
def test_cosine_sim_padded_sweep(n_real, n_pad_extra):
    x = jax.random.normal(jax.random.PRNGKey(n_real * 31 + n_pad_extra),
                          (n_real, 24))
    xp = jnp.zeros((n_real + n_pad_extra, 24)).at[:n_real].set(x)
    got = np.asarray(cosine_sim(xp, bn=8, bk=32, interpret=True))
    np.testing.assert_allclose(got, np.asarray(ref.cosine_sim_ref(xp)),
                               atol=1e-5)
    assert (got[n_real:] == 0.0).all()


def test_prox_update_ragged_tail_matches_ref():
    """Flat param lengths from ragged-arena models never align to the
    block; the kernel's internal zero-pad must not leak into the tail."""
    for n in [1, 63, 64, 65, 255, 257, 1000]:
        ks = jax.random.split(jax.random.PRNGKey(n), 4)
        t, o, gt, go = (jax.random.normal(k, (n,)) for k in ks)
        got_t, got_o = prox_update_flat(t, o, gt, go, 0.05, 0.3,
                                        block=64, interpret=True)
        want_t, want_o = ref.prox_update_ref(t, o, gt, go, 0.05, 0.3)
        assert got_t.shape == (n,) and got_o.shape == (n,)
        np.testing.assert_allclose(np.asarray(got_t), np.asarray(want_t),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(got_o), np.asarray(want_o),
                                   atol=1e-5)


def test_prox_update_masked_region_identity():
    """Zero gradients on masked entries (what a masked loss produces for
    pad rows) leave θ moving only by the prox pull and ω exactly fixed —
    pad examples cannot train."""
    n = 130
    t = jax.random.normal(KEY, (n,))
    o = jax.random.normal(jax.random.fold_in(KEY, 1), (n,))
    mask = (jnp.arange(n) < 77).astype(jnp.float32)
    gt = jax.random.normal(jax.random.fold_in(KEY, 2), (n,)) * mask
    go = jax.random.normal(jax.random.fold_in(KEY, 3), (n,)) * mask
    got_t, got_o = prox_update_flat(t, o, gt, go, 0.1, 0.5,
                                    block=64, interpret=True)
    pad = np.asarray(mask) == 0.0
    np.testing.assert_allclose(np.asarray(got_o)[pad],
                               np.asarray(o)[pad], atol=1e-6)
    want_pad_t = np.asarray(t)[pad] - 0.1 * 0.5 * (np.asarray(t)[pad]
                                                   - np.asarray(o)[pad])
    np.testing.assert_allclose(np.asarray(got_t)[pad], want_pad_t, atol=1e-6)


# ------------------------------------------------------------ ops wrappers
def test_ops_backend_agreement():
    x = jax.random.normal(KEY, (20, 30))
    np.testing.assert_allclose(
        np.asarray(ops.pairwise_cosine(x, backend="jnp")),
        np.asarray(cosine_sim(x, bn=16, bk=16, interpret=True)), atol=1e-5)


def test_prox_update_tree_matches_flat():
    tree = {"a": jax.random.normal(KEY, (10, 3)), "b": jax.random.normal(KEY, (7,))}
    om = jax.tree.map(lambda x: x * 0.5, tree)
    gt = jax.tree.map(lambda x: x * 0.1, tree)
    go = jax.tree.map(lambda x: x * 0.2, tree)
    th2, om2 = ops.prox_update_tree(tree, om, gt, go, 0.1, 0.5, backend="jnp")
    for kk in tree:
        wt, wo = ref.prox_update_ref(tree[kk].ravel(), om[kk].ravel(),
                                     gt[kk].ravel(), go[kk].ravel(), 0.1, 0.5)
        np.testing.assert_allclose(np.asarray(th2[kk]).ravel(), np.asarray(wt), atol=1e-6)
        np.testing.assert_allclose(np.asarray(om2[kk]).ravel(), np.asarray(wo), atol=1e-6)


# ------------------------------------------------- merge_candidates (fused)
@settings(max_examples=12, deadline=None)
@given(n=st.integers(3, 70), d=st.integers(2, 160),
       tau=st.floats(-1.0, 1.0), seed=st.integers(0, 100))
def test_merge_candidates_sweep(n, d, tau, seed):
    """Fused masked-cosine+τ kernel ≡ jnp oracle over shapes, τ, and
    random live masks (interpret mode; Mosaic on real TPU)."""
    from repro.kernels.cosine_sim import merge_candidates
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, d))
    live = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.7, (n,))
    got = merge_candidates(x, live, tau=float(tau), bn=16, bk=64,
                           interpret=True)
    want = ref.merge_candidates_ref(x, live, float(tau))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_merge_candidates_diagonal_and_dead_rows():
    """τ=-1 admits every pair EXCEPT the diagonal and dead rows."""
    from repro.kernels.cosine_sim import merge_candidates
    x = jax.random.normal(KEY, (9, 12))
    live = jnp.array([1, 1, 0, 1, 1, 1, 0, 1, 1], bool)
    adj = np.asarray(merge_candidates(x, live, tau=-1.0, bn=8, bk=16,
                                      interpret=True))
    assert (np.diag(adj) == 0).all()
    assert (adj[2] == 0).all() and (adj[:, 6] == 0).all()
    lv = np.asarray(live)
    expect = np.outer(lv, lv) * (1 - np.eye(9))
    np.testing.assert_array_equal(adj, expect)


# --------------------------------------------- resolve_roots (pointer halving)
@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 200), seed=st.integers(0, 1000))
def test_resolve_roots_pallas_sweep(n, seed):
    """Pointer-halving kernel resolves ANY random forest to the same
    roots as the jnp oracle (interpret mode)."""
    rng = np.random.default_rng(seed)
    parent = np.arange(n, dtype=np.int32)
    for i in rng.permutation(n)[: n // 2]:      # random valid forest:
        parent[i] = rng.integers(0, i + 1)      # parent id <= own id
    got = np.asarray(ops._resolve_pallas(jnp.asarray(parent),
                                         interpret=True))
    want = np.asarray(ref.resolve_roots_ref(jnp.asarray(parent)))
    np.testing.assert_array_equal(got, want)
    # and the oracle itself is a fixed point: every root self-parents
    np.testing.assert_array_equal(want, np.asarray(want)[want])


def test_resolve_roots_worst_case_chain():
    """A maximal-depth chain still resolves in the kernel's static
    ⌈log2 N⌉+1 steps."""
    n = 129
    parent = jnp.asarray(np.maximum(np.arange(n, dtype=np.int32) - 1, 0))
    got = np.asarray(ops._resolve_pallas(parent, interpret=True))
    np.testing.assert_array_equal(got, np.zeros(n, np.int32))
