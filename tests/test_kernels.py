"""Per-kernel allclose vs the ref.py oracles, with hypothesis shape/dtype
sweeps, executed in Pallas interpret mode on CPU (TPU is the target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the test extra
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.cosine_sim import cosine_sim
from repro.kernels.prox_update import prox_update_flat
from repro.kernels.ssm_scan import ssm_scan
from repro.kernels import ops

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------ cosine_sim
@settings(max_examples=12, deadline=None)
@given(n=st.integers(3, 70), d=st.integers(2, 160),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_cosine_sim_sweep(n, d, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(n * 1000 + d), (n, d)) * 2).astype(dtype)
    got = cosine_sim(x, bn=16, bk=64, interpret=True)
    want = ref.cosine_sim_ref(x)
    atol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=atol)


def test_cosine_sim_diagonal_ones():
    x = jax.random.normal(KEY, (33, 50))
    got = cosine_sim(x, bn=16, bk=64, interpret=True)
    np.testing.assert_allclose(np.diag(np.asarray(got)), 1.0, atol=1e-5)


def test_cosine_sim_zero_row_safe():
    x = jnp.zeros((8, 16)).at[1].set(1.0)
    got = cosine_sim(x, bn=8, bk=16, interpret=True)
    assert np.isfinite(np.asarray(got)).all()
    assert np.asarray(got)[0, 0] == 0.0       # zero vector -> zero sim


# ------------------------------------------------------------ prox_update
@settings(max_examples=12, deadline=None)
@given(n=st.integers(1, 5000), eta=st.floats(0.0, 1.0), lam=st.floats(0.0, 10.0))
def test_prox_update_sweep(n, eta, lam):
    ks = jax.random.split(jax.random.PRNGKey(n), 4)
    t, o, gt, go = (jax.random.normal(k, (n,)) for k in ks)
    got_t, got_o = prox_update_flat(t, o, gt, go, eta, lam, block=256, interpret=True)
    want_t, want_o = ref.prox_update_ref(t, o, gt, go, eta, lam)
    np.testing.assert_allclose(np.asarray(got_t), np.asarray(want_t), atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_o), np.asarray(want_o), atol=1e-5)


def test_prox_update_lambda_zero_is_sgd():
    """λ=0 degenerates to two independent SGD steps (paper §3.4)."""
    ks = jax.random.split(KEY, 4)
    t, o, gt, go = (jax.random.normal(k, (300,)) for k in ks)
    got_t, got_o = prox_update_flat(t, o, gt, go, 0.1, 0.0, block=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got_t), np.asarray(t - 0.1 * gt), atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_o), np.asarray(o - 0.1 * go), atol=1e-6)


def test_prox_update_pull_toward_omega():
    """Large λ pulls θ toward ω."""
    t = jnp.ones((100,)) * 5.0
    o = jnp.zeros((100,))
    z = jnp.zeros((100,))
    got_t, _ = prox_update_flat(t, o, z, z, 0.1, 1.0, block=64, interpret=True)
    assert float(jnp.max(jnp.abs(got_t))) < 5.0


# ------------------------------------------------------------ ssm_scan
@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 3), s=st.integers(1, 70), d=st.integers(1, 40),
       n=st.integers(1, 16))
def test_ssm_scan_sweep(b, s, d, n):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(b * s + d), 3)
    dA = jax.nn.sigmoid(jax.random.normal(k1, (b, s, d, n)))
    dBx = jax.random.normal(k2, (b, s, d, n)) * 0.1
    C = jax.random.normal(k3, (b, s, n))
    got = ssm_scan(dA, dBx, C, bd=16, chunk=16, interpret=True)
    want = ref.ssm_scan_ref(dA, dBx, C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_ssm_scan_decay_zero_is_pointwise():
    """dA=0 ⇒ h_t = dBx_t: scan degenerates to a pointwise contraction."""
    b, s, d, n = 2, 10, 8, 4
    dBx = jax.random.normal(KEY, (b, s, d, n))
    C = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, n))
    got = ssm_scan(jnp.zeros((b, s, d, n)), dBx, C, bd=8, chunk=8, interpret=True)
    want = jnp.einsum("bsdn,bsn->bsd", dBx, C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ------------------------------------------------------------ ops wrappers
def test_ops_backend_agreement():
    x = jax.random.normal(KEY, (20, 30))
    np.testing.assert_allclose(
        np.asarray(ops.pairwise_cosine(x, backend="jnp")),
        np.asarray(cosine_sim(x, bn=16, bk=16, interpret=True)), atol=1e-5)


def test_prox_update_tree_matches_flat():
    tree = {"a": jax.random.normal(KEY, (10, 3)), "b": jax.random.normal(KEY, (7,))}
    om = jax.tree.map(lambda x: x * 0.5, tree)
    gt = jax.tree.map(lambda x: x * 0.1, tree)
    go = jax.tree.map(lambda x: x * 0.2, tree)
    th2, om2 = ops.prox_update_tree(tree, om, gt, go, 0.1, 0.5, backend="jnp")
    for kk in tree:
        wt, wo = ref.prox_update_ref(tree[kk].ravel(), om[kk].ravel(),
                                     gt[kk].ravel(), go[kk].ravel(), 0.1, 0.5)
        np.testing.assert_allclose(np.asarray(th2[kk]).ravel(), np.asarray(wt), atol=1e-6)
        np.testing.assert_allclose(np.asarray(om2[kk]).ravel(), np.asarray(wo), atol=1e-6)
