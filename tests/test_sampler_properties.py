"""Hypothesis properties for the on-device cohort sampler
(``repro.engine.sampler``) — the randomized counterpart of the seeded
sweep in ``tests/test_round_scan.py``: no duplicate draws, cohort size
= ⌈rate·live⌉ clipped to the pool, departed/unavailable ids never
drawn, and identical draw sequences from identical keys.
"""
import jax
import numpy as np
import pytest

from repro.engine import sampler

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as hst  # noqa: E402


@settings(deadline=None, max_examples=40)
@given(n=hst.integers(2, 64), rate=hst.floats(0.05, 1.0),
       seed=hst.integers(0, 2**31 - 1), data=hst.data())
def test_sampler_properties(n, rate, seed, data):
    """No duplicates, size = ⌈rate·live⌉ (pool-clipped), masked ids
    never drawn — over hypothesis-chosen populations and masks."""
    left = set(data.draw(hst.sets(hst.integers(0, n - 1), max_size=n - 1)))
    avail = sorted(set(range(n)) - left)
    busy = set(data.draw(hst.sets(hst.sampled_from(avail),
                                  max_size=len(avail) - 1))) \
        if len(avail) > 1 else set()
    pool = sampler.cohort_pool(n, left, busy)
    live = n - len(left)
    m = sampler.cohort_size(rate, live, int(pool.sum()))
    assert m == min(int(np.ceil(rate * live)), int(pool.sum()))
    if m == 0:
        return
    key = jax.random.PRNGKey(seed)
    _, ids = sampler.draw_cohort(key, pool, m)
    ids = set(np.asarray(ids).tolist())
    assert len(ids) == m, "duplicate draw"
    assert not (ids & left), "drew a departed client"
    assert not (ids & busy), "drew an unavailable client"


@settings(deadline=None, max_examples=20)
@given(seed=hst.integers(0, 2**31 - 1))
def test_sampler_deterministic_from_key(seed):
    """Identical key -> identical draw sequence and identically-chained
    advanced keys."""
    pool = sampler.cohort_pool(16, {1, 5}, {2})
    k1 = k2 = jax.random.PRNGKey(seed)
    for _ in range(3):
        k1, a = sampler.draw_cohort(k1, pool, 4)
        k2, b = sampler.draw_cohort(k2, pool, 4)
        assert np.array_equal(np.asarray(a), np.asarray(b))
        assert np.array_equal(np.asarray(k1), np.asarray(k2))
