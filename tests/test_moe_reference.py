"""GShard einsum dispatch vs a naive per-token MoE reference."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.moe import moe_ffn, moe_init


def _naive_moe(params, x, cfg):
    """Per-token loop: top-k experts, normalized gates, no capacity."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    logits = x @ params["router"]["w"]
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_vals, top_idx = jax.lax.top_k(gates, k)
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)

    def expert_ffn(e, t):
        h = jax.nn.silu(t @ params["experts"]["w_gate"][e]) * (t @ params["experts"]["w_up"][e])
        return h @ params["experts"]["w_down"][e]

    out = jnp.zeros_like(x)
    for b in range(B):
        for s in range(S):
            acc = jnp.zeros((d,))
            for j in range(k):
                e = int(top_idx[b, s, j])
                acc += top_vals[b, s, j] * expert_ffn(e, x[b, s])
            out = out.at[b, s].set(acc)
    return out


def test_moe_matches_naive_reference():
    cfg = get_config("phi3.5-moe-42b-a6.6b", smoke=True).with_(
        d_model=32, d_ff=16, n_experts=4, moe_top_k=2, capacity_factor=16.0,
        dtype="float32")
    key = jax.random.PRNGKey(0)
    params = moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 6, 32)) * 0.5
    got, _ = moe_ffn(params, x, cfg)
    want = _naive_moe(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_moe_group_size_invariance_without_drops():
    """With ample capacity, dispatch group size must not change the math
    (the §Perf #1 knob is a pure perf transform)."""
    cfg = get_config("phi3.5-moe-42b-a6.6b", smoke=True).with_(
        d_model=32, d_ff=16, capacity_factor=16.0, dtype="float32")
    key = jax.random.PRNGKey(2)
    params = moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, 32)) * 0.5
    out_big, _ = moe_ffn(params, x, cfg, group_size=32)
    out_small, _ = moe_ffn(params, x, cfg, group_size=8)
    np.testing.assert_allclose(np.asarray(out_big), np.asarray(out_small),
                               atol=1e-4, rtol=1e-4)


def test_ssm_cache_is_constant_in_seq_len():
    """The long_500k story: SSM decode state is O(1) in context length."""
    from repro.models import build
    cfg = get_config("falcon-mamba-7b", smoke=True)
    model = build(cfg)
    c1 = jax.eval_shape(lambda: model.make_cache(1, 1024))
    c2 = jax.eval_shape(lambda: model.make_cache(1, 524_288))
    assert jax.tree.map(lambda a: a.shape, c1) == jax.tree.map(lambda a: a.shape, c2)
    # dense full-attention cache, by contrast, scales with seq
    cfg_d = get_config("llama3-8b", smoke=True)
    model_d = build(cfg_d)
    d1 = jax.eval_shape(lambda: model_d.make_cache(1, 1024))
    d2 = jax.eval_shape(lambda: model_d.make_cache(1, 2048))
    s1 = jax.tree.leaves(d1)[0].shape
    s2 = jax.tree.leaves(d2)[0].shape
    assert s2[2] == 2 * s1[2]
    # ...unless the sliding-window variant caps it (the long_500k fix)
    cfg_w = cfg_d.with_(sliding_window=512)
    model_w = build(cfg_w)
    w1 = jax.eval_shape(lambda: model_w.make_cache(1, 524_288))
    assert jax.tree.leaves(w1)[0].shape[2] == 512
