"""Hypothesis properties for the client-axis sharding helpers.

``sharding.specs``'s cohort helpers (``client_axes``,
``mesh_client_count``, ``align_cohort_chunk``, ``cohort_spec``) read
only ``mesh.axis_names`` / ``mesh.shape``, so the properties sweep FAKE
meshes (SimpleNamespace) over arbitrary axis layouts without needing
devices — the whole file runs on one CPU device in tier-1. The few
placement properties that need real shardings use the real local
mesh and scale with however many devices the run has.

Pinned invariants (docs/SHARDING.md §padding):

- ``align_cohort_chunk`` returns the least multiple of the mesh's
  client-device count ≥ chunk; it is idempotent, monotone, and the
  identity for single-device/no-mesh cases.
- pow2 quantization composes with mesh alignment: for pow2 mesh sizes
  (the only sizes CI runs), ``align_cohort_chunk(pool_capacity(n))``
  IS ``pool_capacity(n)`` whenever the pool bracket ≥ the device count
  — which is why the sampler pool is deliberately not mesh-aligned.
- ``cohort_spec`` shards exactly the leading axis, over exactly
  ``client_axes``, and ``mesh_client_count`` is the product of those
  axes' sizes.
- ``param_shardings`` / ``place_cohort`` relax any non-divisible axis
  to replicated instead of erroring (divisibility safety).

CI runs these with the ``[test]`` extra; deterministic seeded slices of
the same invariants live in ``tests/test_sharding_launch.py`` for
extra-less environments.
"""
from types import SimpleNamespace

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the test extra
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax
from jax.sharding import PartitionSpec as P

from repro.engine import sampler
from repro.sharding import specs

# ---------------------------------------------------------- fake meshes
CLIENT_AXES = ("pod", "data", "clients")
OTHER_AXES = ("model", "expert")


@st.composite
def fake_meshes(draw):
    """A mesh-shaped object: 1-4 named axes with sizes 1-16, any mix of
    client-carrying and other axes, in any order."""
    n_axes = draw(st.integers(1, 4))
    names = draw(st.permutations(CLIENT_AXES + OTHER_AXES))[:n_axes]
    shape = {a: draw(st.integers(1, 16)) for a in names}
    return SimpleNamespace(axis_names=tuple(names), shape=shape)


@st.composite
def pow2_client_meshes(draw):
    """The meshes CI actually runs: 1-D ("clients",) with pow2 size."""
    n = 2 ** draw(st.integers(0, 4))
    return SimpleNamespace(axis_names=("clients",), shape={"clients": n})


# ------------------------------------------------- align_cohort_chunk
@settings(max_examples=200, deadline=None)
@given(fake_meshes(), st.integers(1, 4096))
def test_align_is_least_dividing_multiple(mesh, chunk):
    n = specs.mesh_client_count(mesh)
    a = specs.align_cohort_chunk(chunk, mesh)
    assert a >= chunk
    assert a % max(n, 1) == 0
    assert a - chunk < max(n, 1), "not the LEAST dividing multiple"


@settings(max_examples=200, deadline=None)
@given(fake_meshes(), st.integers(1, 4096))
def test_align_idempotent(mesh, chunk):
    a = specs.align_cohort_chunk(chunk, mesh)
    assert specs.align_cohort_chunk(a, mesh) == a


@settings(max_examples=100, deadline=None)
@given(st.integers(0, 4096))
def test_align_identity_without_mesh(chunk):
    assert specs.align_cohort_chunk(chunk, None) == chunk


@settings(max_examples=100, deadline=None)
@given(fake_meshes(), st.integers(1, 2048), st.integers(1, 2048))
def test_align_monotone(mesh, a, b):
    lo, hi = min(a, b), max(a, b)
    assert (specs.align_cohort_chunk(lo, mesh)
            <= specs.align_cohort_chunk(hi, mesh))


# --------------------------------------- pow2 ∘ mesh-alignment composition
@settings(max_examples=200, deadline=None)
@given(pow2_client_meshes(), st.integers(1, 100_000))
def test_pool_capacity_already_mesh_aligned(mesh, n):
    """pow2 divides pow2: whenever the pool bracket is at least the
    device count, mesh-aligning it is the identity — the sampler's pool
    (and Ditto's personal capacity, and the bank's row capacity) need
    no mesh-specific padding. This is the invariant that lets the
    engine leave ``pool_capacity`` untouched by the mesh (changing the
    pool shape would fork the draw sequence and break parity)."""
    cap = sampler.pool_capacity(n)
    ndev = specs.mesh_client_count(mesh)
    if cap >= ndev:
        assert specs.align_cohort_chunk(cap, mesh) == cap


@settings(max_examples=100, deadline=None)
@given(pow2_client_meshes(), st.integers(1, 100_000))
def test_arena_capacity_alignment_survives_doubling(mesh, n):
    """engine.init mesh-aligns the arena row capacity once; ClientArena
    grows by pow2 doubling, which must preserve the alignment."""
    cap = specs.align_cohort_chunk(n, mesh)
    ndev = specs.mesh_client_count(mesh)
    for _ in range(4):
        cap *= 2
        assert cap % max(ndev, 1) == 0


# -------------------------------------- cohort_spec / mesh_client_count
@settings(max_examples=200, deadline=None)
@given(fake_meshes(), st.integers(0, 5))
def test_cohort_spec_consistent_with_client_axes(mesh, ndim):
    axes = specs.client_axes(mesh)
    spec = specs.cohort_spec(mesh, ndim)
    if ndim == 0 or not axes:
        assert spec == P()
        return
    lead = spec[0]
    lead_axes = lead if isinstance(lead, tuple) else (lead,)
    assert tuple(lead_axes) == axes, "leading axis must cover client_axes"
    assert all(s is None for s in spec[1:]), "only the leading axis shards"
    n = 1
    for a in lead_axes:
        n *= mesh.shape[a]
    assert n == specs.mesh_client_count(mesh)


@settings(max_examples=200, deadline=None)
@given(fake_meshes())
def test_client_axes_subset_and_order(mesh):
    axes = specs.client_axes(mesh)
    assert set(axes) <= set(CLIENT_AXES)
    assert set(axes) == set(mesh.axis_names) & set(CLIENT_AXES)
    # canonical order, independent of mesh axis order
    assert list(axes) == [a for a in CLIENT_AXES if a in axes]


# ------------------------------------------------ divisibility relaxing
@settings(max_examples=200, deadline=None)
@given(st.integers(1, 64), st.integers(1, 8), st.integers(0, 3))
def test_divisible_predicate_matches_arithmetic(rows, ndev, trailing):
    mesh = SimpleNamespace(axis_names=("clients",),
                           shape={"clients": ndev})
    x = SimpleNamespace(shape=(rows,) + (3,) * trailing, ndim=1 + trailing)
    spec = specs.cohort_spec(mesh, x.ndim)
    assert specs._divisible(x, spec, mesh) == (rows % ndev == 0)


def test_place_cohort_relaxes_non_divisible_rows():
    """Real-mesh check: a row count that does not divide the device
    count must place replicated (every device holds all rows), while a
    dividing one splits — silently, no error either way."""
    ndev = len(jax.devices())
    mesh = jax.sharding.Mesh(np.array(jax.devices()), ("clients",))
    ok = specs.place_cohort(jax.numpy.zeros((4 * ndev, 3)), mesh)
    assert ok.sharding.spec[0] == ("clients" if ndev > 1 else None) \
        or ndev == 1
    bad = specs.place_cohort(jax.numpy.zeros((4 * ndev + 1, 3)), mesh)
    assert all(s is None for s in bad.sharding.spec), \
        "non-divisible rows must relax to replicated"


def test_param_shardings_divisible_fallback_probe():
    """``param_shardings`` applies the same relax-to-replicated rule to
    the MaxText-style rule table (the existing tier-1 coverage in
    test_sharding_launch.py pins the full table; this probes just the
    divisibility interaction on whatever devices this run has)."""
    ndev = len(jax.devices())
    if ndev < 2:
        pytest.skip("needs multi-device (REPRO_FORCE_HOST_DEVICES)")
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()).reshape(ndev, 1), ("data", "model"))
    params = {"layers": {"attn": {"wq": jax.numpy.zeros((ndev * 2, 4)),
                                  "odd": {"wq": jax.numpy.zeros((ndev + 1, 4))}}}}
    sh = specs.param_shardings(params, mesh)
    assert sh["layers"]["attn"]["wq"].spec[0] == "data"
    assert all(s is None for s in sh["layers"]["attn"]["odd"]["wq"].spec)


# --------------------------------------------------- mesh_fingerprint
def test_mesh_fingerprint_distinguishes_sizes_and_none():
    """The scan-cache static: distinct device counts (and the no-mesh
    case) must hash differently, same mesh twice must hash the same."""
    assert specs.mesh_fingerprint(None) is None
    devs = jax.devices()
    m1 = jax.sharding.Mesh(np.array(devs[:1]), ("clients",))
    assert specs.mesh_fingerprint(m1) == specs.mesh_fingerprint(
        jax.sharding.Mesh(np.array(devs[:1]), ("clients",)))
    assert hash(specs.mesh_fingerprint(m1)) is not None
    if len(devs) > 1:
        m2 = jax.sharding.Mesh(np.array(devs[:2]), ("clients",))
        assert specs.mesh_fingerprint(m1) != specs.mesh_fingerprint(m2)
    d = jax.sharding.Mesh(np.array(devs[:1]), ("data",))
    assert specs.mesh_fingerprint(m1) != specs.mesh_fingerprint(d)
