"""Regression: MLA with q head-dim ≠ v head-dim through the CHUNKED
attention path (S > query-chunk) — caught by the deepseek dry-run."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.models.attention as attn_mod
from repro.configs import get_config
from repro.models import build


def test_mla_chunked_equals_unchunked(monkeypatch):
    cfg = get_config("deepseek-v2-236b", smoke=True).with_(dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 24), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    logits_big, _ = model.forward_train(params, batch)       # S < chunk: unchunked
    monkeypatch.setattr(attn_mod, "_CHUNK", 8)               # force chunked path
    logits_small, _ = model.forward_train(params, batch)
    np.testing.assert_allclose(np.asarray(logits_big), np.asarray(logits_small),
                               atol=2e-3, rtol=2e-3)


def test_gqa_chunked_equals_unchunked(monkeypatch):
    cfg = get_config("llama3-8b", smoke=True).with_(dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    logits_big, _ = model.forward_train(params, batch)
    monkeypatch.setattr(attn_mod, "_CHUNK", 8)
    logits_small, _ = model.forward_train(params, batch)
    np.testing.assert_allclose(np.asarray(logits_big), np.asarray(logits_small),
                               atol=2e-3, rtol=2e-3)
