"""Sharding rules + launch-layer units (host-scale, 1 CPU device).

Includes deterministic seeded slices of the cohort-helper invariants
whose full hypothesis sweep lives in ``tests/test_shard_properties.py``
(which needs the ``[test]`` extra; these run everywhere).
"""
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.dryrun import collective_bytes, model_flops
from repro.launch.mesh import make_client_mesh, make_host_mesh
from repro.models import build
from repro.models.config import INPUT_SHAPES, InputShape
from repro.sharding import ShardCtx, param_shardings, spec_for_path, specs


def test_spec_rules():
    ctx = ShardCtx.__new__(ShardCtx)
    ctx.mesh = None
    ctx.logical_map = {"tp": "model", "fsdp": "data", "batch": ("pod", "data"),
                       "expert": "model"}
    assert spec_for_path("layers/attn/wq", 2, ctx) == P("data", "model")
    assert spec_for_path("layers/mlp/w_down", 2, ctx) == P("model", "data")
    assert spec_for_path("embed", 2, ctx) == P("model", None)
    assert spec_for_path("lm_head", 2, ctx) == P(None, "model")
    # stacked (leading L axis) pads with None
    assert spec_for_path("layers/attn/wq", 3, ctx) == P(None, "data", "model")
    assert spec_for_path("layers/mlp/experts/w_gate", 4, ctx) == P(None, "model", "data", None)
    assert spec_for_path("final_norm/scale", 1, ctx) == P(None)


def test_param_shardings_divisibility_relaxed():
    mesh = make_host_mesh(model_parallel=1)
    cfg = get_config("qwen2-1.5b", smoke=True)
    model = build(cfg)
    pspecs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    shardings = param_shardings(pspecs, mesh)
    # every sharded dim must divide (relaxation guarantees it)
    for s, spec in zip(jax.tree.leaves(pspecs), jax.tree.leaves(shardings)):
        for dim, ax in zip(s.shape, spec.spec):
            if ax is not None:
                axes = ax if isinstance(ax, tuple) else (ax,)
                n = 1
                for a in axes:
                    n *= mesh.shape[a]
                assert dim % n == 0


def test_collective_bytes_parser():
    hlo = """
  %ar = f32[8,32]{1,0} all-reduce(%dot), channel_id=1
  %ag = bf16[1024]{0} all-gather(%x), dims={0}
  %rs.1 = f32[16]{0} reduce-scatter(%y), dims={0}
  %a2a = f32[4,4]{1,0} all-to-all(%z)
  %cp = s32[10]{0} collective-permute(%w)
  %done = f32[8,32]{1,0} all-reduce-done(%ar)
  %other = f32[99]{0} add(%a, %b)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 8 * 32 * 4
    assert out["all-gather"] == 1024 * 2
    assert out["reduce-scatter"] == 16 * 4
    assert out["all-to-all"] == 16 * 4
    assert out["collective-permute"] == 40
    assert out["counts"]["all-reduce"] == 1          # -done not double counted
    assert out["total"] == sum(out[k] for k in
                               ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute"))


def test_model_flops_moe_active():
    cfg = get_config("phi3.5-moe-42b-a6.6b", smoke=True)
    model = build(cfg)
    shape = INPUT_SHAPES["train_4k"]
    mf_train = model_flops(cfg, model, shape, "train")
    mf_prefill = model_flops(cfg, model, INPUT_SHAPES["prefill_32k"], "prefill")
    assert mf_train > 0 and mf_prefill > 0
    # train counts both bi-level models: 6x forward cost
    assert mf_train == pytest.approx(
        6 * mf_prefill * (shape.global_batch * shape.seq_len)
        / (INPUT_SHAPES["prefill_32k"].global_batch * INPUT_SHAPES["prefill_32k"].seq_len))


def test_lower_step_on_host_mesh():
    """The step builders lower + compile on a 1-device host mesh."""
    from repro.launch.steps import lower_step
    mesh = make_host_mesh()
    cfg = get_config("qwen2-1.5b", smoke=True)
    model = build(cfg)
    shape = InputShape("t", 64, 2, "train")
    for kind in ["train", "prefill"]:
        lowered, _ = lower_step(model, shape, mesh, kind)
        compiled = lowered.compile()
        assert compiled.cost_analysis() is not None
    dshape = InputShape("d", 64, 2, "decode")
    lowered, _ = lower_step(model, dshape, mesh, "decode")
    assert lowered.compile() is not None


# ----------------------- cohort-helper invariants (deterministic slices)
def _fake_mesh(**shape):
    return SimpleNamespace(axis_names=tuple(shape), shape=shape)


def test_align_cohort_chunk_least_multiple_and_idempotent():
    for ndev in (1, 2, 3, 4, 7, 8, 16):
        mesh = _fake_mesh(clients=ndev)
        for chunk in (1, 2, 5, 8, 15, 16, 31, 1000):
            a = specs.align_cohort_chunk(chunk, mesh)
            assert a >= chunk and a % ndev == 0 and a - chunk < ndev
            assert specs.align_cohort_chunk(a, mesh) == a
    assert specs.align_cohort_chunk(13, None) == 13
    assert specs.align_cohort_chunk(0, _fake_mesh(clients=4)) == 0


def test_pool_capacity_is_already_mesh_aligned():
    """pow2 divides pow2: the sampler pool bracket never needs
    mesh-specific padding on the pow2 mesh sizes CI runs (changing the
    pool shape would fork the draw sequence — docs/SHARDING.md)."""
    from repro.engine.sampler import pool_capacity
    for ndev in (1, 2, 4, 8):
        mesh = _fake_mesh(clients=ndev)
        for n in (1, 3, 8, 12, 100, 4000):
            cap = pool_capacity(n)
            if cap >= ndev:
                assert specs.align_cohort_chunk(cap, mesh) == cap


def test_cohort_spec_tracks_client_axes():
    m = _fake_mesh(pod=2, data=4, model=8)
    assert specs.client_axes(m) == ("pod", "data")
    assert specs.mesh_client_count(m) == 8
    assert specs.cohort_spec(m, 3) == P(("pod", "data"), None, None)
    assert specs.cohort_spec(m, 0) == P()
    c = _fake_mesh(clients=4)
    assert specs.cohort_spec(c, 2) == P("clients", None)
    assert specs.cohort_spec(_fake_mesh(model=4), 2) == P()


def test_place_and_constrain_relax_non_divisible():
    """Divisibility safety on the real local mesh: dividing rows shard,
    non-dividing rows replicate — silently, both eagerly (place_cohort)
    and in-trace (constrain_cohort)."""
    ndev = len(jax.devices())
    mesh = make_client_mesh()
    ok = specs.place_cohort(jnp.zeros((4 * ndev, 3)), mesh)
    if ndev > 1:
        assert ok.sharding.spec[0] == "clients"
    bad = specs.place_cohort(jnp.zeros((4 * ndev + 1, 3)), mesh)
    if ndev > 1:
        assert all(s is None for s in bad.sharding.spec)
    else:
        # one device divides everything — nothing to relax
        assert bad.sharding.spec[0] == "clients"
    out = jax.jit(lambda x: specs.constrain_cohort(x, mesh))(
        jnp.zeros((4 * ndev + 1, 3)))
    assert np.asarray(out).shape == (4 * ndev + 1, 3)


def test_mesh_fingerprint_identity():
    """Scan-cache static: same mesh → same key, different size/axes/no
    mesh → different key."""
    assert specs.mesh_fingerprint(None) is None
    devs = jax.devices()
    m1 = jax.sharding.Mesh(np.array(devs[:1]), ("clients",))
    m1b = jax.sharding.Mesh(np.array(devs[:1]), ("clients",))
    assert specs.mesh_fingerprint(m1) == specs.mesh_fingerprint(m1b)
    assert specs.mesh_fingerprint(m1) != specs.mesh_fingerprint(
        jax.sharding.Mesh(np.array(devs[:1]), ("data",)))
    if len(devs) > 1:
        m2 = jax.sharding.Mesh(np.array(devs[:2]), ("clients",))
        assert specs.mesh_fingerprint(m1) != specs.mesh_fingerprint(m2)
