"""Per-assigned-architecture smoke tests (deliverable f).

Each instantiates the REDUCED same-family variant (≤2 layers core,
d_model≤512, ≤4 experts) and runs one forward/train step + one decode
step on CPU, asserting output shapes and no NaNs. The FULL configs are
exercised only via the dry-run (ShapeDtypeStructs, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, CLI_ALIASES, get_config
from repro.data import synthetic_lm_batch
from repro.models import build
from repro.models.config import InputShape

S, B = 32, 2


def _batch(cfg, key):
    b = synthetic_lm_batch(cfg, S, B, seed=0)
    return jax.tree.map(jnp.asarray, b)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_reduced_variant_limits(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.n_layers <= 4 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, 0)
    loss, grads = jax.jit(jax.value_and_grad(model.loss_fn))(params, batch)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves)
    # one SGD step changes the loss
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
    loss2 = float(jax.jit(model.loss_fn)(params2, batch))
    assert np.isfinite(loss2) and loss2 != float(loss)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes(arch):
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, 0)
    logits, aux = jax.jit(model.forward_train)(params, batch)
    n_text = batch["tokens"].shape[1]
    assert logits.shape == (B, n_text, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, 0)
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    fresh = model.make_cache(B, S)
    tok = jnp.zeros((B,), jnp.int32)
    dlogits, new_cache = jax.jit(model.decode)(params, tok, fresh, jnp.int32(1))
    assert dlogits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(dlogits, dtype=np.float32)).all()
    assert jax.tree.structure(fresh) == jax.tree.structure(new_cache)


def test_cli_aliases_cover_assignment():
    assigned = ["phi3.5-moe-42b-a6.6b", "llama3-8b", "whisper-medium",
                "internlm2-1.8b", "falcon-mamba-7b", "internvl2-26b",
                "zamba2-1.2b", "granite-3-8b", "deepseek-v2-236b", "qwen2-1.5b"]
    for a in assigned:
        assert a in CLI_ALIASES
        cfg = get_config(a)
        assert cfg.name == a


def test_full_configs_match_assignment():
    spec = {
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
    }
    for arch, (L, d, H, kv, ff, V) in spec.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == \
            (L, d, H, kv, ff, V), arch
    c = get_config("deepseek-v2-236b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab_size) == (60, 5120, 128, 102400)
    assert (c.n_experts, c.moe_top_k, c.n_shared_experts, c.kv_lora_rank) == (160, 6, 2, 512)
    c = get_config("falcon-mamba-7b")
    assert (c.n_layers, c.d_model, c.vocab_size, c.ssm_state) == (64, 4096, 65024, 16)
