"""Multi-device parity battery for the mesh-sharded scanned engine.

``engine.init(..., mesh=make_client_mesh(n))`` turns the fully-jitted
``run_rounds`` scan into one SPMD program over a 1-D ``("clients",)``
mesh: arena rows, cohort gathers and the vmapped per-client training
partition over the devices, cross-client aggregations all-reduce across
them (docs/SHARDING.md). These tests pin the parity contract against
the single-device scan for every registered strategy at mesh sizes
{1, 2, 4, 8}:

- mesh size 1 is BITWISE equal to the no-mesh scan (same programs, same
  reduction order);
- larger meshes keep every piece of integer bookkeeping exact — PRNG
  keys (so draw sequences never fork), partition assignments, Ψ reps,
  member tuples, round counters, departure sets — while trained floats
  agree to a documented tolerance (an all-reduce of per-shard partials
  sums in a different order than the single-device row-major reduction;
  rtol 2e-5 on this fixture);
- churn boundaries (join/leave between scanned spans), mid-scan
  checkpoint save/resume, ragged arenas and non-mesh-divisible cohort
  sizes all preserve that contract;
- the GSPMD-lowered aggregation matches ``sharding.psum_segments``, an
  independent hand-written shard_map collective (per-shard segment-sum
  + cross-shard psum);
- the client-sharded scan carry (Ditto's stacked personal bank) keeps
  its ``NamedSharding`` across scan iterations — the donation contract
  on accelerators requires the carry sharding to be a fixed point.

Multi-device lane: run under ``REPRO_FORCE_HOST_DEVICES=8`` (conftest
translates it to ``--xla_force_host_platform_device_count`` before jax
imports; CI does). On a plain single-device run only the mesh-size-1
cases execute — still meaningful: they prove the mesh machinery itself
changes nothing.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import engine
from repro.checkpoint import load_server_state, save_server_state
from repro.data import rotated
from repro.launch.mesh import make_client_mesh
from repro.models import simple
from repro.sharding import specs

TASK = simple.SYNTH_MLP
LOSS = lambda p, b: simple.loss_fn(p, b, TASK)

ALL = ["stocfl", "fedavg", "fedprox", "ditto", "ifca", "cfl"]
MESH_SIZES = [s for s in (1, 2, 4, 8) if s <= len(jax.devices())]
# reduction-order tolerance for trained floats at mesh > 1 (see module
# docstring); mesh size 1 bypasses this and compares bitwise
RTOL, ATOL = 2e-5, 1e-6


def _fed(n_clients=12, n_per=32, seed=3):
    clients, tc, tests = rotated(n_clusters=2, n_clients=n_clients,
                                 n_per=n_per, seed=seed)
    return [jax.tree.map(jnp.asarray, c) for c in clients]


def _params(seed=0):
    return simple.init(jax.random.PRNGKey(seed), TASK)


def _cfg(name, **kw):
    kw.setdefault("local_steps", 2)
    kw.setdefault("sample_rate", 0.5)
    kw.setdefault("seed", 0)
    kw.setdefault("rng_backend", "device")
    if name == "stocfl":
        kw.setdefault("cluster_backend", "device")
    if name == "cfl":
        kw["sample_rate"] = 1.0
        kw.setdefault("eps_rel", 0.9)
        kw.setdefault("eps2", 1e-4)
    return engine.EngineConfig(**kw)


def _init(name, clients, mesh=None, **kw):
    return engine.init(name, LOSS, _params(), clients, _cfg(name, **kw),
                       arena=True, mesh=mesh)


def _leaves_equal(a, b, exact):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(la) != len(lb):
        return False
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        if exact or not np.issubdtype(x.dtype, np.floating):
            if not np.array_equal(x, y):
                return False
        elif not np.allclose(x, y, rtol=RTOL, atol=ATOL):
            return False
    return True


def _assert_states_match(ref, got, exact):
    """ref = single-device scan, got = sharded scan. ``exact`` compares
    bitwise (the mesh-size-1 contract); otherwise floats use the
    documented tolerance and every integer/bookkeeping field stays
    exact."""
    assert _leaves_equal(ref.omega, got.omega, exact), "omega diverged"
    assert set(ref.models.keys()) == set(got.models.keys()), \
        "bank keys diverged"
    for k in ref.models:
        assert _leaves_equal(ref.models[k], got.models[k], exact), \
            f"bank row {k} diverged"
    assert set(ref.personal) == set(got.personal)
    for k in ref.personal:
        assert _leaves_equal(ref.personal[k], got.personal[k], exact), \
            f"personal model {k} diverged"
    if ref.clusters is not None:
        assert ref.clusters.assignment() == got.clusters.assignment(), \
            "partition diverged"
        assert sorted(ref.clusters.seen) == sorted(got.clusters.seen)
        for c in ref.clusters.seen:
            # Ψ reps are per-client (no cross-client reduction in the
            # extractor): exact at every mesh size
            assert np.array_equal(np.asarray(ref.clusters.reps[c]),
                                  np.asarray(got.clusters.reps[c])), \
                f"Ψ rep of client {c} diverged"
    assert ref.members == got.members, "CFL partition diverged"
    assert ref.round == got.round
    assert ref.left == got.left
    assert len(ref.history) == len(got.history)
    for hr, hg in zip(ref.history, got.history):
        assert set(hr) == set(hg)
        for k in hr:
            if isinstance(hr[k], float) and not exact:
                assert np.allclose(hr[k], hg[k], rtol=RTOL, atol=ATOL), \
                    f"history[{k}] diverged"
            else:
                assert hr[k] == hg[k], f"history[{k}] diverged"
    if ref.rng_key is not None or got.rng_key is not None:
        assert np.array_equal(np.asarray(ref.rng_key),
                              np.asarray(got.rng_key)), \
            "PRNG key diverged (draw sequences would fork)"


# =============================================== core mesh parity battery
@pytest.mark.parametrize("nd", MESH_SIZES)
@pytest.mark.parametrize("name", ALL)
def test_sharded_scan_matches_single_device(name, nd):
    """run_rounds over a ("clients",) mesh of every size ≡ the no-mesh
    scan, for all six strategies over 5 rounds."""
    clients = _fed()
    ref = engine.run_rounds(_init(name, clients), 5)
    got = engine.run_rounds(_init(name, clients, mesh=make_client_mesh(nd)), 5)
    _assert_states_match(ref, got, exact=(nd == 1))


@pytest.mark.parametrize("nd", MESH_SIZES)
@pytest.mark.parametrize("name", ["stocfl", "fedavg", "ditto"])
def test_churn_boundary_sharded(name, nd):
    """Join + leave between scanned spans under the mesh: the arena
    rebuild/tombstone, the pool-bracket transition and the fresh scan
    compile all preserve parity with the single-device timeline."""
    clients = _fed()
    extra = _fed(n_clients=14, seed=9)[12:]

    def timeline(mesh):
        st = _init(name, list(clients), mesh=mesh)
        st = engine.run_rounds(st, 2)
        st, _ = engine.join(st, extra[0])
        st = engine.run_rounds(st, 2)
        st = engine.leave(st, 3)
        return engine.run_rounds(st, 2)

    ref = timeline(None)
    got = timeline(make_client_mesh(nd))
    _assert_states_match(ref, got, exact=(nd == 1))


@pytest.mark.parametrize("name", ["stocfl", "ditto", "cfl"])
def test_checkpoint_resume_mid_scan_sharded(name, tmp_path):
    """Save after a sharded span, reload into a FRESH sharded engine,
    finish there: bitwise vs the uninterrupted sharded run (same mesh →
    same programs → same reduction order; checkpoints round-trip
    exactly and reloaded host arrays re-place on the next span)."""
    nd = MESH_SIZES[-1]
    clients = _fed()
    cont = engine.run_rounds(_init(name, clients, mesh=make_client_mesh(nd)), 5)

    st = engine.run_rounds(_init(name, clients, mesh=make_client_mesh(nd)), 2)
    save_server_state(str(tmp_path / "ck"), st)
    fresh = _init(name, clients, mesh=make_client_mesh(nd))
    resumed = load_server_state(str(tmp_path / "ck"), fresh)
    resumed = engine.run_rounds(resumed, 3)
    _assert_states_match(cont, resumed, exact=True)


@pytest.mark.parametrize("name", ["fedavg", "stocfl"])
def test_non_divisible_cohort_sharded(name):
    """A cohort size that does not divide the mesh (10 clients at 50% →
    m=5 on 4 devices) must relax to replicated placement, not crash or
    change results (divisibility-safe constraints, docs/SHARDING.md)."""
    nd = max(MESH_SIZES)
    clients = _fed(n_clients=10)
    ref = engine.run_rounds(_init(name, clients), 4)
    got = engine.run_rounds(_init(name, clients, mesh=make_client_mesh(nd)), 4)
    _assert_states_match(ref, got, exact=(nd == 1))


@pytest.mark.parametrize("nd", MESH_SIZES)
def test_ragged_arena_sharded(nd):
    """Ragged federations (mask leaf in the gathered batch) shard like
    equal-size ones."""
    clients = _fed()
    clients[1] = jax.tree.map(lambda x: x[:17], clients[1])
    clients[5] = jax.tree.map(lambda x: x[:9], clients[5])
    ref = engine.run_rounds(_init("fedavg", clients), 4)
    got = engine.run_rounds(
        _init("fedavg", clients, mesh=make_client_mesh(nd)), 4)
    _assert_states_match(ref, got, exact=(nd == 1))


# ===================================================== collective oracle
def test_psum_segments_matches_dense_aggregation():
    """The hand-written shard_map collective (per-shard segment-sum +
    psum over the client axis) equals the dense weighted segment-sum the
    engine's GSPMD path lowers from — the two implementations are
    independent, so they cross-check each other."""
    nd = max(MESH_SIZES)
    mesh = make_client_mesh(nd)
    rng = np.random.default_rng(0)
    rows = 16
    stacked = {"w": jnp.asarray(rng.normal(size=(rows, 5, 3)), jnp.float32),
               "b": jnp.asarray(rng.normal(size=(rows, 7)), jnp.float32)}
    weights = jnp.asarray(rng.uniform(1, 4, size=rows), jnp.float32)
    seg = jnp.asarray(rng.integers(0, 4, size=rows), jnp.int32)

    dense = jax.tree.map(
        lambda x: jax.ops.segment_sum(
            x * weights.reshape((-1,) + (1,) * (x.ndim - 1)),
            seg, num_segments=4), stacked)
    got = specs.psum_segments(specs.place_cohort(stacked, mesh),
                              specs.place_cohort(weights, mesh),
                              specs.place_cohort(seg, mesh), 4, mesh)
    for k in dense:
        assert np.allclose(np.asarray(dense[k]), np.asarray(got[k]),
                           rtol=1e-6, atol=1e-6), k


def test_psum_segments_falls_back_when_not_divisible():
    """A leading axis that does not divide the mesh takes the dense
    fallback — same result, no shard_map shape error."""
    nd = max(MESH_SIZES)
    mesh = make_client_mesh(nd)
    rows = nd + 1 if nd > 1 else 3
    stacked = jnp.arange(rows * 2, dtype=jnp.float32).reshape(rows, 2)
    weights = jnp.ones((rows,), jnp.float32)
    seg = jnp.zeros((rows,), jnp.int32)
    got = specs.psum_segments(stacked, weights, seg, 2, mesh)
    assert np.allclose(np.asarray(got)[0], np.asarray(stacked).sum(0))


# ============================================= carry sharding / donation
def test_ditto_carry_keeps_client_sharding_across_scan():
    """The one client-sharded carry leaf (Ditto's stacked personal bank)
    must come OUT of the scan with the same ``NamedSharding`` it went in
    with — donation on accelerators requires input/output carry
    shardings to match, and a silent reshard would also double the
    scan's memory. Regression for the in-step ``constrain_cohort``
    output pin."""
    nd = max(MESH_SIZES)
    if nd < 2:
        pytest.skip("needs a multi-device mesh (REPRO_FORCE_HOST_DEVICES)")
    mesh = make_client_mesh(nd)
    st = _init("ditto", _fed(), mesh=mesh)
    prog = engine.scan_program(st, 3)
    fn, carry0, consts, finalize = prog
    carry1, _ys = fn(carry0, consts)
    p0, p1 = carry0[2], carry1[2]

    def spec_of(x):
        # trailing None dims are implicitly replicated: P("clients") and
        # P("clients", None) are the same sharding — normalize
        spec = tuple(getattr(x.sharding, "spec", ()) or ())
        while spec and spec[-1] is None:
            spec = spec[:-1]
        return spec

    for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
        assert spec_of(a) == spec_of(b), \
            f"carry sharding changed across scan: {spec_of(a)} -> {spec_of(b)}"
    # and the rows really are split over the client axis, not replicated
    lead = jax.tree.leaves(p1)[0]
    assert spec_of(lead) and spec_of(lead)[0] is not None, \
        "personal bank came back replicated — cohort constraint lost"


def test_mesh_size_one_is_bitwise_with_no_mesh():
    """The degenerate 1-device mesh must change NOTHING: same draws,
    same floats, bit for bit (it runs in tier-1 on a single device)."""
    clients = _fed()
    for name in ALL:
        ref = engine.run_rounds(_init(name, clients), 3)
        got = engine.run_rounds(
            _init(name, clients, mesh=make_client_mesh(1)), 3)
        _assert_states_match(ref, got, exact=True)
