"""Compile-set pinning battery: ``sanitize.compile_budget`` as a
regression gate on XLA program churn.

The engine's shape-quantization story (pow2-padded cohort pool, sizes
vector, arena row map, Ditto personal carry; doubling arena capacity)
bounds the set of distinct compiled programs under population churn to
O(log population).  These tests pin that bound so a future change that
re-keys a compile on the raw client count — instead of its pow2
bracket — fails loudly instead of silently recompiling every round.

Three claims, strongest first:

* re-running the *same* transition compiles nothing (all six
  strategies);
* joins inside one pow2 bracket with a constant cohort size add ZERO
  compiled programs;
* a warmed join/train/leave/train churn cycle re-uses the compiled
  set — exactly 0 new programs for fedavg / fedprox / ditto / ifca /
  cfl, and a small documented residue for stocfl, whose bank rebuild
  runs host-side eager ops shaped by the data-dependent cluster
  structure (Alg. 1's merge list — by design, see docs/ANALYSIS.md).
"""
import jax
import jax.numpy as jnp
import pytest

from repro import engine
from repro.analysis import sanitize
from repro.data import rotated
from repro.models import simple

TASK = simple.SYNTH_MLP
LOSS = lambda p, b: simple.loss_fn(p, b, TASK)
EVAL = jax.jit(lambda p, b: simple.accuracy(p, b, TASK))

ALL = ["stocfl", "fedavg", "fedprox", "ditto", "ifca", "cfl"]

# stocfl's finalize rebuilds the cluster bank through host eager ops
# whose shapes follow the merged cluster structure; under churn those
# shapes drift with the data.  Everything device-side is pinned (see
# test_rerun_same_transition_pins_to_zero), so the budget only has to
# absorb the bank-rebuild residue — well under the ~86-program cold
# compile of the same cycle.
CHURN_BUDGET = {name: 0 for name in ALL}
CHURN_BUDGET["stocfl"] = 64


def _fed(n_clients=12, n_per=32, seed=3):
    clients, tc, tests = rotated(n_clusters=2, n_clients=n_clients,
                                 n_per=n_per, seed=seed)
    clients = [jax.tree.map(jnp.asarray, c) for c in clients]
    return clients, tc, tests


def _cfg(name, **kw):
    kw.setdefault("local_steps", 2)
    kw.setdefault("sample_rate", 0.5)
    kw.setdefault("seed", 0)
    kw.setdefault("rng_backend", "device")
    if name == "stocfl":
        kw.setdefault("cluster_backend", "device")
    if name == "cfl":
        kw["sample_rate"] = 1.0
        kw.setdefault("eps_rel", 0.9)
        kw.setdefault("eps2", 1e-4)
    return engine.EngineConfig(**kw)


def _init(name, clients, **kw):
    return engine.init(name, LOSS, simple.init(jax.random.PRNGKey(0), TASK),
                       clients, _cfg(name, **kw), eval_fn=EVAL, arena=True)


def _churn_cycle(st, batch):
    """join → train → leave → train: the canonical population churn."""
    st, cid = engine.join(st, batch)
    st = engine.run_rounds(st, 2)
    st = engine.leave(st, cid)
    st = engine.run_rounds(st, 2)
    return st


@pytest.mark.parametrize("name", ALL)
def test_rerun_same_transition_pins_to_zero(name):
    """``run_rounds`` is a pure transition: replaying it on the same
    state compiles NOTHING.  Two warm calls, not one — the first
    materializes lazily-cached device buffers (bank/arena row maps) on
    the shared containers, which re-keys a handful of eager ops once."""
    clients, _, _ = _fed()
    st = _init(name, clients)
    engine.run_rounds(st, 2)
    engine.run_rounds(st, 2)
    with sanitize.compile_budget(0):
        st2 = engine.run_rounds(st, 2)
    assert st2.round == st.round + 2


def test_joins_within_pow2_bracket_add_zero_programs():
    """The O(log population) claim, sharp end: growing 14 → 15 → 16
    clients stays inside the pow2-16 pool/sizes/rowmap bracket, and
    sample_rate=0.25 keeps the cohort size m=4 constant — so three
    joins plus six scanned rounds re-use every compiled program."""
    clients, _, _ = _fed()                # 12 clients
    extra, _, _ = _fed(n_clients=4, seed=11)
    st = _init("fedavg", clients, sample_rate=0.25)
    st = engine.run_rounds(st, 2)
    st, _ = engine.join(st, extra[0])     # n=13: warms join + arena growth
    st = engine.run_rounds(st, 2)
    with sanitize.compile_budget(0) as log:
        for batch in extra[1:]:           # n=14, 15, 16
            st, _ = engine.join(st, batch)
            st = engine.run_rounds(st, 2)
    assert log.count == 0
    assert st.n_clients == 16 and st.round == 10


def test_sharded_joins_within_pow2_bracket_add_zero_programs():
    """The bracket claim survives the mesh: on a multi-device
    ("clients",) mesh the scan's compile key gains the mesh fingerprint
    but still quantizes shapes by pow2 bracket — joins 14 → 16 with a
    constant cohort compile ZERO new programs, and the per-span
    ``device_put`` re-pins of already-placed arena shards count no
    compiles either. Runs on 4 devices under REPRO_FORCE_HOST_DEVICES
    (CI); on fewer devices the mesh degenerates but the code path is
    the same."""
    from repro.launch.mesh import make_client_mesh
    nd = min(4, len(jax.devices()))
    clients, _, _ = _fed()                # 12 clients
    extra, _, _ = _fed(n_clients=4, seed=11)
    st = engine.init("fedavg", LOSS,
                     simple.init(jax.random.PRNGKey(0), TASK), clients,
                     _cfg("fedavg", sample_rate=0.25), eval_fn=EVAL,
                     arena=True, mesh=make_client_mesh(nd))
    st = engine.run_rounds(st, 2)
    st, _ = engine.join(st, extra[0])     # n=13: warms join + arena growth
    st = engine.run_rounds(st, 2)
    with sanitize.compile_budget(0) as log:
        for batch in extra[1:]:           # n=14, 15, 16
            st, _ = engine.join(st, batch)
            st = engine.run_rounds(st, 2)
    assert log.count == 0
    assert st.n_clients == 16 and st.round == 10


def test_mesh_fingerprint_keys_separate_scan_caches():
    """Two engines over the same federation but different meshes must
    not share a compiled scan (the constraint lowering differs): the
    scan-cache key includes ``sharding.mesh_fingerprint``."""
    from repro.launch.mesh import make_client_mesh
    clients, _, _ = _fed()
    a = _init("fedavg", clients)
    b = engine.init("fedavg", LOSS,
                    simple.init(jax.random.PRNGKey(0), TASK), clients,
                    _cfg("fedavg"), eval_fn=EVAL, arena=True,
                    mesh=make_client_mesh(1))
    ka = [k for k in (engine.scan_program(a, 2), a.ctx.cache)[1]
          if k.startswith("scan:")]
    kb = [k for k in (engine.scan_program(b, 2), b.ctx.cache)[1]
          if k.startswith("scan:")]
    assert ka and kb and set(ka).isdisjoint(kb), (ka, kb)


@pytest.mark.parametrize("name", ["stocfl", "fedavg"])
def test_steady_async_rounds_compile_zero_programs(name):
    """Steady-state async rounds (constant cohort, constant delay —
    hence constant dispatch and flush widths) compile ZERO new XLA
    programs after warmup: the buffer's scatter/gather are keyed on
    (capacity, width), both constant, and the merge runs the same
    aggregation programs as the warmed rounds."""
    import numpy as np
    clients, _, _ = _fed()
    st = _init(name, clients, async_cfg=engine.AsyncConfig())
    d = np.ones(6, np.int64)
    for _ in range(6):                      # warm: partition settles,
        st, _ = engine.run_round_async(st, delays=d)   # widths lock in
    with sanitize.compile_budget(0):
        for _ in range(3):
            st, rec = engine.run_round_async(st, delays=d)
            assert rec["merged"] == 6       # full steady flush
    assert st.round == 9


def test_async_buffer_capacity_brackets_bound_programs():
    """Buffer growth is pow2-amortized: a delay burst that doubles the
    row capacity re-keys only the per-capacity row programs (grow +
    scatter + gather per bank) — a small documented residue, NOT a
    recompile of the training or aggregation programs."""
    import numpy as np
    clients, _, _ = _fed()
    st = _init("fedavg", clients,
               async_cfg=engine.AsyncConfig(buffer_capacity=8,
                                            staleness_cap=8))
    z = np.zeros(6, np.int64)
    for _ in range(3):                      # warm at capacity 8
        st, _ = engine.run_round_async(st, delays=z)
    assert st.buffer.capacity == 8
    with sanitize.compile_budget(16, log_names=True) as log:
        # burst: everyone 4 rounds late, twice — occupancy 12 > 8 forces
        # one doubling; training/aggregation programs must all be reused
        st, _ = engine.run_round_async(st, delays=np.full(6, 4, np.int64))
        st, _ = engine.run_round_async(st, delays=np.full(6, 4, np.int64))
    assert st.buffer.capacity == 16
    assert log.count <= 16, log.describe()
    for _ in range(3):
        st, _ = engine.run_round_async(st, delays=z)
    # the grown capacity is itself steady again: zero from here
    st, _ = engine.run_round_async(st, delays=z)
    with sanitize.compile_budget(0):
        st, _ = engine.run_round_async(st, delays=z)


@pytest.mark.parametrize("name", ALL)
def test_churn_cycle_compile_set_pinned(name):
    """After two warm churn cycles, a third identical-shape cycle stays
    within CHURN_BUDGET new programs (0 for every strategy except
    stocfl's documented host bank-rebuild residue).  Each cycle
    registers one more client id, so this also re-proves the bracket
    claim: n grows 13 → 14 → 15 under a pinned pow2-16 shape set."""
    clients, _, _ = _fed()
    extra, _, _ = _fed(n_clients=4, seed=11)
    st = _init(name, clients)
    st = engine.run_rounds(st, 2)                 # base compile
    st = _churn_cycle(st, extra[0])               # warm churn shapes
    st = _churn_cycle(st, extra[1])               # warm lazy-cache re-keys
    with sanitize.compile_budget(CHURN_BUDGET[name], log_names=True) as log:
        st = _churn_cycle(st, extra[2])
    assert log.count <= CHURN_BUDGET[name], log.describe()
    assert st.n_clients == 15
