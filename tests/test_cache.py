"""Persistent-compilation-cache + donation-safety checks.

``utils.cache.enable_compilation_cache`` must make recompiles after
``jax.clear_caches()`` get SERVED from disk — observed through the
``cache_hits`` counter that ``analysis.sanitize.compile_budget`` now
tallies (the backend-compile event fires per request, served or not, so
a warm serve shows up as ``cache_hits >= 1`` alongside the count).

The donation tests pin the safety contract of the donating entry
points: donation resolves at call/build time and is OFF on CPU, so
donated-in-name inputs stay readable and no hidden host↔device copies
appear (``no_transfer`` guard).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import sanitize
from repro.kernels.prox_update import prox_update_flat
from repro.utils.cache import enable_compilation_cache


def test_compilation_cache_serves_after_clear(tmp_path):
    prev_dir = jax.config.jax_compilation_cache_dir
    prev_time = jax.config.jax_persistent_cache_min_compile_time_secs
    prev_size = jax.config.jax_persistent_cache_min_entry_size_bytes
    try:
        used = enable_compilation_cache(str(tmp_path))
        assert used == str(tmp_path)

        @jax.jit
        def f(x):
            return jnp.tanh(x) * 3.0 + jnp.cos(x)

        x = jnp.arange(128, dtype=jnp.float32)
        want = np.asarray(f(x))                   # cold: compiles + writes
        jax.clear_caches()
        with sanitize.compile_budget() as log:
            got = np.asarray(f(x))                # warm: served from disk
        np.testing.assert_array_equal(want, got)
        assert log.cache_hits >= 1, "recompile was not served from the cache"
        assert log.count >= log.cache_hits
    finally:
        jax.config.update("jax_compilation_cache_dir", prev_dir)
        jax.config.update("jax_persistent_cache_min_compile_time_secs",
                          prev_time)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes",
                          prev_size)
        # drop the cache handle + used-latch so later tests re-resolve
        # against the restored config instead of this test's tmpdir
        from jax.experimental.compilation_cache import compilation_cache as cc
        cc.reset_cache()


def test_prox_donation_contract_on_cpu():
    # the donate=None default resolves to NON-donating on CPU: inputs
    # stay readable and no implicit host transfer sneaks past the guard
    th, om = jnp.ones((64,)), jnp.zeros((64,))
    gt, go = jnp.full((64,), 0.5), jnp.full((64,), 0.25)
    eta, lam = jnp.float32(0.1), jnp.float32(0.05)   # device scalars
    with sanitize.no_transfer():
        t2, o2 = prox_update_flat(th, om, gt, go, eta, lam,
                                  block=32, interpret=True)
        t2.block_until_ready()
    f32 = np.float32
    exp_t = f32(1.0) - f32(0.1) * (f32(0.5) + f32(0.05) * (f32(1.0) - f32(0.0)))
    exp_o = f32(0.0) - f32(0.1) * f32(0.25)
    np.testing.assert_array_equal(np.asarray(th), np.ones(64))
    np.testing.assert_array_equal(np.asarray(t2), np.full(64, exp_t, f32))
    np.testing.assert_array_equal(np.asarray(o2), np.full(64, exp_o, f32))

    # explicit donate=True consumes the operands EVEN on CPU (jax
    # invalidates donated arrays whether or not the backend can alias
    # them) — this is why the call-time default matters, and why every
    # fused call site rebinds θ/ω immediately instead of reusing them
    t3, _ = prox_update_flat(th, om, gt, go, eta, lam,
                             block=32, interpret=True, donate=True)
    np.testing.assert_array_equal(np.asarray(t3), np.asarray(t2))
    with pytest.raises(RuntimeError, match="deleted"):
        np.asarray(th)


def test_run_rounds_state_readable_after_scan():
    # the scanned round loop donates its carry off-CPU; on CPU the input
    # state must remain fully readable after the call (build-time resolve)
    from repro import engine
    from repro.data import rotated
    from repro.models import simple

    task = simple.SYNTH_MLP
    loss = lambda p, b: simple.loss_fn(p, b, task)
    clients, _, _ = rotated(n_clusters=2, n_clients=8, n_per=16, seed=0)
    clients = [jax.tree.map(jnp.asarray, c) for c in clients]
    cfg = engine.EngineConfig(local_steps=1, sample_rate=0.5, seed=0,
                              rng_backend="device",
                              cluster_backend="device")
    st = engine.init("stocfl", loss, simple.init(jax.random.PRNGKey(0), task),
                     clients, cfg, arena=True)
    out = engine.run_rounds(st, 2)
    # reading the PRE-scan state after the scan would be use-after-donate
    # if donation were (incorrectly) enabled on CPU
    for leaf in jax.tree.leaves(st.omega):
        assert np.isfinite(np.asarray(leaf)).all()
    assert out.round == st.round + 2


def test_donated_carry_sharding_is_scan_fixed_point():
    """Donation audit under sharding: on accelerators the scan donates
    its carry, and XLA can only alias a donated buffer when the carry's
    OUTPUT sharding equals its input sharding. This pins that contract
    for the one client-sharded carry leaf (Ditto's stacked personal
    bank) and for a replicated carry (fedavg's ω): every carry leaf
    must come out of the compiled span with the sharding it went in
    with — a silent reshard would break donation (and double the
    scan's carry memory) the day this runs on TPU. Mesh size adapts to
    the available devices (1 on plain tier-1, 4+ in the CI mesh lane),
    so the invariant itself is checked everywhere."""
    from repro import engine
    from repro.data import rotated
    from repro.launch.mesh import make_client_mesh
    from repro.models import simple

    task = simple.SYNTH_MLP
    loss = lambda p, b: simple.loss_fn(p, b, task)
    clients, _, _ = rotated(n_clusters=2, n_clients=8, n_per=16, seed=0)
    clients = [jax.tree.map(jnp.asarray, c) for c in clients]
    mesh = make_client_mesh(min(4, len(jax.devices())))
    for name in ("ditto", "fedavg"):
        cfg = engine.EngineConfig(local_steps=1, sample_rate=0.5, seed=0,
                                  rng_backend="device")
        st = engine.init(name, loss,
                         simple.init(jax.random.PRNGKey(0), task),
                         clients, cfg, arena=True, mesh=mesh)
        fn, carry0, consts, _fin = engine.scan_program(st, 2)
        carry1, _ys = fn(carry0, consts)
        # jax's own equivalence: handles trailing-None specs and size-1
        # mesh axes (P("clients") ≡ P() on one device) — exactly the
        # notion XLA's donation aliasing uses
        for a, b in zip(jax.tree.leaves(carry0), jax.tree.leaves(carry1)):
            assert a.sharding.is_equivalent_to(b.sharding, a.ndim), \
                f"{name}: carry sharding not a scan fixed point " \
                f"({a.sharding} -> {b.sharding})"
