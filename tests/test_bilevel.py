"""Bi-level optimization properties + degeneration equivalences (§3.4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bilevel
from repro.core.stocfl import StoCFL, StoCFLConfig
from repro.core.baselines import FLConfig, FedAvg
from repro.data import rotated
from repro.models import simple
from repro.utils import trees

TASK = simple.SYNTH_MLP
LOSS = lambda p, b: simple.loss_fn(p, b, TASK)


def _setup(n_clients=8, n_per=32, seed=0):
    clients, tc, tests = rotated(n_clusters=2, n_clients=n_clients, n_per=n_per, seed=seed)
    clients = [jax.tree.map(jnp.asarray, c) for c in clients]
    params = simple.init(jax.random.PRNGKey(seed), TASK)
    return clients, tc, tests, params


def test_client_update_lambda_zero_is_sgd():
    clients, _, _, params = _setup()
    cu = bilevel.make_client_update(LOSS, lr=0.1, lam=0.0, local_steps=3, backend="jnp")
    th, om = cu(params, params, clients[0])
    om_ref = bilevel.local_sgd(LOSS, params, clients[0], 0.1, 3)
    for a, b in zip(jax.tree.leaves(th), jax.tree.leaves(om)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    for a, b in zip(jax.tree.leaves(om), jax.tree.leaves(om_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_client_update_reduces_loss():
    clients, _, _, params = _setup()
    cu = bilevel.make_client_update(LOSS, lr=0.1, lam=0.05, local_steps=5, backend="jnp")
    th, om = cu(params, params, clients[0])
    l0 = float(LOSS(params, clients[0]))
    assert float(LOSS(th, clients[0])) < l0
    assert float(LOSS(om, clients[0])) < l0


def test_cohort_update_matches_individual():
    clients, _, _, params = _setup(n_clients=4)
    cohort = bilevel.make_cohort_update(LOSS, lr=0.1, lam=0.05, local_steps=2)
    thetas = jax.tree.map(lambda x: jnp.stack([x] * 4), params)
    batches = jax.tree.map(lambda *xs: jnp.stack(xs), *clients[:4])
    th_s, om_s = cohort(thetas, params, batches)
    cu = bilevel.make_client_update(LOSS, lr=0.1, lam=0.05, local_steps=2, backend="jnp")
    th1, om1 = cu(params, params, clients[2])
    for a, b in zip(jax.tree.leaves(jax.tree.map(lambda x: x[2], th_s)), jax.tree.leaves(th1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    for a, b in zip(jax.tree.leaves(jax.tree.map(lambda x: x[2], om_s)), jax.tree.leaves(om1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_aggregate_stacked_weighted_mean():
    t1 = {"w": jnp.ones((3,))}
    t2 = {"w": jnp.zeros((3,))}
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), t1, t2)
    out = bilevel.aggregate_stacked(stacked, [3.0, 1.0])
    np.testing.assert_allclose(np.asarray(out["w"]), 0.75)


def test_stocfl_tau_minus1_lam0_equals_fedavg():
    """λ=0, τ=−1 ⇒ StoCFL's ω AND single cluster model follow FedAvg
    (paper §3.4) when the same cohort is sampled."""
    clients, _, _, params = _setup(n_clients=6)
    ids = [np.arange(6)] * 3                      # full participation
    sto = StoCFL(LOSS, params, clients,
                 StoCFLConfig(tau=-1.0, lam=0.0, lr=0.1, local_steps=2,
                              sample_rate=1.0, seed=0))
    fed = FedAvg(LOSS, params, clients,
                 FLConfig(lr=0.1, local_steps=2, sample_rate=1.0, seed=0))
    for r in ids:
        sto.round(r)
        fed.round(r)
    assert sto.state.n_clusters() == 1
    for a, b in zip(jax.tree.leaves(sto.omega), jax.tree.leaves(fed.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    root = sto.state.uf.find(0)
    for a, b in zip(jax.tree.leaves(sto.models[root]), jax.tree.leaves(fed.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_stocfl_tau_one_stays_personalized():
    clients, _, _, params = _setup(n_clients=6)
    sto = StoCFL(LOSS, params, clients,
                 StoCFLConfig(tau=1.1, lam=0.05, lr=0.1, local_steps=1,
                              sample_rate=1.0, seed=0))
    for _ in range(3):
        sto.round(np.arange(6))
    assert sto.state.n_clusters() == 6            # Ditto regime


def test_local_sgd_prox_pulls_toward_reference():
    clients, _, _, params = _setup()
    ref = jax.tree.map(jnp.zeros_like, params)
    out = bilevel.local_sgd(LOSS, params, clients[0], lr=0.1, steps=5,
                            prox_to=ref, lam=10.0)
    assert float(trees.tree_norm(out)) < float(trees.tree_norm(params))
