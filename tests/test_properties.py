"""Property-based battery for ``UnionFind`` / ``ClusterState`` — the host
bookkeeping the whole bi-level orchestration trusts. Invariants:

  * the partition reached by merging is independent of merge/observation
    order (the merge pass is the transitive closure of the τ-threshold
    graph, so only the edge SET matters);
  * ``remove()`` leaves the union-find, reps, and assignment mutually
    consistent (roots are live minimum members; remap is exact);
  * the Eq. 2 objective Σ_{i<j} cos(Ψ̃_i, Ψ̃_j) is non-increasing under
    merge passes for representations in the non-negative cone.

The cone restriction on the last property is necessary, not cosmetic:
with mixed-sign Ψ a merge can INCREASE Eq. 2 (e.g. unit reps a,b with
cos(a,b)=0.31 ≥ τ and a third cluster c ≈ −(a+b): merging {a,b} replaces
cos(a,c)+cos(b,c) ≈ −1.62 with cos(m,c) ≈ −1, a net increase). For
non-negative vectors, cos(mean(G), x) ≤ Σ_{g∈G} cos(g, x) (Cauchy-Schwarz
plus |Σg| ≥ max|g| when all pairwise dots are ≥ 0) and every removed
intra-pair contributes ≥ 0, so each merge pass can only shrink the sum.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the test extra
from hypothesis import given, settings, strategies as st

from repro.core.clustering import ClusterState, UnionFind


# --------------------------------------------------------------- generators
def _unit_reps(labels, seed, d=8, noise=0.05):
    rng = np.random.default_rng(seed)
    anchors = rng.normal(size=(max(labels) + 1, d))
    anchors /= np.linalg.norm(anchors, axis=1, keepdims=True)
    out = []
    for g in labels:
        v = anchors[g] + rng.normal(size=d) * noise
        out.append((v / np.linalg.norm(v)).astype(np.float32))
    return out


def _partition(cs: ClusterState):
    """Partition as a canonical set of frozensets of client ids."""
    return frozenset(frozenset(m) for m in cs.clusters().values())


# ----------------------------------------------------------------- unionfind
@settings(max_examples=40, deadline=None, derandomize=True)
@given(st.lists(st.tuples(st.integers(0, 15), st.integers(0, 15)),
                max_size=40),
       st.integers(0, 10_000))
def test_unionfind_order_independent(edges, shuffle_seed):
    """The final partition depends only on the edge SET, never the order
    unions are applied in; every root is its component's smallest id."""
    a, b = UnionFind(), UnionFind()
    for i in range(16):
        a.add(i)
        b.add(i)
    shuffled = list(edges)
    np.random.default_rng(shuffle_seed).shuffle(shuffled)
    for x, y in edges:
        a.union(x, y)
    for x, y in shuffled:
        b.union(x, y)
    groups_a, groups_b = {}, {}
    for i in range(16):
        groups_a.setdefault(a.find(i), set()).add(i)
        groups_b.setdefault(b.find(i), set()).add(i)
    assert set(map(frozenset, groups_a.values())) == \
        set(map(frozenset, groups_b.values()))
    for root, members in groups_a.items():
        assert root == min(members)           # smaller id always wins


@settings(max_examples=25, deadline=None, derandomize=True)
@given(st.lists(st.integers(0, 3), min_size=2, max_size=24),
       st.integers(0, 100), st.integers(0, 10_000))
def test_merge_partition_observation_order_independent(labels, seed,
                                                       shuffle_seed):
    """Observing the same clients in any order yields the same partition:
    merge_round unions every pair of the τ-graph transitively, and the
    graph is a function of the rep set alone."""
    reps = _unit_reps(labels, seed)
    ids = list(range(len(labels)))
    perm = list(ids)
    np.random.default_rng(shuffle_seed).shuffle(perm)

    cs_a = ClusterState(tau=0.8)
    cs_a.observe(ids, reps)
    cs_a.merge_round()

    cs_b = ClusterState(tau=0.8)
    cs_b.observe(perm, [reps[i] for i in perm])
    cs_b.merge_round()

    assert _partition(cs_a) == _partition(cs_b)
    # idempotence: a second pass with no new observations changes nothing
    before = _partition(cs_a)
    cs_a.merge_round()
    assert _partition(cs_a) == before


@settings(max_examples=25, deadline=None, derandomize=True)
@given(st.lists(st.integers(0, 3), min_size=3, max_size=20),
       st.integers(0, 100),
       st.lists(st.integers(0, 19), min_size=1, max_size=6))
def test_remove_keeps_roots_consistent(labels, seed, departures):
    """After any sequence of removals: (a) no removed id survives anywhere,
    (b) every assigned root is a live observed client and the minimum of
    its members, (c) the returned remap points exactly at the re-rooted
    clusters, (d) cluster_means covers exactly the live roots."""
    cs = ClusterState(tau=0.8)
    cs.observe(range(len(labels)), _unit_reps(labels, seed))
    cs.merge_round()
    for cid in departures:
        cid = cid % len(labels)
        before = {r: set(m) for r, m in cs.clusters().items()}
        remap = cs.remove(cid)
        assert cid not in cs.reps and cid not in cs.seen
        assert cid not in cs.uf.parent
        for old, new in remap.items():
            assert old != new
            assert new == min(m for m in before[old] if m != cid)
        if not cs.reps:
            assert cs.assignment() == {}
            continue
        assign = cs.assignment()
        assert cid not in assign
        roots, _ = cs.cluster_means()
        assert set(assign.values()) == set(roots)
        for r, members in cs.clusters().items():
            assert r == min(members)
            assert r in cs.reps


# ------------------------------------------------------------- Eq. 2 descent
@settings(max_examples=30, deadline=None, derandomize=True)
@given(n=st.integers(2, 18), d=st.integers(2, 10),
       tau=st.floats(0.3, 0.95), seed=st.integers(0, 1000))
def test_objective_nonincreasing_under_merges_nonneg_cone(n, d, tau, seed):
    """Eq. 2 descent: in the non-negative cone, every merge pass (and
    chains of passes) can only lower Σ_{i<j} cos(Ψ̃_i, Ψ̃_j)."""
    rng = np.random.default_rng(seed)
    reps = [rng.uniform(0.05, 1.0, size=d).astype(np.float32)
            for _ in range(n)]
    cs = ClusterState(tau=tau)
    cs.observe(range(n), reps)
    obj = cs.objective()
    for _ in range(3):                        # cascaded passes too
        merges = cs.merge_round()
        after = cs.objective()
        assert after <= obj + 1e-4
        obj = after
        if not merges:
            break


def test_objective_can_increase_outside_cone():
    """Documents WHY the cone restriction above exists: a legal mixed-sign
    configuration where one merge raises Eq. 2 — monotonicity is a
    cone property, not a general one."""
    a = np.array([1.0, 0.0, 0.0], np.float32)
    th = np.arccos(0.31)
    b = np.array([np.cos(th), np.sin(th), 0.0], np.float32)
    c = -(a + b) / np.linalg.norm(a + b)
    cs = ClusterState(tau=0.3)
    cs.observe([0, 1, 2], [a, b, c.astype(np.float32)])
    before = cs.objective()
    cs.merge_round()                          # merges {a,b}; c stays apart
    assert cs.n_clusters() == 2
    assert cs.objective() > before
