"""Mixed-precision battery for ``EngineConfig(dtype="bfloat16")``.

The policy under test (ARCHITECTURE.md "hot path"): params, grads and
client batches compute in bf16, while everything the CLUSTERING decision
reads stays fp32 — Ψ-embeddings (extractor anchored at the fp32 init
params), cluster means, and the Eq. 2 closed-form objective. So a bf16
run must (a) carry bf16 leaves end-to-end, (b) keep its Ψ/objective
surfaces in finite fp32, (c) track the fp32 trajectory to bf16 accuracy
per strategy, and (d) round-trip through the npz checkpoint bit-exactly
even though npy headers can't express ml_dtypes' bfloat16.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.checkpoint import (load_pytree, load_server_state, save_pytree,
                              save_server_state, wait_pending)
from repro.data import rotated
from repro.models import simple

TASK = simple.SYNTH_MLP
LOSS = lambda p, b: simple.loss_fn(p, b, TASK)

ALL = ["stocfl", "fedavg", "fedprox", "ditto", "ifca", "cfl"]


def _fed(n_clients=12, n_per=32, seed=3):
    clients, tc, tests = rotated(n_clusters=2, n_clients=n_clients,
                                 n_per=n_per, seed=seed)
    return [jax.tree.map(jnp.asarray, c) for c in clients], tc, tests


def _cfg(name, **kw):
    kw.setdefault("local_steps", 2)
    kw.setdefault("sample_rate", 0.5)
    kw.setdefault("seed", 0)
    kw.setdefault("rng_backend", "device")
    if name == "stocfl":
        kw.setdefault("cluster_backend", "device")
    if name == "cfl":
        kw["sample_rate"] = 1.0
        kw.setdefault("eps_rel", 0.9)
        kw.setdefault("eps2", 1e-4)
    return engine.EngineConfig(**kw)


def _run(name, dtype, rounds=4, scan=False, fused=False):
    clients, _, _ = _fed()
    st = engine.init(name, LOSS, simple.init(jax.random.PRNGKey(0), TASK),
                     clients, _cfg(name, dtype=dtype, fused_step=fused),
                     arena=True)
    if scan:
        return engine.run_rounds(st, rounds)
    for _ in range(rounds):
        st, _ = engine.run_round(st)
    return st


def _flat(tree):
    return np.concatenate([np.asarray(l, np.float32).ravel()
                           for l in jax.tree.leaves(tree)])


@pytest.mark.parametrize("name", ALL)
def test_bf16_tracks_fp32_trajectory(name):
    a = _run(name, "float32")
    b = _run(name, "bfloat16")
    for leaf in jax.tree.leaves(b.omega):
        assert leaf.dtype == jnp.bfloat16
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
    fa, fb = _flat(a.omega), _flat(b.omega)
    # bf16 has ~8 mantissa bits: demand the bf16 run stays within a few
    # ulp-accumulations of the fp32 one over the 4-round window
    rel = np.linalg.norm(fa - fb) / max(np.linalg.norm(fa), 1e-6)
    assert rel < 0.05, f"{name}: bf16 drifted {rel:.4f} from fp32"


def test_bf16_stocfl_clustering_surfaces_stay_fp32():
    st = _run("stocfl", "bfloat16")
    arrs = st.clusters.arrays()
    assert arrs["rep"].dtype == jnp.float32, "Ψ reps must stay fp32"
    # same partition as the fp32 run on this well-separated fixture
    ref = _run("stocfl", "float32")
    assert st.clusters.assignment() == ref.clusters.assignment()
    for rec in st.history:
        obj = np.asarray(rec["objective"], np.float32)
        assert np.isfinite(obj)


def test_bf16_scan_matches_eager_toleranced():
    a = _run("stocfl", "bfloat16", scan=False)
    b = _run("stocfl", "bfloat16", scan=True)
    fa, fb = _flat(a.omega), _flat(b.omega)
    np.testing.assert_allclose(fa, fb, rtol=0.02, atol=0.02)
    assert a.clusters.assignment() == b.clusters.assignment()


def test_bf16_fused_step_composes():
    # dtype and fused_step are independent axes; together they still
    # produce a finite bf16 trajectory near the unfused bf16 one
    a = _run("stocfl", "bfloat16", fused=False)
    b = _run("stocfl", "bfloat16", fused=True)
    fa, fb = _flat(a.omega), _flat(b.omega)
    rel = np.linalg.norm(fa - fb) / max(np.linalg.norm(fa), 1e-6)
    assert np.isfinite(fb).all() and rel < 0.05


def test_bf16_pytree_checkpoint_roundtrip_bitexact(tmp_path):
    tree = {"w": jnp.asarray(np.random.RandomState(0).randn(32, 8),
                             jnp.bfloat16),
            "b": jnp.zeros((8,), jnp.float32)}
    path = str(tmp_path / "t.npz")
    save_pytree(path, tree)
    back = load_pytree(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_bf16_server_state_roundtrip_async(tmp_path):
    st = _run("stocfl", "bfloat16", rounds=2)
    fut = save_server_state(str(tmp_path), st, block=False)
    assert fut is not None
    wait_pending()
    clients, _, _ = _fed()
    fresh = engine.init("stocfl", LOSS,
                        simple.init(jax.random.PRNGKey(0), TASK), clients,
                        _cfg("stocfl", dtype="bfloat16"), arena=True)
    back = load_server_state(str(tmp_path), fresh)
    for a, b in zip(jax.tree.leaves(st.omega), jax.tree.leaves(back.omega)):
        assert b.dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert back.clusters.assignment() == st.clusters.assignment()
