"""Dynamic-federation simulator battery (repro.sim + churn-ready arena).

Three promises under test: (1) timelines are deterministic, replayable,
and serialize losslessly; (2) the simulator drives the engine's pure
transitions without breaking any bookkeeping invariant, for every
registered strategy, on both data paths (arena gather vs legacy restack
— bitwise-identical trajectories through arbitrary churn); (3) the
arena's amortized growth / tombstone / compaction machinery is invisible
to gathers: ids stay stable, values stay bitwise, pad rows contribute
nothing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.data import drift_batch, rotated, rotated_factory
from repro.data.arena import ClientArena
from repro.models import simple
from repro.sim import (Availability, Drift, Join, Leave, Straggle, Timeline,
                       simulate)

TASK = simple.SYNTH_MLP
LOSS = lambda p, b: simple.loss_fn(p, b, TASK)
EVAL = jax.jit(lambda p, b: simple.accuracy(p, b, TASK))
ALL = ["stocfl", "fedavg", "fedprox", "ditto", "ifca", "cfl"]


def _fed(n_clients=8, n_per=16, seed=3):
    clients, tc, tests = rotated(n_clusters=2, n_clients=n_clients,
                                 n_per=n_per, seed=seed)
    clients = [jax.tree.map(jnp.asarray, c) for c in clients]
    tests = {k: jax.tree.map(jnp.asarray, v) for k, v in tests.items()}
    return clients, tc, tests


def _params(seed=0):
    return simple.init(jax.random.PRNGKey(seed), TASK)


def _cfg(**kw):
    kw.setdefault("local_steps", 1)
    kw.setdefault("sample_rate", 0.5)
    kw.setdefault("seed", 0)
    return engine.EngineConfig(**kw)


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _states_bitwise_equal(a, b):
    assert a.round == b.round
    assert a.left == b.left
    assert a.sizes == b.sizes
    _leaves_equal(a.omega, b.omega)
    assert a.models.keys() == b.models.keys()
    for k in a.models:
        _leaves_equal(a.models[k], b.models[k])
    assert a.personal.keys() == b.personal.keys()
    for k in a.personal:
        _leaves_equal(a.personal[k], b.personal[k])
    if a.clusters is not None:
        assert a.clusters.assignment() == b.clusters.assignment()
    assert a.members == b.members


# ================================================================ timeline
def test_poisson_timeline_deterministic():
    a = Timeline.from_poisson(rounds=20, join_rate=1.0, leave_rate=0.5,
                              straggle=0.1, drift_every=5, n_clusters=4,
                              seed=7)
    b = Timeline.from_poisson(rounds=20, join_rate=1.0, leave_rate=0.5,
                              straggle=0.1, drift_every=5, n_clusters=4,
                              seed=7)
    assert a.events() == b.events()
    c = Timeline.from_poisson(rounds=20, join_rate=1.0, leave_rate=0.5,
                              seed=8)
    assert a.events() != c.events()
    counts = a.counts()
    assert counts["straggle"] == 19          # every round from start=1
    assert counts.get("join", 0) > 0 and counts.get("leave", 0) > 0
    assert all(ev.t >= 1 for ev in a.events())   # start=1 spares round 0


def test_trace_roundtrip(tmp_path):
    tl = Timeline([Join(t=1, cluster=2), Leave(t=2, cid=5), Leave(t=2),
                   Straggle(t=3, rate=0.25),
                   Drift(t=4, cids=(0, 3), strength=0.1)],
                  windows=[Availability(cid=1, start=0, end=3)])
    p = str(tmp_path / "trace.json")
    tl.to_trace(p)
    back = Timeline.from_trace(p)
    assert back.events() == tl.events()
    assert back.windows == tl.windows


def test_join_with_batch_payload_does_not_serialize(tmp_path):
    tl = Timeline([Join(t=0, batch={"x": np.zeros((2, 4))})])
    with pytest.raises(ValueError, match="batch"):
        tl.to_trace(str(tmp_path / "t.json"))


def test_from_spec_kv_and_trace(tmp_path):
    tl = Timeline.from_spec("join=1.0,leave=0.5,straggle=0.2", rounds=10,
                            seed=0, n_clusters=4)
    want = Timeline.from_poisson(rounds=10, join_rate=1.0, leave_rate=0.5,
                                 straggle=0.2, n_clusters=4, seed=0)
    assert tl.events() == want.events()
    p = str(tmp_path / "trace.json")
    want.to_trace(p)
    assert Timeline.from_spec(p, rounds=99).events() == want.events()
    with pytest.raises(ValueError, match="churn"):
        Timeline.from_spec("nonsense", rounds=5)


def test_availability_windows():
    tl = Timeline(windows=[Availability(cid=2, start=3, end=6),
                           Availability(cid=2, start=8, end=9),
                           Availability(cid=4, start=0, end=100)])
    assert tl.unavailable(0) == {2}
    assert tl.unavailable(3) == frozenset()
    assert tl.unavailable(6) == {2}
    assert tl.unavailable(8) == frozenset()       # second window
    # cid 4's window covers everything; unwindowed clients never appear
    assert 4 not in tl.unavailable(50)


# ================================================================ sampling
def test_sample_clients_respects_unavailable_and_live_count():
    clients, _, _ = _fed(n_clients=10)
    st = engine.init("fedavg", LOSS, _params(), clients,
                     _cfg(sample_rate=0.5))
    _, ids = engine.sample_clients(st, unavailable={0, 1, 2})
    assert set(ids.tolist()).isdisjoint({0, 1, 2})
    st = engine.leave(st, 9)
    st = engine.leave(st, 8)
    # cohort size follows the LIVE population (8), not the registered (10)
    _, ids = engine.sample_clients(st)
    assert len(ids) == 4
    assert set(ids.tolist()).isdisjoint({8, 9})


def test_simulate_cohort_quantum_bounds_shapes():
    clients, _, _ = _fed(n_clients=12)
    st = engine.init("fedavg", LOSS, _params(), clients,
                     _cfg(sample_rate=0.75))
    tl = Timeline([Straggle(t=t, rate=0.3) for t in range(6)])
    st, log = simulate(st, tl, rounds=6, seed=0, cohort_quantum=4)
    sizes = {r["cohort"] for r in log.records if not r["skipped"]}
    assert all(c % 4 == 0 or c < 4 for c in sizes)


# ================================================================ invariants
def test_simulate_keeps_state_world_consistent():
    clients, tc, tests = _fed(n_clients=10)
    st = engine.init("stocfl", LOSS, _params(), clients,
                     _cfg(sample_rate=1.0), eval_fn=EVAL, arena=True)
    tl = Timeline.from_poisson(rounds=8, join_rate=0.8, leave_rate=0.5,
                               straggle=0.2, drift_every=3, n_clusters=2,
                               seed=5)
    factory = rotated_factory(n_clusters=2, n_per=16, seed=3)
    st, log = simulate(st, tl, rounds=8, client_factory=factory, seed=1,
                       eval_every=4, test_sets=tests, true_cluster=tc)
    # world and state agree about the population
    assert st.n_clients == len(st.ctx.clients) == len(st.sizes)
    assert st.ctx.arena.n_clients == st.n_clients
    np.testing.assert_array_equal(np.asarray(st.ctx.arena.sizes),
                                  np.asarray(st.sizes))
    assert st.left == frozenset(log.departed)
    assert set(log.joined) == set(range(10, st.n_clients))
    # departed clients are out of the partition; live sampled ones are in
    assign = st.clusters.assignment()
    assert not set(assign) & st.left
    for leaf in jax.tree.leaves(st.omega):
        assert np.isfinite(np.asarray(leaf)).all()
    # the log's population trajectory is internally consistent
    for r in log.records:
        assert r["n_live"] == r["n_registered"] - len(
            [c for c in log.departed
             if any(x["t"] <= r["t"] for x in log.records
                    if f"leave:{c}" in x["events"])])


def test_leave_events_commute():
    """Where the math promises order-invariance, the simulator delivers
    it: two departures in one round yield the same state either way
    (leave touches disjoint bookkeeping per cid)."""
    clients, _, _ = _fed(n_clients=8)

    def run(order):
        st = engine.init("stocfl", LOSS, _params(), clients,
                         _cfg(sample_rate=1.0), arena=True)
        st, _ = engine.run_round(st, np.arange(8))
        tl = Timeline([Leave(t=0, cid=order[0]), Leave(t=0, cid=order[1])])
        st, _ = simulate(st, tl, rounds=1, seed=0)
        return st

    _states_bitwise_equal(run((2, 5)), run((5, 2)))


def test_drift_rewrites_world_and_arena():
    clients, _, _ = _fed(n_clients=6)
    st = engine.init("fedavg", LOSS, _params(), clients,
                     _cfg(sample_rate=1.0), arena=True)
    before = np.asarray(st.ctx.clients[0]["x"]).copy()
    tl = Timeline([Drift(t=0, cids=(0,), strength=0.5)])
    st, _ = simulate(st, tl, rounds=1, seed=0)
    after = np.asarray(st.ctx.clients[0]["x"])
    assert not np.array_equal(before, after)
    # arena row mirrors the world; labels and shapes are preserved
    _leaves_equal(st.ctx.arena.client(0), st.ctx.clients[0])
    assert after.shape == before.shape


def test_drift_batch_preserves_labels_and_norms():
    rng = np.random.default_rng(0)
    b = {"x": rng.normal(size=(10, 8)).astype(np.float32),
         "y": rng.integers(0, 3, size=10).astype(np.int32)}
    d = drift_batch(b, np.random.default_rng(1), strength=0.1)
    np.testing.assert_array_equal(d["y"], b["y"])
    # orthogonal transform: row norms preserved
    np.testing.assert_allclose(np.linalg.norm(d["x"], axis=1),
                               np.linalg.norm(b["x"], axis=1), rtol=1e-4)


def test_routed_model_ifca_uses_best_hypothesis():
    """IFCA keeps no persistent assignment; routing must follow the
    paper's argmin-local-loss rule, not fall back to the untrained ω."""
    from repro.sim import routed_model
    clients, _, _ = _fed(n_clients=6)
    st = engine.init("ifca", LOSS, _params(), clients, _cfg(sample_rate=1.0))
    st, _ = engine.run_round(st)
    losses = [float(LOSS(st.models[m], st.ctx.clients[0]))
              for m in range(st.ctx.cfg.n_models)]
    _leaves_equal(routed_model(st, 0), st.models[int(np.argmin(losses))])


def test_full_participation_ignores_cohort_events_honestly():
    """CFL trains its whole partition regardless of the cohort argument:
    stragglers/availability must not fabricate a reduced cohort in the
    log — the round carries an explicit inapplicability marker."""
    clients, _, _ = _fed(n_clients=6)
    st = engine.init("cfl", LOSS, _params(), clients, _cfg(sample_rate=1.0))
    tl = Timeline([Straggle(t=0, rate=0.9)])
    st, log = simulate(st, tl, rounds=1, seed=0)
    r = log.records[0]
    assert r["cohort"] == 6
    assert "full-participation:cohort-events-inapplicable" in r["events"]


# ===================================================== arena/legacy parity
@pytest.mark.parametrize("name", ALL)
def test_arena_matches_legacy_under_churn(name):
    """The same churn timeline drives bitwise-identical ServerState
    trajectories on the arena and the legacy restack path — joins,
    departures, stragglers and all — for every registered strategy."""
    clients, _, _ = _fed()
    factory = rotated_factory(n_clusters=2, n_per=16, seed=3)
    tl = Timeline([Join(t=1, cluster=1), Leave(t=2, cid=0),
                   Straggle(t=3, rate=0.3), Join(t=3, cluster=0),
                   Leave(t=4)])

    def run(arena):
        st = engine.init(name, LOSS, _params(), clients, _cfg(),
                         eval_fn=EVAL, arena=arena)
        st, log = simulate(st, tl, rounds=5, client_factory=factory, seed=9)
        return st, log

    (a, la), (b, lb) = run(False), run(True)
    strip = lambda recs: [{k: v for k, v in r.items()
                           if not k.startswith("sec_")} for r in recs]
    assert strip(la.records) == strip(lb.records)
    assert la.joined == lb.joined and la.departed == lb.departed
    _states_bitwise_equal(a, b)


def test_join_leave_join_arena_regression():
    """§5 regression: join -> leave -> join under arena=True stays
    bit-identical to the legacy path, and the departed client's padded
    row contributes nothing afterwards (no stale rows in any loss)."""
    clients, _, _ = _fed(n_clients=6)
    extra, _, _ = _fed(n_clients=3, seed=11)

    def run(arena):
        st = engine.init("stocfl", LOSS, _params(), clients,
                         _cfg(sample_rate=1.0), arena=arena)
        st, _ = engine.run_round(st)
        st, c1 = engine.join(st, extra[0])
        st, _ = engine.run_round(st)
        st = engine.leave(st, c1)
        st, _ = engine.run_round(st)
        st, c2 = engine.join(st, extra[1])
        st, _ = engine.run_round(st)
        return st, (c1, c2)

    a, ids_a = run(False)
    b, ids_b = run(True)
    assert ids_a == ids_b == (6, 7)
    _states_bitwise_equal(a, b)
    # the arena still serves every live client's exact shard
    for cid in [0, 3, 7]:
        _leaves_equal(b.ctx.arena.client(cid), b.ctx.clients[cid])


# ========================================================== arena mechanics
def _mk(rng, n, d=4):
    return {"x": rng.normal(size=(n, d)).astype(np.float32),
            "y": rng.integers(0, 3, size=n).astype(np.int32)}


def test_arena_grow_doubles_capacity():
    rng = np.random.default_rng(0)
    ar = ClientArena.from_clients([_mk(rng, 6) for _ in range(3)])
    assert ar.capacity == 3
    ar = ar.append(_mk(rng, 6))
    assert ar.capacity == 6 and ar.n_rows == 4       # doubled, not +1
    ar = ar.append(_mk(rng, 6))
    assert ar.capacity == 6 and ar.n_rows == 5       # spare row reused
    assert ar.grow(6) is ar                          # no-op under capacity
    assert ar.grow(7).capacity == 12


def test_arena_from_clients_with_capacity():
    rng = np.random.default_rng(0)
    ar = ClientArena.from_clients([_mk(rng, 6) for _ in range(3)],
                                  capacity=10)
    assert ar.capacity == 10 and ar.n_rows == 3 and ar.n_clients == 3
    got = ar.gather([0, 2])
    assert jax.tree.leaves(got)[0].shape[0] == 2


def test_arena_tombstone_and_autocompact():
    rng = np.random.default_rng(1)
    shards = [_mk(rng, 5) for _ in range(4)]
    ar = ClientArena.from_clients(shards)
    ar = ar.tombstone(1)
    assert ar.n_live == 3 and ar.n_clients == 4
    # data still resident: forked pre-departure states can gather it
    _leaves_equal(ar.client(1), shards[1])
    ar = ar.tombstone(2)
    assert ar.n_rows == 4                    # 2/4 dead: not yet EXCEEDING half
    # third death exceeds 50% -> auto-compaction reclaims the rows
    ar = ar.tombstone(3)
    assert ar.n_rows == 1 and ar.capacity == 1
    with pytest.raises(KeyError):
        ar.gather([1])
    # the survivor keeps its id and its exact bytes
    _leaves_equal(ar.client(0), shards[0])
    # append after compaction regrows and keeps id stability
    new = _mk(rng, 5)
    ar = ar.append(new)
    assert ar.n_clients == 5
    _leaves_equal(ar.client(4), new)


def test_arena_compact_explicit_preserves_gather_values():
    rng = np.random.default_rng(2)
    shards = [_mk(rng, n) for n in (4, 7, 5, 7)]
    ar = ClientArena.from_clients(shards)
    ar = ar.tombstone(0, compact_frac=0)             # no auto-compact
    before = ar.gather([1, 3, 2])
    ar2 = ar.compact()
    after = ar2.gather([1, 3, 2])
    for x, y in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert ar2.n_rows == 3 and ar2.capacity == 3


def test_arena_update_rewrites_row_in_place():
    rng = np.random.default_rng(3)
    ar = ClientArena.from_clients([_mk(rng, 6) for _ in range(3)])
    nb = _mk(rng, 6)
    ar2 = ar.update(1, nb)
    _leaves_equal(ar2.client(1), nb)
    _leaves_equal(ar2.client(0), ar.client(0))
    # shorter rewrite goes ragged; the mask hides the tail
    short = _mk(rng, 4)
    ar3 = ar2.update(1, short)
    assert ar3.ragged
    got = ar3.gather([1])
    assert float(np.asarray(got["mask"]).sum()) == 4.0
    with pytest.raises(ValueError):
        ar2.update(1, _mk(rng, 99))                  # longer than n_max


# ============================================== straggler auditability
def test_straggle_victims_recorded_in_event_labels():
    """Regression: stragglers used to vanish from the cohort silently —
    the round logged ``straggle:{rate}`` but not WHO was dropped. The
    victims now ride the event labels (``straggle-victims:<cids>``), so
    async delay attribution is auditable from the log alone."""
    clients, _, _ = _fed(n_clients=12)
    st = engine.init("fedavg", LOSS, _params(), clients,
                     _cfg(sample_rate=0.75))
    tl = Timeline([Straggle(t=1, rate=0.6)])
    st, log = simulate(st, tl, rounds=3, seed=0)
    rec = log.records[1]
    assert any(l.startswith("straggle:") for l in rec["events"])
    victim_labels = [l for l in rec["events"]
                     if l.startswith("straggle-victims:")]
    assert victim_labels, "victim ids missing from the event log"
    victims = [int(c) for c in victim_labels[0].split(":", 1)[1].split(",")]
    assert victims, "label present but empty"
    # same seed, no straggle → the same draw trains in full; the victims
    # are exactly the sampled-minus-trained gap
    st0 = engine.init("fedavg", LOSS, _params(), clients,
                      _cfg(sample_rate=0.75))
    _, log0 = simulate(st0, Timeline([]), rounds=3, seed=0)
    assert rec["cohort"] + len(victims) == log0.records[1]["cohort"]


def test_straggle_victims_replay_identically():
    """Same seed, same timeline → same victims, both modes: the async
    path consumes the identical rng draw to delay instead of drop."""
    clients, _, _ = _fed(n_clients=12)

    def labels(async_mode):
        cfg = _cfg(sample_rate=0.75, rng_backend="device",
                   cluster_backend="device",
                   async_cfg=engine.AsyncConfig() if async_mode else None)
        st = engine.init("stocfl", LOSS, _params(), clients, cfg, arena=True)
        tl = Timeline([Straggle(t=1, rate=0.6)])
        _, log = simulate(st, tl, rounds=3, seed=0, async_mode=async_mode)
        return [l for l in log.records[1]["events"]
                if l.startswith("straggle-victims:")]

    sync_victims, async_victims = labels(False), labels(True)
    assert sync_victims and sync_victims == async_victims


def test_simulate_async_mode_delay_events():
    """The async dispatch loop: Straggle victims report late instead of
    dropping (cohort stays full), Delay events push whole-cohort latency,
    and the per-round records carry the flush bookkeeping."""
    from repro.sim import Delay
    clients, _, _ = _fed(n_clients=8)
    st = engine.init("stocfl", LOSS, _params(), clients,
                     _cfg(rng_backend="device", cluster_backend="device",
                          async_cfg=engine.AsyncConfig(staleness_cap=3)),
                     arena=True)
    tl = Timeline([Straggle(t=1, rate=0.5), Delay(t=3, rounds=2)])
    st, log = simulate(st, tl, rounds=7, seed=0, async_mode=True)
    recs = log.records
    assert all("merged" in r and "in_flight" in r for r in recs
               if not r["skipped"])
    # straggle round keeps its full cohort (victims delayed, not dropped)
    assert recs[1]["cohort"] == 4
    # the Delay round defers its whole cohort: nothing it dispatched
    # can merge before t+2
    assert recs[3]["in_flight"] >= recs[3]["cohort"]
    # conservation: every dispatched delta is merged or explicitly dropped
    dispatched = sum(r["cohort"] for r in recs if not r["skipped"])
    merged = sum(r.get("merged", 0) for r in recs)
    dropped = sum(r.get("dropped_stale", 0) + r.get("dropped_left", 0)
                  for r in recs)
    in_flight = recs[-1]["in_flight"]
    assert merged + dropped + in_flight == dispatched


def test_delay_event_round_trips_through_trace():
    """Delay serializes like every other event (kind + fields, cids
    list⇄tuple)."""
    from repro.sim import Delay, event_from_dict, to_dict
    ev = Delay(t=4, rounds=3, cids=(1, 2))
    assert event_from_dict(to_dict(ev)) == ev
    assert to_dict(Delay(t=1))["kind"] == "delay"
