"""Docs that can't rot: executable quickstart, enforced docstring
coverage, and resolvable markdown links.

The README's "Engine quickstart" code block is executed verbatim — if
the public API drifts, this test (not a reader) finds out. The
docstring test walks the ``__all__`` of ``repro.engine``, ``repro.sim``,
``repro.core``, ``repro.kernels``, ``repro.analysis`` and
``repro.sharding`` and fails on any public function,
class, or class member without a docstring, which is what keeps
`docs/ARCHITECTURE.md`'s and `docs/CLUSTERING.md`'s "see the
docstrings" stance honest."""
import ast
import importlib.util
import inspect
import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ========================================================= docstring walk
def _public_members(cls):
    for name, obj in vars(cls).items():
        if name.startswith("_"):
            continue
        if isinstance(obj, property):
            yield name, obj.fget
        elif inspect.isfunction(obj):
            yield name, obj
        elif isinstance(obj, (classmethod, staticmethod)):
            yield name, obj.__func__


@pytest.mark.parametrize("modname", ["repro.engine", "repro.sim",
                                     "repro.core", "repro.kernels",
                                     "repro.analysis", "repro.sharding",
                                     "repro.serve"])
def test_public_api_docstring_coverage(modname):
    mod = __import__(modname, fromlist=["__all__"])
    assert mod.__doc__, f"{modname} needs a module docstring"
    missing = []
    for name in mod.__all__:
        obj = getattr(mod, name)
        if inspect.isfunction(obj) or inspect.isclass(obj):
            if not (obj.__doc__ or "").strip():
                missing.append(f"{modname}.{name}")
        if inspect.isclass(obj):
            for mname, fn in _public_members(obj):
                if not (fn.__doc__ or "").strip():
                    missing.append(f"{modname}.{name}.{mname}")
    assert not missing, f"public API without docstrings: {missing}"


# ==================================================== executable quickstart
def _readme_quickstart():
    with open(os.path.join(REPO, "README.md")) as f:
        text = f.read()
    m = re.search(r"## Engine quickstart\s+```python\n(.*?)```", text,
                  re.DOTALL)
    assert m, "README lost its '## Engine quickstart' python block"
    return m.group(1)


def test_readme_quickstart_runs_verbatim():
    """The README quickstart is executed as-is: init, rounds, §5
    join/leave, §4.4 infer, evaluate. API drift fails here first."""
    code = _readme_quickstart()
    # keep CI wall time sane: the 30-round loop runs, but shortened
    shortened = code.replace("for _ in range(30):", "for _ in range(3):")
    assert shortened != code, "README quickstart round loop changed; " \
        "update the test's shortening substitution"
    exec(compile(shortened, "README.md:quickstart", "exec"), {})


def test_examples_parse():
    """Every example stays at least syntactically in date."""
    exdir = os.path.join(REPO, "examples")
    for fn in sorted(os.listdir(exdir)):
        if fn.endswith(".py"):
            with open(os.path.join(exdir, fn)) as f:
                ast.parse(f.read(), filename=fn)


def test_quickstart_example_matches_readme_api_surface():
    """examples/quickstart.py exercises every engine call the README
    block shows (the example may do more, never less)."""
    code = _readme_quickstart()
    with open(os.path.join(REPO, "examples", "quickstart.py")) as f:
        example = f.read()
    norm = lambda s: {"run" if c == "run_round" else c for c in s}
    readme_calls = norm(re.findall(r"engine\.(\w+)\(", code))
    example_calls = norm(re.findall(r"engine\.(\w+)\(", example))
    core = readme_calls & {"init", "run", "evaluate"}   # run ≡ run_round
    assert core <= example_calls, (
        f"examples/quickstart.py lost engine calls: {core - example_calls}")


# ============================================================= link check
def test_markdown_links_resolve():
    spec = importlib.util.spec_from_file_location(
        "check_links", os.path.join(REPO, "scripts", "check_links.py"))
    check_links = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(check_links)
    broken = check_links.check(root=REPO)
    assert not broken, f"broken markdown links: {broken}"
