"""Flash-decode (shard_map partial-softmax over the seq-sharded KV cache)
must match the dense decode path bit-for-tolerance.

Runs on the single real CPU device with a 1×1 mesh (n_shards=1 exercises
the shard_map machinery, masking, ring-buffer logic); the multi-shard case
is validated in the 8-device dry-run harness (scripts/ + §Perf it3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs import get_config
from repro.models import build
from repro.models.registry import grow_cache
from repro.sharding import ShardCtx


def _mesh11():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


@pytest.mark.parametrize("window", [None, 8])
def test_flash_matches_dense(window):
    cfg = get_config("llama3-8b", smoke=True).with_(dtype="float32",
                                                    sliding_window=window)
    dense = build(cfg)
    flash = build(cfg.with_(flash_decode=True))
    params = dense.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    cache = dense.make_cache(B, S)
    tok = jnp.ones((B,), jnp.int32)

    pos = jnp.int32(12 if window is None else 11)  # window: wraps ring buffer
    ld, cd = jax.jit(dense.decode)(params, tok, cache, pos)
    with ShardCtx(_mesh11()):
        lf, cf = jax.jit(flash.decode)(params, tok, cache, pos)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lf), atol=2e-3, rtol=2e-3)
    for a, b in zip(jax.tree.leaves(cd), jax.tree.leaves(cf)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


def test_flash_sequential_decode_consistency():
    """Token-by-token flash decode reproduces teacher forcing."""
    cfg = get_config("qwen2-1.5b", smoke=True).with_(dtype="float32",
                                                     flash_decode=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    logits_all, _ = model.forward_train(params, {"tokens": tokens})
    with ShardCtx(_mesh11()):
        pre, cache = model.prefill(params, {"tokens": tokens[:, : S - 1]})
        cache = grow_cache(model, cache, B, S)
        dec, _ = jax.jit(model.decode)(params, tokens[:, S - 1], cache, jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_all[:, -1]),
                               atol=2e-3, rtol=2e-3)
