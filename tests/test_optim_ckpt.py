"""Optimizers, schedules, pytree utils, checkpoint round-trips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the test extra
from hypothesis import given, settings, strategies as st

from repro.checkpoint import load_pytree, save_pytree
from repro.optim import adam, constant, cosine_decay, sgd, sgd_momentum, warmup_cosine
from repro.optim.sgd import apply_updates, clip_by_global_norm
from repro.utils import trees


def _quad_problem():
    target = {"a": jnp.array([1.0, -2.0, 3.0]), "b": jnp.array([[0.5]])}
    params = jax.tree.map(jnp.zeros_like, target)

    def loss(p):
        return trees.tree_dot(trees.tree_sub(p, target), trees.tree_sub(p, target))

    return params, target, loss


@pytest.mark.parametrize("opt", [sgd(0.1), sgd_momentum(0.05), adam(0.1)])
def test_optimizers_converge_on_quadratic(opt):
    params, target, loss = _quad_problem()
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(loss)(params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert float(loss(params)) < 1e-2


def test_clip_by_global_norm():
    g = {"x": jnp.ones((4,)) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 20.0)
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["x"])), 1.0, rtol=1e-5)


def test_schedules():
    assert float(constant(0.5)(100)) == 0.5
    cd = cosine_decay(1.0, 100)
    assert float(cd(0)) == pytest.approx(1.0)
    assert float(cd(100)) == pytest.approx(0.0, abs=1e-6)
    wc = warmup_cosine(1.0, 10, 100)
    assert float(wc(0)) < float(wc(9))
    assert float(wc(9)) <= 1.0


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 50))
def test_tree_flatten_roundtrip(n):
    key = jax.random.PRNGKey(n)
    tree = {"w": jax.random.normal(key, (n, 3)), "b": jax.random.normal(key, (2,)),
            "nested": {"s": jax.random.normal(key, ())}}
    vec = trees.tree_flatten_vector(tree)
    assert vec.shape == (n * 3 + 2 + 1,)
    back = trees.tree_unflatten_vector(vec, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_tree_weighted_mean():
    t1 = {"w": jnp.array([2.0])}
    t2 = {"w": jnp.array([6.0])}
    out = trees.tree_weighted_mean([t1, t2], [1.0, 3.0])
    np.testing.assert_allclose(float(out["w"][0]), 5.0)


def test_save_load_pytree(tmp_path):
    tree = {"layers": {"w": np.arange(6, dtype=np.float32).reshape(2, 3)},
            "scale": np.float32(2.5) * np.ones((1,), np.float32)}
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, tree)
    flat = load_pytree(path)
    assert "layers/w" in flat and "scale" in flat
    back = load_pytree(path, template=tree)
    np.testing.assert_allclose(back["layers"]["w"], tree["layers"]["w"])
