"""Dev loop: run every smoke arch through loss/prefill/decode on CPU."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import build
from repro.models.registry import decode_specs
from repro.models.config import InputShape

key = jax.random.PRNGKey(0)
S, B = 64, 2

for arch in (sys.argv[1:] or ARCH_IDS):
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    params = model.init(key)
    shape = InputShape("dev", S, B, "train")
    specs = model.input_specs(shape)
    batch = {
        k: (jax.random.randint(key, v.shape, 0, cfg.vocab_size, v.dtype)
            if v.dtype == jnp.int32 else jax.random.normal(key, v.shape, v.dtype))
        for k, v in specs.items()
    }
    loss = jax.jit(model.loss_fn)(params, batch)
    logits, cache = jax.jit(model.prefill)(params, batch)
    # decode one token against a fresh cache of length S
    cache2 = model.make_cache(B, S)
    tok = jnp.zeros((B,), jnp.int32)
    dlogits, _ = jax.jit(model.decode)(params, tok, cache2, jnp.int32(3))
    ok = bool(jnp.isfinite(loss)) and bool(jnp.all(jnp.isfinite(dlogits)))
    print(f"{arch:22s} loss={float(loss):8.4f} prefill_logits={logits.shape} "
          f"decode_logits={dlogits.shape} finite={ok}")
    assert ok, arch
print("ALL OK")
