#!/usr/bin/env python
"""Assert the persistent compilation cache actually kills the compile tax.

Two-process protocol (the cache only matters ACROSS processes — inside
one process jax's in-memory executable cache would mask it):

  1. probe #1 in a fresh subprocess with an empty cache directory:
     every XLA compile is cold and gets written to the directory.
  2. probe #2 in a second fresh subprocess sharing the directory:
     every compile request must now be SERVED from the cache
     (``cache_hits == compiles`` — the compile event fires per request,
     cached or not) and the first ``run_rounds`` call must get
     dramatically cheaper.

Each probe builds a small StoCFL federation (device arena + partition +
rng, the run_rounds preconditions), runs one scanned span, and prints
JSON ``{"first_s", "compiles", "cache_hits"}`` counted by
``repro.analysis.sanitize.compile_budget``.

CI runs this after the bench steps with the shared
``JAX_COMPILATION_CACHE_DIR``; a cold==warm result fails the build.

  PYTHONPATH=src python scripts/check_warm_cache.py           # full check
  PYTHONPATH=src python scripts/check_warm_cache.py --probe   # one probe
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys
import tempfile
import time


def probe() -> dict:
    import jax
    import jax.numpy as jnp

    from repro import engine
    from repro.analysis import sanitize
    from repro.data import rotated
    from repro.models import simple
    from repro.utils.cache import enable_compilation_cache

    enable_compilation_cache()   # honors JAX_COMPILATION_CACHE_DIR

    task = simple.SYNTH_MLP
    loss = lambda p, b: simple.loss_fn(p, b, task)
    clients, _, _ = rotated(n_clusters=4, n_clients=12, n_per=16, seed=0)
    clients = [jax.tree.map(jnp.asarray, c) for c in clients]
    cfg = engine.EngineConfig(tau=0.5, lam=0.05, lr=0.1, local_steps=1,
                              sample_rate=0.5, seed=0, project_dim=256,
                              cluster_backend="device", rng_backend="device")
    with sanitize.compile_budget() as log:
        st = engine.init("stocfl", loss,
                         simple.init(jax.random.PRNGKey(0), task),
                         clients, cfg, arena=True)
        t0 = time.time()
        st = engine.run_rounds(st, 3)
        jax.block_until_ready(st.omega)
        first_s = time.time() - t0
    return {"first_s": round(first_s, 4), "compiles": log.count,
            "cache_hits": log.cache_hits}


def run_probe(cache_dir: str) -> dict:
    env = dict(os.environ, JAX_COMPILATION_CACHE_DIR=cache_dir,
               JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", "cpu"))
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--probe"],
        env=env, capture_output=True, text=True, check=True)
    return json.loads(out.stdout.strip().splitlines()[-1])


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--probe", action="store_true",
                    help="run one in-process probe and print its JSON")
    ap.add_argument("--dir", default=None,
                    help="cache directory (default: fresh temp dir, "
                         "removed afterwards)")
    args = ap.parse_args()
    if args.probe:
        print(json.dumps(probe()))
        return 0

    cache_dir = args.dir or tempfile.mkdtemp(prefix="warm-cache-")
    made_temp = args.dir is None
    try:
        cold = run_probe(cache_dir)
        warm = run_probe(cache_dir)
        report = {"cache_dir": cache_dir, "cold": cold, "warm": warm}
        print(json.dumps(report, indent=1))
        ok = True
        if warm["cache_hits"] < 1:
            print("FAIL: warm probe had no persistent-cache hits")
            ok = False
        # the compile event fires per request even when served; warm
        # means (almost) every request was a hit. Slack of 2 covers
        # programs XLA refuses to cache (e.g. host callbacks)
        if warm["cache_hits"] < warm["compiles"] - 2:
            print(f"FAIL: only {warm['cache_hits']} of "
                  f"{warm['compiles']} warm compile requests were "
                  f"served from the cache")
            ok = False
        if warm["first_s"] > max(1.0, cold["first_s"] / 2):
            print(f"FAIL: warm first-call {warm['first_s']}s not under "
                  f"max(1.0, cold/2={cold['first_s'] / 2:.2f})s")
            ok = False
        if ok:
            print(f"OK: warm start {cold['first_s']}s -> "
                  f"{warm['first_s']}s, {warm['cache_hits']} cache hits")
        return 0 if ok else 1
    finally:
        if made_temp:
            shutil.rmtree(cache_dir, ignore_errors=True)


if __name__ == "__main__":
    sys.exit(main())
