"""§Perf hillclimbing driver.

Re-lowers the three selected (arch × shape) pairs with candidate changes
and records before/after roofline terms. Run AFTER the baseline sweep:

  PYTHONPATH=src python scripts/hillclimb.py [pair ...]

Pairs:
  moe    — phi3.5-moe train_4k   (worst useful-flops ratio: dispatch-bound)
  serve  — llama3-8b decode_32k  (most collective-bound: fsdp regather)
  mesh   — qwen2-1.5b train_4k   (paper-representative StoCFL round:
                                  Megatron-TP term vs mesh aspect)
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
import sys

sys.path.insert(0, "src")

from repro.launch.dryrun import run_one  # noqa: E402

OUT = "results/perf"


def moe():
    # baseline re-record under expert-parallel sharding (rule-order fix)
    run_one("phi35_moe_42b", "train_4k", False, OUT, tag_suffix="+base")
    for g in (1024, 512):
        run_one("phi35_moe_42b", "train_4k", False, OUT,
                overrides={"moe_group_size": g}, tag_suffix=f"+g{g}")
    # capacity factor 1.0 (tighter buffers)
    run_one("phi35_moe_42b", "train_4k", False, OUT,
            overrides={"moe_group_size": 512, "capacity_factor": 1.0},
            tag_suffix="+g512cf1")


def serve():
    run_one("llama3_8b", "decode_32k", False, OUT, tag_suffix="+base")
    run_one("llama3_8b", "decode_32k", False, OUT, serve_tp_only=True,
            tag_suffix="+tponly")
    # combine with bf16 params for serving (halves resident weight bytes)
    run_one("llama3_8b", "decode_32k", False, OUT, serve_tp_only=True,
            overrides={"param_dtype": "bfloat16"}, tag_suffix="+tponly_bf16")


def mesh():
    run_one("qwen2_1_5b", "train_4k", False, OUT, tag_suffix="+base")
    run_one("qwen2_1_5b", "train_4k", False, OUT, mesh_shape=(64, 4),
            tag_suffix="+mesh64x4")
    run_one("qwen2_1_5b", "train_4k", False, OUT, mesh_shape=(128, 2),
            tag_suffix="+mesh128x2")
    run_one("qwen2_1_5b", "train_4k", False, OUT, mesh_shape=(256, 1),
            tag_suffix="+mesh256x1")


if __name__ == "__main__":
    wanted = sys.argv[1:] or ["moe", "serve", "mesh"]
    for name in wanted:
        {"moe": moe, "serve": serve, "mesh": mesh}[name]()
    print("hillclimb done; JSONs in", OUT)
