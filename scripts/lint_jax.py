#!/usr/bin/env python
"""CI gate for the JAX hazard linter (``repro.analysis.jaxlint``).

Usage::

    PYTHONPATH=src python scripts/lint_jax.py [paths...] [--strict]
        [--format text|json] [--waivers OUT.json]

Default path is ``src/repro``. Exit codes: 0 clean, 1 findings (in
``--strict`` mode a reason-less waiver also fails — an unexplained
waiver is a silenced finding, which is exactly what the waiver syntax
exists to prevent). ``--waivers`` writes the full waiver inventory as a
JSON artifact so CI keeps intentional hazards auditable over time.
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.analysis import jaxlint  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories (default: src/repro)")
    ap.add_argument("--strict", action="store_true",
                    help="fail on reason-less waivers too")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--waivers", metavar="OUT",
                    help="write waiver inventory JSON to OUT")
    args = ap.parse_args(argv)

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    paths = args.paths or [os.path.join(repo, "src", "repro")]
    report = jaxlint.lint_paths(paths)

    if args.waivers:
        with open(args.waivers, "w") as f:
            json.dump(report.to_json(), f, indent=2, sort_keys=True)

    failures = list(report.errors)
    reasonless = report.reasonless_waivers() if args.strict else []

    if args.format == "json":
        print(json.dumps(report.to_json(), indent=2, sort_keys=True))
    else:
        for f in report.findings:
            print(f.format())
        for w in reasonless:
            print(f"{w.path}:{w.line}: waiver for {','.join(w.rules)} "
                  "has no justification (strict mode requires one)")
        for w in report.unused_waivers():
            print(f"{w.path}:{w.line}: note: unused waiver for "
                  f"{','.join(w.rules)}")
        n_waived = sum(1 for f in report.findings if f.waived)
        print(f"jaxlint: {len(failures)} error(s), {n_waived} waived, "
              f"{len(report.waivers)} waiver(s) "
              f"({len(report.unused_waivers())} unused)")

    return 1 if (failures or reasonless) else 0


if __name__ == "__main__":
    sys.exit(main())
