"""Markdown link check: every relative link in the repo's docs must
resolve to a real file (network-free — http(s) links are skipped, as are
intra-page anchors). Run standalone or via the tier-1 docs test:

    python scripts/check_links.py [files...]

Exits non-zero listing every broken link, so the CI docs step (and the
test that wraps it) fails the moment ARCHITECTURE/README/EXPERIMENTS
drift from the tree.
"""
from __future__ import annotations

import os
import re
import sys

DEFAULT_FILES = ["README.md", "docs/ARCHITECTURE.md", "docs/CLUSTERING.md",
                 "docs/ANALYSIS.md", "docs/SHARDING.md", "docs/ASYNC.md",
                 "docs/SERVING.md",
                 "EXPERIMENTS.md", "ROADMAP.md", "CHANGES.md"]
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_file(path: str, root: str = ".") -> list:
    """Broken relative links in one markdown file, as (target, reason)."""
    broken = []
    with open(os.path.join(root, path)) as f:
        text = f.read()
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        resolved = os.path.normpath(os.path.join(root, os.path.dirname(path), rel))
        if not os.path.exists(resolved):
            broken.append((target, f"{resolved} does not exist"))
    return broken


def check(files=None, root: str = ".") -> dict:
    """{file: [(target, reason), ...]} over ``files`` (default: the
    repo's top-level docs that exist)."""
    files = [f for f in (files or DEFAULT_FILES)
             if os.path.exists(os.path.join(root, f))]
    out = {}
    for path in files:
        bad = check_file(path, root)
        if bad:
            out[path] = bad
    return out


def main(argv) -> int:
    broken = check(argv or None)
    for path, items in broken.items():
        for target, reason in items:
            print(f"{path}: broken link '{target}' ({reason})")
    if not broken:
        print("all markdown links resolve")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
