"""Roofline aggregator: reads results/dryrun/*.json and renders the
per-(arch × shape × mesh) roofline table for EXPERIMENTS.md §Roofline.

  PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

NOTES = {
    ("moe", "train"): "shrink MoE dispatch groups / sort-based dispatch to cut all-to-all + dispatch flops",
    ("moe", "prefill"): "expert-parallel all-to-all overlap with expert GEMMs",
    ("moe", "decode"): "serve with tp-resident weights (no fsdp regather) + fused top-k dispatch",
    ("dense", "train"): "reduce tp width for small d_model (Megatron all-reduces dominate) / overlap grad reduce",
    ("dense", "prefill"): "flash attention tiling keeps logits in VMEM; fuse rope+qkv",
    ("dense", "decode"): "tp-resident weights for serving; flash-decode over seq-sharded cache",
    ("ssm", "train"): "Pallas ssm_scan fuses h trajectory in VMEM (no HBM h_all)",
    ("ssm", "prefill"): "same fused-scan win; conv+gate fusion",
    ("ssm", "decode"): "O(1) state decode is weight-bound: tp-resident weights",
    ("hybrid", "train"): "shared-attn block reuse amortizes; scan groups",
    ("hybrid", "prefill"): "fused mamba2 chunk scan",
    ("hybrid", "decode"): "tp-resident weights; mamba state update fusion",
    ("audio", "train"): "cross-attn K/V computed once per batch (already); fuse enc layers",
    ("audio", "prefill"): "cache cross-K/V across requests with same audio",
    ("audio", "decode"): "tp-resident weights; small-batch decode is latency-bound",
    ("vlm", "train"): "patch prefix shares the dense path; same tp trade-offs",
    ("vlm", "prefill"): "flash attention over 32k mixed patch+text context",
    ("vlm", "decode"): "tp-resident weights; sliding window for 500k",
}


def load(dirpath):
    recs = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def arch_type_of(arch):
    from repro.configs import get_config
    return get_config(arch).arch_type


def render(recs, md=False):
    rows = []
    for r in recs:
        if r.get("status") == "skipped":
            rows.append((r["arch"], r["shape"], r["mesh"], "SKIP", r["reason"], "", "", "", ""))
            continue
        t = r["terms"]
        at = arch_type_of(r["arch"])
        note = NOTES.get((at, r["kind"]), "")
        rows.append((
            r["arch"], r["shape"], r["mesh"],
            f"{t['compute_s']*1e3:.1f}", f"{t['memory_s']*1e3:.1f}",
            f"{t['collective_s']*1e3:.1f}",
            r["dominant"].replace("_s", ""),
            f"{r['useful_flops_ratio']:.3f}" if r.get("useful_flops_ratio") else "-",
            note,
        ))
    hdr = ("arch", "shape", "mesh", "compute_ms", "memory_ms", "collective_ms",
           "dominant", "useful_ratio", "what_moves_the_dominant_term")
    if md:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
        for row in rows:
            print("| " + " | ".join(str(x) for x in row) + " |")
    else:
        print(",".join(hdr))
        for row in rows:
            print(",".join(f'"{x}"' if "," in str(x) else str(x) for x in row))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    render(load(args.dir), md=args.md)


if __name__ == "__main__":
    main()
