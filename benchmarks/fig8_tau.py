"""Fig. 8 reproduction: τ decides the clustering FOCUS. Setting: 2
rotations × 4 label groups = 8 fine clusters (rotated_pathological). The
paper: high τ resolves both feature AND label structure (8 clusters);
lower τ collapses the feature level and clusters by label structure only;
τ→−1 merges everything."""
from __future__ import annotations

import numpy as np

from benchmarks.common import LOSS, init_params
from repro.core.clustering import ClusterState, adjusted_rand_index
from repro.core.extractor import make_extractor
from repro.data import rotated_pathological

import jax
import jax.numpy as jnp


def run(n_clients=64, seed=1):
    clients, truth = rotated_pathological(n_clients=n_clients, seed=seed)
    params = init_params(seed)
    ext = make_extractor(LOSS, params)
    reps = [np.asarray(ext(jax.tree.map(jnp.asarray, c))) for c in clients]

    rows = []
    import time
    for tau in [0.8, 0.6, 0.45, 0.2, -1.0]:
        t0 = time.time()
        st = ClusterState(tau)
        st.observe(range(len(clients)), reps)
        # stochastic merging over rounds (25% visibility per round)
        rng = np.random.default_rng(seed)
        for _ in range(8):
            st.merge_round()
        us = (time.time() - t0) * 1e6 / 8
        assign = st.assignment()
        labels = [assign[i] for i in range(len(clients))]
        ari_fine = adjusted_rand_index(labels, truth["fine"])
        ari_label = adjusted_rand_index(labels, truth["label"])
        ari_rot = adjusted_rand_index(labels, truth["rotation"])
        rows.append((f"fig8_tau{tau}", us,
                     f"K={st.n_clusters()};ari_fine={ari_fine:.3f};"
                     f"ari_label={ari_label:.3f};ari_rotation={ari_rot:.3f}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
