"""Serving microbenchmark: continuous batching vs the sequential loop.

Writes ``BENCH_serve.json``. Four modes per (arch, batch) point:

- ``sequential``  — ``serve.SequentialLoop``, the DEBUGGED legacy loop
  (preallocated cache, on-device token accumulation, one transfer per
  request), one request at a time;
- ``continuous``  — ``serve.ServeEngine`` routing across K personalized
  cluster models, total batch window = clusters × slots;
- ``continuous-shared`` — the single-model baseline at equal batch: the
  SAME engine, same K groups, same slots, but every group holds the
  same weights. The program is identical to ``continuous`` (XLA cannot
  see the weights are equal), so the gap prices exactly what
  cluster-routing adds: Ψ-routing, the per-cluster queues, and
  heterogeneous weights — ``routed_overhead_pct`` in the summary;
- ``continuous-fused`` — one cluster group of K·slots lanes (the
  cluster axis collapsed). Serving K heterogeneous models is a
  block-diagonal batched GEMM where one model is a single fused GEMM;
  on CPU smoke shapes XLA's batched dot is measurably slower, and
  ``blockdiag_overhead_pct`` keeps that gap visible (it is a compute-
  shape property of heterogeneity itself, not serve-engine overhead —
  no scheduler can serve two different weight matrices with one GEMM).

Timing protocol (the serve.py bug this bench exists to keep fixed):
every mode runs a warmup wave at IDENTICAL shapes first — paying all
XLA compiles and the Ψ-routing extractor — then ``reset()`` (which
keeps compiled programs + routing cache) and times a reconnect wave
that compiles nothing and routes from the cache. ``first_compile_s``
is the warmup wall, reported separately from ``wall_s``/``tok_per_s``.

  PYTHONPATH=src python -m benchmarks.serve_bench --smoke --out BENCH_serve.json
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro import serve
from repro.configs import get_config
from repro.launch.serve import build_server_state, make_requests
from repro.models import build


def _row_key(r):
    return (r["mode"], r["arch"], r["clusters"], r["batch"])


def _merge_rows(out: str, rows: list, summary: dict) -> None:
    doc = {"rows": []}
    if os.path.exists(out):
        with open(out) as f:
            doc = json.load(f)
    fresh = {_row_key(r) for r in rows}
    doc["rows"] = [r for r in doc.get("rows", [])
                   if _row_key(r) not in fresh] + rows
    doc.setdefault("summary", {}).update(summary)
    with open(out, "w") as f:
        json.dump(doc, f, indent=1)


def _reconnects(reqs, gen):
    return [serve.Request(rid=f"warm-{r.rid}", client_id=r.client_id,
                          prompt=r.prompt, gen=gen) for r in reqs]


def bench_continuous(cfg, model, clusters, slots, requests, prompt_len,
                     gen, shared=False):
    st = build_server_state(cfg, model, clusters, tau=0.3, seed=0)
    if shared:                             # one model behind every group
        one = next(iter(st.models.values()))
        st = st.replace(models={r: one for r in st.models})
    eng = serve.ServeEngine(model, st, serve.ServeConfig(
        slots=slots, max_len=prompt_len + gen, max_gen=gen))
    reqs = make_requests(cfg, requests, prompt_len, gen, clusters)
    t0 = time.time()
    eng.submit_many(reqs)                  # routes every client (misses)
    eng.run()                              # pays every compile
    first = time.time() - t0
    wall = float("inf")                    # best-of-4: the timed waves
    for rep in range(4):                   # are tiny, single-shot is noisy
        eng.reset()                        # keeps programs + route cache
        timed = _reconnects(reqs, gen)
        t0 = time.time()
        eng.submit_many(timed)             # all cache hits
        res = eng.run()
        wall = min(wall, time.time() - t0)
        assert len(res) == requests
    return first, wall, eng.stats()


def bench_sequential(cfg, model, clusters, requests, prompt_len, gen):
    st = build_server_state(cfg, model, clusters, tau=0.3, seed=0)
    loop = serve.SequentialLoop(model, st, max_len=prompt_len + gen,
                                max_gen=gen)
    reqs = make_requests(cfg, requests, prompt_len, gen, clusters)
    t0 = time.time()
    loop.router.route_many([(r.client_id, r.history) for r in reqs])
    loop.serve(reqs[0])                    # pays every compile
    first = time.time() - t0
    wall = float("inf")
    for rep in range(4):
        timed = _reconnects(reqs, gen)
        t0 = time.time()
        for r in timed:
            loop.serve(r)
        wall = min(wall, time.time() - t0)
    return first, wall, {"router_hits": loop.router.hits,
                         "router_misses": loop.router.misses}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep (smoke configs, small grid)")
    ap.add_argument("--arch", default="qwen2_1_5b")
    ap.add_argument("--clusters", type=int, default=2)
    ap.add_argument("--slots", type=int, nargs="+", default=None,
                    help="per-cluster slot counts to sweep")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()

    slots_sweep = args.slots or ([1, 2, 4] if args.smoke else [2, 4, 8])
    cfg = get_config(args.arch, smoke=args.smoke)
    model = build(cfg)
    P, G, K = args.prompt_len, args.gen, args.clusters
    rows, base = [], {"arch": args.arch, "prompt_len": P, "gen": G,
                      "devices": jax.device_count()}

    def emit(mode, clusters, batch, requests, first, wall, stats):
        row = dict(base, mode=mode, clusters=clusters, batch=batch,
                   requests=requests, tokens=requests * G,
                   first_compile_s=round(first, 3), wall_s=round(wall, 4),
                   tok_per_s=round(requests * G / max(wall, 1e-9), 1),
                   router_hits=stats.get("router_hits", 0),
                   router_misses=stats.get("router_misses", 0))
        rows.append(row)
        print(json.dumps(row))
        return row

    # sequential anchor: one run, request count = the largest sweep point
    n_seq = 2 * K * slots_sweep[-1]
    first, wall, stats = bench_sequential(cfg, model, K, n_seq, P, G)
    emit("sequential", K, 1, n_seq, first, wall, stats)

    for slots in slots_sweep:
        batch = K * slots
        n = 2 * batch                     # two admission generations
        for mode, clusters, sl, shared in (
                ("continuous", K, slots, False),
                ("continuous-shared", K, slots, True),
                ("continuous-fused", 1, batch, False)):
            first, wall, stats = bench_continuous(cfg, model, clusters, sl,
                                                  n, P, G, shared=shared)
            emit(mode, clusters, batch, n, first, wall, stats)

    seq_tps = next(r["tok_per_s"] for r in rows if r["mode"] == "sequential")
    summary = {}

    def _tps(mode, batch):
        return next(r["tok_per_s"] for r in rows
                    if r["mode"] == mode and r["batch"] == batch)

    for r in rows:
        if r["mode"] != "continuous":
            continue
        shared, fused = (_tps("continuous-shared", r["batch"]),
                         _tps("continuous-fused", r["batch"]))
        summary[f"{args.arch}/batch{r['batch']}"] = {
            "speedup_vs_sequential": round(r["tok_per_s"] / seq_tps, 2),
            "routed_overhead_pct": round(
                100.0 * (shared - r["tok_per_s"]) / shared, 1),
            "blockdiag_overhead_pct": round(
                100.0 * (fused - r["tok_per_s"]) / fused, 1),
        }
    print(json.dumps({"summary": summary}))
    _merge_rows(args.out, rows, summary)


if __name__ == "__main__":
    main()
