"""Fig. 6/Table (Shifted, cross-silo N=20) reproduction: StoCFL vs CFL
(Sattler recursive bi-partitioning), IFCA, FedAvg with full participation.
Paper claim: StoCFL ≈ CFL accuracy without needing full participation."""
from __future__ import annotations

from benchmarks.common import run_baseline, run_stocfl, to_dev
from repro.data import shifted


def run(rounds=25, seed=1):
    clients, tc, tests = shifted(n_clusters=4, n_clients=20, n_per=256, seed=seed)
    clients, tests = to_dev(clients, tests)
    rows = []
    s = run_stocfl(clients, tc, tests, rounds=rounds, sample_rate=1.0, seed=seed)
    rows.append(("table2_stocfl", s["us_per_round"],
                 f"acc={s['acc']:.4f};ari={s['ari']:.3f};K={s['k']}"))
    # StoCFL with PARTIAL participation — the flexibility claim
    s2 = run_stocfl(clients, tc, tests, rounds=rounds, sample_rate=0.25, seed=seed)
    rows.append(("table2_stocfl_25pct", s2["us_per_round"],
                 f"acc={s2['acc']:.4f};ari={s2['ari']:.3f};K={s2['k']}"))
    for algo in ["cfl", "ifca", "fedavg"]:
        b = run_baseline(algo, clients, tc, tests, rounds=rounds, sample_rate=1.0, seed=seed)
        rows.append((f"table2_{algo}", b["us_per_round"], f"acc={b['acc']:.4f}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
