"""Table 3 reproduction: effect of regularization weight λ on cluster-model
accuracy across Non-IID settings. Paper claims: λ>0 beats λ=0 (knowledge
transfer through ω); the best λ is setting-dependent."""
from __future__ import annotations

from benchmarks.common import run_stocfl, to_dev
from repro.data import pathological, rotated, shifted, hybrid

LAMBDAS = [0.0, 0.01, 0.05, 0.5, 1.0]


def run(n_clients=40, rounds=25, seed=1):
    rows = []
    for name, maker in [("rotated", rotated), ("shifted", shifted),
                        ("pathological", pathological), ("hybrid", hybrid)]:
        clients, tc, tests = maker(n_clients=n_clients, seed=seed)
        clients, tests = to_dev(clients, tests)
        accs = []
        us = 0.0
        for lam in LAMBDAS:
            out = run_stocfl(clients, tc, tests, rounds=rounds, lam=lam,
                             sample_rate=0.25, seed=seed)
            accs.append(out["acc"])
            us = out["us_per_round"]
        derived = ";".join(f"lam{l}={a:.4f}" for l, a in zip(LAMBDAS, accs))
        rows.append((f"table3_{name}", us, derived))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
