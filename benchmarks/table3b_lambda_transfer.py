"""Table 3 companion: the λ knowledge-transfer regime (EXPERIMENTS.md
Table-3 note). Clusters share 48/64 feature dims and clients hold only 12
samples — the paper's rotated-digits regime, where λ>0 must dominate λ=0."""
from __future__ import annotations

from benchmarks.common import run_stocfl, to_dev
from repro.data.synthetic import rotated_partial

LAMBDAS = [0.0, 0.05, 0.5, 1.0]


def run(seed=1, rounds=30):
    clients, tc, tests = rotated_partial(seed=seed)
    clients, tests = to_dev(clients, tests)
    rows = []
    for tau, tag in [(0.6, "personalized"), (0.45, "mid")]:
        accs = []
        us = 0.0
        for lam in LAMBDAS:
            out = run_stocfl(clients, tc, tests, rounds=rounds, lam=lam,
                             tau=tau, sample_rate=0.25, seed=seed)
            accs.append(out["acc"])
            us = out["us_per_round"]
        derived = ";".join(f"lam{l}={a:.4f}" for l, a in zip(LAMBDAS, accs))
        rows.append((f"table3b_{tag}", us, derived))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
