"""Fig. 3 reproduction: stochastic client clustering over rounds with 10%
participation on all four skews — cluster count trajectory, Eq. 2 objective,
final ARI vs ground truth."""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_stocfl, to_dev
from repro.data import hybrid, pathological, rotated, shifted


def run(n_clients=60, rounds=40, seed=1):
    rows = []
    for name, maker in [("pathological", pathological), ("rotated", rotated),
                        ("shifted", shifted), ("hybrid", hybrid)]:
        clients, tc, tests = maker(n_clients=n_clients, seed=seed)
        clients, tests = to_dev(clients, tests)
        out = run_stocfl(clients, tc, tests, rounds=rounds, sample_rate=0.1, seed=seed)
        hist = out["state"].history
        k_curve = [h["n_clusters"] for h in hist[:: max(rounds // 8, 1)]]
        rows.append((f"fig3_{name}", out["us_per_round"],
                     f"ari={out['ari']:.3f};K={out['k']};k_curve={'/'.join(map(str, k_curve))}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
