"""Shared harness for the paper-table benchmarks — on the engine API."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.core import adjusted_rand_index
from repro.models import simple

TASK = simple.SYNTH_MLP
LOSS = lambda p, b: simple.loss_fn(p, b, TASK)
EVAL = jax.jit(lambda p, b: simple.accuracy(p, b, TASK))


def setup_cache(path: str | None = None) -> str:
    """Enable the persistent XLA compilation cache for this bench
    process (``$JAX_COMPILATION_CACHE_DIR`` or the user default). CI
    shares one directory across bench steps so every step after the
    first starts warm; returns the directory used."""
    from repro.utils.cache import enable_compilation_cache
    return enable_compilation_cache(path)


def to_dev(clients, tests):
    clients = [jax.tree.map(jnp.asarray, c) for c in clients]
    tests = {k: jax.tree.map(jnp.asarray, v) for k, v in tests.items()}
    return clients, tests


def init_params(seed=0):
    return simple.init(jax.random.PRNGKey(seed), TASK)


def run_stocfl(clients, tc, tests, rounds=25, tau=0.5, lam=0.05, lr=0.1,
               local_steps=5, sample_rate=0.2, seed=0):
    st = engine.init("stocfl", LOSS, init_params(seed), clients,
                     engine.EngineConfig(tau=tau, lam=lam, lr=lr,
                                         local_steps=local_steps,
                                         sample_rate=sample_rate, seed=seed),
                     eval_fn=EVAL)
    t0 = time.time()
    st = engine.run(st, rounds)
    wall = time.time() - t0
    assign = st.clusters.assignment()
    ids = sorted(assign)
    ari = adjusted_rand_index([assign[c] for c in ids], [tc[c] for c in ids]) if ids else 0.0
    res = engine.evaluate(st, tests, tc)
    return {"acc": res["cluster_avg"], "global_acc": res["global_avg"],
            "ari": ari, "k": st.clusters.n_clusters(),
            "us_per_round": wall / rounds * 1e6, "state": st}


def run_baseline(name, clients, tc, tests, rounds=25, lr=0.1, local_steps=5,
                 sample_rate=0.2, seed=0, mu=0.05, n_models=4):
    cfg = engine.EngineConfig(lr=lr, local_steps=local_steps,
                              sample_rate=1.0 if name == "cfl" else sample_rate,
                              seed=seed, mu=mu, n_models=n_models)
    st = engine.init(name, LOSS, init_params(seed), clients, cfg, eval_fn=EVAL)
    t0 = time.time()
    st = engine.run(st, rounds)
    wall = time.time() - t0
    res = engine.evaluate(st, tests, tc)
    return {"acc": res["cluster_avg"], "us_per_round": wall / rounds * 1e6,
            "state": st}


def emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")
