"""Dynamic-federation bench: churn rate × population sweep plus the §5
newly-joined-client recovery experiment. Writes ``BENCH_churn.json``.

Two questions, one artifact:

1. **Does churn cost anything per round?** For each (population N, churn
   fraction c): onboard a rotated federation on the arena path, measure
   the static steady-state round time, then drive ``repro.sim.simulate``
   with a Poisson timeline whose total join+leave volume is ``c·N`` over
   the run and measure the steady-state round time *under churn*
   (``sec_train`` — the ``run_round`` call alone) next to the per-event
   application cost. The headline ratio ``churn_over_static`` should
   stay ~1: joins are amortized-O(1) arena writes, leaves are
   tombstones, and ``cohort_quantum`` keeps the set of compiled cohort
   shapes bounded while the population drifts.

2. **Do newly-joined clients recover (§5)?** Train a federation to a
   settled partition, burst-join 20% new clients drawn from the same
   latent distributions, and record the routed-model accuracy of the
   newcomers vs. a sample of incumbents every round — the recovery curve
   (``recovery.joined_acc`` / ``incumbent_acc``; final gap should be
   within ~2 accuracy points).

  PYTHONPATH=src python -m benchmarks.churn_sweep            # full sweep
  PYTHONPATH=src python -m benchmarks.churn_sweep --smoke    # CI-sized
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.data import rotated, rotated_factory
from repro.models import simple
from repro.sim import Timeline, simulate
from repro.sim.events import Join

TASK = simple.SYNTH_MLP
LOSS = lambda p, b: simple.loss_fn(p, b, TASK)
EVAL = jax.jit(lambda p, b: simple.accuracy(p, b, TASK))


def _federation(n_clients: int, n_per: int, seed: int = 0):
    clients, tc, tests = rotated(n_clusters=4, n_clients=n_clients,
                                 n_per=n_per, seed=seed)
    clients = [jax.tree.map(jnp.asarray, c) for c in clients]
    tests = {k: jax.tree.map(jnp.asarray, v) for k, v in tests.items()}
    return clients, tc, tests


def _cfg(sample_rate: float, chunk: int, local_steps: int,
         seed: int = 0) -> engine.EngineConfig:
    # Ψ sketched to 1024 dims: keeps per-client clustering state O(1k) at
    # every population (same choice/rationale as benchmarks/scale_cohort)
    return engine.EngineConfig(tau=0.5, lam=0.05, lr=0.1,
                               local_steps=local_steps,
                               sample_rate=sample_rate, seed=seed,
                               project_dim=1024, cohort_chunk=chunk)


def _onboard(state, n_clients: int, settle: int = 3):
    """One full-participation onboarding round (all Ψ observed, big
    shapes compiled) + settle rounds, so both the static and the churn
    measurements start from the same steady partition."""
    t0 = time.time()
    state, _ = engine.run_round(state, np.arange(n_clients))
    onboard = time.time() - t0
    for _ in range(settle):
        state, _ = engine.run_round(state)
    return state, onboard


def _static_rounds(state, rounds: int):
    times = []
    for _ in range(rounds):
        t0 = time.time()
        state, _ = engine.run_round(state)
        jax.block_until_ready(state.omega)
        times.append(time.time() - t0)
    return state, float(np.median(times))


def churn_point(n_clients: int, churn: float, rounds: int, n_per: int,
                sample_rate: float, chunk: int, quantum: int,
                seed: int = 0) -> dict:
    """One sweep point: static steady-state timing, then the same state
    driven through a Poisson churn timeline of total volume churn·N."""
    clients, tc, tests = _federation(n_clients, n_per, seed)
    cfg = _cfg(sample_rate, chunk, local_steps=1, seed=seed)
    t_start = time.time()
    st = engine.init("stocfl", LOSS, simple.init(jax.random.PRNGKey(0), TASK),
                     clients, cfg, eval_fn=EVAL, arena=True)
    st, onboard = _onboard(st, n_clients)
    st, sec_static = _static_rounds(st, rounds=5)

    rate = churn * n_clients / (2 * rounds)      # joins + leaves = churn·N
    tl = Timeline.from_poisson(rounds=rounds, join_rate=rate,
                               leave_rate=rate, n_clusters=4,
                               seed=seed, start=0)
    factory = rotated_factory(n_clusters=4, n_per=n_per, seed=seed)
    st, log = simulate(st, tl, rounds=rounds, client_factory=factory,
                       seed=seed, cohort_quantum=quantum)

    trained = [r for r in log.records if not r["skipped"]]
    warm = trained[min(3, max(len(trained) - 2, 0)):]   # drop compile warmup
    sec_churn = float(np.median([r["sec_train"] for r in warm]))
    ev_rounds = [r for r in warm if r["had_events"]]
    sec_event = (float(np.median([r["sec_round"] - r["sec_train"]
                                  for r in ev_rounds]))
                 if ev_rounds else 0.0)
    arena = st.ctx.arena
    return {
        "clients": n_clients, "churn": churn, "rounds": rounds,
        "events": tl.counts(), "joined": len(log.joined),
        "departed": len(log.departed),
        "sec_onboard": round(onboard, 2),
        "sec_round_static": round(sec_static, 4),
        "sec_round_churn": round(sec_churn, 4),
        "sec_event_apply": round(sec_event, 4),
        "churn_over_static": round(sec_churn / sec_static, 3),
        "n_registered_final": st.n_clients,
        "n_live_final": st.n_clients - len(st.left),
        "arena": {"capacity": arena.capacity, "n_rows": arena.n_rows,
                  "dead_resident": sum(1 for c in arena.dead
                                       if arena.rows[c] >= 0)},
        "n_clusters_final": st.clusters.n_clusters(),
        "sec_total": round(time.time() - t_start, 2),
        "records": log.records,
    }


def recovery_experiment(n_clients: int, join_frac: float, pre_rounds: int,
                        post_rounds: int, n_per: int, seed: int = 0) -> dict:
    """§5 newly-joined-client experiment: settle a federation, burst-join
    ``join_frac``·N fresh clients from the same latent clusters, and
    track routed accuracy of newcomers vs incumbents every round."""
    clients, tc, tests = _federation(n_clients, n_per, seed)
    cfg = _cfg(sample_rate=0.2, chunk=0, local_steps=3, seed=seed)
    st = engine.init("stocfl", LOSS, simple.init(jax.random.PRNGKey(0), TASK),
                     clients, cfg, eval_fn=EVAL, arena=True)
    st, _ = _onboard(st, n_clients, settle=0)
    st = engine.run(st, pre_rounds)

    n_join = max(int(round(join_frac * n_clients)), 1)
    rng = np.random.default_rng(seed + 1)
    joins = [Join(t=0, cluster=int(rng.integers(4))) for _ in range(n_join)]
    factory = rotated_factory(n_clusters=4, n_per=n_per, seed=seed)
    st, log = simulate(st, Timeline(joins), rounds=post_rounds,
                       client_factory=factory, seed=seed, eval_every=1,
                       test_sets=tests, true_cluster=tc)
    ts, joined = log.curve("joined_acc")
    _, incumbent = log.curve("incumbent_acc")
    gaps = [round(i - j, 5) for i, j in zip(incumbent, joined)]
    return {
        "clients": n_clients, "joined": n_join, "join_frac": join_frac,
        "pre_rounds": pre_rounds, "post_rounds": post_rounds,
        "rounds": ts, "joined_acc": [round(a, 5) for a in joined],
        "incumbent_acc": [round(a, 5) for a in incumbent],
        "gap": gaps, "final_gap": gaps[-1] if gaps else None,
        "recovered_within_2pts": bool(gaps and abs(gaps[-1]) <= 0.02),
    }


def run(smoke: bool = False, rounds: int = 30, n_per: int = 32,
        sample_rate: float = 0.1, chunk: int = 64, quantum: int = 32):
    populations = [40] if smoke else [200, 1000]
    churns = [0.2] if smoke else [0.05, 0.2]
    if smoke:
        rounds = min(rounds, 8)
    points = []
    for n in populations:
        for c in churns:
            # the quantum must stay below the nominal cohort or every
            # round degenerates to the single-shape floor
            q = min(quantum, max(int(sample_rate * n / 2), 2))
            pt = churn_point(n, c, rounds, n_per, sample_rate, chunk, q)
            points.append(pt)
            print(f"# clients={n} churn={c} static={pt['sec_round_static']:.3f}s "
                  f"churn={pt['sec_round_churn']:.3f}s "
                  f"ratio={pt['churn_over_static']}")
    rec = (recovery_experiment(24, 0.25, pre_rounds=6, post_rounds=6,
                               n_per=n_per)
           if smoke else
           recovery_experiment(400, 0.2, pre_rounds=20, post_rounds=15,
                               n_per=64))
    print(f"# recovery: final_gap={rec['final_gap']} "
          f"within_2pts={rec['recovered_within_2pts']}")
    return points, rec


def summarize(points, rec) -> dict:
    out = {}
    for p in points:
        out[f"ratio_{p['clients']}_c{p['churn']}"] = p["churn_over_static"]
    out["max_churn_over_static"] = max(p["churn_over_static"] for p in points)
    out["recovery_final_gap"] = rec["final_gap"]
    out["recovered_within_2pts"] = rec["recovered_within_2pts"]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep (40 clients, few rounds)")
    ap.add_argument("--rounds", type=int, default=30,
                    help="churn rounds per sweep point")
    ap.add_argument("--n-per", type=int, default=32)
    ap.add_argument("--sample-rate", type=float, default=0.1)
    ap.add_argument("--chunk", type=int, default=64,
                    help="cohort_chunk (bounds memory AND, with --quantum, "
                         "the compiled-shape set)")
    ap.add_argument("--quantum", type=int, default=32,
                    help="cohort_quantum under churn (see repro.sim.simulate)")
    ap.add_argument("--out", default="BENCH_churn.json")
    args = ap.parse_args()

    t0 = time.time()
    points, rec = run(smoke=args.smoke, rounds=args.rounds, n_per=args.n_per,
                      sample_rate=args.sample_rate, chunk=args.chunk,
                      quantum=args.quantum)
    doc = {
        "bench": "churn_sweep",
        "task": TASK.name,
        "n_per": args.n_per,
        "sample_rate": args.sample_rate,
        "chunk": args.chunk,
        "quantum": args.quantum,
        "backend": jax.default_backend(),
        "host": platform.machine(),
        "smoke": args.smoke,
        "wall_s": round(time.time() - t0, 1),
        "points": points,
        "recovery": rec,
        "summary": summarize(points, rec),
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(json.dumps(doc["summary"], indent=1))
    print(f"# wrote {args.out} ({len(points)} points) in {doc['wall_s']}s")


if __name__ == "__main__":
    main()
