"""Round-loop bench: one fused ``lax.scan`` vs the eager per-round loop.
Writes ``BENCH_rounds.json``.

Sweeps clients ∈ {40, 400, 4000} of the StoCFL round (the paper's
synthetic MLP task, device arena + device partition + device sampling in
BOTH modes — the operands are identical, so the ratio isolates exactly
what ``engine.run_rounds`` removes: the per-round host dispatch,
trace-cache lookup and numpy cohort draw):

  eager   rounds × ``engine.run_round`` (device rng backend), timed per
          round after warm-up — the pre-scan steady state.
  scan    ``engine.run_rounds(state, R)`` — the whole span is one XLA
          program. The first call compiles; the compiled program is
          cached on the engine context (keyed by carry/operand shapes),
          so the steady-state number is a SECOND call through the same
          cache, and ``first_compile_s`` is reported separately (the
          honest one-time cost of fusing R rounds).

Both modes run the same key chain, so they execute the same cohorts on
the same data — the parity battery (tests/test_round_scan.py) asserts
the trajectories are bitwise equal; this bench only asks which one is
faster.

Besides the timing sweep, ``--compile-sets`` measures the OTHER cost
the fused scan is designed to bound: the number of distinct XLA
programs compiled per strategy across a population-churn timeline
(cold start, then repeated join → train → leave → train cycles),
counted with ``repro.analysis.sanitize.compile_budget``.  The pow2
shape quantization (cohort pool / sizes / arena row map / Ditto
personal carry) pins the warm-cycle count to 0 for every strategy
except stocfl's host bank rebuild (data-dependent merge shapes — see
docs/ANALYSIS.md); the regression battery in
``tests/test_compile_budget.py`` gates exactly these numbers.

  PYTHONPATH=src python -m benchmarks.round_scan              # full sweep
  PYTHONPATH=src python -m benchmarks.round_scan --smoke      # CI-sized
  PYTHONPATH=src python -m benchmarks.round_scan --compile-sets
                         # churn compile-count sweep only; merges the
                         # ``compile_sets`` section into an existing out file
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.data import rotated
from repro.models import simple

TASK = simple.SYNTH_MLP
LOSS = lambda p, b: simple.loss_fn(p, b, TASK)


def _federation(n_clients: int, n_per: int, seed: int = 0):
    clients, _, _ = rotated(n_clusters=4, n_clients=n_clients, n_per=n_per,
                            seed=seed)
    return [jax.tree.map(jnp.asarray, c) for c in clients]


def _cfg(sample_rate: float, chunk: int) -> engine.EngineConfig:
    return engine.EngineConfig(
        tau=0.5, lam=0.05, lr=0.1, local_steps=1, sample_rate=sample_rate,
        seed=0, project_dim=1024, cohort_chunk=chunk,
        cluster_backend="device", rng_backend="device")


def _init(clients, cfg):
    return engine.init("stocfl", LOSS, simple.init(jax.random.PRNGKey(0), TASK),
                       clients, cfg, arena=True)


def _onboard(state, n_clients: int):
    """One full-participation round (observe every client, settle the
    partition) + a few sampled rounds so both modes start from the same
    settled federation."""
    state, _ = engine.run_round(state, np.arange(n_clients))
    for _ in range(3):
        state, _ = engine.run_round(state)
    return state


def run_point(n_clients: int, rounds: int, sample_rate: float,
              chunk: int, n_per: int) -> dict:
    clients = _federation(n_clients, n_per)
    cfg = _cfg(sample_rate, chunk)

    # ---- eager reference
    st = _onboard(_init(clients, cfg), n_clients)
    for _ in range(2):                       # steady-shape warm-up
        st, _ = engine.run_round(st)
    t0 = time.time()
    se = st
    for _ in range(rounds):
        se, _ = engine.run_round(se)
    jax.block_until_ready(se.omega)
    eager_s = time.time() - t0

    # ---- fused scan: first call compiles, second call is steady state
    st = _onboard(_init(clients, cfg), n_clients)
    t0 = time.time()
    s1 = engine.run_rounds(st, rounds)
    jax.block_until_ready(s1.omega)
    first_s = time.time() - t0
    t0 = time.time()
    s2 = engine.run_rounds(s1, rounds)
    jax.block_until_ready(s2.omega)
    scan_s = time.time() - t0

    return {
        "clients": n_clients, "rounds": rounds, "sample_rate": sample_rate,
        "cohort": int(np.ceil(sample_rate * n_clients)),
        "cohort_chunk": chunk, "n_per": n_per,
        "eager_s": round(eager_s, 4),
        "eager_rounds_per_s": round(rounds / eager_s, 2),
        "scan_s": round(scan_s, 4),
        "scan_rounds_per_s": round(rounds / scan_s, 2),
        "first_compile_s": round(first_s - scan_s, 4),
        "speedup": round(eager_s / scan_s, 2),
    }


def compile_sets(n_clients: int = 12, cycles: int = 3) -> dict:
    """Distinct-XLA-program counts per strategy over a churn timeline:
    ``cold`` is the full first-contact compile (init + first scanned
    span), ``cycle_i`` the programs added by the i-th join → train →
    leave → train cycle. Shape quantization makes the warm cycles 0
    for every strategy except stocfl's host bank rebuild."""
    from repro.analysis import sanitize
    from repro.models import simple as _simple

    eval_fn = jax.jit(lambda p, b: _simple.accuracy(p, b, TASK))
    extra = _federation(4, 32, seed=11)
    out = {}
    for name in ("stocfl", "fedavg", "fedprox", "ditto", "ifca", "cfl"):
        kw = dict(tau=0.5, lam=0.05, lr=0.1, local_steps=2, sample_rate=0.5,
                  seed=0, rng_backend="device")
        if name == "stocfl":
            kw["cluster_backend"] = "device"
        if name == "cfl":
            kw.update(sample_rate=1.0, eps_rel=0.9, eps2=1e-4)
        cfg = engine.EngineConfig(**kw)
        clients = _federation(n_clients, 32)
        counts = {}
        with sanitize.compile_budget() as log:
            st = engine.init(name, LOSS,
                             _simple.init(jax.random.PRNGKey(0), TASK),
                             clients, cfg, eval_fn=eval_fn, arena=True)
            st = engine.run_rounds(st, 2)
        counts["cold"] = log.count
        for i in range(cycles):
            with sanitize.compile_budget() as log:
                st, cid = engine.join(st, extra[i])
                st = engine.run_rounds(st, 2)
                st = engine.leave(st, cid)
                st = engine.run_rounds(st, 2)
            counts[f"cycle_{i + 1}"] = log.count
        out[name] = counts
        print(json.dumps({name: counts}))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep (small populations, few rounds)")
    ap.add_argument("--out", default="BENCH_rounds.json")
    ap.add_argument("--rounds", type=int, default=0,
                    help="rounds per timed span (0 = per-size default)")
    ap.add_argument("--compile-sets", action="store_true",
                    help="measure per-strategy compile counts under churn "
                         "and merge them into --out (skips the timing sweep)")
    args = ap.parse_args()

    if args.compile_sets:
        try:
            with open(args.out) as f:
                doc = json.load(f)
        except FileNotFoundError:
            doc = {"bench": "round_scan"}
        doc["compile_sets"] = {
            "task": "distinct XLA programs per strategy: cold start, then "
                    "join/train/leave/train churn cycles (12 clients, "
                    "2-round spans; counted by analysis.sanitize."
                    "compile_budget). Strategies run in-order in ONE "
                    "process, so programs shared across strategies (local "
                    "SGD, eval) are attributed to the first one measured "
                    "(stocfl); warm-cycle counts are the regression-gated "
                    "signal (tests/test_compile_budget.py)",
            "results": compile_sets()}
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {args.out}")
        return

    if args.smoke:
        points = [(24, 10, 0.5, 0, 16), (48, 10, 0.25, 0, 16)]
    else:
        points = [(40, 20, 0.25, 0, 64),
                  (400, 20, 0.1, 0, 64),
                  (4000, 10, 0.05, 64, 32)]
    results = []
    for n, rounds, rate, chunk, n_per in points:
        rounds = args.rounds or rounds
        r = run_point(n, rounds, rate, chunk, n_per)
        print(json.dumps(r))
        results.append(r)

    doc = {"bench": "round_scan",
           "task": "stocfl round loop, scan (run_rounds) vs eager "
                   "(run_round), device arena+partition+rng in both",
           "platform": {"machine": platform.machine(),
                        "python": platform.python_version(),
                        "jax": jax.__version__,
                        "backend": jax.default_backend()},
           "results": results}
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
