"""Round-loop bench: one fused ``lax.scan`` vs the eager per-round loop.
Writes ``BENCH_rounds.json``.

Sweeps clients ∈ {40, 400, 4000} of the StoCFL round (the paper's
synthetic MLP task, device arena + device partition + device sampling in
BOTH modes — the operands are identical, so the ratio isolates exactly
what ``engine.run_rounds`` removes: the per-round host dispatch,
trace-cache lookup and numpy cohort draw):

  eager   rounds × ``engine.run_round`` (device rng backend), timed per
          round after warm-up — the pre-scan steady state.
  scan    ``engine.run_rounds(state, R)`` — the whole span is one XLA
          program. The first call compiles; the compiled program is
          cached on the engine context (keyed by carry/operand shapes),
          so the steady-state number is a SECOND call through the same
          cache, and ``first_compile_s`` is reported separately (the
          honest one-time cost of fusing R rounds).

Both modes run the same key chain, so they execute the same cohorts on
the same data — the parity battery (tests/test_round_scan.py) asserts
the trajectories are bitwise equal; this bench only asks which one is
faster.

  PYTHONPATH=src python -m benchmarks.round_scan              # full sweep
  PYTHONPATH=src python -m benchmarks.round_scan --smoke      # CI-sized
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.data import rotated
from repro.models import simple

TASK = simple.SYNTH_MLP
LOSS = lambda p, b: simple.loss_fn(p, b, TASK)


def _federation(n_clients: int, n_per: int, seed: int = 0):
    clients, _, _ = rotated(n_clusters=4, n_clients=n_clients, n_per=n_per,
                            seed=seed)
    return [jax.tree.map(jnp.asarray, c) for c in clients]


def _cfg(sample_rate: float, chunk: int) -> engine.EngineConfig:
    return engine.EngineConfig(
        tau=0.5, lam=0.05, lr=0.1, local_steps=1, sample_rate=sample_rate,
        seed=0, project_dim=1024, cohort_chunk=chunk,
        cluster_backend="device", rng_backend="device")


def _init(clients, cfg):
    return engine.init("stocfl", LOSS, simple.init(jax.random.PRNGKey(0), TASK),
                       clients, cfg, arena=True)


def _onboard(state, n_clients: int):
    """One full-participation round (observe every client, settle the
    partition) + a few sampled rounds so both modes start from the same
    settled federation."""
    state, _ = engine.run_round(state, np.arange(n_clients))
    for _ in range(3):
        state, _ = engine.run_round(state)
    return state


def run_point(n_clients: int, rounds: int, sample_rate: float,
              chunk: int, n_per: int) -> dict:
    clients = _federation(n_clients, n_per)
    cfg = _cfg(sample_rate, chunk)

    # ---- eager reference
    st = _onboard(_init(clients, cfg), n_clients)
    for _ in range(2):                       # steady-shape warm-up
        st, _ = engine.run_round(st)
    t0 = time.time()
    se = st
    for _ in range(rounds):
        se, _ = engine.run_round(se)
    jax.block_until_ready(se.omega)
    eager_s = time.time() - t0

    # ---- fused scan: first call compiles, second call is steady state
    st = _onboard(_init(clients, cfg), n_clients)
    t0 = time.time()
    s1 = engine.run_rounds(st, rounds)
    jax.block_until_ready(s1.omega)
    first_s = time.time() - t0
    t0 = time.time()
    s2 = engine.run_rounds(s1, rounds)
    jax.block_until_ready(s2.omega)
    scan_s = time.time() - t0

    return {
        "clients": n_clients, "rounds": rounds, "sample_rate": sample_rate,
        "cohort": int(np.ceil(sample_rate * n_clients)),
        "cohort_chunk": chunk, "n_per": n_per,
        "eager_s": round(eager_s, 4),
        "eager_rounds_per_s": round(rounds / eager_s, 2),
        "scan_s": round(scan_s, 4),
        "scan_rounds_per_s": round(rounds / scan_s, 2),
        "first_compile_s": round(first_s - scan_s, 4),
        "speedup": round(eager_s / scan_s, 2),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep (small populations, few rounds)")
    ap.add_argument("--out", default="BENCH_rounds.json")
    ap.add_argument("--rounds", type=int, default=0,
                    help="rounds per timed span (0 = per-size default)")
    args = ap.parse_args()

    if args.smoke:
        points = [(24, 10, 0.5, 0, 16), (48, 10, 0.25, 0, 16)]
    else:
        points = [(40, 20, 0.25, 0, 64),
                  (400, 20, 0.1, 0, 64),
                  (4000, 10, 0.05, 64, 32)]
    results = []
    for n, rounds, rate, chunk, n_per in points:
        rounds = args.rounds or rounds
        r = run_point(n, rounds, rate, chunk, n_per)
        print(json.dumps(r))
        results.append(r)

    doc = {"bench": "round_scan",
           "task": "stocfl round loop, scan (run_rounds) vs eager "
                   "(run_round), device arena+partition+rng in both",
           "platform": {"machine": platform.machine(),
                        "python": platform.python_version(),
                        "jax": jax.__version__,
                        "backend": jax.default_backend()},
           "results": results}
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
